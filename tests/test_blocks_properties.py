"""Property tests for model building blocks: rotary embedding isometry and
relative-position property, norm invariants, GQA head-grouping equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # degrade to the deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro import configs
from repro.models import blocks

jax.config.update("jax_platform_name", "cpu")
RNG = np.random.default_rng(3)


class TestRope:
    @given(st.integers(1, 3), st.integers(1, 16), st.integers(1, 4))
    @settings(max_examples=20, deadline=None)
    def test_isometry(self, b, l, h):
        """Rotation preserves per-head norms."""
        dh = 32
        x = jnp.asarray(RNG.normal(size=(b, l, h, dh)).astype(np.float32))
        pos = jnp.broadcast_to(jnp.arange(l)[None], (b, l))
        y = blocks.rope(x, pos, theta=1e4)
        np.testing.assert_allclose(
            np.asarray(jnp.linalg.norm(y, axis=-1)),
            np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-5)

    def test_relative_position_property(self):
        """<rope(q,i), rope(k,j)> depends only on i - j."""
        dh = 64
        q = jnp.asarray(RNG.normal(size=(1, 1, 1, dh)).astype(np.float32))
        k = jnp.asarray(RNG.normal(size=(1, 1, 1, dh)).astype(np.float32))

        def dot_at(i, j):
            qi = blocks.rope(q, jnp.full((1, 1), i, jnp.int32), 1e4)
            kj = blocks.rope(k, jnp.full((1, 1), j, jnp.int32), 1e4)
            return float(jnp.sum(qi * kj))

        assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-3
        assert abs(dot_at(0, 0) - dot_at(100, 100)) < 1e-3

    def test_position_zero_identity(self):
        x = jnp.asarray(RNG.normal(size=(1, 1, 2, 16)).astype(np.float32))
        y = blocks.rope(x, jnp.zeros((1, 1), jnp.int32), 1e4)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


class TestNorms:
    def _cfg(self, norm):
        return dataclasses.replace(
            configs.smoke_variant(configs.get_config("olmo-1b")),
            norm=norm)

    @pytest.mark.parametrize("norm", ["rmsnorm", "ln", "ln_nonparam"])
    def test_scale_invariance_direction(self, norm):
        """Norm output is invariant to positive input scaling (ln subtracts
        mean first; rms after scaling is proportional)."""
        cfg = self._cfg(norm)
        p = jax.tree.map(lambda q: q.value, blocks.norm_init(cfg),
                         is_leaf=lambda q: hasattr(q, "axes"))
        x = jnp.asarray(RNG.normal(size=(2, 3, cfg.d_model)).astype(
            np.float32))
        y1 = blocks.apply_norm(cfg, p, x)
        y2 = blocks.apply_norm(cfg, p, x * 7.5)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-5)

    def test_ln_zero_mean_unit_var(self):
        cfg = self._cfg("ln_nonparam")
        x = jnp.asarray(RNG.normal(size=(4, 8, cfg.d_model)).astype(
            np.float32)) * 3 + 2
        y = blocks.apply_norm(cfg, {}, x)
        np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0,
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(jnp.var(y, -1)), 1,
                                   atol=1e-3)

    def test_group_norm_groups_independent(self):
        x = jnp.asarray(RNG.normal(size=(2, 4, 32)).astype(np.float32))
        scale = jnp.ones((32,))
        y1 = blocks.group_norm(x, scale, n_groups=4)
        # perturbing group 0 must not change groups 1..3
        x2 = x.at[..., :8].mul(5.0)
        y2 = blocks.group_norm(x2, scale, n_groups=4)
        np.testing.assert_allclose(np.asarray(y1[..., 8:]),
                                   np.asarray(y2[..., 8:]), atol=1e-5)


class TestGQA:
    def test_grouped_equals_repeated(self):
        """Grouped-head chunked attention == reference with kv repetition."""
        from repro.kernels import ref
        b, l, hq, hkv, dh = 2, 24, 8, 2, 16
        q = jnp.asarray(RNG.normal(size=(b, l, hq, dh)).astype(np.float32))
        k = jnp.asarray(RNG.normal(size=(b, l, hkv, dh)).astype(np.float32))
        v = jnp.asarray(RNG.normal(size=(b, l, hkv, dh)).astype(np.float32))
        o1 = blocks.chunked_causal_attention(q, k, v, chunk=8)
        o2 = ref.attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=2e-4, atol=2e-4)

    @given(st.integers(4, 40), st.integers(1, 3))
    @settings(max_examples=10, deadline=None)
    def test_chunk_size_irrelevant(self, l, c_pow):
        b, h, dh = 1, 2, 16
        q = jnp.asarray(RNG.normal(size=(b, l, h, dh)).astype(np.float32))
        k = jnp.asarray(RNG.normal(size=(b, l, h, dh)).astype(np.float32))
        v = jnp.asarray(RNG.normal(size=(b, l, h, dh)).astype(np.float32))
        o1 = blocks.chunked_causal_attention(q, k, v, chunk=2 ** c_pow)
        o2 = blocks.chunked_causal_attention(q, k, v, chunk=l)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=2e-4, atol=2e-4)
