"""HLO text analysis unit tests: collective parsing, op census, roofline
terms arithmetic (hlo_analysis) — complements test_hlo_cost.py."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis

jax.config.update("jax_platform_name", "cpu")

SAMPLE = """
HloModule test

%add_comp (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (p0: bf16[8,128]) -> bf16[8,128] {
  %p0 = bf16[8,128]{1,0} parameter(0)
  %ag = bf16[16,128]{1,0} all-gather(%p0), dimensions={0}
  %ar = bf16[8,128]{1,0} all-reduce(%p0), to_apply=%add_comp
  %cp = bf16[8,128]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  ROOT %out = bf16[8,128]{1,0} add(%ar, %cp)
}
"""


class TestCollectiveStats:
    def test_counts_and_bytes(self):
        st = hlo_analysis.collective_stats(SAMPLE)
        assert st.count_by_kind == {"all-gather": 1, "all-reduce": 1,
                                    "collective-permute": 1}
        per_op = 8 * 128 * 2                      # bf16 operand
        assert st.bytes_by_kind["all-reduce"] == per_op
        assert st.bytes_by_kind["all-gather"] == per_op
        assert st.total_bytes == 3 * per_op

    def test_census(self):
        c = hlo_analysis.op_census(SAMPLE)
        assert c["add"] >= 2 and c["parameter"] >= 1

    def test_real_compiled_program(self):
        txt = jax.jit(lambda x: x @ x).lower(
            jnp.zeros((64, 64))).compile().as_text()
        st = hlo_analysis.collective_stats(txt)
        assert st.total_bytes == 0                # single device: none


class TestRoofline:
    def test_terms_arithmetic(self):
        r = hlo_analysis.roofline_terms(
            hlo_flops=197e12 * 256, hlo_bytes=819e9 * 256,
            collective_bytes=50e9 * 256, chips=256,
            model_flops=197e12 * 256 / 2)
        assert abs(r.compute_s - 1.0) < 1e-9
        assert abs(r.memory_s - 1.0) < 1e-9
        assert abs(r.collective_s - 1.0) < 1e-9
        assert r.useful_flops_ratio == pytest.approx(0.5)
        assert r.dominant in ("compute", "memory", "collective")
        assert r.roofline_fraction == pytest.approx(0.5)

    def test_dominant_selection(self):
        r = hlo_analysis.roofline_terms(1e12, 900e12, 1e9, 256, 1e12)
        assert r.dominant == "memory"

    def test_zero_safe(self):
        r = hlo_analysis.roofline_terms(0, 0, 0, 256, 0)
        assert r.roofline_fraction == 0.0
        assert r.useful_flops_ratio == 0.0
