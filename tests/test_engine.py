"""Continuous-batching engine tests: slot pool state hygiene, decode
parity over many steps (the invariant slot admission relies on), scan
resumability across chunk boundaries, and engine-vs-sequential
equivalence under slot churn."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels import ops
from repro.models import registry
from repro.parallel import sharding
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.state_pool import SlotStatePool

jax.config.update("jax_platform_name", "cpu")


def _setup(name):
    cfg = configs.smoke_variant(configs.get_config(name))
    cfg = dataclasses.replace(cfg, vocab=64, dtype="float32",
                              capacity_factor=float(max(cfg.n_experts, 1)))
    params = sharding.tree_values(
        registry.init_params(cfg, jax.random.key(0)))
    return cfg, params


def _tree_equal(a, b):
    flat_a, flat_b = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(flat_a) == len(flat_b)
    return all(bool(jnp.array_equal(x, y.astype(x.dtype)))
               for x, y in zip(flat_a, flat_b))


DECODE_ARCHS = ["mamba-130m", "granite-20b", "qwen2-7b", "jamba-v0.1-52b",
                "xlstm-350m", "qwen2-moe-a2.7b"]
POOL_ARCHS = ["mamba-130m", "granite-20b", "jamba-v0.1-52b", "xlstm-350m"]


# ---------------------------------------------------------------------------
# Slot state pool
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", POOL_ARCHS)
def test_pool_admit_read_roundtrip_bitexact(name):
    """Scatter of prefilled state into a slot, then gather, is the
    identity — per-slot state survives pooling bit-exactly."""
    cfg, params = _setup(name)
    pool = SlotStatePool(cfg, n_slots=3, max_seq=32)
    fresh = sharding.tree_values(registry.init_cache(cfg, 1, 32))
    toks = jax.random.randint(jax.random.key(1), (1, 7), 0, cfg.vocab,
                              dtype=jnp.int32)
    _, sub = registry.prefill(cfg, params, fresh, {"tokens": toks})
    slot = pool.alloc()
    pool.admit(slot, sub)
    assert _tree_equal(sub, pool.read([slot]))


def test_pool_alloc_evict_accounting():
    cfg, _ = _setup("mamba-130m")
    pool = SlotStatePool(cfg, n_slots=2, max_seq=16)
    a, b = pool.alloc(), pool.alloc()
    assert {a, b} == {0, 1} and pool.alloc() is None
    assert pool.n_active == 2 and pool.n_free == 0
    pool.evict(a)
    assert pool.n_free == 1 and pool.active_slots() == [b]
    assert pool.alloc() == a           # lowest-first reuse


@pytest.mark.parametrize("name", ["mamba-130m", "granite-20b"])
def test_evicted_slot_never_leaks_into_new_request(name):
    """Admit A, decode it a few steps, evict, admit B into the same slot:
    the slot's state must equal a fresh prefill of B bit-exactly, and the
    other slot must be untouched throughout."""
    cfg, params = _setup(name)
    pool = SlotStatePool(cfg, n_slots=2, max_seq=32)
    fresh = lambda: sharding.tree_values(registry.init_cache(cfg, 1, 32))
    key = jax.random.key(2)
    pa, pb, pc = (jax.random.randint(jax.random.fold_in(key, i), (1, 5 + i),
                                     0, cfg.vocab, dtype=jnp.int32)
                  for i in range(3))
    # bystander request C in slot 1
    sc_slot = 1
    _, sub_c = registry.prefill(cfg, params, fresh(), {"tokens": pc})
    s0 = pool.alloc()
    s1 = pool.alloc()
    assert (s0, s1) == (0, 1)
    pool.admit(sc_slot, sub_c)
    # A lives in slot 0, decodes 3 steps (slot 1 masked/frozen)
    _, sub_a = registry.prefill(cfg, params, fresh(), {"tokens": pa})
    pool.admit(0, sub_a)
    tok = jnp.zeros((2, 1), jnp.int32)
    for _ in range(3):
        _, new_cache = registry.decode_step(cfg, params, pool.cache,
                                            {"tokens": tok})
        pool.commit(new_cache, active=np.array([True, False]))
    pool.evict(0)
    # slot 0 is back to the init state — nothing of A remains
    assert _tree_equal(pool.read([0]), fresh())
    # B admitted into the recycled slot equals a standalone prefill of B
    _, sub_b = registry.prefill(cfg, params, fresh(), {"tokens": pb})
    slot = pool.alloc()
    assert slot == 0
    pool.admit(slot, sub_b)
    assert _tree_equal(pool.read([0]), sub_b)
    # bystander C was frozen through all of it
    assert _tree_equal(pool.read([sc_slot]), sub_c)


# ---------------------------------------------------------------------------
# Decode parity: prefill + N decode steps == full forward (the invariant
# slot admission relies on)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", DECODE_ARCHS)
def test_prefill_plus_n_decode_steps_matches_forward(name):
    cfg, params = _setup(name)
    b, lp, n_steps = 2, 4, 6
    L = lp + n_steps
    toks = jax.random.randint(jax.random.key(3), (b, L), 0, cfg.vocab,
                              dtype=jnp.int32)
    full, _ = registry.forward(cfg, params, {"tokens": toks})
    cache = sharding.tree_values(registry.init_cache(cfg, b, max_seq=16))
    logits, cache = registry.prefill(cfg, params, cache,
                                     {"tokens": toks[:, :lp]})
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full[:, :lp]),
                               rtol=2e-2, atol=2e-2)
    for t in range(n_steps):
        logits, cache = registry.decode_step(
            cfg, params, cache, {"tokens": toks[:, lp + t:lp + t + 1]})
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full[:, lp + t]),
            rtol=2e-2, atol=2e-2,
            err_msg=f"decode step {t} diverged from forward")


# ---------------------------------------------------------------------------
# Scan resumability: split + carry h equals one-shot, across chunk
# padding boundaries
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["seq", "assoc", "chunked", "chunked_seq"])
@pytest.mark.parametrize("L1", [1, 7, 16, 17, 31, 39])
def test_selective_scan_resumes_across_split(impl, L1):
    """scan([0:L1]) carrying h into scan([L1:L]) == scan([0:L]) even when
    L1 straddles the chunk (block_l) padding boundary (chunk=16)."""
    rng = np.random.default_rng(11)
    b, L, d, n = 2, 40, 8, 4
    x = jnp.asarray(rng.normal(size=(b, L, d)).astype(np.float32))
    dt = jax.nn.softplus(jnp.asarray(
        rng.normal(size=(b, L, d)).astype(np.float32)))
    A = -jnp.exp(jnp.asarray(
        rng.normal(size=(d, n)).astype(np.float32)) * 0.5)
    B = jnp.asarray(rng.normal(size=(b, L, n)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(b, L, n)).astype(np.float32))
    D = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    z = jnp.asarray(rng.normal(size=(b, L, d)).astype(np.float32))

    kw = dict(D=D, z=z, impl=impl, chunk=16)
    y_full, h_full = ops.selective_scan(x, dt, A, B, C, **kw)
    y1, h1 = ops.selective_scan(x[:, :L1], dt[:, :L1], A, B[:, :L1],
                                C[:, :L1], D=D, z=z[:, :L1],
                                impl=impl, chunk=16)
    y2, h2 = ops.selective_scan(x[:, L1:], dt[:, L1:], A, B[:, L1:],
                                C[:, L1:], D=D, z=z[:, L1:], h0=h1,
                                impl=impl, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Engine end-to-end: continuous batching must equal per-request greedy
# decode under admission/eviction churn
# ---------------------------------------------------------------------------

def _reference_greedy(cfg, params, prompt, max_new, eos_id=None):
    """Single-request greedy generation straight off registry functions."""
    cache = sharding.tree_values(registry.init_cache(cfg, 1, max_seq=64))
    logits, cache = registry.prefill(cfg, params, cache,
                                     {"tokens": jnp.asarray(prompt[None])})
    tok = int(jnp.argmax(logits[0, -1].astype(jnp.float32)))
    out = [tok]
    while len(out) < max_new and (eos_id is None or tok != eos_id):
        logits, cache = registry.decode_step(
            cfg, params, cache, {"tokens": jnp.asarray([[tok]], jnp.int32)})
        tok = int(jnp.argmax(logits[0, -1].astype(jnp.float32)))
        out.append(tok)
    return out


@pytest.mark.parametrize("name", ["mamba-130m", "granite-20b"])
def test_engine_matches_sequential_reference(name):
    """5 variable-length requests through 2 slots (forcing queueing,
    eviction, and slot reuse) produce exactly the tokens each request
    would get decoded alone."""
    cfg, params = _setup(name)
    rng = np.random.default_rng(5)
    lens = [3, 5, 9, 4, 7]
    max_news = [6, 3, 8, 5, 4]
    prompts = [rng.integers(0, cfg.vocab, size=(l,)).astype(np.int32)
               for l in lens]
    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64))
    reqs = [eng.submit(p, max_new=m) for p, m in zip(prompts, max_news)]
    done = eng.run()
    assert len(done) == len(reqs)
    for p, m, r in zip(prompts, max_news, reqs):
        assert r.finished and len(r.tokens) == m
        assert r.tokens == _reference_greedy(cfg, params, p, m), \
            f"req {r.req_id} diverged under continuous batching"


def test_engine_eos_evicts_and_backfills():
    """A request whose EOS fires early frees its slot; the queued request
    is admitted and still decodes exactly."""
    cfg, params = _setup("mamba-130m")
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, size=(l,)).astype(np.int32)
               for l in (4, 6, 5)]
    # learn req0's natural 3rd token, then make it the EOS
    ref0 = _reference_greedy(cfg, params, prompts[0], 10)
    eos = ref0[2]
    eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    r0 = eng.submit(prompts[0], max_new=10, eos_id=eos)
    r1 = eng.submit(prompts[1], max_new=4)
    r2 = eng.submit(prompts[2], max_new=3)
    eng.run()
    assert r0.tokens == ref0[:3] and r0.tokens[-1] == eos
    assert r1.tokens == _reference_greedy(cfg, params, prompts[1], 4)
    assert r2.tokens == _reference_greedy(cfg, params, prompts[2], 3)
    assert eng.stats.n_requests == 3


def test_engine_stats_counters():
    cfg, params = _setup("mamba-130m")
    rng = np.random.default_rng(13)
    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64))
    reqs = [eng.submit(rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
                       max_new=m) for m in (5, 3, 4)]
    eng.run()
    s = eng.stats
    assert s.n_requests == 3
    assert s.prefill_calls == 3 and s.prefill_tokens == 12
    assert s.useful_tokens == sum(len(r.tokens) for r in reqs) == 12
    smry = s.summary()
    assert smry["tokens_per_s"] > 0
    assert 0 < smry["occupancy"] <= 1
    assert all(t >= 0 for t in (smry["ttft_mean_s"], smry["latency_p95_s"]))


def test_engine_rejects_oversized_request():
    cfg, params = _setup("mamba-130m")
    eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=16))
    with pytest.raises(ValueError):
        eng.submit(np.arange(10, dtype=np.int32), max_new=10)


# ---------------------------------------------------------------------------
# Scratch slots (speculative-decode forks): leases never collide with
# live slots, and no lease survives a burst — even an aborted one
# ---------------------------------------------------------------------------

def test_scratch_lease_never_collides_with_live_slots():
    """Interleave admission/eviction with lease/release arbitrarily:
    live ids and leased ids must stay disjoint (the id ranges are
    disjoint by construction — this pins that invariant), and both
    accountings must stay exact."""
    cfg, _ = _setup("mamba-130m")
    pool = SlotStatePool(cfg, n_slots=3, max_seq=16, n_scratch=3)
    rng = np.random.default_rng(21)
    live, leased = [], []
    for _ in range(200):
        op = rng.integers(0, 4)
        if op == 0:
            slot = pool.alloc()
            if slot is not None:
                live.append(slot)
        elif op == 1 and live:
            pool.evict(live.pop(rng.integers(len(live))))
        elif op == 2:
            sc = pool.lease_scratch()
            if sc is not None:
                leased.append(sc)
        elif op == 3 and leased:
            pool.release_scratch(leased.pop(rng.integers(len(leased))))
        assert not (set(live) & set(leased))
        assert all(s < pool.n_slots for s in live)
        assert all(pool.n_slots <= s < pool.n_total for s in leased)
        assert pool.n_active == len(live)
        assert pool.n_scratch_free == pool.n_scratch - len(leased)
    # scratch ids never appear in the live active mask
    mask = pool.active_mask()
    assert not mask[pool.n_slots:].any()


def test_scratch_release_rejects_bad_ids():
    cfg, _ = _setup("mamba-130m")
    pool = SlotStatePool(cfg, n_slots=2, max_seq=16, n_scratch=1)
    with pytest.raises(ValueError):
        pool.release_scratch(0)            # live id, not scratch
    with pytest.raises(ValueError):
        pool.release_scratch(2)            # scratch id, but not leased


def test_no_scratch_lease_leaks_after_spec_run():
    """Every speculative pass leases scratch slots; after run() returns
    the pool must be fully drained: all live slots free, all scratch
    leases returned."""
    from repro.runtime.spec_decode import DraftConfig
    cfg, params = _setup("mamba-130m")
    rng = np.random.default_rng(23)
    eng = Engine(cfg, params,
                 EngineConfig(n_slots=2, max_seq=64,
                              draft=DraftConfig(k=2, layers=2)))
    for m in (5, 3, 4):
        eng.submit(rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
                   max_new=m)
    eng.run()
    assert eng.pool.n_active == 0 and eng.pool.n_free == eng.pool.n_slots
    assert eng.pool.n_scratch_free == eng.pool.n_scratch


# ---------------------------------------------------------------------------
# Cancellation: aborting a request mid-burst / mid-spec-pass reclaims
# its slot (and scratch leases) and leaves every survivor's stream
# bitwise unchanged — per-slot keys make sampling independent of
# co-resident evictions.
# ---------------------------------------------------------------------------

def _cancel_fixture(cfg):
    from repro.runtime.sampling import SamplingParams
    rng = np.random.default_rng(41)
    pa, pb, pc = (rng.integers(0, cfg.vocab, size=(l,)).astype(np.int32)
                  for l in (4, 6, 5))
    # the survivor is SAMPLED: bitwise survival is only guaranteed
    # because randomness is per-slot counter-based, never shared
    sp = SamplingParams(temperature=0.9, seed=7, max_new=12)
    return pa, pb, pc, sp


def test_cancel_mid_burst_reclaims_slot_and_preserves_survivors():
    cfg, params = _setup("mamba-130m")
    pa, pb, pc, sp = _cancel_fixture(cfg)
    # reference: the same trace with the victim never submitted
    ref = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64,
                                           sched_quantum=2))
    a0 = ref.submit(pa, params=sp)
    c0 = ref.submit(pc, max_new=6)
    ref.run()

    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64,
                                           sched_quantum=2))

    def cb(req, toks):
        if len(req.tokens) >= 3:
            assert eng.cancel(req.req_id)

    a = eng.submit(pa, params=sp)
    b = eng.submit(pb, max_new=12, stream_cb=cb)
    c = eng.submit(pc, max_new=6)          # backfills the freed slot
    eng.run()
    assert b.cancelled and b.finished
    assert 3 <= len(b.tokens) < 12          # stopped well short of budget
    assert a.tokens == a0.tokens, \
        "sampled survivor perturbed by a co-resident cancellation"
    assert c.tokens == c0.tokens
    # no pool leak: every slot free, params rows reset
    assert eng.pool.n_active == 0 and eng.pool.n_free == eng.pool.n_slots
    assert not eng.pool.params.temperature.any()
    assert eng.stats.n_cancelled == 1
    assert eng.stats.summary()["cancelled"] == 1
    assert eng.stats.n_requests == 2        # cancelled req not counted


def test_cancel_mid_spec_pass_reclaims_scratch_and_preserves_survivors():
    from repro.runtime.spec_decode import DraftConfig
    cfg, params = _setup("mamba-130m")
    pa, pb, pc, sp = _cancel_fixture(cfg)
    draft = DraftConfig(k=3, layers=2)
    ref = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64,
                                           draft=draft))
    a0 = ref.submit(pa, params=sp)
    c0 = ref.submit(pc, max_new=6)
    ref.run()

    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64,
                                           draft=draft))

    def cb(req, toks):
        if len(req.tokens) >= 3:
            eng.cancel(req.req_id)

    a = eng.submit(pa, params=sp)
    b = eng.submit(pb, max_new=12, stream_cb=cb)
    c = eng.submit(pc, max_new=6)
    eng.run()
    assert b.cancelled and len(b.tokens) < 12
    assert a.tokens == a0.tokens and c.tokens == c0.tokens
    assert eng.pool.n_active == 0 and eng.pool.n_free == eng.pool.n_slots
    assert eng.pool.n_scratch_free == eng.pool.n_scratch, \
        "cancellation leaked a scratch lease"
    assert eng.stats.n_cancelled == 1


def test_cancel_queued_request_never_admitted():
    cfg, params = _setup("mamba-130m")
    pa, pb, _, _ = _cancel_fixture(cfg)
    eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    r1 = eng.submit(pa, max_new=4)
    r2 = eng.submit(pb, max_new=4)
    assert eng.cancel(r2.req_id)
    assert not eng.cancel(r2.req_id)        # idempotent: already flagged
    eng.run()
    assert r2.cancelled and r2.finished and r2.tokens == []
    assert r1.tokens and not r1.cancelled
    assert eng.stats.n_cancelled == 1 and eng.stats.n_requests == 1
    assert not eng.cancel(12345)            # unknown id


def test_cancel_sweep_preserves_fifo_order_of_survivors():
    """The cancel sweep rebuilds the ready heap from the ORIGINAL
    (priority, seq) tuples: queued survivors keep their FIFO order
    even though raw heap-array order is scrambled after a pop."""
    cfg, params = _setup("mamba-130m")
    rng = np.random.default_rng(43)
    prompts = [rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32)
               for _ in range(4)]
    eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))

    def cb(req, toks):
        if len(req.tokens) >= 2:
            eng.cancel(rb.req_id)      # cancel a QUEUED request

    ra = eng.submit(prompts[0], max_new=4, stream_cb=cb)
    rb = eng.submit(prompts[1], max_new=4)
    rc = eng.submit(prompts[2], max_new=4)
    rd = eng.submit(prompts[3], max_new=4)
    done = eng.run()
    assert rb.cancelled and rb.tokens == []
    # submission order among survivors must hold: a, then c, then d
    completed = [r.req_id for r in done if not r.cancelled]
    assert completed == [ra.req_id, rc.req_id, rd.req_id], completed


def test_adaptive_draft_warmup_zero_does_not_crash():
    """adapt_warmup=0 floors at one pass (the clamp needs a realized
    pass before it can divide by the pass count)."""
    from repro.runtime.spec_decode import DraftConfig
    cfg, params = _setup("mamba-130m")
    eng = Engine(cfg, params,
                 EngineConfig(n_slots=1, max_seq=64,
                              draft=DraftConfig(k=3, layers=2,
                                                adaptive=True,
                                                adapt_warmup=0)))
    r = eng.submit(np.arange(4, dtype=np.int32), max_new=8)
    eng.run()
    assert len(r.tokens) == 8


def test_cancel_pending_arrival_gated_request():
    cfg, params = _setup("mamba-130m")
    pa, pb, _, _ = _cancel_fixture(cfg)
    eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    r1 = eng.submit(pa, max_new=3)
    r2 = eng.submit(pb, max_new=3, arrival=0.01)
    eng.cancel(r2.req_id)
    eng.run()
    assert r2.cancelled and r2.tokens == [] and r1.finished


def test_abandoned_lease_released_when_burst_aborts(monkeypatch):
    """A speculative pass that dies mid-burst (here: the verify jit
    raises) must still return its scratch leases — an abandoned lease
    would silently halve speculation capacity forever."""
    from repro.runtime.spec_decode import DraftConfig
    cfg, params = _setup("mamba-130m")
    eng = Engine(cfg, params,
                 EngineConfig(n_slots=2, max_seq=64,
                              draft=DraftConfig(k=2, layers=2)))
    eng.submit(np.arange(4, dtype=np.int32), max_new=4)

    def boom(*a, **k):
        raise RuntimeError("verify died mid-burst")

    monkeypatch.setattr(eng._spec, "verify", boom)
    with pytest.raises(RuntimeError):
        eng.run()
    assert eng.pool.n_scratch_free == eng.pool.n_scratch, \
        "aborted burst leaked a scratch lease"


def test_raising_stream_cb_isolated_and_auto_cancelled():
    """A client callback that raises must not take down the scheduler
    loop: the error is counted, the offender's stream is auto-cancelled
    at that sync, and co-resident streams — including a SAMPLED one —
    are bitwise untouched."""
    cfg, params = _setup("mamba-130m")
    pa, pb, pc, sp = _cancel_fixture(cfg)
    # reference: the same trace with the offender never submitted
    ref = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64,
                                           sched_quantum=2))
    a0 = ref.submit(pa, params=sp)
    c0 = ref.submit(pc, max_new=6)
    ref.run()

    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64,
                                           sched_quantum=2))
    a_deliveries = []

    def good_cb(req, toks):
        a_deliveries.append(list(toks))

    def bad_cb(req, toks):
        raise RuntimeError("client connection went away")

    a = eng.submit(pa, params=sp, stream_cb=good_cb)
    b = eng.submit(pb, max_new=12, stream_cb=bad_cb)
    c = eng.submit(pc, max_new=6)          # backfills the freed slot
    eng.run()                              # must NOT raise
    assert eng.stats.n_callback_errors == 1
    assert b.stream_cb is None             # offender's cb dropped
    assert b.cancelled and b.finished
    assert len(b.tokens) < 12              # stopped short of its budget
    assert a.tokens == a0.tokens, \
        "sampled survivor perturbed by a co-resident callback failure"
    assert c.tokens == c0.tokens
    # the healthy callback saw a's complete stream, before and after
    # the offender was dropped
    assert [t for batch in a_deliveries for t in batch] == a.tokens
    assert eng.pool.n_active == 0 and eng.pool.n_free == eng.pool.n_slots
    assert eng.stats.n_cancelled == 1
