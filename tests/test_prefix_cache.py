"""Prefix-state cache + fork-served best-of-n (ISSUE 6 / PR 6).

The contract under test:

  * Cached-prefix admission is TOKEN-IDENTICAL to cold full prefill —
    across families and state_dtype {f32, int8} — because the restored
    snapshot IS the donor prefill's state at that boundary and the
    suffix runs through the same per-token decode dispatch.
  * The LRU store is bounded (entries and bytes) and churn can never
    leak a stale snapshot's payload or scales into a later admission
    (the stale-scale regression style of tests/test_state_quant.py).
  * ``fork(branch_tags=...)`` re-derives destination keys per branch
    (the fork-seed aliasing fix): sampled best-of-n branches from one
    prefix produce DISTINCT streams, while tag-less forks copy the key
    verbatim — the spec-decode draft contract — and greedy streams are
    bitwise unchanged either way.
  * Cancelling a best-of-n parent mid-flight reclaims every branch
    slot with no pool leak.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import registry
from repro.parallel import sharding
from repro.runtime import sampling
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.prefix_cache import PrefixCache, PrefixCacheConfig
from repro.runtime.sampling import SamplingParams
from repro.runtime.spec_decode import DraftConfig
from repro.runtime.state_pool import SlotStatePool

jax.config.update("jax_platform_name", "cpu")


def _setup(name="mamba-130m"):
    cfg = configs.smoke_variant(configs.get_config(name))
    cfg = dataclasses.replace(cfg, vocab=64, dtype="float32",
                              capacity_factor=float(max(cfg.n_experts, 1)))
    params = sharding.tree_values(
        registry.init_params(cfg, jax.random.key(0)))
    return cfg, params


def _shared_prefix_prompts(vocab, n=4, prefix_len=16, suffix_len=5,
                           seed=0):
    """n prompts sharing a system-prompt-style common prefix."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, vocab, size=prefix_len).astype(np.int32)
    return [np.concatenate([prefix,
                            rng.integers(1, vocab,
                                         size=suffix_len).astype(np.int32)])
            for _ in range(n)]


CACHE_ARCHS = ["mamba-130m", "jamba-v0.1-52b", "xlstm-350m"]


# ---------------------------------------------------------------------------
# PrefixCache unit behavior (no model)
# ---------------------------------------------------------------------------

def test_boundary_is_largest_block_multiple_strictly_below_length():
    pc = PrefixCache(PrefixCacheConfig(block=8))
    assert pc.boundary(5) == 0        # shorter than one block
    assert pc.boundary(8) == 0        # suffix must be non-empty
    assert pc.boundary(9) == 8
    assert pc.boundary(16) == 8
    assert pc.boundary(17) == 16
    assert pc.boundary(24) == 16


def test_lookup_walks_down_to_deepest_cached_boundary():
    pc = PrefixCache(PrefixCacheConfig(block=4, max_entries=8))
    toks = np.arange(1, 20, dtype=np.int32)
    snap4 = {"x": jnp.zeros((1, 4))}
    snap8 = {"x": jnp.ones((1, 4))}
    pc.insert(toks[:4], snap4)
    pc.insert(toks[:8], snap8)
    n, snap = pc.lookup(toks[:11])
    assert n == 8 and bool(jnp.all(snap["x"] == 1))
    # a prompt diverging after 4 tokens hits the shallower entry
    other = np.concatenate([toks[:4], toks[:4] + 30])
    n, snap = pc.lookup(np.concatenate([other, toks[:3]]))
    assert n == 4 and bool(jnp.all(snap["x"] == 0))
    assert pc.hits == 2 and pc.misses == 0


def test_lru_bounds_entries_and_bytes():
    pc = PrefixCache(PrefixCacheConfig(block=2, max_entries=3))
    for i in range(6):
        pc.insert(np.arange(i, i + 2, dtype=np.int32),
                  {"x": jnp.full((1, 2), i, jnp.float32)})
    assert len(pc) == 3 and pc.evictions == 3
    # byte bound: each entry is 8 bytes of f32 -> cap at 2 entries
    pc2 = PrefixCache(PrefixCacheConfig(block=2, max_entries=100,
                                        max_bytes=16))
    for i in range(5):
        pc2.insert(np.arange(i, i + 2, dtype=np.int32),
                   {"x": jnp.full((1, 2), i, jnp.float32)})
    assert pc2.n_bytes <= 16 and len(pc2) == 2


def test_host_store_defers_offload_until_flush():
    pc = PrefixCache(PrefixCacheConfig(block=2, store="host"))
    pc.insert(np.arange(2, dtype=np.int32), {"x": jnp.zeros((1, 2))})
    assert pc.has_pending()
    assert pc.flush_pending(limit=None) == 1
    assert not pc.has_pending()
    ent = next(iter(pc._entries.values()))
    assert ent.on_host and isinstance(jax.tree.leaves(ent.snap)[0],
                                      np.ndarray)
    # a lookup rehydrates to a device array
    _, snap = pc.lookup(np.arange(3, dtype=np.int32))
    assert isinstance(jax.tree.leaves(snap)[0], jnp.ndarray)


def test_flush_pending_dead_keys_do_not_consume_limit():
    """Regression: a queued key whose entry has since died (LRU-evicted
    between queueing and the sync) must be skipped WITHOUT charging the
    per-sync limit — previously a run of dead keys at the head of the
    queue starved the live snapshots behind them of their offload slot,
    leaving them device-resident indefinitely."""
    pc = PrefixCache(PrefixCacheConfig(block=2, store="host"))
    pc.insert(np.arange(2, dtype=np.int32), {"x": jnp.zeros((1, 2))})
    # stale keys at the head of the queue (the eviction-while-pending
    # interleaving, constructed directly)
    pc._pending.appendleft(b"dead-1")
    pc._pending.appendleft(b"dead-0")
    assert pc.flush_pending(limit=1) == 1   # live snapshot offloaded
    ent = next(iter(pc._entries.values()))
    assert ent.on_host
    assert not pc.has_pending()
    # the drain structure is a deque: popleft is O(1) per sync, where
    # the old list.pop(0) walked the whole queue
    import collections
    assert isinstance(pc._pending, collections.deque)


def test_oversized_insert_refused_without_thrashing_cache():
    """Regression: a snapshot larger than max_bytes can never be
    retained — inserting it used to evict EVERY resident entry and then
    evict itself (full-cache thrash, zero value).  It must be refused
    up front, counted, and leave the cache untouched."""
    pc = PrefixCache(PrefixCacheConfig(block=2, max_entries=100,
                                       max_bytes=16))
    for i in range(2):
        pc.insert(np.arange(i, i + 2, dtype=np.int32),
                  {"x": jnp.full((1, 2), i, jnp.float32)})   # 8 B each
    assert len(pc) == 2 and pc.n_bytes == 16
    pc.insert(np.arange(8, 10, dtype=np.int32),
              {"x": jnp.zeros((1, 6), jnp.float32)})         # 24 B > cap
    assert pc.rejects == 1 and pc.counters()["rejects"] == 1
    assert len(pc) == 2 and pc.n_bytes == 16
    assert pc.evictions == 0, "oversized insert must not thrash"
    assert pc.lookup(np.arange(0, 3, dtype=np.int32)) is not None


def test_serve_stats_surface_prefix_rejects():
    """ServeStats.sync_prefix adopts the cache's reject counter and the
    summary exposes it (the ops signal that max_bytes is mis-sized for
    the model's snapshot footprint)."""
    from repro.runtime.metrics import ServeStats
    stats = ServeStats()
    stats.sync_prefix({"inserts": 2, "evictions": 1, "rejects": 3,
                       "bytes": 16})
    assert stats.summary()["prefix_rejects"] == 3


def test_config_validation():
    for bad in (PrefixCacheConfig(block=0), PrefixCacheConfig(max_entries=0),
                PrefixCacheConfig(max_bytes=0),
                PrefixCacheConfig(store="gpu")):
        with pytest.raises(ValueError):
            bad.validate()


# ---------------------------------------------------------------------------
# Cached admission == cold prefill (families x state_dtype)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", CACHE_ARCHS)
@pytest.mark.parametrize("state_dtype", [None, "int8"])
def test_cached_admission_token_identical(name, state_dtype):
    """The acceptance gate: a cache HIT emits exactly the tokens a COLD
    admission of the same prompt produces, with >0 hits and strictly
    fewer prefilled (computed) tokens than the no-cache engine.

    Hit-vs-cold identity is by construction for ANY state_dtype: a
    cache-enabled engine chunks every admission at the same block
    boundaries (cold = block prefill + suffix chain, hit = restored
    snapshot + the same chain), and the snapshot IS the cold path's
    state at that boundary.  In f32 the chunked computation is
    additionally bitwise the cache-OFF engine's single-shot prefill
    (asserted below); with a quantized state_dtype the quantization
    POINTS differ between chunked and single-shot prompt processing
    (same reason int8 decode agreement has a floor, not a guarantee,
    in test_state_quant.py), so cross-engine identity is asserted
    against a cache-enabled cold engine instead."""
    cfg, params = _setup(name)
    prompts = _shared_prefix_prompts(cfg.vocab)
    pcc = PrefixCacheConfig(block=8, max_entries=16)
    ecfg = dict(n_slots=2, max_seq=64, state_dtype=state_dtype)
    eng0 = Engine(cfg, params, EngineConfig(**ecfg))
    nocache = [eng0.submit(p, max_new=6) for p in prompts]
    eng0.run()
    eng1 = Engine(cfg, params, EngineConfig(**ecfg, prefix_cache=pcc))
    got = [eng1.submit(p, max_new=6) for p in prompts]
    eng1.run()
    # cold reference for each prompt: a fresh cache-enabled engine per
    # request, so every admission misses but chunks identically
    ref = []
    for p in prompts:
        e = Engine(cfg, params, EngineConfig(**ecfg, prefix_cache=pcc))
        r = e.submit(p, max_new=6)
        e.run()
        assert e.stats.prefix_hits == 0
        ref.append(r)
    assert [r.tokens for r in got] == [r.tokens for r in ref]
    if state_dtype is None:
        assert [r.tokens for r in got] == [r.tokens for r in nocache]
    s = eng1.stats.summary()
    assert s["prefix_hits"] > 0
    assert eng1.stats.prefill_tokens < eng0.stats.prefill_tokens
    assert s["prefix_cached_tokens"] > 0


def test_unaligned_shared_prefix_hits_at_block_boundary():
    """Two prompts sharing a prefix that is NOT a block multiple still
    hit at the deepest common boundary (cold admissions snapshot every
    boundary they cross)."""
    cfg, params = _setup()
    rng = np.random.default_rng(3)
    shared = rng.integers(1, cfg.vocab, size=13).astype(np.int32)  # 13 % 4 != 0
    p1 = np.concatenate([shared, rng.integers(1, cfg.vocab, size=4).astype(np.int32)])
    p2 = np.concatenate([shared, rng.integers(1, cfg.vocab, size=6).astype(np.int32)])
    eng = Engine(cfg, params, EngineConfig(
        n_slots=1, max_seq=64,
        prefix_cache=PrefixCacheConfig(block=4, max_entries=16)))
    r1 = eng.submit(p1, max_new=4)
    r2 = eng.submit(p2, max_new=4)
    eng.run()
    assert eng.stats.prefix_hits == 1
    # the hit restored 12 of 13 shared tokens (deepest boundary = 12)
    assert eng.stats.prefix_cached_tokens == 12
    eng0 = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    q1, q2 = eng0.submit(p1, max_new=4), eng0.submit(p2, max_new=4)
    eng0.run()
    assert (r1.tokens, r2.tokens) == (q1.tokens, q2.tokens)


@pytest.mark.parametrize("state_dtype", [None, "int8"])
def test_lru_churn_no_scale_or_payload_leak(state_dtype):
    """Stale-state regression under eviction churn: a tiny cache cycled
    through many distinct prompts (every insert evicts) must keep every
    restored admission token-identical — a snapshot surviving under the
    wrong key, or a payload restored under another entry's scales,
    would corrupt the stream."""
    cfg, params = _setup()
    rng = np.random.default_rng(7)
    prefixes = [rng.integers(1, cfg.vocab, size=8).astype(np.int32)
                for _ in range(5)]
    # interleave: each prefix admitted twice, far enough apart that the
    # 2-entry LRU evicts between most reuses
    prompts = []
    for round_ in range(2):
        for pfx in prefixes:
            prompts.append(np.concatenate(
                [pfx, rng.integers(1, cfg.vocab, size=3).astype(np.int32)]))
    ecfg = dict(n_slots=2, max_seq=32, state_dtype=state_dtype)
    # reference: every prompt served cold on a fresh cache-enabled
    # engine (same block chunking, zero hits) — valid for any dtype
    ref = []
    for p in prompts:
        e = Engine(cfg, params, EngineConfig(
            **ecfg, prefix_cache=PrefixCacheConfig(block=8)))
        r = e.submit(p, max_new=4)
        e.run()
        ref.append(r)
    eng1 = Engine(cfg, params, EngineConfig(
        **ecfg, prefix_cache=PrefixCacheConfig(block=8, max_entries=2)))
    got = [eng1.submit(p, max_new=4) for p in prompts]
    eng1.run()
    assert [r.tokens for r in got] == [r.tokens for r in ref]
    assert eng1.stats.prefix_evictions > 0
    assert len(eng1._prefix) <= 2


def test_host_store_engine_roundtrip_and_drain():
    cfg, params = _setup()
    prompts = _shared_prefix_prompts(cfg.vocab)
    eng0 = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64))
    ref = [eng0.submit(p, max_new=6) for p in prompts]
    eng0.run()
    eng1 = Engine(cfg, params, EngineConfig(
        n_slots=2, max_seq=64,
        prefix_cache=PrefixCacheConfig(block=8, store="host")))
    got = [eng1.submit(p, max_new=6) for p in prompts]
    eng1.run()
    assert [r.tokens for r in got] == [r.tokens for r in ref]
    assert not eng1._prefix.has_pending()   # drained by run()'s deadline
    assert eng1.stats.prefix_hits > 0


def test_spec_decode_over_cached_prefix_token_identical():
    """The three state movers compose: restore (prefix cache), fork
    (spec draft), rollback (verify) — greedy streams stay bitwise."""
    cfg, params = _setup()
    prompts = _shared_prefix_prompts(cfg.vocab)
    eng0 = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64))
    ref = [eng0.submit(p, max_new=6) for p in prompts]
    eng0.run()
    eng1 = Engine(cfg, params, EngineConfig(
        n_slots=2, max_seq=64, draft=DraftConfig(k=3),
        prefix_cache=PrefixCacheConfig(block=8)))
    got = [eng1.submit(p, max_new=6) for p in prompts]
    eng1.run()
    assert [r.tokens for r in got] == [r.tokens for r in ref]
    assert eng1.stats.prefix_hits > 0


# ---------------------------------------------------------------------------
# Fork-seed aliasing fix (pool level)
# ---------------------------------------------------------------------------

def test_fork_untagged_copies_key_verbatim():
    """The spec-decode contract: a tag-less fork's destination key is a
    byte-for-byte copy of the source — bitwise the pre-fix behavior."""
    cfg, _ = _setup()
    pool = SlotStatePool(cfg, n_slots=2, max_seq=16, n_scratch=2)
    s = pool.alloc()
    pool.params.set(s, SamplingParams(temperature=0.9, seed=123), 123)
    sc = pool.lease_scratch()
    pool.fork([s], [sc])
    assert np.array_equal(pool.params.key_data[sc],
                          pool.params.key_data[s])
    pool.release_scratch(sc)


def test_fork_branch_tags_rederive_keys_per_branch():
    """The aliasing fix: tagged destinations get fold_in(src_key, tag)
    — distinct per branch, deterministic, and tag 0 stays verbatim."""
    cfg, _ = _setup()
    pool = SlotStatePool(cfg, n_slots=4, max_seq=16)
    s = pool.alloc()
    d0, d1, d2 = pool.alloc(), pool.alloc(), pool.alloc()
    pool.params.set(s, SamplingParams(temperature=0.9, seed=5), 5)
    pool.fork([s, s, s], [d0, d1, d2], branch_tags=[0, 1, 2])
    kd = pool.params.key_data
    assert np.array_equal(kd[d0], kd[s])          # tag 0 == verbatim
    assert not np.array_equal(kd[d1], kd[s])
    assert not np.array_equal(kd[d2], kd[s])
    assert not np.array_equal(kd[d1], kd[d2])
    # deterministic: the fold of the SOURCE key, not of slot position
    want = jax.random.key_data(jax.random.fold_in(
        jax.random.wrap_key_data(jnp.asarray(kd[s])), 1))
    assert np.array_equal(kd[d1], np.asarray(want))
    with pytest.raises(ValueError):
        pool.fork([s], [d1], branch_tags=[1, 2])


# ---------------------------------------------------------------------------
# Best-of-n (engine level)
# ---------------------------------------------------------------------------

def test_bestofn_sampled_branches_distinct_ranked_and_branch0_bitwise():
    cfg, params = _setup()
    prompt = _shared_prefix_prompts(cfg.vocab, n=1)[0]
    sp = SamplingParams(temperature=0.9, seed=7, n=3, max_new=6)
    eng = Engine(cfg, params, EngineConfig(n_slots=4, max_seq=64))
    parent = eng.submit(prompt, params=sp)
    eng.run()
    assert parent.finished and len(parent.branches) == 3
    streams = [tuple(c.tokens) for c in parent.branches]
    assert len(set(streams)) == 3, "sampled branches must diverge"
    # ranked by cumulative logprob, best surfaced on the parent
    cums = [c.cum_logprob for c in parent.branches]
    assert cums == sorted(cums, reverse=True)
    assert parent.tokens == parent.branches[0].tokens
    assert parent.cum_logprob == parent.branches[0].cum_logprob
    # branch 0 is bitwise the same request served at n=1
    eng1 = Engine(cfg, params, EngineConfig(n_slots=4, max_seq=64))
    solo = eng1.submit(prompt, params=dataclasses.replace(sp, n=1))
    eng1.run()
    b0 = next(c for c in parent.branches if c.branch == 0)
    assert b0.tokens == solo.tokens
    # stats: ONE request submitted, one retired, no branch double-count
    assert eng.stats.n_requests == 1
    assert len(eng.pool._free) == 4


def test_bestofn_greedy_branches_identical_streams():
    """Greedy ignores the key stream entirely, so re-derived branch
    keys must not perturb it: all branches argmax-identical."""
    cfg, params = _setup()
    prompt = _shared_prefix_prompts(cfg.vocab, n=1)[0]
    eng = Engine(cfg, params, EngineConfig(n_slots=3, max_seq=64))
    parent = eng.submit(prompt, params=SamplingParams(n=3, max_new=5))
    eng.run()
    streams = [tuple(c.tokens) for c in parent.branches]
    assert len(set(streams)) == 1
    eng1 = Engine(cfg, params, EngineConfig(n_slots=3, max_seq=64))
    solo = eng1.submit(prompt, params=SamplingParams(max_new=5))
    eng1.run()
    assert list(streams[0]) == solo.tokens


def test_bestofn_needs_n_slots_and_blocks_head_of_line():
    cfg, params = _setup()
    prompt = _shared_prefix_prompts(cfg.vocab, n=1)[0]
    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64))
    with pytest.raises(ValueError):
        eng.submit(prompt, params=SamplingParams(n=3))
    with pytest.raises(ValueError):
        eng.submit(prompt, params=SamplingParams(n=2),
                   stream_cb=lambda r, t: None)
    # n=2 on a 2-slot engine: a single-slot request queued behind it
    # must not jump the line while only one slot is free
    r1 = eng.submit(prompt, max_new=8)
    r2 = eng.submit(prompt, params=SamplingParams(n=2, max_new=4,
                                                  temperature=0.5,
                                                  seed=3))
    r3 = eng.submit(prompt, max_new=2)
    eng.run()
    assert r1.finished and r2.finished and r3.finished
    assert len(r2.branches) == 2
    assert eng.pool.n_free == 2


def test_cancel_mid_fork_reclaims_every_branch_no_leak():
    """Cancel the parent while its branches are mid-decode: every
    branch slot must return to the free list, the parent retires as
    ONE cancelled request, and co-resident requests are unperturbed."""
    cfg, params = _setup()
    prompts = _shared_prefix_prompts(cfg.vocab, n=2)
    eng0 = Engine(cfg, params, EngineConfig(n_slots=4, max_seq=64))
    ref = eng0.submit(prompts[1], max_new=8)
    eng0.run()
    eng = Engine(cfg, params, EngineConfig(n_slots=4, max_seq=64,
                                           sched_quantum=2))
    parent = eng.submit(prompts[0],
                        params=SamplingParams(temperature=0.8, seed=9,
                                              n=3, max_new=16))
    # the bystander's stream_cb keeps bursts quantum-capped (an
    # uncertain event), so two steps leave everyone mid-decode
    bystander = eng.submit(prompts[1], max_new=8,
                           stream_cb=lambda r, t: None)
    # admit + a couple of bursts, then cancel the parent mid-flight
    eng.step()
    eng.step()
    assert eng.pool.n_active == 4
    assert eng.cancel(parent.req_id)
    eng.run()
    assert parent.finished and parent.cancelled
    assert all(c.finished for c in parent.branches)
    assert eng.pool.n_free == 4
    assert eng._by_id == {}
    assert eng.stats.n_cancelled == 1 and eng.stats.n_requests == 1
    assert bystander.tokens == ref.tokens
    # slot params rows were cleared on eviction (no key/temp leak)
    assert float(eng.pool.params.temperature.max()) == 0.0
    assert int(eng.pool.params.key_data.max()) == 0


def test_bestofn_over_cached_prefix():
    """Tentpole composition: the n-way fork rides a cache-restored
    admission; branch streams still diverge and branch 0 still matches
    the cold n=1 stream."""
    cfg, params = _setup()
    prompts = _shared_prefix_prompts(cfg.vocab, n=2)
    sp = SamplingParams(temperature=0.9, seed=11, n=3, max_new=5)
    eng = Engine(cfg, params, EngineConfig(
        n_slots=3, max_seq=64, prefix_cache=PrefixCacheConfig(block=8)))
    warm = eng.submit(prompts[0], max_new=4)        # seeds the cache
    eng.run()
    parent = eng.submit(prompts[1], params=sp)
    eng.run()
    assert eng.stats.prefix_hits > 0
    streams = [tuple(c.tokens) for c in parent.branches]
    assert len(set(streams)) == 3
    eng1 = Engine(cfg, params, EngineConfig(n_slots=3, max_seq=64))
    solo = eng1.submit(prompts[1], params=dataclasses.replace(sp, n=1))
    eng1.run()
    b0 = next(c for c in parent.branches if c.branch == 0)
    assert b0.tokens == solo.tokens
