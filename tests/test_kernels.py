"""Per-kernel validation: shape/dtype sweeps vs the ref.py pure-jnp oracles."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # degrade to the deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import selective_scan as css
from repro.kernels import (conv1d as conv_k, fast_exp as fexp_k,
                           flash_attention as flash_k,
                           piecewise_silu as silu_k,
                           selective_scan as scan_k, ref)

jax.config.update("jax_platform_name", "cpu")
RNG = np.random.default_rng(42)


def _randn(shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Element-wise kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8,), (33,), (4, 129), (2, 3, 257),
                                   (1, 1024), (5, 7, 11, 13)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fast_exp_kernel_matches_oracle(shape, dtype):
    x = _randn(shape, dtype) * 3 - 2
    got = fexp_k.fast_exp(x)
    want = ref.our_exp(x)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-3, atol=1e-6)


@pytest.mark.parametrize("shape", [(16,), (3, 100), (2, 5, 300)])
@pytest.mark.parametrize("variant", ["ours", "paper"])
def test_silu_kernel_matches_oracle(shape, variant):
    x = _randn(shape) * 4
    got = silu_k.piecewise_silu(x, variant=variant)
    want = (ref.piecewise_silu(x) if variant == "ours"
            else ref.piecewise_silu_paper(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Selective scan: the flagship kernel
# ---------------------------------------------------------------------------

def _scan_inputs(b, L, d, n, dtype=jnp.float32, with_d=True, with_z=True):
    x = _randn((b, L, d), dtype)
    dt = jax.nn.softplus(_randn((b, L, d))).astype(dtype)
    A = -jnp.exp(_randn((d, n)) * 0.5)
    B = _randn((b, L, n), dtype)
    C = _randn((b, L, n), dtype)
    D = _randn((d,)) if with_d else None
    z = _randn((b, L, d), dtype) if with_z else None
    return x, dt, A, B, C, D, z


@pytest.mark.parametrize("b,L,d,n", [(1, 16, 8, 4), (2, 64, 32, 16),
                                     (1, 100, 48, 8), (3, 33, 130, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scan_kernel_matches_ref(b, L, d, n, dtype):
    x, dt, A, B, C, D, z = _scan_inputs(b, L, d, n, dtype)
    y0, h0 = ref.selective_scan(x, dt, A, B, C, D, z)
    y1, h1 = scan_k.selective_scan(x, dt, A, B, C, D, z,
                                   block_d=32, block_l=32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y0, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("with_d,with_z", [(False, False), (True, False),
                                           (False, True)])
def test_scan_kernel_optional_inputs(with_d, with_z):
    x, dt, A, B, C, D, z = _scan_inputs(2, 32, 16, 8, with_d=with_d,
                                        with_z=with_z)
    y0, h0 = ref.selective_scan(x, dt, A, B, C, D, z)
    y1, h1 = scan_k.selective_scan(x, dt, A, B, C, D, z,
                                   block_d=16, block_l=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=2e-4, atol=2e-4)


def test_scan_kernel_h0_continuation():
    """Chunk-streaming: scanning [0:L1] then [L1:L] == scanning [0:L]."""
    x, dt, A, B, C, D, z = _scan_inputs(2, 64, 32, 16)
    y_full, h_full = scan_k.selective_scan(x, dt, A, B, C, D, z,
                                           block_d=32, block_l=32)
    y1, h1 = scan_k.selective_scan(x[:, :32], dt[:, :32], A, B[:, :32],
                                   C[:, :32], D, z[:, :32],
                                   block_d=32, block_l=32)
    y2, h2 = scan_k.selective_scan(x[:, 32:], dt[:, 32:], A, B[:, 32:],
                                   C[:, 32:], D, z[:, 32:], h0=h1,
                                   block_d=32, block_l=32)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("exp_impl,silu_impl", [("ours", "ours"),
                                                ("fast", "paper")])
def test_scan_kernel_approx_modes(exp_impl, silu_impl):
    """Kernel approx modes must match ref approx modes exactly (same algo)."""
    x, dt, A, B, C, D, z = _scan_inputs(1, 48, 32, 8)
    y0, h0 = ref.selective_scan(x, dt, A, B, C, D, z,
                                exp_impl=exp_impl, silu_impl=silu_impl)
    y1, h1 = scan_k.selective_scan(x, dt, A, B, C, D, z, block_d=32,
                                   block_l=16, exp_impl=exp_impl,
                                   silu_impl=silu_impl)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=1e-3, atol=1e-3)


def test_scan_impl_equivalence_chunked_assoc_seq():
    x, dt, A, B, C, D, z = _scan_inputs(2, 96, 24, 16)
    y0, h0 = css.selective_scan_seq(x, dt, A, B, C, D, z)
    for impl, kw in [(css.selective_scan_chunked, dict(chunk=32)),
                     (css.selective_scan_chunked, dict(chunk=17)),
                     (css.selective_scan_assoc, {})]:
        y, h = impl(x, dt, A, B, C, D, z, **kw)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(h), np.asarray(h0),
                                   rtol=2e-4, atol=2e-4)


def test_scan_chunked_differentiable():
    x, dt, A, B, C, D, z = _scan_inputs(1, 32, 16, 8)

    def loss(x, dt, A, B, C, D, z):
        y, _ = css.selective_scan_chunked(x, dt, A, B, C, D, z, chunk=8)
        return jnp.sum(y ** 2)

    grads = jax.grad(loss, argnums=(0, 1, 2))(x, dt, A, B, C, D, z)
    for g in grads:
        assert np.all(np.isfinite(np.asarray(g)))
        assert float(jnp.max(jnp.abs(g))) > 0


@given(st.integers(1, 3), st.integers(1, 40), st.integers(1, 40),
       st.integers(1, 16))
@settings(max_examples=20, deadline=None)
def test_scan_kernel_property_shapes(b, L, d, n):
    """Property: kernel handles arbitrary (b, L, d, n) via padding."""
    x, dt, A, B, C, D, z = _scan_inputs(b, L, d, n)
    y0, h0 = ref.selective_scan(x, dt, A, B, C, D, z)
    y1, h1 = scan_k.selective_scan(x, dt, A, B, C, D, z,
                                   block_d=16, block_l=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0),
                               rtol=5e-4, atol=5e-4)


def test_state_step_matches_scan_tail():
    """One decode step == last step of a scan."""
    x, dt, A, B, C, D, z = _scan_inputs(2, 8, 16, 4)
    y_full, h_full = ref.selective_scan(x, dt, A, B, C, D, z)
    _, h_prefix = ref.selective_scan(x[:, :-1], dt[:, :-1], A, B[:, :-1],
                                     C[:, :-1], D, z[:, :-1])
    y_t, h_t = ref.selective_state_step(h_prefix, x[:, -1], dt[:, -1], A,
                                        B[:, -1], C[:, -1], D, z[:, -1])
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, -1]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_t), np.asarray(h_full),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Conv1d kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,L,d,k", [(1, 16, 8, 4), (2, 100, 96, 4),
                                     (3, 33, 17, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv1d_kernel_matches_ref(b, L, d, k, dtype):
    x = _randn((b, L, d), dtype)
    w = _randn((k, d))
    bias = _randn((d,))
    xprev = _randn((b, k - 1, d), dtype)
    y0, s0 = ref.causal_conv1d(x, w, bias, xprev)
    y1, s1 = conv_k.causal_conv1d(x, w, bias, xprev, block_d=16, block_l=16)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y0, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(s1, np.float32),
                               np.asarray(s0, np.float32), rtol=tol, atol=tol)


def test_conv1d_streaming_equals_full():
    b, L, d, k = 2, 64, 32, 4
    x = _randn((b, L, d))
    w = _randn((k, d))
    y_full, _ = ref.causal_conv1d(x, w)
    y1, s1 = conv_k.causal_conv1d(x[:, :40], w, block_d=32, block_l=8)
    y2, _ = conv_k.causal_conv1d(x[:, 40:], w, x_prev=s1, block_d=32,
                                 block_l=8)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], axis=1)), np.asarray(y_full),
        rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Flash attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,l,hq,hkv,dh", [(1, 64, 4, 4, 32),
                                           (2, 128, 8, 2, 64),
                                           (1, 96, 8, 1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(b, l, hq, hkv, dh, dtype):
    q = _randn((b, l, hq, dh), dtype)
    k = _randn((b, l, hkv, dh), dtype)
    v = _randn((b, l, hkv, dh), dtype)
    o0 = ref.attention(q, k, v, causal=True)
    o1 = flash_k.flash_attention(q, k, v, causal=True, block_q=32,
                                 block_k=32)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o0, np.float32), rtol=tol, atol=tol)


def test_flash_suffix_decode_chunk():
    """lq < lk: queries are the suffix (speculative/chunked decode)."""
    b, lq, lk, hq, hkv, dh = 2, 17, 100, 8, 2, 64
    q = _randn((b, lq, hq, dh))
    k = _randn((b, lk, hkv, dh))
    v = _randn((b, lk, hkv, dh))
    o0 = ref.attention(q, k, v, causal=True)
    o1 = flash_k.flash_attention(q, k, v, causal=True, block_q=16,
                                 block_k=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o0), rtol=2e-5,
                               atol=2e-5)


@given(st.integers(1, 2), st.integers(4, 70), st.integers(0, 2),
       st.integers(0, 1))
@settings(max_examples=15, deadline=None)
def test_flash_property(b, l, hq_pow, dh_pow):
    hq = 2 ** hq_pow
    dh = 32 * (2 ** dh_pow)
    q = _randn((b, l, hq, dh))
    k = _randn((b, l, hq, dh))
    v = _randn((b, l, hq, dh))
    o0 = ref.attention(q, k, v, causal=True)
    o1 = flash_k.flash_attention(q, k, v, causal=True, block_q=32,
                                 block_k=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o0), rtol=1e-4,
                               atol=1e-4)
