"""Launcher CLI integration tests (subprocess, single CPU device)."""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                       text=True, timeout=timeout, env=env, cwd=ROOT)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_train_cli_tiny(tmp_path):
    out = _run(["repro.launch.train", "--arch", "mamba-130m",
                "--steps", "6", "--seq", "32", "--global-batch", "4",
                "--dtype", "float32", "--no-resume",
                "--ckpt-dir", str(tmp_path)])
    assert "[launch.train] mamba-130m" in out


def test_train_cli_resumes(tmp_path):
    _run(["repro.launch.train", "--arch", "mamba-130m", "--steps", "4",
          "--seq", "32", "--global-batch", "4", "--dtype", "float32",
          "--no-resume", "--ckpt-every", "2", "--ckpt-dir", str(tmp_path)])
    out = _run(["repro.launch.train", "--arch", "mamba-130m", "--steps",
                "6", "--seq", "32", "--global-batch", "4", "--dtype",
                "float32", "--ckpt-every", "2", "--ckpt-dir",
                str(tmp_path)])
    assert "resumed from step 4" in out


def test_serve_cli_smoke():
    out = _run(["repro.launch.serve", "--arch", "mamba-130m", "--smoke",
                "--requests", "2", "--batch-slots", "2", "--max-new", "4"])
    assert "tok/s" in out


def test_dryrun_cli_help_without_devices():
    """dryrun --help must work (and not crash on the forced device count)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--help"],
        capture_output=True, text=True, timeout=240, env=env, cwd=ROOT)
    assert r.returncode == 0 and "--mesh" in r.stdout
