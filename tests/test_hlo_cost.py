"""Validate the static HLO cost analyzer against hand-computable programs
(this analyzer produces the §Roofline numbers, so it must be right)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost

jax.config.update("jax_platform_name", "cpu")


def _cost_of(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return hlo_cost.analyze(txt)


def test_single_matmul_flops():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 512), jnp.float32)
    c = _cost_of(lambda x, y: x @ y, a, b)
    want = 2 * 128 * 256 * 512
    assert abs(c.flops - want) / want < 0.05, c.flops


def test_matmul_in_fori_loop_multiplied_by_trips():
    a = jnp.zeros((128, 128), jnp.float32)

    def f(x):
        def body(i, acc):
            return acc @ a + 1.0
        return jax.lax.fori_loop(0, 17, body, x)

    c = _cost_of(f, a)
    want = 17 * 2 * 128 * 128 * 128
    assert c.flops > 0.9 * want, (c.flops, want)
    assert c.flops < 1.3 * want, (c.flops, want)
    assert c.unknown_trip_whiles == 0


def test_scan_layers_flops():
    """Scanned 8-layer MLP: flops ~ 8 * (2*b*d*f + 2*b*f*d)."""
    b, d, f, L = 32, 64, 256, 8
    w1 = jnp.zeros((L, d, f), jnp.float32)
    w2 = jnp.zeros((L, f, d), jnp.float32)

    def net(x):
        def layer(h, ws):
            a, bb = ws
            return jnp.maximum(h @ a, 0) @ bb, None
        y, _ = jax.lax.scan(layer, x, (w1, w2))
        return y

    c = _cost_of(net, jnp.zeros((b, d), jnp.float32))
    want = L * (2 * b * d * f + 2 * b * f * d)
    assert 0.9 * want < c.flops < 1.3 * want, (c.flops, want)


def test_grad_of_scan_counts_backward():
    """grad through a scanned matmul: >= 3x forward flops."""
    b, d, L = 16, 64, 6
    w = jnp.zeros((L, d, d), jnp.float32)

    def net(w, x):
        def layer(h, wi):
            return jnp.tanh(h @ wi), None
        y, _ = jax.lax.scan(layer, x, w)
        return jnp.sum(y)

    fwd = _cost_of(lambda w, x: net(w, x), w, jnp.zeros((b, d)))
    bwd = _cost_of(lambda w, x: jax.grad(net)(w, x), w, jnp.zeros((b, d)))
    assert bwd.flops > 2.5 * fwd.flops, (fwd.flops, bwd.flops)
    assert bwd.unknown_trip_whiles == 0


def test_bytes_dominated_by_big_operand():
    big = jnp.zeros((4096, 4096), jnp.float32)      # 64 MB

    def f(x):
        return x * 2.0 + 1.0

    c = _cost_of(f, big)
    want = 2 * big.size * 4                          # read + write
    assert 0.9 * want < c.bytes < 1.5 * want, (c.bytes, want)


def test_elementwise_in_loop_bytes_scale_with_trips():
    x = jnp.zeros((1024, 1024), jnp.float32)        # 4 MB

    def f(x):
        def body(i, acc):
            return acc * 1.0001 + 1.0
        return jax.lax.fori_loop(0, 10, body, x)

    c = _cost_of(f, x)
    assert c.bytes > 10 * x.size * 4, c.bytes        # >= trips * one pass


def test_collectives_counted_with_trips():
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (run via subprocess suite)")


def test_transcendentals_counted():
    x = jnp.zeros((256, 256), jnp.float32)
    c = _cost_of(lambda x: jnp.exp(x), x)
    assert c.transcendentals >= x.size
