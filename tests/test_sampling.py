"""Per-request generation API: vectorized sampling, one jit cache for
heterogeneous traffic, seeded reproducibility, streaming, cancellation,
priority, stop ids.

The contract under test (ISSUE 5 / PR 5):

  * SamplingParams are DATA — a batch mixing greedy, temperature,
    top-k and top-p requests is served by ONE compiled
    prefill/decode signature; changing any field never retraces
    (sampling.TRACE_COUNTS deltas are asserted to be zero).
  * Greedy slots are bitwise identical to an all-greedy engine — and
    to the pre-redesign engine — no matter what shares the batch.
  * A sampled stream is a pure function of (seed, params, prompt,
    weights): independent of slot placement, batch composition, and
    co-resident admissions/evictions/cancellations.
  * Streaming callbacks deliver every token exactly once, in order,
    at scheduler syncs; cancellation reclaims the slot (and scratch
    leases) without perturbing survivors.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import registry
from repro.parallel import sharding
from repro.runtime import sampling
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.sampling import SamplingParams

jax.config.update("jax_platform_name", "cpu")
RNG = np.random.default_rng(31)


def _setup(name="mamba-130m"):
    cfg = configs.smoke_variant(configs.get_config(name))
    cfg = dataclasses.replace(cfg, vocab=64, dtype="float32",
                              capacity_factor=float(max(cfg.n_experts, 1)))
    params = sharding.tree_values(
        registry.init_params(cfg, jax.random.key(0)))
    return cfg, params


def _prompts(n, vocab=64, seed=5, lo=3, hi=10):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=(l,)).astype(np.int32)
            for l in rng.integers(lo, hi, size=n)]


MIXED = [SamplingParams(),
         SamplingParams(temperature=0.8, seed=11),
         SamplingParams(temperature=1.2, top_k=8, seed=12),
         SamplingParams(temperature=0.7, top_p=0.9, seed=13)]


# ---------------------------------------------------------------------------
# filter_logits / sample units
# ---------------------------------------------------------------------------

def test_filter_logits_top_k_per_row():
    lg = jnp.asarray([[1.0, 4.0, 2.0, 3.0],
                      [1.0, 4.0, 2.0, 3.0],
                      [1.0, 4.0, 2.0, 3.0]])
    top_k = jnp.asarray([0, 1, 2], jnp.int32)          # disabled, 1, 2
    top_p = jnp.ones((3,), jnp.float32)
    out = np.asarray(sampling.filter_logits(lg, top_k, top_p))
    assert np.isfinite(out[0]).all()                   # k=0 keeps all
    assert np.isfinite(out[1]).sum() == 1 and out[1, 1] == 4.0
    assert np.isfinite(out[2]).sum() == 2              # keeps {4, 3}
    assert np.isfinite(out[2, [1, 3]]).all()


def test_filter_logits_top_p_crossing_token_included():
    # softmax of [2, 1, 0, -9] ~ [0.705, 0.259, 0.095, ...]: top_p=0.5
    # keeps the crossing token (the first), top_p=0.8 keeps two
    lg = jnp.asarray([[2.0, 1.0, 0.0, -9.0],
                      [2.0, 1.0, 0.0, -9.0]])
    top_p = jnp.asarray([0.5, 0.8], jnp.float32)
    out = np.asarray(sampling.filter_logits(
        lg, jnp.zeros((2,), jnp.int32), top_p))
    assert np.isfinite(out[0]).sum() == 1 and np.isfinite(out[0, 0])
    assert np.isfinite(out[1]).sum() == 2
    assert np.isfinite(out[1, [0, 1]]).all()


def test_filter_logits_always_keeps_one_token():
    # tiny top_p must still keep the argmax, never an empty support
    lg = jnp.asarray(RNG.normal(size=(4, 16)), jnp.float32)
    out = np.asarray(sampling.filter_logits(
        lg, jnp.zeros((4,), jnp.int32),
        jnp.full((4,), 1e-9, jnp.float32)))
    for r in range(4):
        assert np.isfinite(out[r]).sum() == 1
        assert np.isfinite(out[r, np.argmax(np.asarray(lg)[r])])


def test_sample_greedy_rows_are_argmax():
    b, v = 5, 32
    lg = jnp.asarray(RNG.normal(size=(b, v)), jnp.float32)
    sp = {"temperature": jnp.zeros((b,), jnp.float32),
          "top_k": jnp.zeros((b,), jnp.int32),
          "top_p": jnp.ones((b,), jnp.float32),
          "key_data": jnp.asarray(
              np.stack([sampling.seed_key_data(i) for i in range(b)]))}
    toks = np.asarray(sampling.sample(lg, sp, jnp.zeros((b,), jnp.int32)))
    np.testing.assert_array_equal(toks, np.argmax(np.asarray(lg), -1))


def test_sample_respects_per_row_support():
    """Sampled tokens always land inside each row's own top-k/top-p
    support — per-row filtering really is per-row."""
    b, v = 3, 32
    lg = jnp.asarray(RNG.normal(size=(b, v)) * 2, jnp.float32)
    sp = {"temperature": jnp.full((b,), 1.5, jnp.float32),
          "top_k": jnp.asarray([4, 0, 2], jnp.int32),
          "top_p": jnp.asarray([1.0, 0.5, 1.0], jnp.float32),
          "key_data": jnp.asarray(
              np.stack([sampling.seed_key_data(i) for i in range(b)]))}
    support = np.isfinite(np.asarray(sampling.sample_dist(lg, sp)))
    assert support[0].sum() == 4 and support[2].sum() == 2
    for step in range(50):
        toks = np.asarray(sampling.sample(
            lg, sp, jnp.full((b,), step, jnp.int32)))
        for r in range(b):
            assert support[r, toks[r]], (step, r, toks[r])


def test_sample_batch_matches_per_row_calls():
    """Vectorization is sound: sampling a batch equals sampling each
    row alone with the same key/step — the property that makes streams
    batch-composition-independent."""
    b, v = 4, 24
    lg = jnp.asarray(RNG.normal(size=(b, v)), jnp.float32)
    sp = {"temperature": jnp.asarray([0.0, 0.9, 1.3, 0.6], jnp.float32),
          "top_k": jnp.asarray([0, 0, 5, 0], jnp.int32),
          "top_p": jnp.asarray([1.0, 1.0, 1.0, 0.8], jnp.float32),
          "key_data": jnp.asarray(
              np.stack([sampling.seed_key_data(7 + i) for i in range(b)]))}
    step = jnp.asarray([3, 1, 4, 1], jnp.int32)
    full = np.asarray(sampling.sample(lg, sp, step))
    for r in range(b):
        row = {k: val[r:r + 1] for k, val in sp.items()}
        one = np.asarray(sampling.sample(lg[r:r + 1], row,
                                         step[r:r + 1]))
        assert one[0] == full[r], (r, one, full)


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1).validate()
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1).validate()
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0).validate()
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5).validate()
    with pytest.raises(ValueError):
        SamplingParams(max_new=0).validate()


def test_engine_rejects_invalid_params():
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=32))
    with pytest.raises(ValueError):
        eng.submit(np.arange(4, dtype=np.int32),
                   params=SamplingParams(top_p=2.0))


# ---------------------------------------------------------------------------
# One jit cache for heterogeneous traffic
# ---------------------------------------------------------------------------

def test_mixed_sampling_batch_zero_retrace_and_greedy_bitwise():
    """The tentpole gate: after a greedy warmup, serving a batch that
    mixes greedy / temperature / top-k / top-p retraces NOTHING
    (decode and prefill compile counts unchanged), and the greedy
    rows' streams are bitwise the all-greedy engine's."""
    cfg, params = _setup()
    prompts = [p[:4] for p in _prompts(4, lo=4, hi=5)]   # one length ->
    # the per-prompt-length prefill compile is warmed by the first run
    ref_eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64))
    ref = [ref_eng.submit(p, max_new=6) for p in prompts]
    ref_eng.run()

    before = dict(sampling.TRACE_COUNTS)
    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64))
    got = [eng.submit(p, params=sp, max_new=6)
           for p, sp in zip(prompts, MIXED)]
    eng.run()
    after = dict(sampling.TRACE_COUNTS)
    assert after.get("decode_step", 0) == before.get("decode_step", 0), \
        "heterogeneous SamplingParams retraced the decode step"
    assert after.get("prefill_admit", 0) == before.get("prefill_admit", 0), \
        "heterogeneous SamplingParams retraced the prefill"
    # greedy slots bitwise vs the all-greedy engine
    assert got[0].tokens == ref[0].tokens
    # sampled slots actually sample (streams differ from greedy)
    assert any(got[i].tokens != ref[i].tokens for i in (1, 2, 3))
    # deterministic accounting: every request got its full budget
    assert all(len(r.tokens) == 6 for r in got)


def test_seeded_stream_independent_of_batch_composition():
    """The same seeded request produces the identical token stream
    alone, among greedy fillers, and among other sampled requests —
    sampling randomness is per-slot counter-based, never shared."""
    cfg, params = _setup()
    prompts = _prompts(4)
    sp = SamplingParams(temperature=0.9, seed=42, max_new=8)
    alone = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64))
    r_alone = alone.submit(prompts[0], params=sp)
    alone.run()
    crowd = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64))
    fillers = [crowd.submit(p, params=q, max_new=5)
               for p, q in zip(prompts[1:], MIXED[1:])]
    r_crowd = crowd.submit(prompts[0], params=sp)
    crowd.run()
    assert r_alone.tokens == r_crowd.tokens, \
        "seeded stream depended on batch composition"
    assert all(f.finished for f in fillers)


def test_same_seed_same_stream_distinct_seeds_differ():
    cfg, params = _setup()
    p = _prompts(1)[0]
    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64))
    a = eng.submit(p, params=SamplingParams(temperature=1.5, seed=3,
                                            max_new=8))
    b = eng.submit(p, params=SamplingParams(temperature=1.5, seed=3,
                                            max_new=8))
    c = eng.submit(p, params=SamplingParams(temperature=1.5, seed=4,
                                            max_new=8))
    eng.run()
    assert a.tokens == b.tokens
    assert a.tokens != c.tokens


def test_unseeded_requests_get_deterministic_derived_seeds():
    """seed=None derives from (engine seed, request id): two runs of
    the same trace agree; distinct requests differ."""
    cfg, params = _setup()
    p = _prompts(1)[0]
    sp = SamplingParams(temperature=1.2, max_new=8)      # no seed
    runs = []
    for _ in range(2):
        eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64,
                                               seed=9))
        a = eng.submit(p, params=sp)
        b = eng.submit(p, params=sp)
        eng.run()
        runs.append((list(a.tokens), list(b.tokens)))
    assert runs[0] == runs[1]
    assert runs[0][0] != runs[0][1]


# ---------------------------------------------------------------------------
# Streaming front-end
# ---------------------------------------------------------------------------

def test_stream_cb_delivers_every_token_once_in_order():
    cfg, params = _setup()
    prompts = _prompts(3)
    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64,
                                           sched_quantum=3))
    seen: dict[int, list] = {}
    finished_at_last: dict[int, bool] = {}

    def cb(req, toks):
        assert len(toks) >= 1
        seen.setdefault(req.req_id, []).extend(toks)
        finished_at_last[req.req_id] = req.finished

    reqs = [eng.submit(p, params=sp, max_new=7, stream_cb=cb)
            for p, sp in zip(prompts, MIXED)]
    eng.run()
    for r in reqs:
        assert seen[r.req_id] == r.tokens, \
            "stream deliveries diverged from the final token list"
        assert finished_at_last[r.req_id], \
            "final delivery did not see req.finished"


def test_stream_cb_first_token_delivered_at_admit():
    cfg, params = _setup()
    first: dict[int, int] = {}

    def cb(req, toks):
        first.setdefault(req.req_id, toks[0])

    eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    r = eng.submit(_prompts(1)[0], max_new=5, stream_cb=cb)
    eng.run()
    assert first[r.req_id] == r.tokens[0]


# ---------------------------------------------------------------------------
# Priority-aware admission
# ---------------------------------------------------------------------------

def test_priority_admits_before_earlier_fifo_submissions():
    cfg, params = _setup()
    prompts = _prompts(3)
    eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    lo1 = eng.submit(prompts[0], max_new=3)
    lo2 = eng.submit(prompts[1], max_new=3)
    hi = eng.submit(prompts[2], max_new=3, priority=5)
    done = eng.run()
    order = [r.req_id for r in done]
    # all three were ready at run(): the high-priority request admits
    # first, then FIFO among the equal-priority rest
    assert order == [hi.req_id, lo1.req_id, lo2.req_id]


def test_arrival_trace_inserts_sorted_out_of_order():
    """bisect.insort keeps the pending list arrival-sorted however the
    trace is submitted; replay completes every request."""
    cfg, params = _setup()
    prompts = _prompts(4)
    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64))
    arrivals = [0.03, 0.0, 0.02, 0.01]
    reqs = [eng.submit(p, max_new=3, arrival=a)
            for p, a in zip(prompts, arrivals)]
    assert [r.arrival for r in eng._pending] == sorted(arrivals)
    eng.run()
    assert all(r.finished for r in reqs)


# ---------------------------------------------------------------------------
# Stop token ids
# ---------------------------------------------------------------------------

def test_stop_ids_any_of_set_stops_stream():
    cfg, params = _setup()
    p = _prompts(1)[0]
    ref_eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    ref = ref_eng.submit(p, max_new=10)
    ref_eng.run()
    stop = (ref.tokens[4], ref.tokens[2])     # second one fires first
    eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    r = eng.submit(p, params=SamplingParams(stop=stop, max_new=10))
    eng.run()
    assert r.tokens == ref.tokens[:3] and r.tokens[-1] == stop[1]


def test_eos_id_composes_with_params_stop():
    cfg, params = _setup()
    p = _prompts(1)[0]
    ref_eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    ref = ref_eng.submit(p, max_new=10)
    ref_eng.run()
    eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    r = eng.submit(p, params=SamplingParams(stop=(ref.tokens[5],),
                                            max_new=10),
                   eos_id=ref.tokens[1])
    eng.run()
    assert r.tokens == ref.tokens[:2]         # eos_id fired first


# ---------------------------------------------------------------------------
# Multi-token stop sequences: suffix-window matching + overshoot trim
# ---------------------------------------------------------------------------

def _first_window_match(stream, seq):
    """Index of the token that completes the first suffix-window match
    of ``seq`` in ``stream``, or None."""
    n = len(seq)
    for j in range(n - 1, len(stream)):
        if tuple(stream[j - n + 1:j + 1]) == tuple(seq):
            return j
    return None


def test_stop_seqs_suffix_window_stops_and_trims_overshoot():
    """A 2-token stop sequence ends the stream at the token completing
    the match, with burst overshoot past the match trimmed — and the
    result is burst-boundary independent (sched_quantum=1 forces the
    match to complete on its own burst)."""
    cfg, params = _setup()
    p = _prompts(1)[0]
    ref_eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    ref = ref_eng.submit(p, max_new=12)
    ref_eng.run()
    seq = (ref.tokens[3], ref.tokens[4])
    j = _first_window_match(ref.tokens, seq)   # may fire before idx 4
    for quantum in (8, 1):
        eng = Engine(cfg, params,
                     EngineConfig(n_slots=1, max_seq=64,
                                  sched_quantum=quantum))
        r = eng.submit(p, params=SamplingParams(stop_seqs=(seq,),
                                                max_new=12))
        eng.run()
        assert r.tokens == ref.tokens[:j + 1], quantum
        assert tuple(r.tokens[-2:]) == seq


def test_stop_seqs_no_false_positive_and_any_of_set():
    """A sequence that never occurs leaves the stream bitwise the
    no-stop reference; with several sequences the earliest match wins
    (any-of semantics, like stop ids)."""
    cfg, params = _setup()
    p = _prompts(1)[0]
    ref_eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    ref = ref_eng.submit(p, max_new=10)
    ref_eng.run()
    # a 3-token window with a perturbed last token cannot complete
    miss = (ref.tokens[2], ref.tokens[3], (ref.tokens[4] + 1) % cfg.vocab)
    assert _first_window_match(ref.tokens, miss) is None
    eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    r = eng.submit(p, params=SamplingParams(stop_seqs=(miss,),
                                            max_new=10))
    eng.run()
    assert r.tokens == ref.tokens
    # any-of: the later-submitted pair fires before the longer window
    pair = (ref.tokens[1], ref.tokens[2])
    late = (ref.tokens[5], ref.tokens[6], ref.tokens[7])
    j = _first_window_match(ref.tokens, pair)
    eng2 = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    r2 = eng2.submit(p, params=SamplingParams(stop_seqs=(late, pair),
                                              max_new=10))
    eng2.run()
    assert r2.tokens == ref.tokens[:j + 1]


def test_stop_seqs_single_token_matches_stop_ids_behavior():
    cfg, params = _setup()
    p = _prompts(1)[0]
    ref_eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    ref = ref_eng.submit(p, max_new=10)
    ref_eng.run()
    a = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    ra = a.submit(p, params=SamplingParams(stop=(ref.tokens[4],),
                                           max_new=10))
    a.run()
    b = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    rb = b.submit(p, params=SamplingParams(stop_seqs=((ref.tokens[4],),),
                                           max_new=10))
    b.run()
    assert rb.tokens == ra.tokens


def test_sampling_params_validation_pr6_fields():
    with pytest.raises(ValueError):
        SamplingParams(n=0).validate()
    with pytest.raises(ValueError):
        SamplingParams(stop_seqs=((),)).validate()
    with pytest.raises(ValueError):
        SamplingParams(top_logprobs=-1).validate()
    with pytest.raises(ValueError):
        SamplingParams(top_logprobs=sampling.TOP_LOGPROBS + 1).validate()
    # the valid envelope passes
    SamplingParams(n=2, stop_seqs=((1, 2), (3,)), logprobs=True,
                   top_logprobs=sampling.TOP_LOGPROBS).validate()


# ---------------------------------------------------------------------------
# Burst scheduling treats stop_seqs and pending cache snapshots as
# uncertain events (quantum-capped bursts)
# ---------------------------------------------------------------------------

def test_burst_len_uncertain_on_stop_seqs_and_prefix_pending():
    """Scheduler-policy unit: a slot with no uncertain event bursts
    uncapped to its remaining budget; stop_seqs or a pending prefix-
    cache snapshot offload cap the burst at sched_quantum."""
    from repro.runtime.prefix_cache import PrefixCacheConfig

    def bind(eng, req):
        # place the request in slot 0 and drain the ready queue so
        # may_admit doesn't cap the burst for an unrelated reason
        eng._slot_req[0] = req
        eng._ready.clear()

    cfg, params = _setup()
    p = _prompts(1)[0]
    base = EngineConfig(n_slots=1, max_seq=64, sched_quantum=4)
    eng = Engine(cfg, params, base)
    bind(eng, eng.submit(p, params=SamplingParams(max_new=20)))
    assert eng._burst_len([0]) == 20          # certain: run to budget
    eng2 = Engine(cfg, params, base)
    bind(eng2, eng2.submit(p, params=SamplingParams(
        max_new=20, stop_seqs=((1, 2),))))
    assert eng2._burst_len([0]) == 4          # stop_seqs -> uncertain
    pcfg = dataclasses.replace(base, prefix_cache=PrefixCacheConfig(
        block=4, store="host"))
    eng3 = Engine(cfg, params, pcfg)
    bind(eng3, eng3.submit(p, params=SamplingParams(max_new=20)))
    assert eng3._burst_len([0]) == 20         # nothing pending yet
    eng3._prefix.insert(np.arange(4, dtype=np.int32),
                        {"h": jnp.zeros((1, 2), jnp.float32)})
    assert eng3._prefix.has_pending()
    assert eng3._burst_len([0]) == 4          # snapshot deadline
    eng3._prefix.flush_pending(limit=None)
    assert eng3._burst_len([0]) == 20         # drained -> certain again


# ---------------------------------------------------------------------------
# Logprob surfaces: greedy engine logprobs == a direct forward pass
# ---------------------------------------------------------------------------

def test_greedy_logprobs_match_direct_forward_pass():
    """Request.logprobs / top_logprobs for a greedy stream must equal
    log_softmax of the raw f32 logits from chaining registry.prefill +
    decode_step directly — the engine's surface is the model's math,
    not a rescaled or filtered variant."""
    cfg, params = _setup()
    p = _prompts(1)[0]
    k = 3
    eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    r = eng.submit(p, params=SamplingParams(logprobs=True,
                                            top_logprobs=k, max_new=6))
    eng.run()
    assert len(r.tokens) == 6
    assert len(r.logprobs) == 6
    assert len(r.top_logprobs) == 6
    assert all(len(row) == k for row in r.top_logprobs)
    cache = sharding.tree_values(registry.init_cache(cfg, 1, max_seq=64))
    logits, cache = registry.prefill(cfg, params, cache,
                                     {"tokens": jnp.asarray(p[None])})
    last = logits[0, -1].astype(jnp.float32)
    for t, tok in enumerate(r.tokens):
        lp = jax.nn.log_softmax(last)
        assert tok == int(jnp.argmax(last))
        assert np.isclose(r.logprobs[t], float(lp[tok]), atol=1e-5), t
        tv, ti = jax.lax.top_k(lp, k)
        assert [i for i, _ in r.top_logprobs[t]] == [int(x) for x in ti]
        assert np.allclose([v for _, v in r.top_logprobs[t]],
                           np.asarray(tv), atol=1e-5), t
        logits, cache = registry.decode_step(
            cfg, params, cache,
            {"tokens": jnp.asarray([[tok]], jnp.int32)})
        last = logits[0, -1].astype(jnp.float32)
    assert np.isclose(r.cum_logprob, sum(r.logprobs), atol=1e-4)
    # lists stay empty unless asked; cum_logprob still accumulates
    eng2 = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    r2 = eng2.submit(p, max_new=6)
    eng2.run()
    assert r2.logprobs == [] and r2.top_logprobs == []
    assert np.isclose(r2.cum_logprob, r.cum_logprob, atol=1e-4)
