"""Substrate tests: data determinism, optimizer (incl. 8-bit moments, EF
compression), checkpoint roundtrip/async/keep-k, schedules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # degrade to the deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM, ShardedLoader
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_schedule, compression,
                         quantized_state as qs)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

class TestData:
    def test_deterministic_and_resumable(self):
        ds = SyntheticLM(vocab=64, seq_len=16, seed=3)
        a = ds.batch_at(step=7, shard=0, num_shards=2, batch_per_shard=4)
        b = ds.batch_at(step=7, shard=0, num_shards=2, batch_per_shard=4)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_shards_disjoint(self):
        ds = SyntheticLM(vocab=64, seq_len=16, seed=3)
        a = ds.batch_at(5, 0, 2, 4)
        b = ds.batch_at(5, 1, 2, 4)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_labels_shift(self):
        ds = SyntheticLM(vocab=64, seq_len=16, seed=0)
        batch = ds.batch_at(0, 0, 1, 2)
        assert batch["tokens"].shape == (2, 16)
        assert batch["labels"].shape == (2, 16)

    def test_loader_resume_matches(self):
        ds = SyntheticLM(vocab=32, seq_len=8, seed=1)
        l1 = ShardedLoader(ds, global_batch=4, start_step=0)
        batches = [next(l1) for _ in range(5)]
        l2 = ShardedLoader(ds, global_batch=4, start_step=3)
        np.testing.assert_array_equal(next(l2)["tokens"],
                                      batches[3]["tokens"])

    def test_structure_learnable(self):
        """Order-2 rule: the same (prev2, prev) context repeats its next
        token >50% of the time (vs 1/V for noise)."""
        ds = SyntheticLM(vocab=32, seq_len=64, seed=0, noise=0.1)
        b = ds.batch_at(0, 0, 1, 64)["tokens"]
        ctx = {}
        hits = total = 0
        for row in b:
            for t in range(2, len(row)):
                key = (row[t - 2], row[t - 1])
                if key in ctx:
                    total += 1
                    hits += ctx[key] == row[t]
                ctx[key] = row[t]
        assert total > 50 and hits / total > 0.5


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def _quadratic_problem():
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 8), jnp.float32)}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    return params, loss, target


class TestAdamW:
    @pytest.mark.parametrize("moment_dtype", ["float32", "bfloat16", "int8"])
    def test_converges_quadratic(self, moment_dtype):
        params, loss, target = _quadratic_problem()
        cfg = AdamWConfig(lr=0.05, weight_decay=0.0,
                          moment_dtype=moment_dtype)
        state = adamw_init(params, cfg)
        for _ in range(300):
            g = jax.grad(loss)(params)
            params, state, _ = adamw_update(g, state, params, cfg)
        assert float(loss(params)) < 0.05

    def test_int8_moments_memory(self):
        params = {"w": jnp.zeros((1024, 256), jnp.float32)}
        cfg = AdamWConfig(moment_dtype="int8")
        state = adamw_init(params, cfg)
        q = state.mu["w"]
        assert qs.is_qtensor(q)
        bytes_q = q.q.size + q.scale.size * 4
        assert bytes_q < 1024 * 256 * 4 / 3         # >3x smaller than f32

    def test_clip_global_norm(self):
        g = {"a": jnp.full((10,), 100.0)}
        clipped, gn = clip_by_global_norm(g, 1.0)
        total = jnp.sqrt(sum(jnp.sum(x ** 2)
                             for x in jax.tree.leaves(clipped)))
        assert abs(float(total) - 1.0) < 1e-5

    def test_schedule_shape(self):
        s0 = float(cosine_schedule(0, 10, 100))
        s10 = float(cosine_schedule(10, 10, 100))
        s100 = float(cosine_schedule(100, 10, 100))
        assert s0 == 0.0 and abs(s10 - 1.0) < 1e-6 and s100 <= 0.11


class TestQuantization:
    @given(st.integers(1, 4), st.integers(1, 600))
    @settings(max_examples=30, deadline=None)
    def test_roundtrip_error_bounded(self, r, c):
        x = jnp.asarray(np.random.default_rng(r * 1000 + c).normal(
            size=(r, c)).astype(np.float32))
        y = qs.dequantize(qs.quantize(x))
        blk_max = float(jnp.max(jnp.abs(x)))
        assert float(jnp.max(jnp.abs(y - x))) <= blk_max / 127 + 1e-6
        assert y.shape == x.shape


class TestCompression:
    def test_error_feedback_unbiased_over_time(self):
        """EF compensates quantization: sum of g_hat ~ sum of g."""
        rng = np.random.default_rng(0)
        err = jnp.zeros((64,), jnp.float32)
        total_g = np.zeros(64)
        total_hat = np.zeros(64)
        for _ in range(200):
            g = jnp.asarray(rng.normal(size=64).astype(np.float32))
            g_hat, err = compression.ef_compress_decompress(g, err)
            total_g += np.asarray(g)
            total_hat += np.asarray(g_hat)
        assert np.max(np.abs(total_g - total_hat)) < 0.2

    def test_ef_training_parity(self):
        """Quadratic convergence with EF-compressed grads ~= exact."""
        params, loss, _ = _quadratic_problem()
        cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
        state = adamw_init(params, cfg)
        err = compression.ef_init(params)
        for _ in range(300):
            g = jax.grad(loss)(params)
            g, err = compression.ef_apply(g, err)
            params, state, _ = adamw_update(g, state, params, cfg)
        assert float(loss(params)) < 0.1


# ---------------------------------------------------------------------------
# Checkpoint manager
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def _tree(self):
        return {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                           "b": jnp.ones((4,), jnp.bfloat16)},
                "step": jnp.int32(5)}

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = self._tree()
        mgr.save(10, tree, blocking=True)
        got, step = mgr.restore(tree)
        assert step == 10
        np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                      np.asarray(tree["params"]["w"]))
        assert got["params"]["b"].dtype == jnp.bfloat16

    def test_async_and_keep_k(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = self._tree()
        for s in [1, 2, 3, 4]:
            mgr.save(s, tree)
        mgr.wait()
        assert mgr.all_steps() == [3, 4]

    def test_latest_restore(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        tree = self._tree()
        mgr.save(1, tree, blocking=True)
        tree2 = jax.tree.map(lambda x: x + 1, tree)
        mgr.save(7, tree2, blocking=True)
        got, step = mgr.restore(tree)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(got["step"]), 6)

    def test_no_partial_checkpoints_visible(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        os.makedirs(tmp_path / "tmp.99")          # simulated torn write
        assert mgr.all_steps() == []


class TestPrefetcher:
    def test_prefetch_preserves_order_and_terminates(self):
        from repro.data import Prefetcher
        items = list(range(20))
        out = list(Prefetcher(iter(items), depth=3))
        assert out == items

    def test_make_train_iterator_end_to_end(self):
        from repro import configs
        from repro.data import make_train_iterator
        cfg = configs.smoke_variant(configs.get_config("mamba-130m"))
        it = make_train_iterator(cfg, global_batch=4, seq_len=16,
                                 start_step=5, prefetch=2)
        b = next(it)
        assert b["tokens"].shape == (4, 16)
        assert (b["tokens"] < cfg.vocab).all()


class TestCheckpointEdge:
    def test_restore_specific_step(self, tmp_path):
        import jax.numpy as jnp
        from repro.checkpoint import CheckpointManager
        mgr = CheckpointManager(str(tmp_path), keep=5)
        tree = {"x": jnp.ones((3,))}
        for s in [1, 2, 3]:
            mgr.save(s, jax.tree.map(lambda v: v * s, tree), blocking=True)
        got, step = mgr.restore(tree, step=2)
        assert step == 2
        np.testing.assert_array_equal(np.asarray(got["x"]), 2 * np.ones(3))

    def test_restore_missing_raises(self, tmp_path):
        from repro.checkpoint import CheckpointManager
        mgr = CheckpointManager(str(tmp_path))
        with pytest.raises(FileNotFoundError):
            mgr.restore({"x": jnp.ones((1,))})

    def test_dtype_cast_on_restore(self, tmp_path):
        from repro.checkpoint import CheckpointManager
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"x": jnp.ones((4,), jnp.float32)}, blocking=True)
        got, _ = mgr.restore({"x": jnp.ones((4,), jnp.bfloat16)})
        assert got["x"].dtype == jnp.bfloat16
