"""Tensor-parallel sharded serving (8 fake host devices via subprocess,
like tests/test_distributed.py; shared run8 helper in _multidevice.py).

The contract under test: an Engine given ``EngineConfig.mesh`` (a 1-D
"model" mesh from launch/mesh.make_serving_mesh) serves token streams
IDENTICAL to the single-device engine — greedy, across model families,
state dtypes, spec decode on/off, fused/megakernel step impls, and the
prefix cache — while the pool's cache leaves live sharded on the mesh
and every step's output sharding equals its input sharding (no per-step
resharding).  Streaming callbacks and cancellation reclaim sharded
slots/leases/params rows exactly like the single-device pool.

Error paths (mesh construction on too few devices) run in the main
process, which is deliberately single-device.
"""
import pytest

from _multidevice import run8


# Shared subprocess preamble: smoke-size model + tiny trace server.
# serve() returns per-request token lists; identity asserts are exact
# (== on int lists), matching the repo's bitwise-stream precedents.
_PRELUDE = """
    import dataclasses
    import numpy as np
    import jax
    from repro import configs
    from repro.models import registry
    from repro.parallel import sharding
    from repro.launch import mesh as mesh_lib
    from repro.runtime.engine import Engine, EngineConfig
    from repro.runtime.sampling import SamplingParams
    from repro.runtime.spec_decode import DraftConfig

    def make_model(arch):
        cfg = configs.smoke_variant(configs.get_config(arch))
        cfg = dataclasses.replace(cfg, vocab=256, dtype='float32')
        params = sharding.tree_values(
            registry.init_params(cfg, jax.random.key(0)))
        return cfg, params

    def make_prompts(n=4, seed=0):
        rng = np.random.default_rng(seed)
        return [rng.integers(1, 256, size=int(L)).tolist()
                for L in rng.choice((6, 8, 12, 16), size=n)]

    def serve(cfg, params, mesh, prompts, max_new=8, **kw):
        eng = Engine(cfg, params,
                     EngineConfig(n_slots=2, max_seq=64, mesh=mesh, **kw))
        reqs = [eng.submit(p, SamplingParams(max_new=max_new))
                for p in prompts]
        eng.run()
        return eng, [r.tokens for r in reqs]
"""


def _identity_body(arch):
    return _PRELUDE + f"""
    cfg, params = make_model('{arch}')
    prompts = make_prompts()
    mesh = mesh_lib.make_serving_mesh(2)
    for sd in (None, 'int8'):
        for draft in (None, DraftConfig(k=2, layers=0)):
            _, single = serve(cfg, params, None, prompts,
                              state_dtype=sd, draft=draft)
            eng, shardd = serve(cfg, params, mesh, prompts,
                                state_dtype=sd, draft=draft)
            tag = f'{arch} sd={{sd}} spec={{draft is not None}}'
            assert single == shardd, (tag, single, shardd)
            # the pool really is sharded: at least one cache leaf has a
            # non-replicated placement on the serving mesh
            shs = [leaf.sharding for leaf in
                   jax.tree.leaves(eng.pool.cache)]
            assert any(not s.is_fully_replicated for s in shs), tag
            print('ok', tag)
    """


@pytest.mark.parametrize("arch", ["mamba-130m", "jamba-v0.1-52b",
                                  "xlstm-350m"])
def test_sharded_greedy_token_identity(arch):
    """Sharded tp=2 greedy streams == single-device streams for the
    family, across {f32, int8} state x spec decode on/off."""
    run8(_identity_body(arch), timeout=1200)


def test_sharded_step_impls_and_tp4_token_identity():
    """Fused + megakernel step routing under the mesh (the Pallas
    interpreter lowers to partitionable XLA ops on CPU), and a tp=4
    spot-check that wider meshes keep identity too."""
    run8(_PRELUDE + """
    cfg, params = make_model('mamba-130m')
    prompts = make_prompts(n=2)
    mesh = mesh_lib.make_serving_mesh(2)
    for impl in ('fused', 'megakernel'):
        _, single = serve(cfg, params, None, prompts, step_impl=impl)
        _, shardd = serve(cfg, params, mesh, prompts, step_impl=impl)
        assert single == shardd, (impl, single, shardd)
        print('ok', impl)
    _, single = serve(cfg, params, None, prompts)
    _, shardd = serve(cfg, params, mesh_lib.make_serving_mesh(4), prompts)
    assert single == shardd, ('tp4', single, shardd)
    print('ok tp4')
    """, timeout=1200)


def test_sharded_prefix_cache_token_identity():
    """Prefix-cache snapshot/restore on sharded state: hits restore a
    sharded snapshot through the suffix micro-scan and streams stay
    identical to both the cold sharded serve and the single-device
    engine."""
    run8(_PRELUDE + """
    from repro.runtime.prefix_cache import PrefixCacheConfig
    cfg, params = make_model('mamba-130m')
    base = list(range(1, 13))
    prompts = [base + [20 + i] for i in range(4)]   # shared 12-tok prefix
    pc = PrefixCacheConfig(block=4)
    _, single = serve(cfg, params, None, prompts, prefix_cache=pc)
    mesh = mesh_lib.make_serving_mesh(2)
    eng, shardd = serve(cfg, params, mesh, prompts, prefix_cache=pc)
    assert single == shardd, (single, shardd)
    assert eng.stats.summary()['prefix_hits'] >= 1
    _, cold = serve(cfg, params, mesh, prompts)
    assert cold == shardd, (cold, shardd)
    print('ok prefix', eng.stats.summary()['prefix_hits'])
    """, timeout=1200)


def test_sharded_streaming_and_cancel_reclaims():
    """Streaming callbacks and Engine.cancel under a sharded pool: a
    mid-stream cancel (from its own stream_cb, during a spec pass so a
    scratch lease is live) reclaims the slot, the scratch lease, and
    the params row; the surviving request's stream is bitwise the
    no-cancel sharded serve's."""
    run8(_PRELUDE + """
    cfg, params = make_model('mamba-130m')
    prompts = make_prompts(n=2, seed=3)
    mesh = mesh_lib.make_serving_mesh(2)
    draft = DraftConfig(k=2, layers=0)

    # reference: no cancellation
    _, ref = serve(cfg, params, mesh, prompts, max_new=10, draft=draft)

    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64,
                                           mesh=mesh, draft=draft))
    got = {}
    def cb(req, new_toks):
        got.setdefault(req.req_id, []).extend(new_toks)
        if req.req_id == victim.req_id and len(req.tokens) >= 3:
            eng.cancel(req.req_id)
    victim = eng.submit(prompts[0], SamplingParams(max_new=10),
                        stream_cb=cb)
    keeper = eng.submit(prompts[1], SamplingParams(max_new=10),
                        stream_cb=cb)
    eng.run()
    # survivor bitwise untouched by the co-resident cancellation
    assert keeper.tokens == ref[1], (keeper.tokens, ref[1])
    assert got[keeper.req_id] == keeper.tokens
    # victim stopped early; delivered tokens stand and match the
    # reference prefix (cancel never rewrites history)
    assert victim.cancelled and len(victim.tokens) < 10
    assert ref[0][:len(victim.tokens)] == victim.tokens
    # full reclamation of sharded resources: slots, scratch leases,
    # params rows (evict's clear() zeroes key_data; set() made it
    # non-zero).  Scratch rows are exempt by design: release_scratch
    # never resets — the next spec fork overwrites every leaf.
    assert eng.pool.n_active == 0 and eng.pool.n_free == 2
    assert len(eng.pool._scratch_free) == 2
    assert not eng.pool.params.key_data[:eng.pool.n_slots].any()
    print('ok cancel', victim.tokens, keeper.tokens)
    """, timeout=1200)


def test_sharded_decode_no_per_step_resharding():
    """The compiled pooled decode step consumes and produces the cache
    at the SAME shardings (chained bursts never reshard), and its
    per-step collective counts are pinned deterministic and small."""
    run8(_PRELUDE + """
    import jax.numpy as jnp
    from repro.launch import hlo_cost
    cfg, params = make_model('mamba-130m')
    mesh = mesh_lib.make_serving_mesh(2)
    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64,
                                           mesh=mesh))
    toks = jnp.asarray(eng._next_tok)
    act = jnp.asarray(eng.pool.active_mask())
    sp = eng.pool.params.device()
    step = jnp.zeros((eng.pool.n_total,), jnp.int32)
    comp = eng._decode.lower(eng.params, eng.pool.cache, toks, act, sp,
                             step).compile()
    cache_in = jax.tree.leaves(comp.input_shardings[0][1])
    cache_out = jax.tree.leaves(comp.output_shardings[4])
    leaves = jax.tree.leaves(eng.pool.cache)
    assert len(cache_in) == len(cache_out) == len(leaves) > 0
    n_sharded = 0
    for i, (a, b, x) in enumerate(zip(cache_in, cache_out, leaves)):
        # equivalence, not ==: GSPMD may drop trailing replicated axes
        # from a spec (P(None, 'model', None) vs P(None, 'model')) —
        # the placement is identical
        assert a.is_equivalent_to(b, x.ndim), (i, a, b)
        n_sharded += not a.is_fully_replicated
    assert n_sharded >= 1
    c = hlo_cost.analyze(comp.as_text())
    n_ar = c.coll_count.get('all-reduce', 0)
    # >= 1 all-reduce per layer (the TP contraction joins), bounded by
    # a small per-layer constant — a blowup here means GSPMD stopped
    # partitioning the step
    assert cfg.n_layers <= n_ar <= 16 * cfg.n_layers, dict(c.coll_count)
    print('ok no-reshard', n_sharded, dict(c.coll_count))
    """, timeout=1200)


def test_sharded_pool_device_capacity():
    """Sharded pool capacity accounting: per-device slot bytes shrink by
    ~the TP degree for sharded leaves, so device_slots_per_gb grows."""
    run8(_PRELUDE + """
    from repro.runtime.state_pool import SlotStatePool
    cfg, _ = make_model('mamba-130m')
    mesh = mesh_lib.make_serving_mesh(2)
    single = SlotStatePool(cfg, 2, 64)
    shardd = SlotStatePool(cfg, 2, 64, mesh=mesh)
    assert shardd.state_bytes_per_slot() == single.state_bytes_per_slot()
    assert (shardd.device_state_bytes_per_slot()
            < single.device_state_bytes_per_slot())
    assert shardd.device_slots_per_gb() > single.device_slots_per_gb()
    print('ok capacity', single.device_state_bytes_per_slot(),
          shardd.device_state_bytes_per_slot())
    """)


def test_serving_mesh_error_paths():
    """make_serving_mesh on too few devices: a clear RuntimeError naming
    the requested and available counts plus the XLA_FLAGS escape hatch
    (main pytest process is deliberately single-device)."""
    import jax

    from repro.launch.mesh import make_serving_mesh

    n = jax.device_count()
    with pytest.raises(RuntimeError) as ei:
        make_serving_mesh(n + 1)
    msg = str(ei.value)
    assert str(n + 1) in msg and str(n) in msg
    assert "xla_force_host_platform_device_count" in msg
    with pytest.raises(ValueError):
        make_serving_mesh(0)
