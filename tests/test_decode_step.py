"""Fused single-token decode step: kernel parity vs the ref.py oracle
(pooled-slot shapes, fp32/bf16, approx impls), per-family fused-vs-xla
decode routing parity, masked-slot hygiene under the fused step, and
engine-level fused == unfused token-for-token."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import selective_scan as css
from repro.kernels import decode_step as dsk
from repro.kernels import ops, ref
from repro.models import registry
from repro.parallel import sharding
from repro.runtime.engine import Engine, EngineConfig

jax.config.update("jax_platform_name", "cpu")
RNG = np.random.default_rng(7)


def _step_inputs(b, d, n, dtype=jnp.float32, with_d=True, with_z=True):
    """Pooled-slot decode inputs: b is the slot-pool batch, h is the f32
    slot state, token tensors are in the model compute dtype."""
    h = jnp.asarray(RNG.normal(size=(b, d, n)).astype(np.float32))
    x = jnp.asarray(RNG.normal(size=(b, d)).astype(np.float32)).astype(dtype)
    dt = jax.nn.softplus(jnp.asarray(
        RNG.normal(size=(b, d)).astype(np.float32))).astype(dtype)
    A = -jnp.exp(jnp.asarray(RNG.normal(size=(d, n)).astype(np.float32))
                 * 0.5)
    B = jnp.asarray(RNG.normal(size=(b, n)).astype(np.float32)).astype(dtype)
    C = jnp.asarray(RNG.normal(size=(b, n)).astype(np.float32)).astype(dtype)
    D = jnp.asarray(RNG.normal(size=(d,)).astype(np.float32)) if with_d \
        else None
    z = (jnp.asarray(RNG.normal(size=(b, d)).astype(np.float32))
         .astype(dtype) if with_z else None)
    return h, x, dt, A, B, C, D, z


# ---------------------------------------------------------------------------
# Kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,d,n", [(1, 8, 4), (4, 64, 16), (3, 130, 16),
                                   (2, 256, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_step_matches_ref(b, d, n, dtype):
    h, x, dt, A, B, C, D, z = _step_inputs(b, d, n, dtype)
    y0, h0 = ref.selective_state_step(h, x, dt, A, B, C, D=D, z_t=z)
    y1, h1 = dsk.selective_state_step(h, x, dt, A, B, C, D=D, z_t=z,
                                      block_d=64)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y0, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("with_d,with_z", [(False, False), (True, False),
                                           (False, True)])
def test_fused_step_optional_terms(with_d, with_z):
    h, x, dt, A, B, C, D, z = _step_inputs(2, 48, 8, with_d=with_d,
                                           with_z=with_z)
    y0, h0 = ref.selective_state_step(h, x, dt, A, B, C, D=D, z_t=z)
    y1, h1 = dsk.selective_state_step(h, x, dt, A, B, C, D=D, z_t=z)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("exp_impl,silu_impl", [("ours", "ours"),
                                                ("fast", "paper")])
def test_fused_step_approx_nonlinearities(exp_impl, silu_impl):
    """The MARCA approximations (biased exp, piecewise SiLU) run *inside*
    the kernel and must match the oracle running the same approximations."""
    h, x, dt, A, B, C, D, z = _step_inputs(3, 64, 16)
    y0, h0 = ref.selective_state_step(h, x, dt, A, B, C, D=D, z_t=z,
                                      exp_impl=exp_impl,
                                      silu_impl=silu_impl)
    y1, h1 = dsk.selective_state_step(h, x, dt, A, B, C, D=D, z_t=z,
                                      exp_impl=exp_impl,
                                      silu_impl=silu_impl)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0),
                               rtol=1e-5, atol=1e-5)


def test_fused_step_equals_one_scan_step():
    """A decode step IS the L=1 scan: the fused step must agree with the
    sequential scan reference driven one token forward."""
    h, x, dt, A, B, C, D, z = _step_inputs(2, 32, 8)
    y_scan, h_scan = ref.selective_scan(
        x[:, None], dt[:, None], A, B[:, None], C[:, None],
        D=D, z=z[:, None], h0=h)
    y1, h1 = dsk.selective_state_step(h, x, dt, A, B, C, D=D, z_t=z)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_scan[:, 0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h_scan),
                               rtol=1e-5, atol=1e-5)


def test_ops_dispatch_and_resolution(monkeypatch):
    h, x, dt, A, B, C, D, z = _step_inputs(2, 16, 4)
    y0, _ = ops.selective_state_step(h, x, dt, A, B, C, D=D, z_t=z,
                                     impl="xla")
    y1, _ = ops.selective_state_step(h, x, dt, A, B, C, D=D, z_t=z,
                                     impl="fused")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=1e-5, atol=1e-5)
    monkeypatch.delenv("REPRO_STEP_IMPL", raising=False)
    assert css.resolve_step_impl("fused") == "fused"
    assert css.resolve_step_impl("pallas") == "fused"
    assert css.resolve_step_impl("xla") == "xla"
    assert css.resolve_step_impl("megakernel") == "megakernel"
    on_tpu = jax.default_backend() == "tpu"
    # auto resolves per backend: the cross-layer megakernel where Pallas
    # lowers natively (TPU), else the family's cheapest correct path
    assert css.resolve_step_impl("auto", needs_pallas=False) == (
        "megakernel" if on_tpu else "fused")
    assert css.resolve_step_impl("auto") == (
        "megakernel" if on_tpu else "xla")
    # REPRO_STEP_IMPL steers "auto" only — explicit configs always win
    monkeypatch.setenv("REPRO_STEP_IMPL", "megakernel")
    assert css.resolve_step_impl("auto") == "megakernel"
    assert css.resolve_step_impl("fused") == "fused"
    monkeypatch.delenv("REPRO_STEP_IMPL")
    # per-layer cell call sites (block verify, drafts) never see
    # "megakernel": the cell resolver folds it back to fused
    assert css.resolve_cell_impl("megakernel") == "fused"
    assert css.resolve_cell_impl("xla") == "xla"
    with pytest.raises(KeyError):
        css.resolve_step_impl("nope")


# ---------------------------------------------------------------------------
# Per-family routing parity: fused decode == unfused decode
# ---------------------------------------------------------------------------

FAMILY_ARCHS = ["mamba-130m", "jamba-v0.1-52b", "xlstm-350m"]


def _setup(name, dtype="float32"):
    cfg = configs.smoke_variant(configs.get_config(name))
    cfg = dataclasses.replace(cfg, vocab=64, dtype=dtype,
                              capacity_factor=float(max(cfg.n_experts, 1)))
    params = sharding.tree_values(
        registry.init_params(cfg, jax.random.key(0)))
    return cfg, params


@pytest.mark.parametrize("name", FAMILY_ARCHS)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_family_fused_decode_matches_xla(name, dtype):
    """prefill once, then decode N tokens through both step routings over
    pooled-slot shapes; logits and caches must agree."""
    cfg, params = _setup(name, dtype)
    b, lp, n_steps = 3, 4, 4
    toks = jax.random.randint(jax.random.key(5), (b, lp + n_steps), 0,
                              cfg.vocab, dtype=jnp.int32)
    cache0 = sharding.tree_values(registry.init_cache(cfg, b, max_seq=16))
    _, cache = registry.prefill(cfg, params, cache0,
                                {"tokens": toks[:, :lp]})
    cfg_f = dataclasses.replace(cfg, step_impl="fused")
    cfg_x = dataclasses.replace(cfg, step_impl="xla")
    cache_f = cache_x = cache
    tol = 3e-2 if dtype == "bfloat16" else 2e-4
    for t in range(n_steps):
        tok = {"tokens": toks[:, lp + t:lp + t + 1]}
        lf, cache_f = registry.decode_step(cfg_f, params, cache_f, tok)
        lx, cache_x = registry.decode_step(cfg_x, params, cache_x, tok)
        np.testing.assert_allclose(
            np.asarray(lf, np.float32), np.asarray(lx, np.float32),
            rtol=tol, atol=tol, err_msg=f"{name} step {t} logits diverged")
    for pf, px in zip(jax.tree.leaves(cache_f), jax.tree.leaves(cache_x)):
        np.testing.assert_allclose(np.asarray(pf, np.float32),
                                   np.asarray(px, np.float32),
                                   rtol=tol, atol=tol)


def test_fused_pooled_decode_freezes_masked_slots():
    """Pooled fused decode + mask_slots: inactive slots must stay frozen
    bit-exactly while an active slot advances (the engine invariant)."""
    cfg, params = _setup("mamba-130m")
    cfg = dataclasses.replace(cfg, step_impl="fused")
    n_slots = 3
    cache0 = sharding.tree_values(
        registry.init_cache(cfg, n_slots, max_seq=16))
    toks = jax.random.randint(jax.random.key(8), (n_slots, 5), 0, cfg.vocab,
                              dtype=jnp.int32)
    _, cache = registry.prefill(cfg, params, cache0, {"tokens": toks})
    before = jax.tree.map(np.asarray, cache)
    active = jnp.asarray([True, False, True])
    tok = jnp.zeros((n_slots, 1), jnp.int32)
    _, new_cache = registry.decode_step(cfg, params, cache, {"tokens": tok})
    new_cache = registry.mask_slots(cfg, cache, new_cache, active)
    axes = registry.cache_slot_axes(cfg)
    active_changed = []
    for ax, old, new in zip(jax.tree.leaves(axes), jax.tree.leaves(before),
                            jax.tree.leaves(new_cache)):
        old_t = np.moveaxis(old, ax, 0)
        new_t = np.moveaxis(np.asarray(new), ax, 0)
        np.testing.assert_array_equal(new_t[1], old_t[1],
                                      err_msg="masked slot mutated")
        active_changed.append(not np.array_equal(new_t[0], old_t[0]))
    assert any(active_changed), "active slot did not advance"


# ---------------------------------------------------------------------------
# Engine level: fused == unfused, token for token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["mamba-130m", "xlstm-350m"])
def test_engine_fused_matches_unfused_token_for_token(name):
    """The PR 1 engine with the unfused per-op decode and the fused
    single-launch decode must emit identical greedy token streams under
    slot churn (queueing, eviction, reuse)."""
    cfg, params = _setup(name)
    rng = np.random.default_rng(17)
    lens = [3, 6, 4, 7]
    max_news = [5, 3, 6, 4]
    prompts = [rng.integers(0, cfg.vocab, size=(l,)).astype(np.int32)
               for l in lens]
    streams = {}
    for impl in ("xla", "fused"):
        eng = Engine(cfg, params,
                     EngineConfig(n_slots=2, max_seq=64, step_impl=impl))
        reqs = [eng.submit(p, max_new=m)
                for p, m in zip(prompts, max_news)]
        eng.run()
        streams[impl] = [r.tokens for r in reqs]
    assert streams["fused"] == streams["xla"], \
        "fused decode burst diverged from unfused engine"
