"""Sharding-layer unit/property tests: spec_for_shape divisibility fallback,
logical resolution, rules overrides, Param pytree behavior."""
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # degrade to the deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_local_mesh
from repro.parallel import sharding
from repro.parallel.sharding import Param, ShardingRules

jax.config.update("jax_platform_name", "cpu")


def _mesh():
    # single CPU device: mesh (1,1) still exercises the resolution logic
    return make_local_mesh((1, 1), ("data", "model"))


class TestParamPytree:
    def test_axes_are_static_aux(self):
        p = {"w": Param(jnp.zeros((4, 8)), ("embed", "ffn"))}
        stacked = jax.vmap(lambda _: p)(jnp.arange(3))
        assert stacked["w"].value.shape == (3, 4, 8)
        assert stacked["w"].axes == ("embed", "ffn")

    def test_eval_shape_preserves_axes(self):
        def init():
            return {"w": Param(jnp.zeros((4, 8)), ("embed", "ffn"))}
        abs_p = jax.eval_shape(init)
        assert abs_p["w"].axes == ("embed", "ffn")
        assert abs_p["w"].value.shape == (4, 8)

    def test_tree_values_idempotent(self):
        p = {"w": Param(jnp.zeros((2,)), ("ffn",))}
        v1 = sharding.tree_values(p)
        v2 = sharding.tree_values(v1)
        assert isinstance(v2["w"], jax.Array)


class TestSpecForShape:
    def _mesh16(self):
        # fake axis sizes via a real mesh is impossible on 1 device;
        # exercise resolve() logic directly with a mock-like namespace
        return _mesh()

    def test_divisible_keeps_axis(self):
        mesh = _mesh()
        spec = sharding.spec_for_shape((16, 32), ("embed", "ffn"), mesh,
                                       ShardingRules())
        assert spec == P("data", "model")     # sizes 1 divide everything

    def test_non_divisible_drops(self):
        mesh = make_local_mesh((1,), ("model",))
        # dim 3 % 1 == 0 -> kept; now simulate bigger axis via rules check
        spec = sharding.spec_for_shape((3,), ("ffn",), mesh, ShardingRules())
        assert spec == P("model")

    def test_none_axes(self):
        mesh = _mesh()
        spec = sharding.spec_for_shape((4, 4), (None, None), mesh,
                                       ShardingRules())
        assert spec == P(None, None)

    def test_missing_mesh_axis_dropped(self):
        mesh = make_local_mesh((1,), ("model",))
        spec = sharding.spec_for_shape(
            (8,), ("embed",), mesh, ShardingRules())  # embed->data absent
        assert spec == P(None)


class TestRules:
    def test_long_context_overrides(self):
        r = ShardingRules(**sharding.LONG_CONTEXT_OVERRIDES)
        assert r.act_batch is None and r.act_seq == "data"

    def test_resolve_tuple_filters_missing(self):
        r = ShardingRules()
        assert r.resolve("act_batch", {"data", "model"}) == ("data",)
        assert r.resolve("act_batch", {"pod", "data", "model"}) == \
            ("pod", "data")

    @given(st.sampled_from(["vocab", "embed", "heads", "kv", "ffn",
                            "expert", "layers", "act_batch", "act_seq"]))
    @settings(max_examples=20, deadline=None)
    def test_resolve_total(self, name):
        r = ShardingRules()
        out = r.resolve(name, {"pod", "data", "model"})
        assert out is None or isinstance(out, (str, tuple))

    def test_constrain_noop_without_mesh(self):
        sharding.set_mesh_and_rules(None, None)
        x = jnp.zeros((4, 4))
        y = sharding.constrain(x, "act_batch", None)
        assert y is x
