"""Cross-layer decode megakernel: identity, launch counts, retraces.

The megakernel path folds the per-layer decode loop into one Pallas
grid (layer axis "arbitrary", stacked weights/state on a leading L
axis).  Three things must hold, and each is pinned here:

  1. Token identity — the megakernel engine's greedy streams are
     bitwise the per-layer fused engine's, for every SSM family,
     f32 and int8 pooled state, with and without speculative decode.
  2. Launch counts — one pallas_call per decoded token (per
     homogeneous run for heterogeneous stacks; jamba's attention
     sublayers are excepted by design), vs one per layer on the
     fused path.  Counted statically from the traced jaxpr
     (core.dispatch_count), so the pin holds on CPU interpret mode
     and TPU lowering alike.
  3. Retrace flatness — bursts under the megakernel engine hit the
     same jit cache across runs (sampling.TRACE_COUNTS deltas zero
     after warmup), per the conftest warm-then-measure convention.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.dispatch_count import count_pallas_launches
from repro.models import registry
from repro.parallel import sharding
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.spec_decode import DraftConfig, default_shallow_layers

jax.config.update("jax_platform_name", "cpu")

FAMILIES = ["mamba-130m", "jamba-v0.1-52b", "xlstm-350m"]


def _setup(name, **over):
    cfg = configs.smoke_variant(configs.get_config(name))
    cfg = dataclasses.replace(cfg, vocab=64, dtype="float32", **over)
    cfg = dataclasses.replace(
        cfg, capacity_factor=float(max(cfg.n_experts, 1)))
    params = sharding.tree_values(
        registry.init_params(cfg, jax.random.key(0)))
    return cfg, params


def _prompts(cfg, n, seed=11):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, size=(l,)).astype(np.int32)
            for l in rng.integers(3, 10, size=n)]


def _run_engine(cfg, params, ecfg, prompts, max_new=6):
    eng = Engine(cfg, params, ecfg)
    reqs = [eng.submit(p, max_new=max_new) for p in prompts]
    eng.run()
    return [r.tokens for r in reqs]


# ---------------------------------------------------------------------------
# 1. Token identity: megakernel == per-layer fused, families x dtypes
#    x spec on/off, under slot churn.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("state_dtype", ["f32", "int8"])
@pytest.mark.parametrize("name", FAMILIES)
def test_megakernel_token_identical(name, state_dtype):
    """One launch per token must change dispatch, never tokens: the
    megakernel engine (plain AND speculative) emits bitwise the fused
    per-layer engine's greedy streams.  Slot churn (4 requests, 2
    slots) keeps admission/eviction on the tested path."""
    cfg, params = _setup(name)
    prompts = _prompts(cfg, 4)
    base = EngineConfig(n_slots=2, max_seq=64, state_dtype=state_dtype)
    ref = _run_engine(cfg, params,
                      dataclasses.replace(base, step_impl="fused"),
                      prompts)
    mega = dataclasses.replace(base, step_impl="megakernel")
    got = _run_engine(cfg, params, mega, prompts)
    assert got == ref, "megakernel decode diverged from per-layer fused"
    draft = DraftConfig(k=3, layers=default_shallow_layers(cfg))
    got_spec = _run_engine(
        cfg, params, dataclasses.replace(mega, draft=draft), prompts)
    assert got_spec == ref, \
        "speculative megakernel decode diverged from per-layer fused"


# ---------------------------------------------------------------------------
# 2. Launch counts (static jaxpr pins).
# ---------------------------------------------------------------------------

def _launches_per_token(cfg, params):
    cache = sharding.tree_values(registry.init_cache(cfg, 2, 32))
    batch = {"tokens": jnp.zeros((2, 1), jnp.int32)}
    return count_pallas_launches(
        functools.partial(registry.decode_step, cfg, params), cache, batch)


def test_mamba_launch_count_one_per_token():
    """Homogeneous stack: megakernel = exactly ONE Pallas dispatch per
    decoded token; the per-layer fused path = one per layer."""
    cfg, params = _setup("mamba-130m", step_impl="megakernel")
    assert _launches_per_token(cfg, params) == 1
    cfg_f = dataclasses.replace(cfg, step_impl="fused")
    assert _launches_per_token(cfg_f, params) == cfg.n_layers


def test_jamba_launch_count_per_homogeneous_run():
    """Interleaved stack (dense variant: 8 layers, attention at 4):
    one launch per homogeneous SSM run — mega(0..3) + mega(5..7) = 2,
    the attention sublayer excepted by design — vs 7 per-layer fused
    launches."""
    cfg, params = _setup("jamba-v0.1-52b", n_experts=0,
                         step_impl="megakernel")
    assert _launches_per_token(cfg, params) == 2
    cfg_f = dataclasses.replace(cfg, step_impl="fused")
    assert _launches_per_token(cfg_f, params) == 7


def test_jamba_moe_positions_stay_per_layer():
    """MoE sublayers route tokens across the batch (capacity gather /
    scatter) and are excluded from the megakernel grid: the MoE smoke
    config keeps its mamba-at-moe-position launches on the per-layer
    path, so megakernel and fused counts coincide there."""
    cfg, params = _setup("jamba-v0.1-52b", step_impl="megakernel")
    n_mega = _launches_per_token(cfg, params)
    cfg_f = dataclasses.replace(cfg, step_impl="fused")
    n_fused = _launches_per_token(cfg_f, params)
    # 3 single-position mega runs + 4 per-layer moe-position launches
    assert n_mega == 7 and n_fused == 7


def test_xlstm_launch_count_per_kind_run():
    """xLSTM's per-layer "fused" step is pure XLA (zero Pallas
    dispatches); the megakernel is its first fused decode path: one
    launch per kind run (mlstm 0..6, slstm 7) = 2 per token."""
    cfg, params = _setup("xlstm-350m", step_impl="megakernel")
    assert _launches_per_token(cfg, params) == 2
    cfg_f = dataclasses.replace(cfg, step_impl="fused")
    assert _launches_per_token(cfg_f, params) == 0


# ---------------------------------------------------------------------------
# 3. Retrace flatness across bursts.
# ---------------------------------------------------------------------------

def test_megakernel_retrace_flat_across_bursts():
    """A second megakernel engine over same-shaped traffic reuses the
    first's jit cache: decode_step/prefill trace counts stay flat
    (warm-then-measure within this module per the conftest)."""
    from repro.runtime import sampling
    cfg, params = _setup("mamba-130m")
    prompts = _prompts(cfg, 4)
    ecfg = EngineConfig(n_slots=2, max_seq=64, step_impl="megakernel")
    warm = _run_engine(cfg, params, ecfg, prompts)
    before = dict(sampling.TRACE_COUNTS)
    again = _run_engine(cfg, params, ecfg, prompts)
    after = dict(sampling.TRACE_COUNTS)
    assert again == warm
    for k in ("decode_step", "prefill_admit", "prefill_prefix"):
        assert after.get(k, 0) == before.get(k, 0), \
            f"megakernel burst retraced {k}"
