"""Quantized slot state (cfg.state_dtype): round-trip error bounds,
scale dynamics, fused-kernel-vs-oracle parity, pool scale hygiene
(eviction resets scales with the payload), and engine token-stream
parity int8-vs-f32 across model families under slot churn."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # degrade to the deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro import configs
from repro.core import state_quant
from repro.kernels import ops, ref
from repro.models import registry
from repro.parallel import sharding
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.state_pool import SlotStatePool

jax.config.update("jax_platform_name", "cpu")
RNG = np.random.default_rng(7)

QUANT_DTYPES = ("int8", "fp8")

# Token-stream agreement floors for the engine parity tests.  Greedy
# decode on random-weight smoke models sits near argmax ties, and one
# flipped token poisons the rest of an autoregressive stream, so the
# gate is a documented agreement fraction, not exactness: int8 state
# keeps mamba/jamba streams (near-)exact; xLSTM's normalized matrix
# readout (C q / max|n q|) amplifies quantization noise and gets a
# lower floor.  Measured agreement on this platform: mamba 0.93-1.0,
# jamba 1.0, xlstm ~0.83 — floors leave margin for jax-version drift.
AGREEMENT_FLOOR = {"mamba-130m": 0.75, "jamba-v0.1-52b": 0.75,
                   "xlstm-350m": 0.5}


def _setup(name, **over):
    cfg = configs.smoke_variant(configs.get_config(name))
    cfg = dataclasses.replace(cfg, vocab=64, dtype="float32", **over)
    params = sharding.tree_values(
        registry.init_params(cfg, jax.random.key(0)))
    return cfg, params


# ---------------------------------------------------------------------------
# Round-trip property: |dequant(quant(x)) - x| is scale-bounded
# ---------------------------------------------------------------------------

class TestRoundTrip:
    @given(st.integers(1, 4), st.integers(8, 600),
           st.sampled_from([1, 4, 16]), st.floats(0.01, 100.0))
    @settings(max_examples=25, deadline=None)
    def test_h_roundtrip_scale_bounded(self, b, d, n, mag):
        """int8: per-element error <= scale/2 (linear symmetric code).
        The group scale is the absmax over that (D_BLOCK, n) channel
        group mapped to 127, so the bound is tight by construction."""
        h = jnp.asarray(RNG.normal(size=(b, d, n)) * mag, jnp.float32)
        q, s = state_quant.quantize_h(h, "int8")
        assert q.shape == h.shape and q.dtype == jnp.int8
        assert s.shape == (b, state_quant.n_groups(d))
        back = state_quant.dequantize_h(q, s)
        bound = np.asarray(s)[..., None] * (0.5 + 1e-4) + 1e-9
        err = np.abs(np.asarray(back - h))
        grouped, _ = state_quant._group_h(jnp.asarray(err))
        per_group = np.asarray(jnp.max(grouped, axis=(-2, -1)))
        assert (per_group <= bound[..., 0]).all(), (
            per_group.max(), bound.min())

    @given(st.integers(1, 3), st.integers(8, 600), st.floats(0.01, 10.0))
    @settings(max_examples=15, deadline=None)
    def test_h_roundtrip_fp8(self, b, d, mag):
        """fp8 e4m3 carries 3 mantissa bits: worst-case error near the
        group absmax is amax * 2^-4 = scale * 448/16 (plus the subnormal
        floor ~scale)."""
        n = 8
        h = jnp.asarray(RNG.normal(size=(b, d, n)) * mag, jnp.float32)
        q, s = state_quant.quantize_h(h, "fp8")
        assert q.dtype == jnp.float8_e4m3fn
        back = state_quant.dequantize_h(q, s)
        bound = float(np.max(np.asarray(s))) * (448.0 / 16.0 + 1.0)
        assert float(jnp.max(jnp.abs(back - h))) <= bound

    @given(st.integers(1, 3), st.integers(2, 6), st.integers(4, 64))
    @settings(max_examples=15, deadline=None)
    def test_mat_roundtrip_per_row(self, b, nh, dh):
        """xLSTM C path: per-row scales, error <= row_scale/2."""
        x = jnp.asarray(RNG.normal(size=(b, nh, dh, dh)) * 5, jnp.float32)
        q, s = state_quant.quantize_mat(x, "int8")
        assert s.shape == (b, nh, dh)
        back = state_quant.dequantize_mat(q, s)
        err = np.max(np.abs(np.asarray(back - x)), axis=-1)
        assert (err <= np.asarray(s) * (0.5 + 1e-4) + 1e-9).all()

    def test_zero_state_roundtrips_to_zero(self):
        """Fresh slots are exactly zero; quantization must keep them
        exactly zero (scale floors at EPS_AMAX, payload at code 0)."""
        h = jnp.zeros((2, 64, 16), jnp.float32)
        for sd in QUANT_DTYPES:
            q, s = state_quant.quantize_h(h, sd)
            assert float(jnp.max(jnp.abs(
                state_quant.dequantize_h(q, s)))) == 0.0
            assert (np.asarray(s) > 0).all()


class TestScaleDynamics:
    def test_running_absmax_tracks_growth_immediately(self):
        """A growing state must never be clipped: the write scale is
        >= the step's true absmax, so requantization is exact-ranged."""
        h = jnp.asarray(RNG.normal(size=(1, 32, 8)), jnp.float32)
        _, s0 = state_quant.quantize_h(h, "int8")
        _, s1 = state_quant.quantize_h(h * 100, "int8", prev_scale=s0)
        amax = float(jnp.max(jnp.abs(h * 100)))
        assert float(s1[0, 0]) * 127.0 >= amax - 1e-5

    def test_running_absmax_decays_on_shrink(self):
        """A shrinking state pulls the scale down by EMA_DECAY per step
        (not instantly — resolution survives transient near-zeros)."""
        h = jnp.asarray(RNG.normal(size=(1, 32, 8)) * 10, jnp.float32)
        _, s0 = state_quant.quantize_h(h, "int8")
        _, s1 = state_quant.quantize_h(h * 1e-3, "int8", prev_scale=s0)
        np.testing.assert_allclose(np.asarray(s1),
                                   np.asarray(s0) * state_quant.EMA_DECAY,
                                   rtol=1e-6)


# ---------------------------------------------------------------------------
# Fused kernel vs oracle
# ---------------------------------------------------------------------------

class TestFusedParity:
    @pytest.mark.parametrize("state_dtype", QUANT_DTYPES)
    @pytest.mark.parametrize("d", [96, 128])
    def test_fused_q_step_matches_oracle(self, state_dtype, d):
        """The in-kernel dequant/requant must match the XLA oracle:
        same scale math, so payloads agree to within one code (XLA FMA
        contraction can flip an exact rounding boundary), scales to
        ~1 ulp, and y to reduction-order float error."""
        b, n = 4, 16
        h = jnp.asarray(RNG.normal(size=(b, d, n)) * 2, jnp.float32)
        q, s = state_quant.quantize_h(h, state_dtype)
        x = jnp.asarray(RNG.normal(size=(b, d)), jnp.float32)
        dt = jnp.abs(jnp.asarray(RNG.normal(size=(b, d)), jnp.float32))
        A = -jnp.abs(jnp.asarray(RNG.normal(size=(d, n)), jnp.float32))
        B = jnp.asarray(RNG.normal(size=(b, n)), jnp.float32)
        C = jnp.asarray(RNG.normal(size=(b, n)), jnp.float32)
        D = jnp.asarray(RNG.normal(size=(d,)), jnp.float32)
        z = jnp.asarray(RNG.normal(size=(b, d)), jnp.float32)
        outs = {}
        for impl in ("xla", "fused"):
            outs[impl] = ops.selective_state_step_q(
                q, s, x, dt, A, B, C, D=D, z_t=z,
                state_dtype=state_dtype, impl=impl)
        np.testing.assert_allclose(np.asarray(outs["xla"][0]),
                                   np.asarray(outs["fused"][0]),
                                   atol=1e-4, rtol=1e-4)
        code_diff = np.max(np.abs(
            np.asarray(outs["xla"][1].astype(jnp.float32))
            - np.asarray(outs["fused"][1].astype(jnp.float32))))
        code_unit = 1.0 if state_dtype == "int8" else 32.0
        assert code_diff <= code_unit, code_diff
        np.testing.assert_allclose(np.asarray(outs["xla"][2]),
                                   np.asarray(outs["fused"][2]),
                                   rtol=1e-5)

    def test_q_step_tracks_f32_step(self):
        """One quantized step stays within the quantization error budget
        of the f32 step it approximates (states, then outputs)."""
        b, d, n = 4, 128, 16
        h = jnp.asarray(RNG.normal(size=(b, d, n)), jnp.float32)
        q, s = state_quant.quantize_h(h, "int8")
        x = jnp.asarray(RNG.normal(size=(b, d)), jnp.float32)
        dt = jnp.abs(jnp.asarray(RNG.normal(size=(b, d)), jnp.float32))
        A = -jnp.abs(jnp.asarray(RNG.normal(size=(d, n)), jnp.float32))
        B = jnp.asarray(RNG.normal(size=(b, n)), jnp.float32)
        C = jnp.asarray(RNG.normal(size=(b, n)), jnp.float32)
        y_f32, h_f32 = ref.selective_state_step(h, x, dt, A, B, C)
        y_q, qn, sn = ref.selective_state_step_q(q, s, x, dt, A, B, C)
        h_q = state_quant.dequantize_h(qn, sn)
        # error budget: input state error (<= s/2) carried through the
        # decay factor (<1) plus fresh requant error (<= s'/2)
        budget = (float(jnp.max(s)) + float(jnp.max(sn))) * 0.5 + 1e-6
        assert float(jnp.max(jnp.abs(h_q - h_f32))) <= budget
        # y contracts n state entries: error <= n * |C|max * budget
        y_budget = n * float(jnp.max(jnp.abs(C))) * budget
        assert float(jnp.max(jnp.abs(y_q - y_f32))) <= y_budget


# ---------------------------------------------------------------------------
# Pool hygiene: scales are part of the slot state
# ---------------------------------------------------------------------------

POOL_QUANT_ARCHS = ["mamba-130m", "jamba-v0.1-52b", "xlstm-350m"]


def _tree_equal(a, b):
    flat_a, flat_b = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(flat_a) == len(flat_b)
    return all(bool(jnp.array_equal(x, y.astype(x.dtype)))
               for x, y in zip(flat_a, flat_b))


class TestPoolScaleHygiene:
    @pytest.mark.parametrize("name", POOL_QUANT_ARCHS)
    def test_quantized_cache_structure_matches_slot_axes(self, name):
        """cache_slot_axes must stay congruent with init_cache for every
        state_dtype — the whole gather/scatter/mask contract rides on
        it."""
        for sd in ("f32", "bf16") + QUANT_DTYPES:
            cfg, _ = _setup(name, state_dtype=sd)
            cache = sharding.tree_values(registry.init_cache(cfg, 2, 16))
            axes = registry.cache_slot_axes(cfg)
            jax.tree.map(lambda ax, leaf: leaf.shape[ax], axes, cache)

    @pytest.mark.parametrize("name", ["mamba-130m", "xlstm-350m"])
    def test_evict_resets_scale_entries(self, name):
        """Regression: a freed slot's scale entries must reset with the
        payload, so the next admitted sequence can never inherit a stale
        scale (which would silently mis-decode its first read)."""
        cfg, params = _setup(name, state_dtype="int8")
        pool = SlotStatePool(cfg, n_slots=2, max_seq=32)
        fresh = sharding.tree_values(registry.init_cache(cfg, 1, 32))
        toks = jax.random.randint(jax.random.key(1), (1, 9), 0, cfg.vocab,
                                  dtype=jnp.int32)
        _, sub = registry.prefill(cfg, params, fresh, {"tokens": toks})
        slot = pool.alloc()
        pool.admit(slot, sub)
        # the prefilled state has live (nonzero) scales in the pool
        scale_leaves = [leaf for path, leaf in
                        jax.tree_util.tree_flatten_with_path(pool.cache)[0]
                        if "scale" in jax.tree_util.keystr(path)]
        assert scale_leaves, "quantized cache must carry scale leaves"
        assert any(float(jnp.max(jnp.abs(sl))) > 0 for sl in scale_leaves)
        pool.evict(slot)
        assert _tree_equal(pool.read([slot]), fresh)

    def test_quantized_pool_capacity_gain(self):
        """int8 state must fit >= 2x the slots of f32 in the same pool
        memory (the acceptance criterion this PR exists for)."""
        cfg_f32, _ = _setup("mamba-130m", state_dtype="f32")
        cfg_i8, _ = _setup("mamba-130m", state_dtype="int8")
        p_f32 = SlotStatePool(cfg_f32, n_slots=2, max_seq=32)
        p_i8 = SlotStatePool(cfg_i8, n_slots=2, max_seq=32)
        gain = (p_f32.state_bytes_per_slot()
                / p_i8.state_bytes_per_slot())
        assert gain >= 2.0, f"int8 capacity gain {gain:.2f}x < 2x"
        assert p_i8.slots_per_gb() > p_f32.slots_per_gb()


# ---------------------------------------------------------------------------
# Engine parity: int8 vs f32 token streams over a multi-eviction trace
# ---------------------------------------------------------------------------

class TestEngineParity:
    @pytest.mark.parametrize("name", POOL_QUANT_ARCHS)
    def test_int8_stream_parity_under_slot_churn(self, name):
        """Greedy-serve 6 requests through 2 slots (>= 4 evictions and
        slot reuses) at f32 and int8; token agreement must clear the
        documented per-family floor and every request must get all its
        tokens at both dtypes."""
        cfg, params = _setup(name)
        prompts = [RNG.integers(0, cfg.vocab, size=(int(m),))
                   .astype(np.int32)
                   for m in RNG.choice([4, 6, 8], size=6)]
        streams = {}
        for sd in ("f32", "int8"):
            eng = Engine(cfg, params,
                         EngineConfig(n_slots=2, max_seq=40,
                                      state_dtype=sd))
            reqs = [eng.submit(p, max_new=8) for p in prompts]
            done = eng.run()
            assert len(done) == len(reqs)
            assert all(len(r.tokens) == 8 for r in reqs)
            streams[sd] = [r.tokens for r in reqs]
        total = sum(len(t) for t in streams["f32"])
        agree = sum(int(x == y)
                    for a, b in zip(streams["f32"], streams["int8"])
                    for x, y in zip(a, b))
        floor = AGREEMENT_FLOOR[name]
        assert agree / total >= floor, (
            f"{name}: int8 agreement {agree}/{total} below floor {floor}")

    def test_bf16_state_runs_and_counts(self):
        """bf16 is the no-scale storage cast: the engine must serve the
        full trace with exact token accounting."""
        cfg, params = _setup("mamba-130m")
        eng = Engine(cfg, params,
                     EngineConfig(n_slots=2, max_seq=32,
                                  state_dtype="bf16"))
        reqs = [eng.submit(RNG.integers(0, cfg.vocab, size=(5,))
                           .astype(np.int32), max_new=6)
                for _ in range(3)]
        eng.run()
        assert all(len(r.tokens) == 6 for r in reqs)

    def test_fp8_engine_smoke(self):
        cfg, params = _setup("mamba-130m")
        eng = Engine(cfg, params,
                     EngineConfig(n_slots=2, max_seq=32,
                                  state_dtype="fp8"))
        req = eng.submit(RNG.integers(0, cfg.vocab, size=(5,))
                         .astype(np.int32), max_new=6)
        eng.run()
        assert len(req.tokens) == 6

    def test_jamba_kv_int8_stream_parity_under_slot_churn(self):
        """kv_cache_dtype="int8" composes with state_dtype through the
        same engine knob: jamba's attention KV strips store int8 with
        per-(slot, position) absmax scales as cache leaves (slot ops
        move payload and scales together, like the recurrent state).
        Greedy-serve 6 requests through 2 slots at every dtype combo;
        token agreement vs the all-f32 engine must clear the jamba
        floor, and the composed combo must beat 2x bytes-per-slot."""
        name = "jamba-v0.1-52b"
        cfg, params = _setup(name)
        prompts = [RNG.integers(0, cfg.vocab, size=(int(m),))
                   .astype(np.int32)
                   for m in RNG.choice([4, 6, 8], size=6)]
        streams, bytes_per_slot = {}, {}
        for kv, sd in (("model", "f32"), ("int8", "f32"),
                       ("int8", "int8")):
            eng = Engine(cfg, params,
                         EngineConfig(n_slots=2, max_seq=40,
                                      kv_cache_dtype=kv, state_dtype=sd))
            reqs = [eng.submit(p, max_new=8) for p in prompts]
            done = eng.run()
            assert len(done) == len(reqs)
            assert all(len(r.tokens) == 8 for r in reqs)
            streams[(kv, sd)] = [r.tokens for r in reqs]
            bytes_per_slot[(kv, sd)] = eng.pool.state_bytes_per_slot()
        base = streams[("model", "f32")]
        total = sum(len(t) for t in base)
        floor = AGREEMENT_FLOOR[name]
        for combo, toks in streams.items():
            agree = sum(int(x == y) for a, b in zip(base, toks)
                        for x, y in zip(a, b))
            assert agree / total >= floor, (
                f"kv/state {combo}: agreement {agree}/{total} "
                f"below floor {floor}")
        # KV strips quantize (strictly smaller slots), and composing
        # both knobs clears the 2x capacity bar on jamba too
        assert (bytes_per_slot[("int8", "f32")]
                < bytes_per_slot[("model", "f32")])
        gain = (bytes_per_slot[("model", "f32")]
                / bytes_per_slot[("int8", "int8")])
        assert gain >= 2.0, f"composed capacity gain {gain:.2f}x < 2x"

    def test_quantized_fused_matches_quantized_xla_stream(self):
        """step_impl routing under int8 state: the fused q-kernel and
        the XLA q-oracle produce identical token streams on this
        platform (same scale math; payloads agree within one code)."""
        cfg, params = _setup("mamba-130m")
        streams = {}
        for impl in ("xla", "fused"):
            eng = Engine(cfg, params,
                         EngineConfig(n_slots=2, max_seq=32,
                                      state_dtype="int8",
                                      step_impl=impl))
            reqs = [eng.submit(np.arange(1, 6, dtype=np.int32) * (i + 1)
                               % cfg.vocab, max_new=6)
                    for i in range(3)]
            eng.run()
            streams[impl] = [r.tokens for r in reqs]
        assert streams["xla"] == streams["fused"]
