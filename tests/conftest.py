"""Shared test-process hygiene.

The tier-1 suite compiles hundreds of distinct XLA programs (every
Engine/Trainer instance owns fresh jits) in ONE pytest process.  On
CPU, jaxlib's compiled-executable memory is never reclaimed while
references live in jit caches, and past a few hundred live executables
the native compiler segfaults (observed deterministically around the
runtime-heavy middle of the suite; the crashing test passes in
isolation).  Dropping every compilation cache at module boundaries
keeps the live-executable population bounded by the largest single
module instead of the whole suite.

Module scope, not function scope: tests that assert zero-retrace
behavior (sampling.TRACE_COUNTS deltas) warm and measure within one
module, so clearing between modules never breaks them, while clearing
between functions would recompile warmed jits mid-module and slow the
suite badly.
"""
import gc

import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    yield
    import jax
    jax.clear_caches()
    gc.collect()
