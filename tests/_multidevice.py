"""Shared helper for multi-device tests: the main pytest process must
stay single-device (jax backends initialize once per process), so every
multi-device case runs ``python -c`` in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` set before jax
imports.  Used by tests/test_distributed.py and
tests/test_sharded_serving.py (and runnable locally the same way CI's
test-multidevice job does: ``bash scripts/test.sh --multidevice``).
"""
import os
import subprocess
import sys
import textwrap


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run8(body: str, timeout=600):
    """Run ``body`` (dedented) in a fresh CPU python with 8 fake devices;
    assert it exits 0 and return its stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    code = textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout
