"""Paper-model layer tests: op graph consistency, buffer-management policy
properties, cycle-model sanity, reproduction-claim gates (the same checks
benchmarks/run.py prints, as hard assertions)."""
import numpy as np

from repro import configs
from repro.core import buffer_manager as bm, marca_model as mm, op_graph


CFG = configs.get_config("mamba-2.8b")


class TestOpGraph:
    def test_flops_match_6nd_forward(self):
        """Linear-op flops of the full model ~ 2*N*D forward."""
        L = 1024
        ops = op_graph.mamba_model_ops(CFG, L)
        lin = sum(o.flops for o in ops if o.cls == "linear")
        n_params = CFG.n_params()
        want = 2 * n_params * L
        assert 0.8 * want < lin < 1.3 * want

    def test_ew_flops_scale_with_L_times_d_n(self):
        o1 = op_graph.summarize(op_graph.mamba_model_ops(CFG, 512))
        o2 = op_graph.summarize(op_graph.mamba_model_ops(CFG, 1024))
        r = o2["element-wise"]["flops"] / o1["element-wise"]["flops"]
        assert abs(r - 2.0) < 0.05

    def test_update_op_marks_steps(self):
        ops = op_graph.mamba_block_ops(CFG, 256)
        upd = [o for o in ops if o.cls == "update"]
        assert len(upd) == 1 and upd[0].steps == 256

    def test_classes_cover_paper_set(self):
        ops = op_graph.mamba_block_ops(CFG, 64)
        classes = {o.cls for o in ops}
        assert {"linear", "ew1", "ew2", "exp", "silu", "softplus",
                "norm", "update"} <= classes


class TestBufferManager:
    def test_policies_ordered(self):
        """both <= intra, inter <= none (adding a policy never adds bytes)."""
        for L in [64, 512, 4096]:
            t = bm.policy_table(op_graph.mamba_model_ops(CFG, L))
            assert t["both"].total <= t["intra"].total + 1
            assert t["both"].total <= t["inter"].total + 1
            assert t["intra"].total <= t["none"].total + 1
            assert t["inter"].total <= t["none"].total + 1

    def test_intra_dominates_short_seq(self):
        """Paper Fig. 10: intra-BM reduction is largest at short seq."""
        t64 = bm.policy_table(op_graph.mamba_model_ops(CFG, 64))
        t4k = bm.policy_table(op_graph.mamba_model_ops(CFG, 4096))
        red = lambda t, k: 1 - t[k].total / t["none"].total
        assert red(t64, "intra") > red(t4k, "intra")
        assert red(t64, "intra") > 0.4           # paper ~0.73

    def test_inter_dominates_long_seq(self):
        t64 = bm.policy_table(op_graph.mamba_model_ops(CFG, 64))
        t4k = bm.policy_table(op_graph.mamba_model_ops(CFG, 4096))
        red = lambda t, k: 1 - t[k].total / t["none"].total
        assert red(t4k, "inter") > red(t64, "inter")
        assert red(t4k, "inter") > 0.3           # paper ~0.49


class TestCycleModel:
    def test_marca_faster_than_baselines_everywhere(self):
        for name in ["mamba-130m", "mamba-2.8b"]:
            cfg = configs.get_config(name)
            for L in [64, 2048]:
                ops = op_graph.mamba_model_ops(cfg, L)
                assert mm.speedup(ops, mm.CPU) > 1
                assert mm.speedup(ops, mm.GPU) > 1
                assert mm.speedup(ops, mm.TENSOR_CORE_ONLY) > 1

    def test_fig9_envelopes_within_2x_of_paper(self):
        cs, gs = [], []
        for name in ["mamba-130m", "mamba-370m", "mamba-790m",
                     "mamba-1.4b", "mamba-2.8b"]:
            cfg = configs.get_config(name)
            for L in [64, 256, 1024, 2048, 4096]:
                ops = op_graph.mamba_model_ops(cfg, L)
                cs.append(mm.speedup(ops, mm.CPU))
                gs.append(mm.speedup(ops, mm.GPU))
        # paper: cpu max 463 avg 194; gpu max 11.66 avg 4.93
        assert 463 / 2.5 < max(cs) < 463 * 2.5
        assert 11.66 / 2.5 < max(gs) < 11.66 * 2.5
        assert 4.93 / 2.5 < np.mean(gs) < 4.93 * 2.5

    def test_fig1_ew_share_grows_and_exceeds_60pct(self):
        shares = []
        for L in [64, 512, 2048]:
            ops = op_graph.mamba_model_ops(CFG, L)
            t = mm.model_time(ops, mm.GPU)
            tot = t["seconds"]
            shares.append((t["by_group"].get("element-wise", 0)
                           + t["by_group"].get("nonlinear", 0)) / tot)
        assert shares[0] < shares[-1]
        assert shares[-1] > 0.60

    def test_energy_follows_power_and_memory(self):
        ops = op_graph.mamba_model_ops(CFG, 1024)
        e_marca = mm.model_time(ops, mm.MARCA)["energy_j"]
        e_gpu = mm.model_time(ops, mm.GPU)["energy_j"]
        assert e_gpu / e_marca > 10          # paper avg 42.5
