"""Minimal stand-in for the ``hypothesis`` API surface this suite uses.

When hypothesis is installed the test files import it directly and this
module is never loaded.  Without it, ``@given`` degrades to a
deterministic sweep: boundary examples first (min/max/zero where in
range), then pseudo-random draws seeded from the test name, capped at
``@settings(max_examples=...)``.  The point is that the suite *collects
and runs* everywhere — property coverage is reduced, never the import.

Supported: given, settings, strategies.{integers, floats, booleans,
sampled_from, lists} with the keyword arguments the suite passes.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib


class _Strategy:
    """A strategy is (boundary examples, draw(rng) -> value)."""

    def __init__(self, boundaries, draw):
        self.boundaries = list(boundaries)
        self.draw = draw


def _clamp_finite(v):
    return 0.0 if v is None else float(v)


class strategies:                          # noqa: N801 (mimics module name)
    @staticmethod
    def integers(min_value=0, max_value=100):
        lo, hi = int(min_value), int(max_value)
        return _Strategy([lo, hi], lambda rng: rng.randint(lo, hi))

    @staticmethod
    def floats(min_value=None, max_value=None, allow_nan=False,
               allow_infinity=False, width=64):
        lo = _clamp_finite(min_value if min_value is not None else -1e6)
        hi = _clamp_finite(max_value if max_value is not None else 1e6)
        bounds = [lo, hi] + ([0.0] if lo <= 0.0 <= hi else [])
        return _Strategy(bounds, lambda rng: rng.uniform(lo, hi))

    @staticmethod
    def booleans():
        return _Strategy([False, True], lambda rng: rng.random() < 0.5)

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(elements[:1], lambda rng: rng.choice(elements))

    @staticmethod
    def lists(elements, min_size=0, max_size=None):
        max_size = max_size if max_size is not None else min_size + 10

        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.draw(rng) for _ in range(n)]

        bound = [[b] * max(min_size, 1) for b in elements.boundaries[:1]]
        if min_size == 0:
            bound.insert(0, [])
        return _Strategy(bound, draw)


st = strategies


def settings(max_examples=20, deadline=None, **_ignored):
    """Records max_examples on the function for @given to pick up."""
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*strats, **kw_strats):
    if kw_strats:
        raise NotImplementedError("shim supports positional strategies only")

    def deco(fn):
        max_examples = getattr(fn, "_shim_max_examples", 20)
        seed = zlib.crc32(fn.__qualname__.encode())

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = random.Random(seed)
            n_bound = max(len(s.boundaries) for s in strats)
            examples = []
            for i in range(n_bound):       # boundary grid (clipped per-strat)
                examples.append(tuple(
                    s.boundaries[min(i, len(s.boundaries) - 1)]
                    for s in strats))
            while len(examples) < max_examples:
                examples.append(tuple(s.draw(rng) for s in strats))
            for ex in examples[:max_examples]:
                fn(*args, *ex, **kwargs)

        # hide the strategy-filled parameters from pytest's fixture
        # resolution: report only the leading params (e.g. ``self``)
        sig = inspect.signature(fn)
        keep = list(sig.parameters.values())[:-len(strats)]
        wrapper.__signature__ = sig.replace(parameters=keep)
        del wrapper.__wrapped__
        return wrapper

    return deco
