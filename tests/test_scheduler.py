"""SLO scheduler invariants — all asserted deterministically.

No wall-clock enters any assertion: WFQ order, shed counts and the
degradation ladder are functions of (submission order, token counts,
config) only, and the one wall-clock surface (SLO violation
accounting) is tested under an injected fake clock.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import registry
from repro.parallel import sharding
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.sampling import SamplingParams
from repro.runtime.scheduler import SchedConfig, SLOClass, SLOScheduler
from repro.runtime.spec_decode import DraftConfig

jax.config.update("jax_platform_name", "cpu")


def _setup(name="mamba-130m"):
    cfg = configs.smoke_variant(configs.get_config(name))
    cfg = dataclasses.replace(cfg, vocab=64, dtype="float32",
                              capacity_factor=float(max(cfg.n_experts, 1)))
    params = sharding.tree_values(
        registry.init_params(cfg, jax.random.key(0)))
    return cfg, params


def _prompt(rng, n=4):
    return rng.integers(1, 60, size=n)


def test_wfq_no_starvation_under_adversarial_burst():
    """Tenant 'heavy' floods 10 requests before 'light' submits 3;
    equal weights and costs.  Start-time fair queuing interleaves them
    1:1 — light's requests land in the first admissions instead of
    behind the flood, and no backlogged tenant is ever passed over more
    than twice between its own admissions."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=32, seed=0))
    sched = SLOScheduler(eng, SchedConfig(
        weights={"heavy": 1.0, "light": 1.0},
        classes=(SLOClass(ttft_budget=10_000),)))
    rng = np.random.default_rng(0)
    for _ in range(10):
        sched.submit(_prompt(rng), tenant="heavy", max_new=4)
    for _ in range(3):
        sched.submit(_prompt(rng), tenant="light", max_new=4)
    done = sched.run()
    assert len(done) == 13
    order = sched.admitted_order
    # every light request admitted within the fair-interleave window,
    # not after the flood
    light_pos = [i for i, t in enumerate(order) if t == "light"]
    assert light_pos == [1, 3, 5], order
    assert sched.starvation_bound <= 2
    assert sched.counters()["shed"] == 0


def test_wfq_weights_bias_admission_share():
    """A weight-4 tenant's virtual finish advances 4x slower, so its
    backlog admits ~4:1 against a weight-1 tenant."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=32, seed=0))
    sched = SLOScheduler(eng, SchedConfig(
        weights={"heavy": 1.0, "premium": 4.0},
        classes=(SLOClass(ttft_budget=10_000),)))
    rng = np.random.default_rng(1)
    for _ in range(6):
        sched.submit(_prompt(rng), tenant="heavy", max_new=4)
    for _ in range(4):
        sched.submit(_prompt(rng), tenant="premium", max_new=4)
    sched.run()
    first5 = sched.admitted_order[:5]
    assert first5.count("premium") >= 3, sched.admitted_order
    assert sched.starvation_bound <= 4


def test_shed_exact_counts_before_budget_violation():
    """1-slot pool, cost 12 per request (4 prompt + 8 decode), TTFT
    budget 20 service steps: the third and fourth submissions project
    24 steps of wait and are shed AT THE DOOR — deterministically, by
    arithmetic, before any resident request is disturbed."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=32, seed=0))
    sched = SLOScheduler(eng, SchedConfig(
        weights={"t": 1.0}, classes=(SLOClass(ttft_budget=20),)))
    rng = np.random.default_rng(2)
    tickets = [sched.submit(_prompt(rng), tenant="t", max_new=8)
               for _ in range(4)]
    assert [t.shed for t in tickets] == [False, False, True, True]
    done = sched.run()
    # shed requests never reached the engine; admitted ones ran to
    # their full budget untouched
    assert len(done) == 2
    assert all(len(r.tokens) == 8 for r in done)
    assert eng.stats.n_shed == 2
    assert eng.stats.summary()["per_tenant"]["t"]["shed"] == 2


def test_degradation_ladder_shrinks_best_of_n_then_sheds():
    """Between degrade_n_frac and 1.0 of the budget, a best-of-n
    request is admitted at n=1 (branch 0 is bitwise the n=1 serve)
    instead of shed; past the budget it sheds."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=32, seed=0))
    sched = SLOScheduler(eng, SchedConfig(
        weights={"t": 1.0},
        classes=(SLOClass(ttft_budget=20),),
        degrade_n_frac=0.5))
    rng = np.random.default_rng(3)
    sp_n2 = SamplingParams(temperature=1.0, n=2, max_new=8, seed=9)
    # backlog 16/2 slots = 8 projected: over 0.5*20, under 20
    sched.submit(_prompt(rng), tenant="t", max_new=12)
    sched.submit(_prompt(rng), tenant="t", max_new=12)
    t_deg = sched.submit(_prompt(rng), sp_n2, tenant="t")
    assert t_deg.degraded and not t_deg.shed
    # push the backlog past the budget: next one sheds
    t_shed = sched.submit(_prompt(rng), sp_n2, tenant="t")
    assert t_shed.shed
    done = sched.run()
    assert eng.stats.n_degraded == 1 and eng.stats.n_shed == 1
    deg = t_deg.req
    assert deg is not None and deg.params.n == 1
    assert len(deg.tokens) == 8
    assert len(done) == 3


def test_spec_depth_capped_under_pressure_and_restored():
    """Rung 1: backlog past spec_degrade_frac caps speculative depth
    engine-wide (host-side only — no retrace); a later low-pressure
    submit restores it."""
    cfg, params = _setup()
    eng = Engine(cfg, params,
                 EngineConfig(n_slots=1, max_seq=32, seed=0,
                              draft=DraftConfig(k=3, layers=0)))
    sched = SLOScheduler(eng, SchedConfig(
        weights={"t": 1.0}, classes=(SLOClass(ttft_budget=100),),
        spec_degrade_frac=0.2))
    rng = np.random.default_rng(4)
    sched.submit(_prompt(rng), tenant="t", max_new=8)      # backlog 0
    assert eng.spec_cap is None
    for _ in range(3):
        sched.submit(_prompt(rng), tenant="t", max_new=8)
    assert eng.spec_cap == 1          # 12..36 projected > 0.2 * 100
    done = sched.run()
    assert all(len(r.tokens) == 8 for r in done)
    sched.submit(_prompt(rng), tenant="t", max_new=8)      # backlog clear
    assert eng.spec_cap is None
    sched.run()


def test_nonsheddable_class_never_rejected():
    """sheddable=False means degrade-only: under heavy overload every
    request is still admitted (and may violate, which is accounting's
    problem, not admission's)."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=32, seed=0))
    sched = SLOScheduler(eng, SchedConfig(
        weights={"t": 1.0},
        classes=(SLOClass(name="critical", ttft_budget=4,
                          sheddable=False),),
        default_class="critical"))
    rng = np.random.default_rng(5)
    for _ in range(6):
        sched.submit(_prompt(rng), tenant="t", max_new=8)
    done = sched.run()
    assert len(done) == 6
    assert eng.stats.n_shed == 0


def test_session_lease_excluded_from_capacity_projection():
    """A pinned session slot is capacity the projection must not count
    on: with 1 of 2 slots leased, queued work projects against ONE
    effective slot."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=16, seed=0))
    sched = SLOScheduler(eng, SchedConfig(
        weights={"t": 1.0}, classes=(SLOClass(ttft_budget=10_000),)))
    rng = np.random.default_rng(6)
    sess = sched.submit(_prompt(rng), tenant="t", session=True)
    sched.step()                       # admit + pin the session
    assert eng.pool.n_pinned == 1
    sched.submit(_prompt(rng), tenant="t", max_new=8)   # cost 12 queued
    assert sched.projected_wait() == pytest.approx(12.0)
    eng.cancel(sess.req.req_id)
    sched.run()


def test_slo_violation_accounting_with_fake_clock():
    """Wall-clock SLO budgets count violations per tenant — under an
    injected clock (1s per reading), every request blows a 1ms TTFT
    budget, deterministically."""
    cfg, params = _setup()
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=32, seed=0),
                 clock=clock)
    sched = SLOScheduler(eng, SchedConfig(
        weights={"a": 1.0, "b": 1.0},
        classes=(SLOClass(ttft_budget=10_000, ttft_slo_s=0.001,
                          tpot_slo_s=0.001),)))
    rng = np.random.default_rng(7)
    for tenant in ("a", "a", "b"):
        sched.submit(_prompt(rng), tenant=tenant, max_new=4)
    sched.run()
    s = eng.stats.summary()
    assert s["slo_ttft_violations"] == 3
    assert s["slo_tpot_violations"] == 3
    assert s["per_tenant"]["a"]["slo_ttft_violations"] == 2
    assert s["per_tenant"]["b"]["slo_ttft_violations"] == 1
    # TPOT distributions populated alongside TTFT
    assert s["tpot_p95_s"] > 0 and s["per_tenant"]["a"]["tpot_p95_s"] > 0


def test_cancelled_requests_stay_out_of_percentiles():
    """A cancelled request contributes to n_cancelled, never to the
    TTFT/TPOT/latency distributions."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=32, seed=0))
    rng = np.random.default_rng(8)
    keep = eng.submit(_prompt(rng), max_new=4, tenant="t")
    kill = eng.submit(_prompt(rng), max_new=4, tenant="t")
    eng.step()
    eng.cancel(kill.req_id)
    eng.run()
    s = eng.stats.summary()
    assert s["requests"] == 1 and s["cancelled"] == 1
    assert len(eng.stats._ttft) == 1
    assert s["per_tenant"]["t"]["requests"] == 1
    assert keep.finished and len(keep.tokens) == 4
