"""Quantized weight storage (cfg.weight_dtype): round-trip error bounds,
param-tree transform invariants (scale leaves ride the tree, skip-keys
stay raw, abstract/real parity), in-kernel dequant parity against the
XLA reference, and engine token-stream agreement int8-vs-f32 weights
across model families x step impls, composed with quantized state and
spec decode.  Sharded int8-weight identity runs in an 8-fake-device
subprocess (tests/_multidevice.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # degrade to the deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from _multidevice import run8
from repro import configs
from repro.core import weight_quant
from repro.kernels import ops
from repro.models import registry
from repro.parallel import sharding
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.sampling import SamplingParams
from repro.runtime.spec_decode import DraftConfig

jax.config.update("jax_platform_name", "cpu")
RNG = np.random.default_rng(11)

# Same rationale as tests/test_state_quant.py: greedy decode on
# random-weight smoke models sits near argmax ties and one flipped token
# poisons the rest of an autoregressive stream, so the gate is a
# documented agreement fraction.  Prefill runs on the f32 master
# weights (decode-side quantization), so only per-decode-step rounding
# noise can flip tokens.  Measured on this platform with the pinned
# INIT_KEY/prompt seeds: mamba 1.0, jamba 1.0, xlstm 1.0 — floors
# leave wide margin for cross-version argmax-near-tie drift.
AGREEMENT_FLOOR = {"mamba-130m": 0.75, "jamba-v0.1-52b": 0.75,
                   "xlstm-350m": 0.5}
FAMILIES = list(AGREEMENT_FLOOR)
STEP_IMPLS = ("fused", "megakernel", "xla")
# Per-family init keys for the agreement gates: random smoke weights
# draw their argmax-margin distribution from the init key, and a
# degenerate draw sits in near-ties that ANY numerical change (even
# f32 FMA reassociation between step impls) flips — the same reason
# test_state_quant pins its seeds.  These keys were picked by
# measuring margins, not by retrying until green: agreement at the
# pinned keys is 1.0, not floor-grazing.
INIT_KEY = {"mamba-130m": 0, "jamba-v0.1-52b": 1, "xlstm-350m": 0}


def _setup(name, **over):
    cfg = configs.smoke_variant(configs.get_config(name))
    cfg = dataclasses.replace(cfg, vocab=64, dtype="float32", **over)
    params = sharding.tree_values(
        registry.init_params(cfg, jax.random.key(INIT_KEY.get(name, 0))))
    return cfg, params


def _prompts(cfg, n=6, rng=None):
    # agreement gates pass an explicit seeded rng so their prompt draw
    # does not depend on which tests ran before them (the shared module
    # RNG advances with every use)
    rng = RNG if rng is None else rng
    return [rng.integers(0, cfg.vocab, size=(int(m),)).astype(np.int32)
            for m in rng.choice([4, 6, 8], size=n)]


def _serve(cfg, params, prompts, max_new=8, sp=None, **ecfg_kw):
    ecfg_kw.setdefault("n_slots", 2)
    ecfg_kw.setdefault("max_seq", 40)
    eng = Engine(cfg, params, EngineConfig(**ecfg_kw))
    reqs = [eng.submit(p, sp, max_new=max_new) for p in prompts]
    done = eng.run()
    assert len(done) == len(reqs)
    assert all(len(r.tokens) == max_new for r in reqs)
    return eng, [r.tokens for r in reqs]


def _agreement(a_streams, b_streams):
    total = sum(len(t) for t in a_streams)
    agree = sum(int(x == y) for a, b in zip(a_streams, b_streams)
                for x, y in zip(a, b))
    return agree / total


# ---------------------------------------------------------------------------
# Round-trip property: |dequant(quant(w)) - w| is scale-bounded
# ---------------------------------------------------------------------------

class TestRoundTrip:
    @given(st.integers(2, 96), st.integers(1, 64), st.floats(0.01, 100.0))
    @settings(max_examples=25, deadline=None)
    def test_w_roundtrip_per_column(self, d_in, d_out, mag):
        """int8 per-output-channel: per-element error <= column scale/2
        (linear symmetric code, absmax over the input dim -> 127)."""
        w = jnp.asarray(RNG.normal(size=(d_in, d_out)) * mag, jnp.float32)
        q, s = weight_quant.quantize_w(w)
        assert q.shape == w.shape and q.dtype == jnp.int8
        assert s.shape == (d_out,) and s.dtype == jnp.float32
        err = np.abs(np.asarray(weight_quant.dequantize_w(q, s) - w))
        bound = np.asarray(s)[None, :] * (0.5 + 1e-4) + 1e-9
        assert (err <= bound).all(), (err.max(), bound.min())

    @given(st.integers(2, 96), st.integers(1, 32), st.floats(0.01, 100.0))
    @settings(max_examples=25, deadline=None)
    def test_rows_roundtrip_per_row(self, r, c, mag):
        """mamba-A orientation: per-row scales over the last axis."""
        x = jnp.asarray(-np.abs(RNG.normal(size=(r, c))) * mag, jnp.float32)
        q, s = weight_quant.quantize_rows(x)
        assert q.dtype == jnp.int8 and s.shape == (r,)
        err = np.abs(np.asarray(weight_quant.dequantize_rows(q, s) - x))
        assert (err <= np.asarray(s)[:, None] * (0.5 + 1e-4) + 1e-9).all()

    def test_stacked_leaves_scale_shapes(self):
        """Quantization maps stacked (L, ...) leaves with per-layer
        scales — the invariant layer-slicing draft views rely on."""
        w = jnp.asarray(RNG.normal(size=(3, 8, 6)), jnp.float32)
        _, s = weight_quant.quantize_w(w)
        assert s.shape == (3, 6)
        a = jnp.asarray(RNG.normal(size=(3, 8, 4)), jnp.float32)
        _, sa = weight_quant.quantize_rows(a)
        assert sa.shape == (3, 8)

    def test_zero_column_is_safe(self):
        """An all-zero output channel gets a positive scale (no divide
        by zero) and dequantizes to exactly zero."""
        w = jnp.zeros((16, 4), jnp.float32)
        q, s = weight_quant.quantize_w(w)
        assert (np.asarray(s) > 0).all()
        assert float(jnp.max(jnp.abs(
            weight_quant.dequantize_w(q, s)))) == 0.0

    def test_unknown_dtype_raises(self):
        with pytest.raises(KeyError):
            weight_quant.is_quantized("int7")
        with pytest.raises(KeyError):
            weight_quant.storage_dtype("bf16")


# ---------------------------------------------------------------------------
# Param-tree transform: scale leaves ride the tree, skip keys stay raw
# ---------------------------------------------------------------------------

class TestTreeTransform:
    def test_mamba_tree_structure(self):
        """int8 init: every dense dict gains an f32 "w_scale" sibling,
        "A_log" becomes int8 "A_q" + f32 "A_scale", and non-dense leaves
        (conv filters, norms) stay f32."""
        _, p = _setup("mamba-130m", weight_dtype="int8")
        layers = p["layers"]["mixer"]
        for name in ("in_proj", "x_proj", "dt_proj", "out_proj"):
            assert layers[name]["w"].dtype == jnp.int8, name
            assert layers[name]["w_scale"].dtype == jnp.float32, name
            assert (layers[name]["w_scale"].shape
                    == layers[name]["w"].shape[:-2]
                    + layers[name]["w"].shape[-1:]), name
        assert "A_log" not in layers
        assert layers["A_q"].dtype == jnp.int8
        assert layers["A_scale"].shape == layers["A_q"].shape[:-1]
        assert layers["conv_w"].dtype == jnp.float32

    def test_skip_keys_stay_raw(self):
        """embed/unembed (tied-transpose consumers) and jamba's MoE
        expert stacks / router (shard_map einsum consumers) must pass
        through unquantized."""
        for name in ("mamba-130m", "jamba-v0.1-52b"):
            _, p = _setup(name, weight_dtype="int8")
            for key in weight_quant.SKIP_KEYS:
                for path, leaf in jax.tree_util.tree_flatten_with_path(p)[0]:
                    ks = jax.tree_util.keystr(path)
                    if f"'{key}'" in ks:
                        assert leaf.dtype != jnp.int8, ks
                        assert "w_scale" not in ks, ks

    def test_double_quantize_raises(self):
        _, p = _setup("mamba-130m", weight_dtype="int8")
        with pytest.raises(ValueError, match="already"):
            weight_quant.quantize_tree(p)

    @pytest.mark.parametrize("name", FAMILIES)
    def test_abstract_params_structural_parity(self, name):
        """registry.abstract_params must mirror the quantized real tree
        exactly (structure, shapes, dtypes) — TP sharding inference and
        engine validation both key off the abstract tree."""
        cfg, real = _setup(name, weight_dtype="int8")
        abstract = sharding.tree_values(registry.abstract_params(cfg))
        flat_r, td_r = jax.tree_util.tree_flatten(real)
        flat_a, td_a = jax.tree_util.tree_flatten(abstract)
        assert td_r == td_a
        for r, a in zip(flat_r, flat_a):
            assert r.shape == a.shape and r.dtype == a.dtype

    def test_scale_param_axes_derive_from_payload(self):
        """Under the Param (init) tree, every scale leaf's logical axes
        are derived from its payload's — dense scales take the OUTPUT
        axis, A scales drop the state axis — so TP sharding keeps scale
        rows on the same shards as the channels they describe."""
        cfg, _ = _setup("mamba-130m")
        cfg = dataclasses.replace(cfg, weight_dtype="int8")
        p = registry.init_params(cfg, jax.random.key(0))

        def walk(node):
            if isinstance(node, dict):
                if "w_scale" in node:
                    w, s = node["w"], node["w_scale"]
                    assert s.axes == w.axes[:-2] + (w.axes[-1],)
                if "A_q" in node:
                    assert node["A_scale"].axes == node["A_q"].axes[:-1]
                for v in node.values():
                    walk(v)

        walk(p)
        assert isinstance(p["layers"]["mixer"]["A_q"], sharding.Param)


# ---------------------------------------------------------------------------
# Step parity: in-kernel dequant vs pre-dequantized / XLA reference
# ---------------------------------------------------------------------------

class TestStepParity:
    def _operands(self, b=4, d=96, n=16):
        h = jnp.asarray(RNG.normal(size=(b, d, n)) * 2, jnp.float32)
        x = jnp.asarray(RNG.normal(size=(b, d)), jnp.float32)
        dt = jnp.abs(jnp.asarray(RNG.normal(size=(b, d)), jnp.float32))
        A = -jnp.abs(jnp.asarray(RNG.normal(size=(d, n)), jnp.float32))
        B = jnp.asarray(RNG.normal(size=(b, n)), jnp.float32)
        C = jnp.asarray(RNG.normal(size=(b, n)), jnp.float32)
        D = jnp.asarray(RNG.normal(size=(d,)), jnp.float32)
        z = jnp.asarray(RNG.normal(size=(b, d)), jnp.float32)
        return h, x, dt, A, B, C, D, z

    @pytest.mark.parametrize("d", [96, 128])
    def test_fused_in_kernel_dequant_is_exact(self, d):
        """The fused kernel's dequant phase computes code_f32 * scale —
        the SAME two f32 operands as dequantizing outside the kernel —
        so in-kernel dequant must be bitwise the pre-dequantized step."""
        h, x, dt, A, B, C, D, z = self._operands(d=d)
        A_q, s = weight_quant.quantize_rows(A)
        y_in, h_in = ops.selective_state_step(
            h, x, dt, A_q, B, C, D=D, z_t=z, impl="fused", a_scale=s)
        y_pre, h_pre = ops.selective_state_step(
            h, x, dt, weight_quant.dequantize_rows(A_q, s), B, C,
            D=D, z_t=z, impl="fused")
        assert np.array_equal(np.asarray(y_in), np.asarray(y_pre))
        assert np.array_equal(np.asarray(h_in), np.asarray(h_pre))

    def test_fused_matches_xla_with_a_scale(self):
        """Same scale math in both impls: any residual difference is the
        pre-existing FMA contraction noise, not quantization."""
        h, x, dt, A, B, C, D, z = self._operands()
        A_q, s = weight_quant.quantize_rows(A)
        outs = {impl: ops.selective_state_step(
                    h, x, dt, A_q, B, C, D=D, z_t=z,
                    impl=impl, a_scale=s)
                for impl in ("xla", "fused")}
        np.testing.assert_allclose(np.asarray(outs["xla"][0]),
                                   np.asarray(outs["fused"][0]),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(outs["xla"][1]),
                                   np.asarray(outs["fused"][1]),
                                   atol=1e-5, rtol=1e-5)

    def test_quantized_state_composes_with_a_scale(self):
        """int8 weights + int8 state in one step: the q-state kernel
        takes a_scale too, and fused tracks the XLA q-oracle within the
        same tolerances as the unquantized-weight case."""
        from repro.core import state_quant
        h, x, dt, A, B, C, D, z = self._operands(d=128)
        q, s_h = state_quant.quantize_h(h, "int8")
        A_q, s_a = weight_quant.quantize_rows(A)
        outs = {impl: ops.selective_state_step_q(
                    q, s_h, x, dt, A_q, B, C, D=D, z_t=z,
                    state_dtype="int8", impl=impl, a_scale=s_a)
                for impl in ("xla", "fused")}
        np.testing.assert_allclose(np.asarray(outs["xla"][0]),
                                   np.asarray(outs["fused"][0]),
                                   atol=1e-4, rtol=1e-4)
        code_diff = np.max(np.abs(
            np.asarray(outs["xla"][1].astype(jnp.float32))
            - np.asarray(outs["fused"][1].astype(jnp.float32))))
        assert code_diff <= 1.0, code_diff


# ---------------------------------------------------------------------------
# Engine agreement: int8 weights vs f32 weights across families x impls
# ---------------------------------------------------------------------------

class TestEngineAgreement:
    @pytest.mark.parametrize("impl", STEP_IMPLS)
    @pytest.mark.parametrize("name", FAMILIES)
    def test_int8_weight_stream_agreement(self, name, impl):
        """Greedy-serve 6 requests through 2 slots (slot churn) with f32
        and int8 weights on every step impl; agreement must clear the
        per-family floor and every request must get all its tokens."""
        cfg, params = _setup(name)
        prompts = _prompts(cfg, rng=np.random.default_rng(11))
        streams = {}
        for wd in (None, "int8"):
            _, streams[wd] = _serve(cfg, params, prompts,
                                    weight_dtype=wd, step_impl=impl)
        frac = _agreement(streams[None], streams["int8"])
        floor = AGREEMENT_FLOOR[name]
        assert frac >= floor, (
            f"{name}/{impl}: int8-weight agreement {frac:.3f} "
            f"below floor {floor}")

    @pytest.mark.parametrize("name", FAMILIES)
    def test_composes_with_int8_state(self, name):
        """weight_dtype="int8" + state_dtype="int8" together: agreement
        vs f32-weights/int8-state clears the same family floor (the
        weight error budget stacks on the state one)."""
        cfg, params = _setup(name)
        prompts = _prompts(cfg, rng=np.random.default_rng(11))
        streams = {}
        for wd in (None, "int8"):
            _, streams[wd] = _serve(cfg, params, prompts,
                                    weight_dtype=wd, state_dtype="int8")
        frac = _agreement(streams[None], streams["int8"])
        assert frac >= AGREEMENT_FLOOR[name], (name, frac)

    def test_fused_and_megakernel_streams_identical(self):
        """Both Pallas paths dequantize with the identical scale
        multiply on identical operands — token streams must match
        exactly, not just above a floor."""
        cfg, params = _setup("mamba-130m")
        prompts = _prompts(cfg, n=4)
        streams = {}
        for impl in ("fused", "megakernel"):
            _, streams[impl] = _serve(cfg, params, prompts,
                                      weight_dtype="int8", step_impl=impl)
        assert streams["fused"] == streams["megakernel"]

    def test_weight_dtype_none_leaves_params_untouched(self):
        """The default is byte-identical to not having the feature: the
        engine must not copy, cast, or re-wrap the caller's tree."""
        cfg, params = _setup("mamba-130m")
        eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=40))
        assert eng.params is params
        assert eng.prefill_params is params
        for leaf in jax.tree.leaves(eng.params):
            assert leaf.dtype != jnp.int8

    def test_prefill_serves_from_f32_master(self):
        """Decode-side quantization: the engine keeps the caller's f32
        tree aliased (no copy) for the compute-bound prefill while
        decode streams the int8 tree — and the first token of every
        request (sampled from prefill logits) therefore matches the f32
        engine exactly."""
        cfg, params = _setup("mamba-130m")
        eng = Engine(cfg, params,
                     EngineConfig(n_slots=2, max_seq=40,
                                  weight_dtype="int8"))
        assert eng.prefill_params is params
        assert eng.params is not params
        prompts = _prompts(cfg, n=4, rng=np.random.default_rng(5))
        _, f32_streams = _serve(cfg, params, prompts)
        _, q_streams = _serve(cfg, params, prompts, weight_dtype="int8")
        for a, b in zip(f32_streams, q_streams):
            assert a[0] == b[0], "prefill-sampled first token drifted"

    def test_prefix_cache_identical_with_int8_weights(self):
        """The cached-prefix suffix micro-scan must run on the same f32
        prefill master as the cold full prefill, or warm admissions
        would produce different tokens than cold ones."""
        from repro.runtime.prefix_cache import PrefixCacheConfig
        cfg, params = _setup("mamba-130m")
        rng = np.random.default_rng(9)
        common = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
        prompts = [np.concatenate([common, t]) for t in
                   (rng.integers(0, cfg.vocab, size=(3,)).astype(np.int32),
                    rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32))]
        cold_kw = dict(weight_dtype="int8")
        warm_kw = dict(weight_dtype="int8",
                       prefix_cache=PrefixCacheConfig(block=4))
        _, cold = _serve(cfg, params, prompts, **cold_kw)
        eng, warm = _serve(cfg, params, prompts, **warm_kw)
        assert cold == warm
        assert eng._prefix.hits >= 1

    def test_weight_bytes_reduction(self):
        """The point of the PR: int8 weight storage must cut total
        param bytes >= 1.5x (embed/unembed stay f32, so not a full 4x)."""
        cfg, params = _setup("mamba-130m")
        eng = Engine(cfg, params,
                     EngineConfig(n_slots=2, max_seq=40,
                                  weight_dtype="int8"))
        f32_bytes = sum(l.nbytes for l in jax.tree.leaves(params))
        q_bytes = sum(l.nbytes for l in jax.tree.leaves(eng.params))
        gain = f32_bytes / q_bytes
        assert gain >= 1.5, f"weight bytes reduction {gain:.2f}x < 1.5x"

    def test_params_bitwise_unchanged_after_forked_serve(self):
        """Serving with forks (best-of-n) and slot churn must never
        write into the weight tree: quantized payloads and scales stay
        bitwise identical, and no scale leaf leaks into slot state
        handling."""
        cfg, params = _setup("mamba-130m")
        eng = Engine(cfg, params,
                     EngineConfig(n_slots=2, max_seq=40,
                                  weight_dtype="int8"))
        before = jax.device_get(eng.params)
        sp = SamplingParams(n=2, temperature=0.8, seed=3, max_new=6)
        reqs = [eng.submit(p, sp) for p in _prompts(cfg, n=3)[:2]]
        reqs += [eng.submit(p, max_new=6) for p in _prompts(cfg, n=2)]
        eng.run()
        assert all(len(r.tokens) == 6 for r in reqs)
        after = jax.device_get(eng.params)
        flat_b, td_b = jax.tree_util.tree_flatten(before)
        flat_a, td_a = jax.tree_util.tree_flatten(after)
        assert td_b == td_a
        for b, a in zip(flat_b, flat_a):
            assert b.dtype == a.dtype
            assert np.array_equal(b, a)

    def test_spec_decode_token_identity_with_int8_weights(self):
        """Spec decode's exactness contract survives weight quant: the
        draft slices the SAME quantized stacked leaves (scales ride the
        layer slice), so greedy spec == greedy plain, token for token."""
        cfg, params = _setup("mamba-130m")
        prompts = _prompts(cfg, n=4)
        _, plain = _serve(cfg, params, prompts, weight_dtype="int8")
        _, spec = _serve(cfg, params, prompts, weight_dtype="int8",
                         draft=DraftConfig(k=2, layers=0))
        assert plain == spec

    def test_model_cfg_already_int8_not_requantized(self):
        """A caller handing in already-quantized params (cfg says int8)
        must not be double-quantized by the engine knob."""
        cfg, qparams = _setup("mamba-130m", weight_dtype="int8")
        eng = Engine(cfg, qparams,
                     EngineConfig(n_slots=2, max_seq=40,
                                  weight_dtype="int8"))
        assert eng.params is qparams
        req = eng.submit(np.arange(1, 6, dtype=np.int32), max_new=4)
        eng.run()
        assert len(req.tokens) == 4


# ---------------------------------------------------------------------------
# Sharded: int8 weights under a TP mesh stream token-identical
# ---------------------------------------------------------------------------

def test_sharded_int8_weights_token_identity():
    """Under a tp=2 serving mesh, int8-weight greedy streams must equal
    the single-device int8-weight streams, with the scale leaves
    sharded alongside their payload columns (at least one quantized
    leaf non-replicated)."""
    run8("""
    import dataclasses
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.models import registry
    from repro.parallel import sharding
    from repro.launch import mesh as mesh_lib
    from repro.runtime.engine import Engine, EngineConfig

    cfg = configs.smoke_variant(configs.get_config('mamba-130m'))
    cfg = dataclasses.replace(cfg, vocab=256, dtype='float32')
    params = sharding.tree_values(
        registry.init_params(cfg, jax.random.key(0)))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 256, size=int(L)).tolist()
               for L in rng.choice((6, 8, 12), size=4)]

    def serve(mesh):
        eng = Engine(cfg, params,
                     EngineConfig(n_slots=2, max_seq=64, mesh=mesh,
                                  weight_dtype='int8'))
        reqs = [eng.submit(p, max_new=8) for p in prompts]
        eng.run()
        return eng, [r.tokens for r in reqs]

    _, single = serve(None)
    eng, shardd = serve(mesh_lib.make_serving_mesh(2))
    assert single == shardd, (single, shardd)
    qleaves = [l for l in jax.tree.leaves(eng.params)
               if l.dtype == jnp.int8]
    assert qleaves, 'sharded engine must hold int8 weight leaves'
    assert any(not l.sharding.is_fully_replicated
               for l in jax.tree.leaves(eng.params)), \\
        'params must actually shard on the mesh'
    print('ok sharded int8 weights')
    """, timeout=1200)
