"""Trainable Pallas scan (custom VJP, chunk-recompute backward): forward
and every gradient match autodiff of the reference; plus property sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # degrade to the deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.selective_scan import selective_scan_trainable

jax.config.update("jax_platform_name", "cpu")
RNG = np.random.default_rng(7)


def _inputs(b, L, d, n):
    x = jnp.asarray(RNG.normal(size=(b, L, d)).astype(np.float32))
    dt = jax.nn.softplus(jnp.asarray(
        RNG.normal(size=(b, L, d)).astype(np.float32)))
    A = -jnp.exp(jnp.asarray(RNG.normal(size=(d, n)).astype(np.float32))
                 * 0.5)
    B = jnp.asarray(RNG.normal(size=(b, L, n)).astype(np.float32))
    C = jnp.asarray(RNG.normal(size=(b, L, n)).astype(np.float32))
    return x, dt, A, B, C


def _losses(chunk):
    def loss_k(x, dt, A, B, C):
        y, h = selective_scan_trainable(x, dt, A, B, C, chunk, True)
        return jnp.sum(y ** 2) + jnp.sum(h ** 2)

    def loss_r(x, dt, A, B, C):
        y, h = ref.selective_scan(x, dt, A, B, C)
        return jnp.sum(y.astype(jnp.float32) ** 2) + jnp.sum(h ** 2)

    return loss_k, loss_r


@pytest.mark.parametrize("b,L,d,n,chunk", [(1, 32, 8, 4, 8),
                                           (2, 96, 24, 8, 32),
                                           (2, 100, 16, 16, 32)])
def test_grads_match_autodiff(b, L, d, n, chunk):
    args = _inputs(b, L, d, n)
    loss_k, loss_r = _losses(chunk)
    assert abs(float(loss_k(*args)) - float(loss_r(*args))) \
        < 1e-4 * abs(float(loss_r(*args)))
    g1 = jax.grad(loss_k, argnums=(0, 1, 2, 3, 4))(*args)
    g2 = jax.grad(loss_r, argnums=(0, 1, 2, 3, 4))(*args)
    for name, a, b_ in zip("x dt A B C".split(), g1, g2):
        scale = float(jnp.max(jnp.abs(b_))) + 1e-9
        rel = float(jnp.max(jnp.abs(a - b_))) / scale
        assert rel < 1e-4, (name, rel)


@given(st.integers(1, 2), st.integers(4, 50), st.integers(2, 12),
       st.integers(1, 8))
@settings(max_examples=10, deadline=None)
def test_grads_property(b, L, d, n):
    args = _inputs(b, L, d, n)
    loss_k, loss_r = _losses(chunk=16)
    g1 = jax.grad(loss_k, argnums=(1,))(*args)[0]
    g2 = jax.grad(loss_r, argnums=(1,))(*args)[0]
    scale = float(jnp.max(jnp.abs(g2))) + 1e-9
    assert float(jnp.max(jnp.abs(g1 - g2))) / scale < 5e-4


def test_jit_and_value_finite():
    args = _inputs(2, 64, 16, 8)
    loss_k, _ = _losses(chunk=16)
    v, g = jax.jit(jax.value_and_grad(loss_k))(*args)
    assert np.isfinite(float(v))
    assert np.all(np.isfinite(np.asarray(g)))
