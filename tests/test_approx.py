"""MARCA §5 approximation algorithms: accuracy + properties (Table 3 class)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # degrade to the deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import approx

jax.config.update("jax_platform_name", "cpu")


class TestFastExp:
    def test_biased_beats_plain_on_paper_distribution(self):
        """The paper's claim: calibrating the bias on the dt*A density set
        improves average accuracy over plain fast_exp (Table 3 rows)."""
        xs = jnp.asarray(approx.exp_density_set())
        t = np.exp(np.asarray(xs, np.float64))
        ours = np.asarray(approx.our_exp(xs), np.float64)
        fast = np.asarray(approx.fast_exp(xs), np.float64)
        rel_ours = (np.abs(ours - t) / t).mean()
        rel_fast = (np.abs(fast - t) / t).mean()
        assert rel_ours < rel_fast
        assert rel_ours < 0.015          # ~1% mean relative error

    def test_max_relative_error_bounded(self):
        xs = jnp.linspace(-7.0, -1e-4, 20001)
        t = np.exp(np.asarray(xs, np.float64))
        ours = np.asarray(approx.our_exp(xs), np.float64)
        assert (np.abs(ours - t) / t).max() < 0.05   # Schraudolph bound ~4%

    def test_calibration_reproduces_constants(self):
        b, c = approx.calibrate_exp_bias()
        assert abs(b - approx.OUR_EXP_B_SHIFT) < 5e-3
        assert abs(c - approx.OUR_EXP_C) < 1e-3

    @given(st.floats(min_value=-60.0, max_value=60.0, allow_nan=False))
    @settings(max_examples=200, deadline=None)
    def test_positive_everywhere(self, x):
        y = float(approx.our_exp(jnp.float32(x)))
        assert y > 0.0

    @given(st.lists(st.floats(min_value=-30.0, max_value=30.0),
                    min_size=2, max_size=50))
    @settings(max_examples=100, deadline=None)
    def test_monotone_nondecreasing(self, xs):
        """The bit trick is monotone: order of inputs preserved."""
        xs = np.sort(np.asarray(xs, np.float32))
        ys = np.asarray(approx.our_exp(jnp.asarray(xs)))
        assert np.all(np.diff(ys) >= 0)

    def test_no_overflow_at_extremes(self):
        y = approx.our_exp(jnp.asarray([-1e9, -100.0, 100.0, 1e9], jnp.float32))
        assert np.all(np.isfinite(np.asarray(y)))

    def test_bf16_roundtrip_dtype(self):
        x = jnp.asarray([-1.0, -0.5], jnp.bfloat16)
        assert approx.our_exp(x).dtype == jnp.bfloat16


class TestPiecewiseSilu:
    def test_paper_eq3_error_on_profiled_range(self):
        """Paper eq. (3) verbatim: bounded error on the profiled [-5, 4]."""
        x = jnp.linspace(-5, 4, 30001)
        err = jnp.abs(approx.piecewise_silu_paper(x) - jax.nn.silu(x))
        assert float(err.max()) < 0.1     # eq. 3 as printed: ~0.081

    def test_ours_tighter_than_paper(self):
        x = jnp.linspace(-5, 4, 30001)
        e_ours = jnp.abs(approx.piecewise_silu(x) - jax.nn.silu(x))
        e_paper = jnp.abs(approx.piecewise_silu_paper(x) - jax.nn.silu(x))
        assert float(e_ours.max()) < float(e_paper.max()) / 3
        assert float(e_ours.max()) < 0.02

    def test_ours_wide_range(self):
        x = jnp.linspace(-30, 30, 60001)
        err = jnp.abs(approx.piecewise_silu(x) - jax.nn.silu(x))
        assert float(err.max()) < 0.02

    def test_fit_reproduces_coefs(self):
        got = approx.fit_piecewise_silu()
        want = np.asarray(approx.SILU_COEFS)
        assert np.allclose(got, want, atol=1e-4)

    @given(st.floats(min_value=-50, max_value=50, allow_nan=False))
    @settings(max_examples=200, deadline=None)
    def test_absolute_error_pointwise(self, x):
        y = float(approx.piecewise_silu(jnp.float32(x)))
        t = float(jax.nn.silu(jnp.float32(x)))
        assert abs(y - t) < 0.02


class TestPiecewiseSigmoid:
    def test_error_bound(self):
        x = jnp.linspace(-30, 30, 60001)
        err = jnp.abs(approx.piecewise_sigmoid(x) - jax.nn.sigmoid(x))
        assert float(err.max()) < 0.025

    def test_range(self):
        x = jnp.linspace(-100, 100, 2001)
        y = approx.piecewise_sigmoid(x)
        assert float(y.min()) >= -0.01 and float(y.max()) <= 1.01


class TestDispatch:
    @pytest.mark.parametrize("name", ["exact", "ours", "fast"])
    def test_exp_impls(self, name):
        f = approx.get_exp(name)
        assert np.isfinite(float(f(jnp.float32(-1.0))))

    @pytest.mark.parametrize("name", ["exact", "ours", "paper"])
    def test_silu_impls(self, name):
        f = approx.get_silu(name)
        assert np.isfinite(float(f(jnp.float32(1.0))))
