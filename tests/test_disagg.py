"""Prefill/decode disaggregation + infinite-stream session tests.

The load-bearing contract: a disaggregated prefill -> snapshot ->
one-scatter decode admission produces token streams BITWISE identical
to the monolithic engine, per family and per state_dtype — not close,
identical, because the worker runs the same compiled prefill program
with the same seed and scatter(gather(x)) is exact data movement.

Sessions: an infinite stream holds its state bytes exactly constant
while decoding far past both max_new and max_seq (the whole point of a
max_seq-independent state), its slot is pinned against eviction, and
families whose cache grows with max_seq are refused up front.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import registry
from repro.parallel import sharding
from repro.runtime.disagg import DisaggConfig, DisaggPipeline, PrefillWorker
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.sampling import SamplingParams

jax.config.update("jax_platform_name", "cpu")


def _setup(name):
    cfg = configs.smoke_variant(configs.get_config(name))
    cfg = dataclasses.replace(cfg, vocab=64, dtype="float32",
                              capacity_factor=float(max(cfg.n_experts, 1)))
    params = sharding.tree_values(
        registry.init_params(cfg, jax.random.key(0)))
    return cfg, params


DISAGG_ARCHS = ["mamba-130m", "jamba-v0.1-52b", "xlstm-350m"]


def _mixed_requests(rng, n=5):
    """Mixed greedy/sampled, varied lengths — the traffic shape that
    would expose any seed/params drift between the two serving paths."""
    out = []
    for i in range(n):
        prompt = rng.integers(1, 60, size=int(rng.integers(4, 12)))
        params = (SamplingParams(max_new=6) if i % 2 == 0 else
                  SamplingParams(temperature=0.9, top_k=12, max_new=6))
        out.append((prompt, params))
    return out


@pytest.mark.parametrize("name", DISAGG_ARCHS)
@pytest.mark.parametrize("state_dtype", ["f32", "int8"])
def test_disagg_bitwise_identical_to_monolithic(name, state_dtype):
    """Same submissions, same order: every request's token stream (and
    cumulative logprob) from the disaggregated pipeline equals the
    monolithic engine's bitwise."""
    cfg, params = _setup(name)
    rng = np.random.default_rng(3)
    reqs = _mixed_requests(rng)
    ecfg = EngineConfig(n_slots=2, max_seq=48, seed=11,
                        state_dtype=state_dtype)

    mono = Engine(cfg, params, ecfg)
    for prompt, sp in reqs:
        mono.submit(prompt, sp)
    ref = {r.req_id: (r.tokens, r.cum_logprob) for r in mono.run()}

    pipe = DisaggPipeline(cfg, params,
                          EngineConfig(n_slots=2, max_seq=48, seed=11,
                                       state_dtype=state_dtype),
                          DisaggConfig(queue_depth=3))
    items = [pipe.submit(prompt, sp) for prompt, sp in reqs]
    pipe.run()
    assert pipe.decode.stats.snapshot_admits == len(reqs)
    assert pipe.decode.stats.prefill_tokens == 0   # no local prefill ran
    for i, item in enumerate(items):
        tokens, cum = ref[i]
        assert item.req.tokens == tokens, (
            f"req {i}: disagg stream diverged from monolithic")
        assert item.req.cum_logprob == cum


def test_bounded_transfer_queue_backpressure():
    """Prefill production stalls at queue_depth: with depth 1 and a
    1-slot decode pool, the queue never holds more than one snapshot."""
    cfg, params = _setup("mamba-130m")
    pipe = DisaggPipeline(cfg, params,
                          EngineConfig(n_slots=1, max_seq=48, seed=0),
                          DisaggConfig(queue_depth=1))
    rng = np.random.default_rng(0)
    items = [pipe.submit(rng.integers(1, 60, size=6), max_new=4)
             for _ in range(5)]
    done = pipe.run()
    assert len(done) == 5
    assert pipe.max_queue_depth == 1
    assert pipe.transfers == 5
    # every transfer ships the same fixed-size state block
    assert pipe.transfer_bytes == 5 * items[0].snap.nbytes


def test_snapshot_layout_mismatch_rejected():
    """A snapshot from an incompatible engine (different state_dtype)
    is refused with a clear error, not silently mis-scattered."""
    cfg, params = _setup("mamba-130m")
    worker = PrefillWorker(cfg, params,
                           EngineConfig(n_slots=1, max_seq=48, seed=0,
                                        state_dtype="f32"))
    snap = worker.prefill(np.arange(1, 7), SamplingParams(max_new=4),
                          seed=1)
    other = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=48,
                                             state_dtype="int8"))
    with pytest.raises(ValueError, match="does not match"):
        other.submit_snapshot(snap)


def test_disagg_rejects_best_of_n():
    cfg, params = _setup("mamba-130m")
    pipe = DisaggPipeline(cfg, params, EngineConfig(n_slots=2, max_seq=48))
    with pytest.raises(ValueError, match="single-stream"):
        pipe.submit(np.arange(1, 5), SamplingParams(n=2, temperature=1.0,
                                                    max_new=4))


def test_pipeline_cancel_at_every_stage():
    """Cancel works wherever the request lives: pending (pre-prefill),
    in the transfer queue, or admitted decode-side."""
    cfg, params = _setup("mamba-130m")
    pipe = DisaggPipeline(cfg, params,
                          EngineConfig(n_slots=1, max_seq=48, seed=0),
                          DisaggConfig(queue_depth=1))
    rng = np.random.default_rng(1)
    items = [pipe.submit(rng.integers(1, 60, size=6), max_new=4)
             for _ in range(4)]
    assert pipe.cancel(items[3])          # still pending
    pipe.step()                            # prefills one into the queue
    # items[1] is now in the transfer queue (0 admitted decode-side)
    done = []
    while pipe.busy():
        if items[1] in pipe._queue:
            assert pipe.cancel(items[1])
        if items[0].req is not None and not items[0].req.finished:
            pipe.cancel(items[0])          # admitted: engine-side cancel
        pipe.step()
    pipe.decode.stats.stop()
    finished = pipe.decode._finished
    ids = {r.req_id for r in finished}
    assert items[2].req is not None and items[2].req.req_id in ids
    assert items[2].req.tokens and not items[2].req.cancelled
    assert items[3].req is None            # never prefilled


# ---------------------------------------------------------------------------
# Infinite-stream sessions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["mamba-130m", "xlstm-350m"])
def test_session_state_bytes_constant_past_horizon(name):
    """An infinite-stream session decodes >= 4x max_new tokens (well
    past max_seq too) with the pool's cache byte-for-byte constant in
    SHAPE: every leaf keeps its shape and nbytes at every sync."""
    cfg, params = _setup(name)
    ecfg = EngineConfig(n_slots=2, max_seq=16, seed=3)
    eng = Engine(cfg, params, ecfg)
    req = eng.submit(np.arange(1, 6), max_new=8, session=True)
    shapes0 = [(leaf.shape, leaf.nbytes)
               for leaf in jax.tree.leaves(eng.pool.cache)]
    bytes0 = eng.pool.state_bytes_per_slot()
    while len(req.tokens) < 4 * 8:
        eng.step()
        shapes = [(leaf.shape, leaf.nbytes)
                  for leaf in jax.tree.leaves(eng.pool.cache)]
        assert shapes == shapes0
        assert eng.pool.state_bytes_per_slot() == bytes0
    assert len(req.tokens) >= 4 * 8 > ecfg.max_seq
    assert eng.pool.n_pinned == 1
    eng.cancel(req.req_id)
    eng.step()
    assert req.finished and eng.pool.n_pinned == 0


def test_session_refused_for_growable_cache():
    """jamba's per-position KV strips grow with max_seq — an infinite
    session there would exhaust the strip, so it is refused up front."""
    cfg, params = _setup("jamba-v0.1-52b")
    eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=16))
    with pytest.raises(ValueError, match="max_seq-independent"):
        eng.submit(np.arange(1, 5), session=True)


def test_session_slot_pinned_against_evict():
    """The pool refuses to evict a pinned lease directly."""
    cfg, params = _setup("mamba-130m")
    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=16))
    req = eng.submit(np.arange(1, 5), session=True)
    eng.step()
    slot = eng._slot_req.index(req)
    with pytest.raises(RuntimeError, match="eviction-free lease"):
        eng.pool.evict(slot)
    eng.cancel(req.req_id)
    eng.step()


def test_session_coexists_with_bounded_requests():
    """A session pins one slot while bounded requests churn through the
    rest; the bounded streams finish normally and the session keeps
    flowing."""
    cfg, params = _setup("mamba-130m")
    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=32, seed=5))
    sess = eng.submit(np.arange(1, 5), session=True)
    rng = np.random.default_rng(2)
    bounded = [eng.submit(rng.integers(1, 60, size=6), max_new=5)
               for _ in range(3)]
    while not all(r.finished for r in bounded):
        eng.step()
    assert all(len(r.tokens) == 5 for r in bounded)
    assert not sess.finished and len(sess.tokens) > 0
    eng.cancel(sess.req_id)
    eng.step()


def test_disagg_session_streams():
    """Sessions compose with disaggregation: prefill remotely, decode
    an unbounded stream locally."""
    cfg, params = _setup("mamba-130m")
    pipe = DisaggPipeline(cfg, params,
                          EngineConfig(n_slots=1, max_seq=16, seed=0))
    item = pipe.submit(np.arange(1, 6), session=True)
    while item.req is None or len(item.req.tokens) < 40:
        pipe.step()
    assert pipe.decode.pool.n_pinned == 1
    pipe.cancel(item)
    pipe.step()
    assert item.req.finished and pipe.decode.pool.n_pinned == 0
