"""Per-architecture smoke tests: reduced same-family config, one forward +
one train-grad step on CPU, output shapes + finiteness (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.zoo import ASSIGNED
from repro.models import registry
from repro.parallel import sharding

jax.config.update("jax_platform_name", "cpu")

ALL = ASSIGNED + ["mamba-130m"]


def _setup(name):
    cfg = configs.smoke_variant(configs.get_config(name))
    params_p = registry.init_params(cfg, jax.random.key(0))
    params = sharding.tree_values(params_p)
    batch = registry.make_batch(cfg, batch_size=2, seq_len=16,
                                key=jax.random.key(1))
    return cfg, params, batch


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes_and_finite(name):
    cfg, params, batch = _setup(name)
    logits, aux = registry.forward(cfg, params, batch)
    b = 2
    if cfg.n_codebooks > 1:
        assert logits.shape == (b, 16, cfg.n_codebooks, cfg.vocab)
    elif cfg.frontend == "vision_stub":
        assert logits.shape == (b, 16 + cfg.img_tokens, cfg.vocab)
    else:
        assert logits.shape == (b, 16, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("name", ALL)
def test_train_grad_step(name):
    cfg, params, batch = _setup(name)

    def loss(p):
        return registry.loss_fn(cfg, p, batch)[0]

    val, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(val))
    norms = jax.tree.map(lambda g: float(jnp.max(jnp.abs(g))), grads)
    flat = jax.tree.leaves(norms)
    assert all(np.isfinite(v) for v in flat)
    assert any(v > 0 for v in flat)


@pytest.mark.parametrize("name", ["mamba-130m", "jamba-v0.1-52b",
                                  "xlstm-350m", "granite-20b"])
def test_decode_cache_roundtrip(name):
    """decode_step runs and advances pos; logits finite."""
    cfg, params, _ = _setup(name)
    cache = sharding.tree_values(registry.init_cache(cfg, batch=2,
                                                     max_seq=32))
    batch = {"tokens": jnp.ones((2, 1), jnp.int32)}
    if cfg.frontend == "audio_stub":
        batch = {"embeds": jnp.ones((2, 1, cfg.d_model), cfg.dtype)}
    logits, new_cache = registry.decode_step(cfg, params, cache, batch)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert int(new_cache["pos"][0]) == 1


@pytest.mark.parametrize("name", ALL)
def test_abstract_params_match_real(name):
    """eval_shape init == real init (shapes/dtypes), no allocation path."""
    cfg = configs.smoke_variant(configs.get_config(name))
    abs_p = registry.abstract_params(cfg)
    real_p = registry.init_params(cfg, jax.random.key(0))
    abs_s = jax.tree.map(lambda p: (p.shape, str(p.dtype)),
                         sharding.tree_values(abs_p))
    real_s = jax.tree.map(lambda p: (p.shape, str(p.dtype)),
                          sharding.tree_values(real_p))
    assert abs_s == real_s


def test_count_params_close_to_real():
    """Analytical count within 2% of actual leaf-size sum (dense archs)."""
    for name in ["mamba-130m", "olmo-1b", "granite-20b"]:
        cfg = configs.get_config(name)
        want = registry.count_params(cfg)
        abs_p = registry.abstract_params(cfg)
        got = sum(int(np.prod(p.shape)) for p in
                  jax.tree.leaves(sharding.tree_values(abs_p)))
        assert abs(got - want) / got < 0.02, (name, got, want)
