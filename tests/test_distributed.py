"""Multi-device tests (8 fake host devices via subprocess — the main pytest
process must stay single-device, so each case runs `python -c` with
XLA_FLAGS set before jax import).

Covers: pjit sharded training step == single-device step, elastic checkpoint
reshard, compressed psum, pipeline parallelism, sequence-parallel scan,
production-mesh construction error path.  (Sharded SERVING lives in
tests/test_sharded_serving.py; both share the run8 subprocess helper.)
"""
from _multidevice import run8


def test_sharded_train_step_matches_single_device():
    run8("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.models import registry
        from repro.parallel import sharding
        from repro.launch.mesh import make_local_mesh

        cfg = configs.smoke_variant(configs.get_config('olmo-1b'))
        cfg = dataclasses.replace(cfg, vocab=64, dtype='float32')
        params_p = registry.init_params(cfg, jax.random.key(0))
        params = sharding.tree_values(params_p)
        batch = registry.make_batch(cfg, 8, 16, key=jax.random.key(1))

        loss1 = float(registry.loss_fn(cfg, params, batch)[0])

        mesh = make_local_mesh((2, 2, 2), ('pod', 'data', 'model'))
        rules = sharding.ShardingRules()
        with sharding.use_mesh(mesh, rules):
            shards = sharding.tree_shardings(params_p, mesh, rules)
            sp = jax.device_put(params, shards)
            loss2 = float(jax.jit(
                lambda p, b: registry.loss_fn(cfg, p, b)[0])(sp, batch))
        assert abs(loss1 - loss2) < 1e-3, (loss1, loss2)
        print('ok', loss1, loss2)
    """)


def test_sharded_grads_match_single_device():
    run8("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.models import registry
        from repro.parallel import sharding
        from repro.launch.mesh import make_local_mesh

        cfg = configs.smoke_variant(configs.get_config('mamba-130m'))
        cfg = dataclasses.replace(cfg, vocab=64, n_layers=2, dtype='float32')
        params_p = registry.init_params(cfg, jax.random.key(0))
        params = sharding.tree_values(params_p)
        batch = registry.make_batch(cfg, 8, 16, key=jax.random.key(1))
        g1 = jax.grad(lambda p: registry.loss_fn(cfg, p, batch)[0])(params)

        mesh = make_local_mesh((4, 2), ('data', 'model'))
        rules = sharding.ShardingRules()
        with sharding.use_mesh(mesh, rules):
            shards = sharding.tree_shardings(params_p, mesh, rules)
            sp = jax.device_put(params, shards)
            g2 = jax.jit(jax.grad(
                lambda p: registry.loss_fn(cfg, p, batch)[0]))(sp, )
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), g1, g2)
        mx = max(jax.tree.leaves(d))
        assert mx < 5e-3, mx
        print('ok', mx)
    """)


def test_elastic_checkpoint_reshard():
    run8("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager
        from repro.launch.mesh import make_local_mesh

        tree = {'w': jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        mesh_a = make_local_mesh((2, 2), ('data', 'model'))
        sh_a = {'w': NamedSharding(mesh_a, P('data', 'model'))}
        tree_a = jax.device_put(tree, sh_a)

        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d, keep=1)
        mgr.save(3, tree_a, blocking=True)

        mesh_b = make_local_mesh((8,), ('data',))
        sh_b = {'w': NamedSharding(mesh_b, P('data'))}
        got, step = mgr.restore(tree, shardings=sh_b)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(got['w']),
                                      np.asarray(tree['w']))
        assert got['w'].sharding == sh_b['w']
        print('ok')
    """)


def test_compressed_psum():
    run8("""
        import functools, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim.compression import compressed_psum
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh((8,), ('data',))
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(8, 64)).astype(np.float32))

        f = shard_map(functools.partial(compressed_psum, axis_name='data'),
                      mesh=mesh, in_specs=P('data'), out_specs=P())
        got = f(x)
        want = x.mean(0)
        err = float(jnp.max(jnp.abs(got - want)))
        scale = float(jnp.max(jnp.abs(x)))
        assert err < scale / 127 * 2, (err, scale)
        print('ok', err)
    """)


def test_pipeline_parallel_matches_sequential():
    run8("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import pipeline_apply
        from repro.launch.mesh import make_local_mesh

        S, b, d = 4, 16, 32
        ws = jax.random.normal(jax.random.key(0), (S, d, d)) * 0.3

        def stage(w, x):
            return jnp.tanh(x @ w['w'])

        mesh = make_local_mesh((4,), ('pipe',))
        x = jax.random.normal(jax.random.key(1), (b, d))
        got = pipeline_apply(mesh, stage, {'w': ws}, x, n_micro=4)

        ref = x
        for i in range(S):
            ref = jnp.tanh(ref @ ws[i])
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

        # and grads flow through the pipeline
        def loss(ws_):
            return jnp.sum(pipeline_apply(mesh, stage, {'w': ws_}, x,
                                          n_micro=4) ** 2)
        def loss_ref(ws_):
            r = x
            for i in range(S):
                r = jnp.tanh(r @ ws_[i])
            return jnp.sum(r ** 2)
        g1 = jax.grad(loss)(ws)
        g2 = jax.grad(loss_ref)(ws)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-4)
        print('ok')
    """)


def test_sequence_parallel_scan_matches_reference():
    run8("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.kernels import ref
        from repro.parallel.sp_scan import sp_selective_scan
        from repro.launch.mesh import make_local_mesh

        rng = np.random.default_rng(0)
        b, L, d, n = 2, 64, 16, 4
        x = jnp.asarray(rng.normal(size=(b, L, d)).astype(np.float32))
        dt = jax.nn.softplus(jnp.asarray(
            rng.normal(size=(b, L, d)).astype(np.float32)))
        A = -jnp.exp(jnp.asarray(rng.normal(size=(d, n)).astype(np.float32))
                     * 0.5)
        B = jnp.asarray(rng.normal(size=(b, L, n)).astype(np.float32))
        C = jnp.asarray(rng.normal(size=(b, L, n)).astype(np.float32))
        D = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
        z = jnp.asarray(rng.normal(size=(b, L, d)).astype(np.float32))

        y0, h0 = ref.selective_scan(x, dt, A, B, C, D, z)
        mesh = make_local_mesh((8,), ('sp',))
        y1, h1 = sp_selective_scan(mesh, x, dt, A, B, C, D=D, z=z)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h0),
                                   rtol=2e-3, atol=2e-3)
        print('ok')
    """)


def test_collectives_counted_with_trip_multipliers():
    run8("""
        import functools, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.launch.mesh import make_local_mesh
        from repro.launch import hlo_cost

        mesh = make_local_mesh((8,), ('data',))
        x = jnp.zeros((8, 1024), jnp.float32)

        def f(x):
            def body(i, acc):
                return acc + jax.lax.psum(acc, 'data') * 1e-6
            return jax.lax.fori_loop(0, 5, body, x)

        g = shard_map(f, mesh=mesh, in_specs=P('data'), out_specs=P('data'))
        txt = jax.jit(g).lower(x).compile().as_text()
        c = hlo_cost.analyze(txt)
        per_iter = 1024 * 4           # one row f32 per device
        assert c.collective_bytes >= 5 * per_iter, c.collective_bytes
        print('ok', c.collective_bytes)
    """)


def test_production_mesh_requires_512():
    run8("""
        from repro.launch.mesh import make_production_mesh
        try:
            make_production_mesh()
            raise SystemExit('should have raised')
        except RuntimeError as e:
            assert '512' in str(e) or '256' in str(e)
        print('ok')
    """)


def test_ep_shardmap_matches_dense_dispatch():
    """Expert-parallel all-to-all dispatch (§Perf Q5) == dense dispatch at
    no-drop capacity; gradients flow through the a2a."""
    run8("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.models import registry
        from repro.parallel import sharding
        from repro.launch.mesh import make_local_mesh

        cfg = configs.smoke_variant(configs.get_config('qwen2-moe-a2.7b'))
        cfg = dataclasses.replace(cfg, vocab=64, dtype='float32',
                                  capacity_factor=float(cfg.n_experts),
                                  expert_pad_to=4)
        params = sharding.tree_values(
            registry.init_params(cfg, jax.random.key(0)))
        batch = registry.make_batch(cfg, 4, 16, key=jax.random.key(1))

        cfg_dense = dataclasses.replace(cfg, moe_impl='dense')
        logits_dense, _ = registry.forward(cfg_dense, params, batch)

        mesh = make_local_mesh((2, 2, 2), ('pod', 'data', 'model'))
        cfg_ep = dataclasses.replace(cfg, moe_impl='ep')
        with sharding.use_mesh(mesh, sharding.ShardingRules()):
            logits_ep, _ = jax.jit(
                lambda p, b: registry.forward(cfg_ep, p, b))(params, batch)
            g = jax.jit(jax.grad(
                lambda p: registry.loss_fn(cfg_ep, p, batch)[0]))(params)
        d = float(jnp.max(jnp.abs(logits_ep - logits_dense)))
        assert d < 2e-2, d
        mx = max(float(jnp.max(jnp.abs(l))) for l in jax.tree.leaves(g))
        assert np.isfinite(mx) and mx > 0
        print('ok', d, mx)
    """)
