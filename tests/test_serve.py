"""Serving tests: prefill+decode consistency vs full forward (the invariant
that makes KV/state caching correct), batched generation, Server API."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import registry
from repro.parallel import sharding
from repro.runtime.serve import ServeConfig, Server

jax.config.update("jax_platform_name", "cpu")


def _setup(name):
    cfg = configs.smoke_variant(configs.get_config(name))
    # capacity_factor high enough that no token ever drops: MoE dropping is
    # count-dependent, which would (correctly) break prefill-vs-forward
    # bit-equality on different sequence lengths.
    cfg = dataclasses.replace(cfg, vocab=64, dtype="float32",
                              capacity_factor=float(max(cfg.n_experts, 1)))
    params = sharding.tree_values(
        registry.init_params(cfg, jax.random.key(0)))
    return cfg, params


DECODE_ARCHS = ["mamba-130m", "granite-20b", "qwen2-7b", "jamba-v0.1-52b",
                "xlstm-350m", "qwen2-moe-a2.7b"]


@pytest.mark.parametrize("name", DECODE_ARCHS)
def test_prefill_then_decode_matches_forward(name):
    """logits from [prefill(t0..t8); decode(t9)] == forward(t0..t9)[:, -1]."""
    cfg, params = _setup(name)
    b, L = 2, 10
    toks = jax.random.randint(jax.random.key(1), (b, L), 0, cfg.vocab,
                              dtype=jnp.int32)
    full_logits, _ = registry.forward(cfg, params, {"tokens": toks})

    cache = sharding.tree_values(registry.init_cache(cfg, b, max_seq=16))
    pre_logits, cache = registry.prefill(cfg, params, cache,
                                         {"tokens": toks[:, :L - 1]})
    np.testing.assert_allclose(
        np.asarray(pre_logits), np.asarray(full_logits[:, :L - 1]),
        rtol=2e-2, atol=2e-2)
    dec_logits, cache = registry.decode_step(cfg, params, cache,
                                             {"tokens": toks[:, L - 1:]})
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0]), np.asarray(full_logits[:, -1]),
        rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("name", ["mamba-130m", "granite-20b"])
def test_greedy_generation_matches_teacher_forcing(name):
    """Each greedily generated token equals argmax of a fresh full forward
    over the extended prefix (decode path == forward path)."""
    cfg, params = _setup(name)
    prompts = np.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], np.int32)
    srv = Server(cfg, params, ServeConfig(max_seq=32))
    gen = srv.generate(prompts, max_new=5)
    seq = np.concatenate([prompts, gen], axis=1)
    for t in range(5):
        ctx = jnp.asarray(seq[:, :4 + t])
        logits, _ = registry.forward(cfg, params, {"tokens": ctx})
        want = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        np.testing.assert_array_equal(seq[:, 4 + t], want)


def test_server_batch_api():
    cfg, params = _setup("mamba-130m")
    srv = Server(cfg, params, ServeConfig(max_seq=64))
    out = srv.generate(np.ones((3, 6), np.int32), max_new=8)
    assert out.shape == (3, 8)
    assert out.dtype in (np.int32, np.int64)
    assert (out >= 0).all() and (out < cfg.vocab).all()


@pytest.mark.parametrize("name", ["granite-20b", "qwen2-7b"])
def test_int8_kv_cache_decode_consistency(name):
    """int8 KV cache (per-position absmax): greedy decode agrees with the
    full forward argmax; logit drift bounded by quantization error."""
    cfg, params = _setup(name)
    cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    toks = jax.random.randint(jax.random.key(2), (2, 10), 0, cfg.vocab,
                              dtype=jnp.int32)
    full, _ = registry.forward(cfg, params, {"tokens": toks})
    cache = sharding.tree_values(registry.init_cache(cfg, 2, 16))
    assert cache["k"].dtype == jnp.int8
    _, cache = registry.prefill(cfg, params, cache,
                                {"tokens": toks[:, :9]})
    dec, cache = registry.decode_step(cfg, params, cache,
                                      {"tokens": toks[:, 9:]})
    drift = float(jnp.max(jnp.abs(dec[:, 0] - full[:, -1])))
    assert drift < 0.5, drift
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(dec[:, 0], -1)),
        np.asarray(jnp.argmax(full[:, -1], -1)))
