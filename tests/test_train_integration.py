"""End-to-end training integration: loss decreases on the synthetic corpus,
checkpoint/restart resume equivalence, injected-failure recovery, straggler
detection (deliverable c: integration tier)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.optim import AdamWConfig
from repro.runtime.metrics import StragglerDetector
from repro.runtime.train_loop import TrainConfig, Trainer

jax.config.update("jax_platform_name", "cpu")


def _tiny_cfg(name="mamba-130m", **kw):
    cfg = configs.smoke_variant(configs.get_config(name))
    return dataclasses.replace(cfg, vocab=64, n_layers=2, d_model=32,
                               dt_rank=4, **kw)


def _tcfg(tmp, **kw):
    base = dict(total_steps=60, warmup_steps=5, global_batch=8, seq_len=32,
                ckpt_every=20, ckpt_dir=str(tmp), log_every=1000,
                optimizer=AdamWConfig(lr=3e-3, weight_decay=0.01))
    base.update(kw)
    return TrainConfig(**base)


class TestTraining:
    def test_loss_decreases_mamba(self, tmp_path):
        t = Trainer(_tiny_cfg(), _tcfg(tmp_path))
        _, _, losses = t.run(resume=False)
        first = np.mean(losses[:5])
        last = np.mean(losses[-5:])
        assert last < first - 0.3, (first, last)

    def test_loss_decreases_transformer(self, tmp_path):
        t = Trainer(_tiny_cfg("olmo-1b"), _tcfg(tmp_path))
        _, _, losses = t.run(resume=False)
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3

    def test_resume_bitwise_equivalent(self, tmp_path):
        """Train 40 straight vs 20 + resume + 20: same loss trajectory."""
        cfg = _tiny_cfg()
        t1 = Trainer(cfg, _tcfg(tmp_path / "a", total_steps=40,
                                ckpt_every=20))
        _, _, l_straight = t1.run(resume=False)

        t2 = Trainer(cfg, _tcfg(tmp_path / "b", total_steps=40,
                                ckpt_every=20))
        t2.run(resume=False, max_steps=20)
        t3 = Trainer(cfg, _tcfg(tmp_path / "b", total_steps=40,
                                ckpt_every=20))
        _, _, l_resumed = t3.run(resume=True)
        np.testing.assert_allclose(l_straight[20:], l_resumed, rtol=2e-4,
                                   atol=2e-4)

    def test_crash_recovery(self, tmp_path):
        """Injected failure -> rerun auto-resumes from the flushed ckpt."""
        cfg = _tiny_cfg()
        t = Trainer(cfg, _tcfg(tmp_path, total_steps=30))
        with pytest.raises(RuntimeError, match="injected failure"):
            t.run(resume=False, fail_at_step=12)
        t2 = Trainer(cfg, _tcfg(tmp_path, total_steps=30))
        _, _, losses = t2.run(resume=True)
        assert len(losses) == 18                    # steps 12..29
        assert np.isfinite(losses).all()

    def test_grad_accum_matches_full_batch(self, tmp_path):
        """grad_accum=2 with same global batch gives ~same first-step grads."""
        cfg = _tiny_cfg()
        t1 = Trainer(cfg, _tcfg(tmp_path / "a", total_steps=3))
        _, _, l1 = t1.run(resume=False)
        t2 = Trainer(cfg, _tcfg(tmp_path / "b", total_steps=3,
                                grad_accum=2))
        _, _, l2 = t2.run(resume=False)
        np.testing.assert_allclose(l1, l2, rtol=1e-3, atol=1e-3)

    def test_resume_with_no_checkpoint_falls_back_to_fresh(self, tmp_path):
        """resume=True on an empty (and even not-yet-created) ckpt dir
        must train from fresh init, not raise."""
        t = Trainer(_tiny_cfg(), _tcfg(tmp_path / "never_written",
                                       total_steps=3))
        _, _, losses = t.run(resume=True)
        assert len(losses) == 3
        assert np.isfinite(losses).all()

    @pytest.mark.parametrize("damage", ["missing_npz", "corrupt_npz"])
    def test_resume_with_torn_checkpoint_falls_back_to_fresh(
            self, tmp_path, damage):
        """A crash or disk fault can leave a step dir with meta.json but a
        missing or truncated arrays.npz; resume must fall back to fresh
        init instead of wedging every restart."""
        torn = tmp_path / "step_000000000010"
        torn.mkdir(parents=True)
        (torn / "meta.json").write_text("{\"step\": 10, \"leaves\": {}}")
        if damage == "corrupt_npz":
            (torn / "arrays.npz").write_bytes(b"not a zip archive")
        t = Trainer(_tiny_cfg(), _tcfg(tmp_path, total_steps=3))
        _, _, losses = t.run(resume=True)
        assert len(losses) == 3            # started at step 0, not 10
        assert np.isfinite(losses).all()

    def test_resume_falls_back_to_older_intact_checkpoint(self, tmp_path):
        """If the newest checkpoint is corrupt, resume must retry older
        intact ones before resorting to fresh init — a torn latest write
        must not discard real progress."""
        cfg = _tiny_cfg()
        t = Trainer(cfg, _tcfg(tmp_path, total_steps=20, ckpt_every=10))
        t.run(resume=False)
        steps = t.ckpt.all_steps()
        assert len(steps) >= 2
        newest = tmp_path / f"step_{steps[-1]:012d}"
        (newest / "arrays.npz").write_bytes(b"garbage")
        t2 = Trainer(cfg, _tcfg(tmp_path, total_steps=25, ckpt_every=10))
        _, _, losses = t2.run(resume=True)
        assert len(losses) == 25 - steps[-2]   # resumed from the older step

    def test_grad_compression_trains(self, tmp_path):
        t = Trainer(_tiny_cfg(), _tcfg(tmp_path, grad_compression=True))
        _, _, losses = t.run(resume=False)
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2

    def test_int8_optimizer_trains(self, tmp_path):
        tc = _tcfg(tmp_path, optimizer=AdamWConfig(
            lr=3e-3, weight_decay=0.01, moment_dtype="int8"))
        t = Trainer(_tiny_cfg(), tc)
        _, _, losses = t.run(resume=False)
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


class TestStraggler:
    def test_detects_outlier(self):
        d = StragglerDetector(z=3.0, warmup=5)
        for i in range(20):
            d.record(i, 0.1 + 0.001 * (i % 3))
        assert not d.flagged
        assert d.record(20, 1.0) is True
        assert d.flagged and d.flagged[0][0] == 20

    def test_constant_warmup_does_not_blind_detector(self):
        """Regression: a perfectly constant warmup leaves var == 0, and
        the old inf-std fallback made the detector permanently blind —
        a 100x straggler passed unflagged AND corrupted the EMA mean.
        The std floor (relative to the mean) must flag it while leaving
        ordinary jitter below the floor unflagged."""
        d = StragglerDetector(z=3.0, warmup=5)
        for i in range(5):
            assert not d.record(i, 0.1)
        mean_before = d.mean
        assert d.record(5, 10.0) is True        # 100x step must flag
        assert d.flagged == [(5, 10.0)]
        assert d.mean == mean_before            # flagged: EMA untouched
        assert not d.record(6, 0.101)           # 1% jitter stays quiet

    def test_adapts_to_drift(self):
        d = StragglerDetector(z=4.0, warmup=5)
        for i in range(100):
            d.record(i, 0.1 + i * 0.0002)       # slow drift: no flags
        assert len(d.flagged) == 0
