"""Async front-end tests — every test runs under a HARD asyncio
deadline (``asyncio.wait_for``), so a pump deadlock fails fast instead
of hanging CI.  No pytest-asyncio dependency: each test drives its own
``asyncio.run``.
"""
import asyncio
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import registry
from repro.parallel import sharding
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.frontend import AsyncFrontend
from repro.runtime.sampling import SamplingParams
from repro.runtime.scheduler import SchedConfig, SLOClass, SLOScheduler

jax.config.update("jax_platform_name", "cpu")

DEADLINE_S = 120.0


def _setup(name="mamba-130m"):
    cfg = configs.smoke_variant(configs.get_config(name))
    cfg = dataclasses.replace(cfg, vocab=64, dtype="float32",
                              capacity_factor=float(max(cfg.n_experts, 1)))
    params = sharding.tree_values(
        registry.init_params(cfg, jax.random.key(0)))
    return cfg, params


def _run(coro):
    asyncio.run(asyncio.wait_for(coro, DEADLINE_S))


def test_stream_tokens_match_engine_run():
    """The async iterator delivers exactly the tokens a plain
    ``Engine.run`` of the same submissions produces (bitwise — the
    front-end is plumbing, not math), including sampled streams."""
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 60, size=6) for _ in range(4)]
    sps = [SamplingParams(max_new=5),
           SamplingParams(temperature=0.8, top_k=8, max_new=5, seed=3),
           SamplingParams(max_new=5),
           SamplingParams(temperature=1.1, top_p=0.9, max_new=5, seed=4)]

    ref_eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=32,
                                               seed=1))
    for p, sp in zip(prompts, sps):
        ref_eng.submit(p, sp)
    ref = [r.tokens for r in sorted(ref_eng.run(),
                                    key=lambda r: r.req_id)]

    async def main():
        eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=32,
                                               seed=1))
        async with AsyncFrontend(eng) as fe:
            handles = [await fe.submit(p, sp)
                       for p, sp in zip(prompts, sps)]
            streams = []
            for h in handles:
                toks = [t async for t in h.tokens()]
                req = await h.result()
                assert req.tokens == toks
                streams.append(toks)
        assert streams == ref

    _run(main())


def test_concurrent_consumers_interleave():
    """Two clients consuming their streams concurrently each see their
    own complete stream."""
    cfg, params = _setup()
    rng = np.random.default_rng(1)

    async def main():
        eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=32,
                                               seed=2))
        async with AsyncFrontend(eng) as fe:
            ha = await fe.submit(rng.integers(1, 60, size=6), max_new=6)
            hb = await fe.submit(rng.integers(1, 60, size=6), max_new=6)

            async def consume(h):
                return [t async for t in h.tokens()]

            ta, tb = await asyncio.gather(consume(ha), consume(hb))
            ra, rb = await ha.result(), await hb.result()
            assert ra.tokens == ta and rb.tokens == tb
            assert len(ta) == 6 and len(tb) == 6

    _run(main())


def test_cancel_mid_stream_ends_iterator():
    cfg, params = _setup()
    rng = np.random.default_rng(2)

    async def main():
        eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64,
                                               seed=0, sched_quantum=2))
        async with AsyncFrontend(eng) as fe:
            h = await fe.submit(rng.integers(1, 60, size=5), max_new=40)
            got = []
            async for tok in h.tokens():
                got.append(tok)
                if len(got) == 4:
                    await fe.cancel(h)
            req = await h.result()
            assert req.cancelled and req.finished
            # tokens already delivered stand; no unbounded overrun past
            # the cancel sync
            assert len(got) >= 4 and len(got) < 40

    _run(main())


def test_shed_handle_resolves_with_empty_stream():
    """Admission-control rejection IS the response: the handle resolves
    immediately, shed=True, zero tokens.  Deterministic under the
    concurrent pump: a session pins the ONLY slot, so the projected
    wait for the next request is inf regardless of decode progress."""
    cfg, params = _setup()
    rng = np.random.default_rng(3)

    async def main():
        eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=16,
                                               seed=0))
        sched = SLOScheduler(eng, SchedConfig(
            weights={"t": 1.0}, classes=(SLOClass(ttft_budget=20),)))
        async with AsyncFrontend(eng, sched) as fe:
            sess = await fe.submit(rng.integers(1, 60, size=4),
                                   tenant="t", session=True)
            # one token out => the session is admitted and its lease
            # pinned; from here effective slots == 0, projection == inf
            agen = sess.tokens()
            await agen.__anext__()
            await agen.aclose()
            shed = await fe.submit(rng.integers(1, 60, size=4),
                                   tenant="t", max_new=8)
            assert shed.shed
            toks = [t async for t in shed.tokens()]
            assert toks == [] and await shed.result() is None
            await fe.cancel(sess)
            res = await sess.result()
            assert res.cancelled
        assert eng.stats.n_shed == 1

    _run(main())


def test_tenant_context_binds_labels():
    cfg, params = _setup()
    rng = np.random.default_rng(4)

    async def main():
        eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=32,
                                               seed=0))
        sched = SLOScheduler(eng, SchedConfig(
            weights={"acme": 2.0}, classes=(SLOClass(ttft_budget=999),)))
        async with AsyncFrontend(eng, sched) as fe:
            acme = fe.tenant("acme")
            h = await acme.submit(rng.integers(1, 60, size=5), max_new=4)
            req = await h.result()
            assert req.tenant == "acme"
        assert eng.stats.summary()["per_tenant"]["acme"]["requests"] == 1

    _run(main())


def test_stop_drains_infinite_session():
    """Context-manager exit cancels live sessions so no slot stays
    pinned and every handle resolves — the eviction-free lease ends
    with the connection."""
    cfg, params = _setup()
    rng = np.random.default_rng(5)

    async def main():
        eng = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=16,
                                               seed=0))
        async with AsyncFrontend(eng) as fe:
            h = await fe.submit(rng.integers(1, 60, size=4),
                                session=True)
            got = []
            async for tok in h.tokens():
                got.append(tok)
                if len(got) >= 12:
                    break                 # client walks away mid-stream
            assert eng.pool.n_pinned == 1
        # __aexit__ drained: session cancelled, lease released
        assert eng.pool.n_pinned == 0
        assert h.finished and h.req.cancelled
        assert len(h.req.tokens) >= 12

    _run(main())


def test_submit_before_start_raises():
    cfg, params = _setup()

    async def main():
        eng = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=32))
        fe = AsyncFrontend(eng)
        with pytest.raises(RuntimeError, match="not started"):
            await fe.submit(np.arange(1, 5), max_new=4)

    _run(main())
