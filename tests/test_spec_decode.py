"""Speculative decoding: exactness-first test harness.

Spec decode is notoriously easy to get *silently* wrong — an accepted
token that doesn't match what the target model would have emitted is a
correctness bug that no throughput metric will ever surface.  So the
centerpiece here is the token-identity gate: greedy speculative decode
must produce EXACTLY the token stream of plain greedy decode, for every
model family, quantized and full-precision state, fused and unfused
step dispatch, with both a real (shallow, mostly-rejected) draft and
the degenerate full-depth draft.  Around it: bitwise fork/rollback
state hygiene, property-based acceptance-math bounds (hypothesis with
the deterministic fallback shim), the rejection-sampling marginal, and
parity of the block-level K-token verify wrappers against chained
single-token steps.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # degrade to the deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro import configs
from repro.core import selective_scan as css
from repro.models import mamba, registry, xlstm
from repro.parallel import sharding
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.spec_decode import DraftConfig, accept_tokens
from repro.runtime.state_pool import SlotStatePool

jax.config.update("jax_platform_name", "cpu")
RNG = np.random.default_rng(17)

FAMILIES = ["mamba-130m", "jamba-v0.1-52b", "xlstm-350m"]


def _setup(name, **over):
    cfg = configs.smoke_variant(configs.get_config(name))
    cfg = dataclasses.replace(cfg, vocab=64, dtype="float32",
                              capacity_factor=float(max(cfg.n_experts, 1)),
                              **over)
    params = sharding.tree_values(
        registry.init_params(cfg, jax.random.key(0)))
    return cfg, params


def _shallow_layers(cfg):
    """A real (strict-prefix) draft depth where the family allows one:
    jamba's granularity is whole groups, so its smoke config (one
    group) has no strict prefix and uses full depth — the other
    families use half depth.  Same helper the benchmark defaults to."""
    from repro.runtime.spec_decode import default_shallow_layers
    return default_shallow_layers(cfg)


def _prompts(cfg, n, seed=5):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, size=(l,)).astype(np.int32)
            for l in rng.integers(3, 10, size=n)]


def _tree_equal(a, b):
    flat_a, flat_b = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(flat_a) == len(flat_b)
    return all(bool(jnp.array_equal(x, y.astype(x.dtype)))
               for x, y in zip(flat_a, flat_b))


# ---------------------------------------------------------------------------
# The flagship gate: greedy spec decode == plain greedy decode,
# token for token, across families x state dtypes x step impls.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("step_impl", ["fused", "xla"])
@pytest.mark.parametrize("state_dtype", ["f32", "int8"])
@pytest.mark.parametrize("name", FAMILIES)
def test_greedy_spec_decode_token_identical(name, state_dtype, step_impl):
    """Speculation must change throughput, never tokens: under slot
    churn (more requests than slots), the spec engine's per-request
    streams equal the plain engine's exactly.  The shallow draft makes
    real proposals that are mostly rejected on these random-weight
    models — rejection, correction-token emission, and rollback are all
    on the tested path, not just the accept-everything fast lane."""
    cfg, params = _setup(name)
    prompts = _prompts(cfg, 4)
    base = EngineConfig(n_slots=2, max_seq=64, state_dtype=state_dtype,
                        step_impl=step_impl)
    plain = Engine(cfg, params, base)
    ref = [plain.submit(p, max_new=7) for p in prompts]
    plain.run()
    draft = DraftConfig(k=3, layers=_shallow_layers(cfg))
    eng = Engine(cfg, params, dataclasses.replace(base, draft=draft))
    got = [eng.submit(p, max_new=7) for p in prompts]
    eng.run()
    for r_ref, r_got in zip(ref, got):
        assert r_got.tokens == r_ref.tokens, \
            f"req {r_got.req_id} diverged under speculative decode"
    s = eng.stats.summary()
    assert s["spec_target_passes"] > 0
    assert s["spec_accepted_per_pass"] >= 1.0
    # per-slot speculative-depth bookkeeping adds up: every (pass,
    # active slot) is attributed to exactly one resident request
    assert (sum(r.spec_passes for r in got)
            == eng.stats.spec_slot_passes)
    assert (sum(r.spec_accepted for r in got)
            == eng.stats.spec_accepted)
    assert all(0 <= r.spec_accepted <= r.spec_passes * draft.k
               for r in got)


def test_spec_mixed_batch_greedy_slots_token_identical():
    """Per-slot temperatures in the acceptance math: a batch mixing
    greedy and sampled requests runs through ONE verify jit, and the
    greedy slots' streams are bitwise the all-greedy spec engine's
    (which is bitwise plain greedy decode)."""
    from repro.runtime import sampling
    from repro.runtime.sampling import SamplingParams
    cfg, params = _setup("mamba-130m")
    prompts = _prompts(cfg, 4)
    plain = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64))
    ref = [plain.submit(p, max_new=7) for p in prompts]
    plain.run()
    draft = DraftConfig(k=3, layers=_shallow_layers(cfg))
    # warm the spec jits with an all-greedy run, then assert the mixed
    # batch retraces nothing (params are traced arrays, never keys)
    warm = Engine(cfg, params,
                  EngineConfig(n_slots=2, max_seq=64, draft=draft))
    for p in prompts:
        warm.submit(p, max_new=7)
    warm.run()
    before = dict(sampling.TRACE_COUNTS)
    eng = Engine(cfg, params,
                 EngineConfig(n_slots=2, max_seq=64, draft=draft))
    mix = [None,
           SamplingParams(temperature=0.8, seed=21),
           None,
           SamplingParams(temperature=1.1, top_k=8, seed=22)]
    got = [eng.submit(p, params=sp, max_new=7)
           for p, sp in zip(prompts, mix)]
    eng.run()
    after = dict(sampling.TRACE_COUNTS)
    for k in ("draft_step", "verify", "decode_step"):
        assert after.get(k, 0) == before.get(k, 0), \
            f"mixed-batch spec decode retraced {k}"
    for i in (0, 2):
        assert got[i].tokens == ref[i].tokens, \
            f"greedy slot {i} diverged in a mixed spec batch"
    assert all(len(r.tokens) == 7 for r in got)
    assert eng.pool.n_scratch_free == eng.pool.n_scratch


def test_adaptive_depth_bitwise_greedy_and_fewer_drafts():
    """DraftConfig.adaptive clamps each slot's window to its realized
    acceptance: on a mostly-rejecting shallow draft the drafted-token
    count drops, while every greedy stream stays bitwise identical
    (the clamp changes depth arithmetic, never token values)."""
    cfg, params = _setup("mamba-130m")
    prompts = _prompts(cfg, 3)
    layers = _shallow_layers(cfg)
    fixed = Engine(cfg, params,
                   EngineConfig(n_slots=2, max_seq=64,
                                draft=DraftConfig(k=4, layers=layers)))
    rf = [fixed.submit(p, max_new=12) for p in prompts]
    fixed.run()
    adap = Engine(cfg, params,
                  EngineConfig(n_slots=2, max_seq=64,
                               draft=DraftConfig(k=4, layers=layers,
                                                 adaptive=True)))
    ra = [adap.submit(p, max_new=12) for p in prompts]
    adap.run()
    assert [r.tokens for r in ra] == [r.tokens for r in rf], \
        "adaptive draft depth changed the greedy token stream"
    # realized acceptance on random smoke weights is low, so the
    # adaptive windows shrink and fewer draft tokens are proposed
    assert adap.stats.spec_drafted < fixed.stats.spec_drafted, \
        (adap.stats.spec_drafted, fixed.stats.spec_drafted)
    # the bookkeeping driving the clamp stays exact
    assert (sum(r.spec_accepted for r in ra)
            == adap.stats.spec_accepted)


@pytest.mark.parametrize("name", ["mamba-130m", "xlstm-350m"])
def test_full_depth_draft_accepts_everything(name):
    """The degenerate self-draft (draft == target) must accept every
    proposal: accepted-tokens-per-target-pass == k+1 up to end-of-
    request trims, and the stream still equals plain greedy decode."""
    cfg, params = _setup(name)
    prompts = _prompts(cfg, 2)
    plain = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64))
    ref = [plain.submit(p, max_new=8) for p in prompts]
    plain.run()
    eng = Engine(cfg, params,
                 EngineConfig(n_slots=2, max_seq=64,
                              draft=DraftConfig(k=3, layers=0)))
    got = [eng.submit(p, max_new=8) for p in prompts]
    eng.run()
    assert [r.tokens for r in got] == [r.tokens for r in ref]
    s = eng.stats.summary()
    assert s["spec_acceptance_rate"] == 1.0
    assert s["spec_accepted_per_pass"] > 1.0


def test_full_depth_draft_sampled_window_bitwise_plain():
    """Regression for the fork-seed aliasing fix: the DRAFT fork must
    copy the slot's key stream VERBATIM (branch_tags=None), never
    re-derive it.  With a full-depth draft and sampled requests the
    draft proposes with the target's own weights AND the slot's own key
    at the same fold positions, so p_draft == p_target: every proposal
    is accepted AND the accepted window is bitwise the plain sampled
    stream (the bonus/rejection tokens after the window use their own
    fold tags — distribution-faithful, not bitwise — so only the first
    window is comparable).  If fork ever tagged the draft's key (the
    best-of-n divergence path), the draft would sample with a
    re-derived key, the full-depth window would still be accepted
    (p_t == p_d -> ratio 1), and the emitted tokens would silently
    drift from the slot's own sample stream — exactly what this pins.
    (test_fork_branch_tags_* in test_prefix_cache.py pins the
    divergence direction.)"""
    from repro.runtime.sampling import SamplingParams
    cfg, params = _setup("mamba-130m")
    prompts = _prompts(cfg, 3, seed=13)
    sps = [SamplingParams(temperature=0.9, seed=31),
           SamplingParams(temperature=1.2, top_k=8, seed=32),
           SamplingParams(temperature=0.7, top_p=0.9, seed=33)]
    k = 3
    plain = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64))
    ref = [plain.submit(p, params=sp, max_new=8)
           for p, sp in zip(prompts, sps)]
    plain.run()
    eng = Engine(cfg, params,
                 EngineConfig(n_slots=2, max_seq=64,
                              draft=DraftConfig(k=k, layers=0)))
    got = [eng.submit(p, params=sp, max_new=8)
           for p, sp in zip(prompts, sps)]
    eng.run()
    for r_ref, r_got in zip(ref, got):
        # token 0 = prefill sample, tokens 1..k = the first fully
        # accepted draft window — all sampled with the slot's own
        # verbatim-copied key at plain decode's fold positions
        assert r_got.tokens[:k + 1] == r_ref.tokens[:k + 1], \
            (f"sampled req {r_got.req_id}'s accepted draft window "
             f"diverged from plain decode — draft fork is not "
             f"key-faithful")
    assert eng.stats.summary()["spec_acceptance_rate"] == 1.0


def test_spec_decode_with_eos_eviction_and_backfill():
    """EOS inside an accepted draft window must trim the overshoot,
    evict, and admit queued work — and every stream still equals the
    plain engine's (which equals the sequential reference per
    test_engine.py)."""
    cfg, params = _setup("mamba-130m")
    prompts = _prompts(cfg, 3, seed=9)
    plain = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    r = plain.submit(prompts[0], max_new=10)
    plain.run()
    eos = r.tokens[2]              # fires mid-window at k=3
    plain2 = Engine(cfg, params, EngineConfig(n_slots=1, max_seq=64))
    ref = [plain2.submit(prompts[0], max_new=10, eos_id=eos),
           plain2.submit(prompts[1], max_new=4),
           plain2.submit(prompts[2], max_new=5)]
    plain2.run()
    eng = Engine(cfg, params,
                 EngineConfig(n_slots=1, max_seq=64,
                              draft=DraftConfig(k=3, layers=2)))
    got = [eng.submit(prompts[0], max_new=10, eos_id=eos),
           eng.submit(prompts[1], max_new=4),
           eng.submit(prompts[2], max_new=5)]
    eng.run()
    assert [g.tokens for g in got] == [r.tokens for r in ref]
    assert got[0].tokens[-1] == eos and len(got[0].tokens) == 3


# ---------------------------------------------------------------------------
# Fork -> K-draft -> full-reject -> rollback leaves the pooled state
# bitwise equal to never having speculated.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("state_dtype", ["f32", "int8"])
@pytest.mark.parametrize("name", ["mamba-130m", "xlstm-350m"])
def test_full_reject_rollback_is_bitwise_clean(name, state_dtype,
                                               monkeypatch):
    """Force every draft proposal to be wrong (argmax+1): the pass must
    emit exactly one token (the target's own) and leave the live slot's
    pooled state — payload AND scales — bitwise identical to one plain
    decode step.  A single leaked draft byte (stale scale, conv tail,
    xLSTM stabilizer) fails this."""
    cfg, params = _setup(name, state_dtype=state_dtype)
    prompts = _prompts(cfg, 2, seed=3)
    # reference token streams: plain engine
    plain = Engine(cfg, params, EngineConfig(n_slots=2, max_seq=64))
    ref = [plain.submit(p, max_new=4) for p in prompts]
    plain.run()

    eng = Engine(cfg, params,
                 EngineConfig(n_slots=2, max_seq=64,
                              draft=DraftConfig(k=3, layers=0)))
    spec = eng._spec
    real_propose = spec.propose

    def wrong_propose(*args):
        cache, d_toks, d_logits = real_propose(*args)
        # the full-depth draft proposes the target argmax; +1 mod vocab
        # is therefore guaranteed wrong at every step
        return cache, (d_toks + 1) % cfg.vocab, d_logits

    monkeypatch.setattr(spec, "propose", wrong_propose)
    got = [eng.submit(p, max_new=4) for p in prompts]

    # drive manually: admit both, snapshot, then one forced-full-reject
    # speculative pass
    import heapq
    while eng._ready and eng.pool.n_free:
        eng._admit(heapq.heappop(eng._ready)[2])
    live = eng.pool.active_slots()
    cache0 = eng.pool.cache                    # immutable pytree
    toks0 = eng._next_tok.copy()
    act0 = eng.pool.active_mask()
    eng._spec_pass()
    s = eng.stats.summary()
    assert s["spec_acceptance_rate"] == 0.0
    assert s["spec_accepted_per_pass"] == 1.0
    # oracle: ONE plain decode step from the snapshot, through the
    # engine's own decode dispatch — "never having speculated"
    tok, _, _, _, cache1 = eng._decode(
        eng.params, cache0, jnp.asarray(toks0), jnp.asarray(act0),
        eng.pool.params.device(), jnp.asarray(eng._base_steps(live)))
    gather = lambda c: registry.gather_slots(cfg, c, jnp.asarray(live))
    assert _tree_equal(gather(cache1), gather(eng.pool.cache)), \
        "rollback left speculative residue in the pooled state"
    assert np.array_equal(np.asarray(tok)[live],
                          eng._next_tok[live])
    # and the full runs still agree token-for-token (repeated
    # full-reject churn all the way to completion)
    eng.run()
    assert [g.tokens for g in got] == [r.tokens for r in ref]


def test_fork_then_release_leaves_live_state_untouched():
    """Pool-level hygiene: fork to scratch, mutate nothing live, release
    — the live slot must be bitwise unchanged and every scratch lease
    must return to the free list."""
    cfg, params = _setup("mamba-130m", state_dtype="int8")
    pool = SlotStatePool(cfg, n_slots=2, max_seq=32, n_scratch=2)
    fresh = sharding.tree_values(registry.init_cache(cfg, 1, 32))
    toks = jnp.asarray(_prompts(cfg, 1, seed=11)[0][None])
    _, sub = registry.prefill(cfg, params, fresh, {"tokens": toks})
    slot = pool.alloc()
    pool.admit(slot, sub)
    before = pool.read([slot])
    sc = pool.lease_scratch()
    pool.fork([slot], [sc])
    assert _tree_equal(pool.read([sc]), before)
    pool.release_scratch(sc)
    assert _tree_equal(pool.read([slot]), before)
    assert pool.n_scratch_free == pool.n_scratch


# ---------------------------------------------------------------------------
# Acceptance core: property-based bounds + the rejection-sampling
# marginal (the "is it silently wrong" check, run on raw logits).
# ---------------------------------------------------------------------------

class TestAcceptanceBounds:
    @given(st.integers(1, 6), st.integers(1, 5), st.integers(2, 33),
           st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_greedy_prefix_semantics(self, k, b, vocab, seed):
        """Under random logits: n_acc is the longest draft prefix
        matching the target argmax; emitted count is n_acc + 1 in
        [1, k+1]; pending is the last emitted token."""
        rng = np.random.default_rng(seed)
        drafts = jnp.asarray(rng.integers(0, vocab, size=(k, b)), jnp.int32)
        tl = jnp.asarray(rng.normal(size=(k + 1, b, vocab)), jnp.float32)
        emit, n_acc, pending = accept_tokens(drafts, tl, 0.0)
        tgt = np.argmax(np.asarray(tl), axis=-1)
        for s in range(b):
            j = 0
            while j < k and int(drafts[j, s]) == int(tgt[j, s]):
                j += 1
            assert int(n_acc[s]) == j
            assert 1 <= j + 1 <= k + 1
            stream = [int(emit[t, s]) for t in range(j + 1)]
            # accepted prefix is the draft's, the last token the target's
            assert stream[:j] == [int(drafts[t, s]) for t in range(j)]
            assert stream[-1] == int(tgt[j, s])
            assert int(pending[s]) == stream[-1]

    @given(st.integers(1, 6), st.integers(1, 4), st.floats(0.25, 3.0),
           st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_sampled_identical_distributions_accept_all(self, k, b, temp,
                                                        seed):
        """p_draft == p_target => accept probability min(1, 1) = 1:
        every proposal is accepted regardless of temperature."""
        rng = np.random.default_rng(seed)
        dl = jnp.asarray(rng.normal(size=(k, b, 16)), jnp.float32)
        tl = jnp.concatenate(
            [dl, jnp.asarray(rng.normal(size=(1, b, 16)), jnp.float32)])
        drafts = jnp.asarray(rng.integers(0, 16, size=(k, b)), jnp.int32)
        _, n_acc, _ = accept_tokens(drafts, tl, float(temp),
                                    draft_logits=dl,
                                    key=jax.random.key(seed))
        assert (np.asarray(n_acc) == k).all()

    @given(st.integers(1, 6), st.integers(1, 4), st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_sampled_counts_in_bounds(self, k, b, seed):
        rng = np.random.default_rng(seed)
        dl = jnp.asarray(rng.normal(size=(k, b, 8)) * 3, jnp.float32)
        tl = jnp.asarray(rng.normal(size=(k + 1, b, 8)) * 3, jnp.float32)
        drafts = jnp.asarray(rng.integers(0, 8, size=(k, b)), jnp.int32)
        emit, n_acc, pending = accept_tokens(
            drafts, tl, 1.0, draft_logits=dl, key=jax.random.key(seed))
        na = np.asarray(n_acc)
        assert ((0 <= na) & (na <= k)).all()
        assert emit.shape == (k + 1, b)
        for s in range(b):
            assert int(pending[s]) == int(emit[int(na[s]), s])

    def test_sampled_marginal_matches_target(self):
        """The silent-wrongness check: over many trials with a SKEWED
        draft, the emitted first token's empirical distribution must
        match the target softmax (rejection sampling's whole point),
        within a generous total-variation budget."""
        vocab, trials = 6, 4000
        rng = np.random.default_rng(0)
        tl_row = rng.normal(size=(vocab,)).astype(np.float32)
        dl_row = rng.normal(size=(vocab,)).astype(np.float32) * 2.0
        tl = jnp.asarray(np.tile(tl_row, (2, trials, 1)), jnp.float32)
        dl = jnp.asarray(np.tile(dl_row, (1, trials, 1)), jnp.float32)
        p_d = np.exp(dl_row) / np.exp(dl_row).sum()
        drafts = jnp.asarray(
            rng.choice(vocab, size=(1, trials), p=p_d), jnp.int32)
        emit, n_acc, _ = accept_tokens(drafts, tl, 1.0, draft_logits=dl,
                                       key=jax.random.key(42))
        first = np.asarray(emit[0])
        counts = np.bincount(first, minlength=vocab) / trials
        p_t = np.exp(tl_row) / np.exp(tl_row).sum()
        tv = 0.5 * np.abs(counts - p_t).sum()
        assert tv < 0.05, (tv, counts, p_t)


# ---------------------------------------------------------------------------
# Block-level K-token verify wrappers == chained single-token steps
# (the batched-front-end fast path the engine can adopt once validated
# on real TPU; gated here against the chained oracle).
# ---------------------------------------------------------------------------

def _chain_steps(step_fn, cfg, p, x_seq, state):
    outs, states = [], []
    for t in range(x_seq.shape[1]):
        y, state = step_fn(cfg, p, x_seq[:, t:t + 1], state)
        outs.append(y)
        states.append(state)
    return jnp.concatenate(outs, axis=1), states


@pytest.mark.parametrize("state_dtype", ["f32", "int8"])
@pytest.mark.parametrize("step_impl", ["fused", "xla"])
def test_mamba_block_verify_matches_chained_steps(step_impl, state_dtype):
    cfg, params = _setup("mamba-130m", step_impl=step_impl,
                         state_dtype=state_dtype)
    p = jax.tree.map(lambda q: q[0], params["layers"])["mixer"]
    b, K = 2, 4
    di, n, kcv = cfg.d_inner, cfg.d_state, cfg.d_conv
    state = {"conv": jnp.asarray(RNG.normal(size=(b, kcv - 1, di)),
                                 jnp.float32)}
    h0 = jnp.asarray(RNG.normal(size=(b, di, n)), jnp.float32)
    if state_dtype == "int8":
        from repro.core import state_quant
        q, s = state_quant.quantize_h(h0, "int8")
        state.update({"h": q, "h_scale": s})
    else:
        state["h"] = h0
    x = jnp.asarray(RNG.normal(size=(b, K, cfg.d_model)), jnp.float32)
    y_ref, states_ref = _chain_steps(mamba.mamba_block_step, cfg, p, x,
                                     dict(state))
    y_v, st_v = mamba.mamba_block_verify(cfg, p, x, dict(state))
    np.testing.assert_allclose(np.asarray(y_v), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    for t in range(K):
        for key in states_ref[t]:
            np.testing.assert_allclose(
                np.asarray(st_v[key][:, t], dtype=np.float32),
                np.asarray(states_ref[t][key], dtype=np.float32),
                rtol=1e-5, atol=1e-5,
                err_msg=f"verify state {key} diverged at step {t}")


@pytest.mark.parametrize("state_dtype", ["f32", "int8"])
def test_mlstm_block_verify_matches_chained_steps(state_dtype):
    cfg, params = _setup("xlstm-350m", state_dtype=state_dtype)
    li = next(i for i in range(cfg.n_layers)
              if not xlstm._is_slstm(cfg, i))
    p = params["layers"][li]["mlstm"]
    b, K = 2, 4
    state = sharding.tree_values(
        xlstm.mlstm_state_init(cfg, b, jnp.float32))
    x = jnp.asarray(RNG.normal(size=(b, K, cfg.d_model)), jnp.float32)
    # prime the state so the window starts mid-sequence
    _, state = xlstm.mlstm_block_step(cfg, p, x[:, :1] * 0.7, state)
    y_ref, states_ref = _chain_steps(xlstm.mlstm_block_step, cfg, p, x,
                                     state)
    y_v, st_v = xlstm.mlstm_block_verify(cfg, p, x, state)
    np.testing.assert_allclose(np.asarray(y_v), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    for t in range(K):
        for key in states_ref[t]:
            np.testing.assert_allclose(
                np.asarray(st_v[key][:, t], dtype=np.float32),
                np.asarray(states_ref[t][key], dtype=np.float32),
                rtol=1e-5, atol=1e-5,
                err_msg=f"verify state {key} diverged at step {t}")


def test_slstm_block_verify_matches_chained_steps():
    cfg, params = _setup("xlstm-350m")
    li = next(i for i in range(cfg.n_layers) if xlstm._is_slstm(cfg, i))
    p = params["layers"][li]["slstm"]
    b, K = 2, 4
    state = sharding.tree_values(
        xlstm.slstm_state_init(cfg, b, jnp.float32))
    x = jnp.asarray(RNG.normal(size=(b, K, cfg.d_model)), jnp.float32)
    _, state = xlstm.slstm_block_step(cfg, p, x[:, :1] * 0.7, state)
    y_ref, states_ref = _chain_steps(xlstm.slstm_block_step, cfg, p, x,
                                     state)
    y_v, st_v = xlstm.slstm_block_verify(cfg, p, x, state)
    np.testing.assert_allclose(np.asarray(y_v), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    for t in range(K):
        for key in states_ref[t]:
            np.testing.assert_allclose(
                np.asarray(st_v[key][:, t]),
                np.asarray(states_ref[t][key]),
                rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("impl", ["fused", "xla"])
def test_decode_scan_matches_chained_decode_steps(impl):
    """core.selective_scan.decode_scan (the K-step micro-scan entry
    point) chains the same kernel as K separate decode_step dispatches
    — per-step outputs and states must agree."""
    b, K, d, n = 2, 5, 24, 8
    h = jnp.asarray(RNG.normal(size=(b, d, n)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(b, K, d)), jnp.float32)
    dt = jnp.abs(jnp.asarray(RNG.normal(size=(b, K, d)), jnp.float32)) * .1
    A = -jnp.abs(jnp.asarray(RNG.normal(size=(d, n)), jnp.float32))
    B = jnp.asarray(RNG.normal(size=(b, K, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, K, n)), jnp.float32)
    D = jnp.asarray(RNG.normal(size=(d,)), jnp.float32)
    z = jnp.asarray(RNG.normal(size=(b, K, d)), jnp.float32)
    ys, hs = css.decode_scan(h, x, dt, A, B, C, D=D, z_seq=z, impl=impl)
    # tolerance, not bit-equality: XLA may contract da*h + dbx into an
    # FMA differently inside the scan body than in the standalone step
    # (same reassociation caveat as the q-kernel payload gate)
    h_c = h
    for t in range(K):
        y_t, h_c = css.decode_step(h_c, x[:, t], dt[:, t], A, B[:, t],
                                   C[:, t], D=D, z_t=z[:, t], impl=impl)
        np.testing.assert_allclose(np.asarray(ys[:, t]), np.asarray(y_t),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(hs[:, t]), np.asarray(h_c),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("impl", ["fused", "xla"])
def test_decode_scan_q_matches_chained_steps(impl):
    """Quantized micro-scan vs chained decode_step_q.  The fused kernel
    runs the identical kernel body either way -> bit-exact payloads
    and scales.  The XLA oracle may be FMA-contracted differently
    inside the scan body, which can move an absmax (hence a scale) by
    an ulp and a payload by one code — the same "within one code"
    contract the fused-vs-oracle gate uses."""
    from repro.core import state_quant
    b, K, d, n = 2, 4, 32, 8
    h = jnp.asarray(RNG.normal(size=(b, d, n)) * 2, jnp.float32)
    hq, hs0 = state_quant.quantize_h(h, "int8")
    x = jnp.asarray(RNG.normal(size=(b, K, d)), jnp.float32)
    dt = jnp.abs(jnp.asarray(RNG.normal(size=(b, K, d)), jnp.float32)) * .1
    A = -jnp.abs(jnp.asarray(RNG.normal(size=(d, n)), jnp.float32))
    B = jnp.asarray(RNG.normal(size=(b, K, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, K, n)), jnp.float32)
    ys, hqs, sss = css.decode_scan_q(hq, hs0, x, dt, A, B, C,
                                     state_dtype="int8", impl=impl)
    hq_c, s_c = hq, hs0
    for t in range(K):
        y_t, hq_c, s_c = css.decode_step_q(
            hq_c, s_c, x[:, t], dt[:, t], A, B[:, t], C[:, t],
            state_dtype="int8", impl=impl)
        if impl == "fused":
            assert bool(jnp.array_equal(hqs[:, t], hq_c)), f"payload @ {t}"
            assert bool(jnp.array_equal(sss[:, t], s_c)), f"scales @ {t}"
        else:
            code = float(jnp.max(sss[:, t]))
            pay_err = np.max(np.abs(
                np.asarray(hqs[:, t], np.float32) * np.asarray(sss[:, t])[:, :, None]
                - np.asarray(hq_c, np.float32) * np.asarray(s_c)[:, :, None]))
            assert pay_err <= 2.5 * code, (t, pay_err, code)
            np.testing.assert_allclose(np.asarray(sss[:, t]),
                                       np.asarray(s_c), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(ys[:, t]), np.asarray(y_t),
                                   rtol=1e-4, atol=1e-5)


def test_jamba_sublayer_verify_mamba_position():
    """Jamba's mamba sublayers get the real block-level verify; its
    attention positions explicitly refuse (chained verify covers them
    in the engine)."""
    cfg, params = _setup("jamba-v0.1-52b")
    from repro.models import jamba
    period = cfg.attn_every or 8
    mamba_pos = next(p for p in range(period)
                     if not jamba._pos_kind(cfg, p)[0])
    attn_pos = next(p for p in range(period)
                    if jamba._pos_kind(cfg, p)[0])
    gp = jax.tree.map(lambda q: q[0], params["groups"][f"pos{mamba_pos}"])
    b, K = 2, 3
    di, n, kcv = cfg.d_inner, cfg.d_state, cfg.d_conv
    state = {"h": jnp.asarray(RNG.normal(size=(b, di, n)), jnp.float32),
             "conv": jnp.asarray(RNG.normal(size=(b, kcv - 1, di)),
                                 jnp.float32)}
    x = jnp.asarray(RNG.normal(size=(b, K, cfg.d_model)), jnp.float32)
    y, states = jamba.sublayer_verify(cfg, gp, mamba_pos, x, state)
    assert y.shape == (b, K, cfg.d_model)
    assert states["h"].shape[1] == K
    with pytest.raises(NotImplementedError):
        jamba.sublayer_verify(cfg, gp, attn_pos, x, state)


# ---------------------------------------------------------------------------
# Draft views: slicing + merging round-trips the full cache
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", FAMILIES)
def test_draft_view_merge_roundtrip(name):
    cfg, params = _setup(name)
    n = _shallow_layers(cfg)
    cache = sharding.tree_values(registry.init_cache(cfg, 3, 32))
    sub = registry.draft_cache(cfg, cache, n)
    merged = registry.draft_cache_merge(cfg, cache, sub, n)
    assert _tree_equal(merged, cache)
    dcfg = registry.draft_config(cfg, n)
    dp = registry.draft_params(cfg, params, n)
    logits, sub2 = registry.decode_step(
        dcfg, dp, sub, {"tokens": jnp.zeros((3, 1), jnp.int32)})
    assert logits.shape == (3, 1, cfg.vocab)
    merged2 = registry.draft_cache_merge(cfg, cache, sub2, n)
    assert jax.tree.structure(merged2) == jax.tree.structure(cache)


def test_draft_config_validation():
    cfg, _ = _setup("jamba-v0.1-52b")
    period = cfg.attn_every or 8
    with pytest.raises(ValueError):
        registry.draft_config(cfg, period - 1)   # not a group multiple
    cfg2, _ = _setup("mamba-130m")
    with pytest.raises(ValueError):
        registry.draft_config(cfg2, cfg2.n_layers + 1)
    tcfg, _ = _setup("qwen2-7b")
    with pytest.raises(NotImplementedError):
        registry.draft_config(tcfg, 1)
