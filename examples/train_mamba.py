"""End-to-end training driver (deliverable b): train a Mamba LM on the
synthetic corpus with the full production loop — checkpointing, auto-resume,
preemption flush, straggler detection, cosine schedule.

  PYTHONPATH=src python examples/train_mamba.py --preset tiny --steps 200
  PYTHONPATH=src python examples/train_mamba.py --preset 10m  --steps 300
  PYTHONPATH=src python examples/train_mamba.py --arch mamba-130m ...  # full

Presets keep CPU runtimes sane; the same driver scales to the production
mesh via --mesh (see launch/train.py for the pjit path).
"""
import argparse
import dataclasses

from repro import configs
from repro.optim import AdamWConfig
from repro.runtime.train_loop import TrainConfig, Trainer

PRESETS = {
    "tiny": dict(n_layers=2, d_model=64, dt_rank=8, vocab=256),
    "10m": dict(n_layers=6, d_model=256, dt_rank=16, vocab=1024),
    "50m": dict(n_layers=12, d_model=512, dt_rank=32, vocab=8192),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba-130m")
    ap.add_argument("--preset", default="tiny",
                    choices=list(PRESETS) + ["full"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_mamba")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--int8-adam", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch)
    if args.preset != "full":
        cfg = dataclasses.replace(cfg, **PRESETS[args.preset])
    cfg = dataclasses.replace(cfg, dtype="float32")

    n = cfg.n_params()
    print(f"[train] {args.arch} preset={args.preset}: {n/1e6:.1f}M params, "
          f"{cfg.n_layers}L x d{cfg.d_model}")

    tcfg = TrainConfig(
        total_steps=args.steps,
        warmup_steps=max(args.steps // 20, 5),
        global_batch=args.batch,
        seq_len=args.seq,
        ckpt_every=max(args.steps // 4, 25),
        ckpt_dir=args.ckpt_dir,
        log_every=max(args.steps // 20, 1),
        grad_accum=args.grad_accum,
        grad_compression=args.grad_compression,
        optimizer=AdamWConfig(
            lr=args.lr,
            moment_dtype="int8" if args.int8_adam else "float32"),
    )
    trainer = Trainer(cfg, tcfg)
    _, _, losses = trainer.run(resume=args.resume)
    print(f"[train] done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {len(losses)} steps; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
