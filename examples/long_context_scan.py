"""Long-context streaming with O(1) state (deliverable b, SSM story).

Processes a 64k-token stream through a Mamba block in chunks: the (h, conv)
state is carried between chunks (the same mechanism that makes the
long_500k decode cell O(1) in context), and the result is verified
identical to one full-sequence pass.  Also demonstrates the
sequence-parallel scan entry point.

  PYTHONPATH=src python examples/long_context_scan.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def main():
    rng = np.random.default_rng(0)
    b, L, d, n = 1, 65536, 64, 16
    chunk = 8192
    print(f"[long] streaming scan: L={L} in {L // chunk} chunks of {chunk}")

    x = jnp.asarray(rng.normal(size=(b, L, d)).astype(np.float32))
    dt = jax.nn.softplus(jnp.asarray(
        rng.normal(size=(b, L, d)).astype(np.float32)) - 2.0)
    A = -jnp.exp(jnp.asarray(rng.normal(size=(d, n)).astype(np.float32))
                 * 0.5)
    B = jnp.asarray(rng.normal(size=(b, L, n)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(b, L, n)).astype(np.float32))

    scan = jax.jit(lambda *a, h0=None: ops.selective_scan(
        *a, h0=h0, impl="chunked_seq", chunk=512))

    # full pass
    t0 = time.perf_counter()
    y_full, h_full = scan(x, dt, A, B, C)
    jax.block_until_ready(y_full)
    t_full = time.perf_counter() - t0

    # streaming: state carried between chunks, peak memory ~ chunk-sized
    h = None
    ys = []
    t0 = time.perf_counter()
    for i in range(0, L, chunk):
        sl = slice(i, i + chunk)
        y_c, h = scan(x[:, sl], dt[:, sl], A, B[:, sl], C[:, sl], h0=h)
        ys.append(y_c)
    y_stream = jnp.concatenate(ys, axis=1)
    jax.block_until_ready(y_stream)
    t_stream = time.perf_counter() - t0

    err = float(jnp.max(jnp.abs(y_stream - y_full)))
    print(f"[long] full pass {t_full:.2f}s, streaming {t_stream:.2f}s, "
          f"max|dy| = {err:.2e} (state size: {d * n * 4} bytes, "
          f"independent of context)")
    assert err < 1e-3

    # one decode step at position 64k: O(1) work
    y_t, h_t = ref.selective_state_step(
        h, x[:, -1], dt[:, -1], A, B[:, -1], C[:, -1])
    print(f"[long] single-token step at pos {L}: output {y_t.shape}, "
          f"state {h_t.shape} — O(1) per token (cf. 500k-token decode "
          f"cell in EXPERIMENTS.md §Dry-run)")


if __name__ == "__main__":
    main()
