"""Continuous-batching serving example: variable-length prompts with
per-request token budgets stream through the slot-pool engine; the static
Server wrapper is shown for comparison.

  PYTHONPATH=src python examples/serve_batched.py --arch mamba-130m
  PYTHONPATH=src python examples/serve_batched.py --arch olmo-1b
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro import configs
from repro.models import registry
from repro.parallel import sharding
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.serve import ServeConfig, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba-130m")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--state-dtype", default=None,
                    choices=["f32", "bf16", "int8", "fp8"])
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative draft depth (0 = plain decode); "
                         "greedy streams are identical either way")
    ap.add_argument("--draft-layers", type=int, default=0,
                    help="self-speculative draft depth in layers "
                         "(0 = full depth)")
    args = ap.parse_args()

    cfg = configs.smoke_variant(configs.get_config(args.arch))
    cfg = dataclasses.replace(cfg, vocab=256, dtype="float32")
    params = sharding.tree_values(
        registry.init_params(cfg, jax.random.key(0)))

    # variable-length prompts + per-request budgets: the case the static
    # batch loop could not serve without padding every request
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, size=(int(l),)).astype(np.int32)
               for l in rng.choice([6, 10, 16, 24], size=args.requests)]
    budgets = rng.integers(8, 25, size=args.requests)

    draft = None
    if args.spec_k > 0:
        from repro.runtime.spec_decode import DraftConfig
        draft = DraftConfig(k=args.spec_k, layers=args.draft_layers)
    eng = Engine(cfg, params, EngineConfig(
        n_slots=args.slots, max_seq=64, temperature=args.temperature,
        state_dtype=args.state_dtype, draft=draft))
    reqs = [eng.submit(p, max_new=int(m))
            for p, m in zip(prompts, budgets)]
    eng.run()

    s = eng.stats.summary()
    print(f"[engine] arch={args.arch} slots={args.slots} "
          f"requests={args.requests}")
    print(f"[engine] {s['useful_tokens']} tokens in {s['wall_s']:.2f}s "
          f"({s['tokens_per_s']:.1f} tok/s, occupancy {s['occupancy']:.2f}, "
          f"ttft mean {s['ttft_mean_s'] * 1e3:.0f}ms)")
    if draft is not None:
        print(f"[engine] speculative: "
              f"{s['spec_accepted_per_pass']:.2f} tokens/target-pass "
              f"over {s['spec_target_passes']} passes "
              f"(accept rate {s['spec_acceptance_rate']:.2f})")
    for r in reqs:
        print(f"  req{r.req_id}: prompt[{r.prompt.size}] "
              f"-> {r.tokens}")

    # the legacy rectangular API still works, now engine-backed
    srv = Server(cfg, params, ServeConfig(batch_slots=args.slots,
                                          max_seq=64))
    out = srv.generate(np.ones((args.slots, 8), np.int32), max_new=8)
    print(f"[server] legacy batch API: generated shape {out.shape}")


if __name__ == "__main__":
    main()
