"""Continuous-batching serving example: the per-request generation API.

A heterogeneous batch — greedy, temperature, top-k and top-p requests
side by side — streams through one jit cache; one request streams its
tokens through a callback and another is cancelled mid-stream.  The
legacy static Server wrapper is shown for comparison.

  PYTHONPATH=src python examples/serve_batched.py --arch mamba-130m
  PYTHONPATH=src python examples/serve_batched.py --arch olmo-1b
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro import configs
from repro.models import registry
from repro.parallel import sharding
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.sampling import SamplingParams
from repro.runtime.serve import ServeConfig, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba-130m")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--state-dtype", default=None,
                    choices=["f32", "bf16", "int8", "fp8"])
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative draft depth (0 = plain decode); "
                         "greedy streams are identical either way")
    ap.add_argument("--draft-layers", type=int, default=0,
                    help="self-speculative draft depth in layers "
                         "(0 = full depth)")
    ap.add_argument("--adaptive-draft", action="store_true",
                    help="clamp each slot's draft window to its "
                         "realized acceptance")
    args = ap.parse_args()

    cfg = configs.smoke_variant(configs.get_config(args.arch))
    cfg = dataclasses.replace(cfg, vocab=256, dtype="float32")
    params = sharding.tree_values(
        registry.init_params(cfg, jax.random.key(0)))

    # variable-length prompts, per-request budgets AND per-request
    # sampling: the heterogeneous-traffic case a single engine-wide
    # temperature could not serve without a recompile per setting
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, size=(int(l),)).astype(np.int32)
               for l in rng.choice([6, 10, 16, 24], size=args.requests)]
    cycle = [SamplingParams(),                                  # greedy
             SamplingParams(temperature=0.8, seed=1),
             SamplingParams(temperature=1.1, top_k=16, seed=2),
             SamplingParams(temperature=0.7, top_p=0.9, seed=3)]
    plist = [dataclasses.replace(cycle[i % len(cycle)],
                                 max_new=int(rng.integers(8, 25)))
             for i in range(args.requests)]

    draft = None
    if args.spec_k > 0:
        from repro.runtime.spec_decode import DraftConfig
        draft = DraftConfig(k=args.spec_k, layers=args.draft_layers,
                            adaptive=args.adaptive_draft)
    eng = Engine(cfg, params, EngineConfig(
        n_slots=args.slots, max_seq=64,
        state_dtype=args.state_dtype, draft=draft))

    # request 0 streams its tokens as they decode; request 1 cancels
    # itself after 5 tokens (its slot is reclaimed for the queue)
    def stream(req, toks):
        print(f"  [stream] req{req.req_id} += {toks}"
              f"{' (done)' if req.finished else ''}")

    def cancel_after_5(req, toks):
        if len(req.tokens) >= 5:
            eng.cancel(req.req_id)

    cbs = {0: stream, 1: cancel_after_5}
    reqs = [eng.submit(p, params=sp, stream_cb=cbs.get(i),
                       priority=(5 if i == args.requests - 1 else 0))
            for i, (p, sp) in enumerate(zip(prompts, plist))]
    eng.run()

    s = eng.stats.summary()
    print(f"[engine] arch={args.arch} slots={args.slots} "
          f"requests={args.requests} (last one high-priority)")
    print(f"[engine] {s['useful_tokens']} tokens in {s['wall_s']:.2f}s "
          f"({s['tokens_per_s']:.1f} tok/s, occupancy {s['occupancy']:.2f}, "
          f"ttft mean {s['ttft_mean_s'] * 1e3:.0f}ms, "
          f"cancelled {s['cancelled']})")
    if draft is not None:
        print(f"[engine] speculative: "
              f"{s['spec_accepted_per_pass']:.2f} tokens/target-pass "
              f"over {s['spec_target_passes']} passes "
              f"(accept rate {s['spec_acceptance_rate']:.2f})")
    for r, sp in zip(reqs, plist):
        kind = ("greedy" if sp.temperature <= 0 else
                f"T={sp.temperature}"
                + (f",top_k={sp.top_k}" if sp.top_k else "")
                + (f",top_p={sp.top_p}" if sp.top_p < 1 else ""))
        tag = " CANCELLED" if r.cancelled else ""
        print(f"  req{r.req_id} [{kind}] prompt[{r.prompt.size}] "
              f"-> {r.tokens}{tag}")

    # the legacy rectangular API still works, now engine-backed
    srv = Server(cfg, params, ServeConfig(batch_slots=args.slots,
                                          max_seq=64))
    out = srv.generate(np.ones((args.slots, 8), np.int32), max_new=8)
    print(f"[server] legacy batch API: generated shape {out.shape}")


if __name__ == "__main__":
    main()
