"""Batched serving example (deliverable b): train briefly, then serve
batched generation requests through the prefill+decode Server.

  PYTHONPATH=src python examples/serve_batched.py --arch mamba-130m
  PYTHONPATH=src python examples/serve_batched.py --arch olmo-1b
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import configs
from repro.data import SyntheticLM
from repro.models import registry
from repro.parallel import sharding
from repro.runtime.serve import ServeConfig, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba-130m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = configs.smoke_variant(configs.get_config(args.arch))
    cfg = dataclasses.replace(cfg, vocab=256, dtype="float32")
    params = sharding.tree_values(
        registry.init_params(cfg, jax.random.key(0)))

    ds = SyntheticLM(vocab=cfg.vocab, seq_len=args.prompt_len, seed=7)
    prompts = ds.batch_at(0, 0, 1, args.batch)["tokens"]

    srv = Server(cfg, params, ServeConfig(
        batch_slots=args.batch,
        max_seq=args.prompt_len + args.max_new + 8,
        temperature=args.temperature))

    t0 = time.perf_counter()
    out = srv.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    toks = out.size
    print(f"[serve] arch={args.arch} batch={args.batch} "
          f"prompt={args.prompt_len} new={args.max_new}")
    print(f"[serve] generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s on CPU, prefill+decode path)")
    for i, row in enumerate(out):
        print(f"  req{i}: {prompts[i].tolist()} -> {row.tolist()}")


if __name__ == "__main__":
    main()
