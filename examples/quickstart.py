"""Quickstart: MARCA's three ideas in five minutes, on CPU.

  1. fast biased exponential + piecewise SiLU (the reusable nonlinear unit)
  2. the fused selective-scan (element-wise engine) vs the unfused baseline
  3. a tiny Mamba LM forward with the approximations swapped in

Run: PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import approx
from repro.kernels import ops, ref
from repro.kernels import selective_scan as scan_kernel
from repro.models import registry
from repro.parallel import sharding


def main():
    print("=== 1. MARCA nonlinear approximations (paper §5) ===")
    xs = jnp.asarray(approx.exp_density_set())
    exact = np.exp(np.asarray(xs, np.float64))
    for name, fn in [("fast_exp (Schraudolph)", approx.fast_exp),
                     ("our_exp (biased)", approx.our_exp)]:
        err = np.abs(np.asarray(fn(xs), np.float64) - exact) / exact
        print(f"  {name:<24} mean rel err on dt*A distribution: "
              f"{err.mean():.4%}")
    x = jnp.linspace(-5, 4, 10001)
    for name, fn in [("SiLU eq.(3) paper", approx.piecewise_silu_paper),
                     ("SiLU refit (ours)", approx.piecewise_silu)]:
        err = jnp.max(jnp.abs(fn(x) - jax.nn.silu(x)))
        print(f"  {name:<24} max abs err on [-5,4]: {float(err):.4f}")

    print("\n=== 2. Fused selective scan (paper §4+§6) ===")
    rng = np.random.default_rng(0)
    b, L, d, n = 2, 256, 128, 16
    args = (
        jnp.asarray(rng.normal(size=(b, L, d)).astype(np.float32)),
        jax.nn.softplus(jnp.asarray(
            rng.normal(size=(b, L, d)).astype(np.float32))),
        -jnp.exp(jnp.asarray(rng.normal(size=(d, n)).astype(np.float32))
                 * 0.5),
        jnp.asarray(rng.normal(size=(b, L, n)).astype(np.float32)),
        jnp.asarray(rng.normal(size=(b, L, n)).astype(np.float32)),
    )
    y_ref, h_ref = ref.selective_scan(*args)
    y_ker, h_ker = scan_kernel.selective_scan(*args)   # Pallas, interpret
    print(f"  Pallas fused kernel vs reference: max|dy| = "
          f"{float(jnp.max(jnp.abs(y_ker - y_ref))):.2e}")
    y_apx, _ = ops.selective_scan(*args, impl="chunked_seq",
                                  exp_impl="ours", silu_impl="ours")
    print(f"  with MARCA approximations:        max|dy| = "
          f"{float(jnp.max(jnp.abs(y_apx - y_ref))):.3f} "
          f"(bounded by the ~1% exp error)")

    print("\n=== 3. Tiny Mamba LM forward (exact vs approx) ===")
    cfg = configs.smoke_variant(configs.get_config("mamba-130m"))
    cfg = dataclasses.replace(cfg, vocab=128, dtype="float32")
    params = sharding.tree_values(registry.init_params(cfg,
                                                       jax.random.key(0)))
    batch = registry.make_batch(cfg, 2, 32, key=jax.random.key(1))
    logits, _ = registry.forward(cfg, params, batch)
    cfg_apx = dataclasses.replace(cfg, exp_impl="ours", silu_impl="ours")
    logits_apx, _ = registry.forward(cfg_apx, params, batch)
    drift = float(jnp.mean(jnp.abs(logits - logits_apx)))
    print(f"  logits shape {logits.shape}; mean |logit drift| under "
          f"MARCA approx: {drift:.4f}")
    print("\nNext: examples/train_mamba.py (end-to-end training), "
          "examples/serve_batched.py, examples/long_context_scan.py")


if __name__ == "__main__":
    main()
