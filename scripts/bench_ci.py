#!/usr/bin/env python
"""Deterministic benchmark gate for CI (writes/checks BENCH_PR10.json).

Runs the serving benchmarks in *count mode*: every gated number is a
deterministic function of the code — useful-token counts, token-stream
agreement between state dtypes, per-slot cache bytes / slots-per-GB,
speculative-decode acceptance counters, heterogeneous-sampling jit
retrace counts (one compile must serve mixed greedy/temperature/top-k/
top-p traffic), prefix-cache hit/prefill-savings counts on a shared-
system-prompt trace (plus best-of-n branch divergence), megakernel
Pallas-launches-per-token (statically counted from the traced jaxpr —
the cross-layer megakernel must dispatch strictly fewer kernels per
token than the per-layer fused path, with identical token streams),
tensor-parallel sharded-serving counts (token identity vs the
single-device engine, no-per-step-resharding of the pooled cache,
per-decode-step collective counts from the compiled HLO, per-device
slot bytes — collected in a subprocess with 8 forced host devices),
quantized-weight counts (int8 weight-bytes-per-token reduction vs f32
with floor-gated token agreement — decode streams every weight once
per token, so param bytes ARE the per-token weight traffic),
multi-tenant admission counts on a bursty adversarial trace (exact
shed/degraded counts with no tenant starved — load shedding fires at
the door, before resident requests lose tokens), disaggregated-serving
counts (prefill/decode handoff bitwise identical to monolithic, exact
bytes-per-snapshot and bounded-queue depth),
and fused-kernel-vs-oracle errors.  Wall-clock numbers are recorded
under "informational" but never asserted: CPU timing noise exceeds 20%
and a timing gate on shared CI runners is a flake generator.

  python scripts/bench_ci.py            # compare against BENCH_PR10.json
  python scripts/bench_ci.py --update   # regenerate the baseline

The committed BENCH_PR10.json is the baseline; CI runs compare mode and
fails on drift, so a PR that changes a count (or breaks the >= 2x int8
capacity claim / the > 1.0 accepted-tokens-per-target-pass claim / the
one-launch-per-token megakernel claim / the sharded-serving identity
and collective pins / the >= 1.5x int8 weight-bytes reduction) must
also regenerate — and thereby review — the file.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))
sys.path.insert(0, str(REPO))

BASELINE = REPO / "BENCH_PR10.json"

#: |fresh - baseline| tolerance for token-agreement fractions: exact on
#: one platform, but argmax near-ties may flip across jax/BLAS builds
AGREEMENT_TOL = 0.15
#: hard floor (acceptance criterion): int8 state fits >= 2x the slots
#: of f32 in the same pool memory
MIN_INT8_CAPACITY_GAIN = 2.0
#: hard floor (acceptance criterion): the full-depth self-draft must
#: deliver more than one token per target verify pass
MIN_SPEC_ACCEPTED_PER_PASS = 1.0
#: |fresh - baseline| tolerance for spec accepted-per-pass counters.
#: The full-depth draft accepts by construction (counts are trace
#: arithmetic — tight tol absorbs only rounding); the shallow draft's
#: acceptance depends on argmax near-ties and gets the loose tol.
SPEC_FULL_TOL = 0.05
SPEC_SHALLOW_TOL = 0.5
#: hard floor (acceptance criterion): int8 weights must cut the param
#: bytes each decoded token streams by >= 1.5x (embed/unembed stay f32,
#: so the full 4x is not on the table)
MIN_WEIGHT_BYTES_REDUCTION = 1.5
#: hard floor (acceptance criterion): int8-weight greedy streams on the
#: mamba benchmark model must agree with f32 weights at >= this fraction
MIN_WEIGHT_AGREEMENT = 0.75


def _kernel_vs_oracle():
    """Fused q-kernel vs pure-jnp oracle on fixed tensors: payload must
    match bit-exactly (same scale math by construction), y within fp
    reassociation error."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import state_quant
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    b, d, n = 4, 192, 16
    h = jnp.asarray(rng.normal(size=(b, d, n)) * 2, jnp.float32)
    x = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    dt = jnp.abs(jnp.asarray(rng.normal(size=(b, d)), jnp.float32)) * 0.1
    A = -jnp.abs(jnp.asarray(rng.normal(size=(d, n)), jnp.float32))
    B = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    out = {}
    for sd in ("int8", "fp8"):
        q, s = state_quant.quantize_h(h, sd)
        res = {}
        for impl in ("xla", "fused"):
            y, qn, sn = ops.selective_state_step_q(
                q, s, x, dt, A, B, C, D=D, z_t=z,
                state_dtype=sd, impl=impl)
            res[impl] = (np.asarray(y),
                         np.asarray(qn.astype(jnp.float32)),
                         np.asarray(sn))
        y_err = float(np.max(np.abs(res["xla"][0] - res["fused"][0])))
        # payload gate is tolerance-based, not bit-equality: XLA may or
        # may not contract da*h + dbx into an FMA per compiled program,
        # which can flip a value sitting exactly on a rounding boundary
        # by one code.  One code's value: scale for int8, up to
        # scale * 32 at the top e4m3 binade for fp8.
        code_value = float(np.max(np.asarray(s))) * (
            1.0 if sd == "int8" else 32.0)
        payload_err = float(np.max(np.abs(
            np.asarray(state_quant.dequantize_h(
                jnp.asarray(res["xla"][1]), jnp.asarray(res["xla"][2])))
            - np.asarray(state_quant.dequantize_h(
                jnp.asarray(res["fused"][1]),
                jnp.asarray(res["fused"][2]))))))
        payload_ok = bool(payload_err <= 2.5 * code_value)
        s_ref = np.maximum(np.abs(res["xla"][2]), 1e-30)
        s_err = float(np.max(np.abs(res["xla"][2] - res["fused"][2])
                             / s_ref))
        rt_err = float(np.max(np.abs(
            np.asarray(state_quant.dequantize_h(q, s)) - np.asarray(h))))
        # int8: linear code, err <= scale/2.  fp8 e4m3: 3 mantissa bits,
        # relative half-ulp 2^-4, worst at values near amax = scale*qmax
        # -> err <= scale * 448 / 16
        rt_bound = float(np.max(np.asarray(s))) * (
            0.5 if sd == "int8" else state_quant.qmax("fp8") / 16.0)
        out[sd] = {"y_max_err": y_err,
                   "payload_max_err": payload_err,
                   "payload_within_tol": payload_ok,
                   "scale_max_rel_err": s_err,
                   "roundtrip_max_err": rt_err,
                   "roundtrip_within_bound": bool(rt_err <= rt_bound)}
    return out


def _collect_sharded():
    """The sharded-serving section needs multiple devices; this process
    is deliberately single-device (like the test suite's main pytest
    process), so collect it the way tests/_multidevice.py runs cases:
    a subprocess with 8 forced host devices.  The comparison's own
    asserts (token identity, no-resharding, capacity) fire in the
    subprocess; a non-zero exit surfaces them here."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join([str(REPO / "src"), str(REPO)])
    body = (
        "import json\n"
        "from benchmarks import serve_throughput as st\n"
        "out = st.sharded_serving_comparison(arch='mamba-130m', slots=4,"
        " requests=6, max_new=8, tp=2, quiet=True)\n"
        "print('BENCH_JSON ' + json.dumps(out))\n")
    r = subprocess.run([sys.executable, "-c", body], capture_output=True,
                       text=True, timeout=1800, env=env)
    if r.returncode != 0:
        raise RuntimeError(
            f"sharded-serving collection failed:\nSTDOUT:\n{r.stdout}\n"
            f"STDERR:\n{r.stderr}")
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("BENCH_JSON ")][-1]
    out = json.loads(line[len("BENCH_JSON "):])
    # wall-clock fields stay out of the gated record (subprocess timing
    # on shared runners is the noisiest number we produce)
    return {k: v for k, v in out.items()
            if k not in ("single_tps", "sharded_tps")}, out


def collect():
    """Run the count-mode benchmarks and assemble the gate record."""
    import jax

    from benchmarks import serve_throughput as st

    t0 = time.perf_counter()
    sweep = st.state_dtype_comparison(
        arch="mamba-130m", slots=4, requests=8, max_new=16,
        dtypes=("f32", "bf16", "int8", "fp8"), quiet=True)
    fused = st._fused_decode_comparison(
        arch="mamba-130m", slots=4, requests=6, max_new=8, reps=1,
        quiet=True)
    mega = st.megakernel_decode_comparison(
        arch="mamba-130m", slots=4, requests=6, max_new=8, reps=1,
        quiet=True)
    spec = st.spec_decode_comparison(
        arch="mamba-130m", slots=4, requests=6, max_new=12, k=3,
        quiet=True)
    hetero = st.hetero_sampling_comparison(
        arch="mamba-130m", slots=4, requests=8, max_new=16, quiet=True)
    prefix = st.prefix_cache_comparison(
        arch="mamba-130m", slots=4, requests=8, max_new=12, quiet=True)
    wq = st.weight_dtype_comparison(
        arch="mamba-130m", slots=4, requests=8, max_new=16, quiet=True)
    sched = st.frontend_sched_comparison(
        arch="mamba-130m", slots=2, quiet=True)
    disagg = st.disagg_comparison(
        arch="mamba-130m", slots=2, requests=6, max_new=8,
        queue_depth=2, quiet=True)
    sharded, sharded_full = _collect_sharded()
    kernel = _kernel_vs_oracle()

    dtypes = {}
    for sd, o in sweep.items():
        dtypes[sd] = {
            "useful_tokens": o["useful_tokens"],
            "state_bytes_per_slot": o["state_bytes_per_slot"],
            "slots_per_gb": round(o["slots_per_gb"], 1),
            "token_agreement_vs_f32": round(
                o["token_agreement_vs_f32"], 4),
        }
    gain = (sweep["f32"]["state_bytes_per_slot"]
            / sweep["int8"]["state_bytes_per_slot"])
    return {
        "arch": "mamba-130m-smoke",
        "state_dtypes": dtypes,
        "int8_capacity_gain_vs_f32": round(gain, 3),
        "fused_matches_unfused_tokens": True,  # asserted inside fused cmp
        # token-identity of greedy spec decode vs plain decode is
        # asserted inside spec_decode_comparison for both drafts
        "spec_decode": {
            "tokens_identical": True,
            "full": {
                "accepted_per_pass": round(
                    spec["spec_full"]["accepted_per_pass"], 4),
                "acceptance_rate": round(
                    spec["spec_full"]["acceptance_rate"], 4),
                "target_passes": spec["spec_full"]["target_passes"],
                "useful_tokens": spec["spec_full"]["useful_tokens"],
            },
            "shallow": {
                "accepted_per_pass": round(
                    spec["spec_shallow"]["accepted_per_pass"], 4),
                "acceptance_rate": round(
                    spec["spec_shallow"]["acceptance_rate"], 4),
                "useful_tokens": spec["spec_shallow"]["useful_tokens"],
            },
        },
        # heterogeneous per-request sampling: the PR 5 API-redesign
        # gate — one jit cache, greedy rows bitwise, seeded repro
        "hetero_sampling": {
            "useful_tokens": hetero["useful_tokens"],
            "decode_retraces": hetero["decode_retraces"],
            "greedy_rows_bitwise": hetero["greedy_rows_bitwise"],
            "seeded_repro": hetero["seeded_repro"],
            "sampled_rows_distinct_from_greedy":
                hetero["sampled_rows_distinct_from_greedy"],
        },
        # prefix cache + best-of-n: the PR 6 gate — shared-system-prompt
        # trace must hit, suffix-only prefill must strictly reduce the
        # prompt tokens computed, and token identity vs the cache-off
        # serve is asserted inside the comparison (f32 benchmark model)
        "prefix_cache": {
            "tokens_identical": True,
            "hits": prefix["on"]["hits"],
            "hit_rate": round(prefix["on"]["hit_rate"], 4),
            "cached_tokens": prefix["on"]["cached_tokens"],
            "prefill_tokens_on": prefix["on"]["prefill_tokens"],
            "prefill_tokens_off": prefix["off"]["prefill_tokens"],
            "bestofn_n": prefix["bestofn"]["n"],
            "bestofn_distinct": prefix["bestofn"]["distinct"],
        },
        # cross-layer megakernel: launches/token is a static property of
        # the traced jaxpr (identical on CPU interpret and TPU); token
        # identity vs the fused engine is asserted inside the comparison
        "megakernel": {
            "tokens_identical": True,
            "launches_per_token": mega["launches_megakernel"],
            "fused_launches_per_token": mega["launches_fused"],
        },
        # quantized weights: the PR 9 gate — weight-bytes-per-token is a
        # deterministic layout count (param leaf nbytes), the slot-state
        # layout must be untouched (asserted inside the comparison), and
        # agreement vs f32 weights is floor- and drift-gated
        "weight_quant": {
            "useful_tokens": wq["int8"]["useful_tokens"],
            "weight_bytes_per_token_f32":
                wq["f32"]["weight_bytes_per_token"],
            "weight_bytes_per_token_int8":
                wq["int8"]["weight_bytes_per_token"],
            "bytes_reduction": round(wq["reduction"], 3),
            "state_bytes_per_slot": wq["int8"]["state_bytes_per_slot"],
            "token_agreement_vs_f32": round(
                wq["int8"]["token_agreement_vs_f32"], 4),
        },
        # multi-tenant SLO admission: the PR 10 gate — shed/degraded
        # counts, per-tenant admission shares and the WFQ starvation
        # bound are pure functions of (submission order, token counts,
        # config); shed-before-violation and no-starvation invariants
        # are additionally asserted inside the comparison
        "frontend_sched": sched,
        # prefill/decode disaggregation: the PR 10 gate — token
        # identity vs the monolithic engine is asserted inside the
        # comparison; transfers, bytes-per-snapshot (state block layout
        # arithmetic) and bounded-queue depth are pinned exactly
        "disagg": disagg,
        # tensor-parallel sharded serving: the PR 8 gate — token
        # identity, no-per-step-resharding and per-device capacity are
        # asserted inside the (subprocess) comparison; the collective
        # counts are pinned exactly, like megakernel launches/token
        "sharded_serving": sharded,
        "kernel_vs_oracle": kernel,
        "informational": {
            "backend": jax.default_backend(),
            "fused_tps": round(fused["fused_tps"], 1),
            "unfused_tps": round(fused["unfused_tps"], 1),
            "megakernel_tps": round(mega["megakernel_tps"], 1),
            "weight_int8_tps": round(wq["int8"]["tokens_per_s"], 1),
            "spec_full_tps": round(spec["spec_full"]["tokens_per_s"], 1),
            "plain_tps": round(spec["plain"]["tokens_per_s"], 1),
            "sharded_tps": round(sharded_full["sharded_tps"], 1),
            "sharded_single_tps": round(sharded_full["single_tps"], 1),
            "collect_wall_s": round(time.perf_counter() - t0, 1),
        },
    }


def compare(fresh: dict, base: dict) -> list[str]:
    """Deterministic diff; returns human-readable failures (empty = ok)."""
    fails = []

    def chk(cond, msg):
        if not cond:
            fails.append(msg)

    chk(fresh["int8_capacity_gain_vs_f32"] >= MIN_INT8_CAPACITY_GAIN,
        f"int8 capacity gain {fresh['int8_capacity_gain_vs_f32']} "
        f"< required {MIN_INT8_CAPACITY_GAIN}x")
    chk(fresh["fused_matches_unfused_tokens"],
        "fused decode diverged from unfused token stream")
    # speculative decode: exactness + accepted-tokens-per-target-pass
    sp_f, sp_b = fresh.get("spec_decode"), base.get("spec_decode")
    if sp_f is None or sp_b is None:
        fails.append("spec_decode section present only in "
                     f"{'baseline' if sp_f is None else 'fresh'}")
    else:
        chk(sp_f["tokens_identical"],
            "greedy spec decode diverged from plain decode")
        chk(sp_f["full"]["accepted_per_pass"]
            > MIN_SPEC_ACCEPTED_PER_PASS,
            f"full-draft accepted/pass "
            f"{sp_f['full']['accepted_per_pass']} <= floor "
            f"{MIN_SPEC_ACCEPTED_PER_PASS}")
        for key in ("target_passes", "useful_tokens"):
            chk(sp_f["full"][key] == sp_b["full"][key],
                f"spec.full.{key}: fresh {sp_f['full'][key]} != "
                f"baseline {sp_b['full'][key]}")
        for side, tol in (("full", SPEC_FULL_TOL),
                          ("shallow", SPEC_SHALLOW_TOL)):
            d = abs(sp_f[side]["accepted_per_pass"]
                    - sp_b[side]["accepted_per_pass"])
            chk(d <= tol,
                f"spec.{side}.accepted_per_pass drifted {d:.3f} "
                f"(> {tol}): fresh {sp_f[side]['accepted_per_pass']} "
                f"vs baseline {sp_b[side]['accepted_per_pass']}")
        chk(sp_f["shallow"]["useful_tokens"]
            == sp_b["shallow"]["useful_tokens"],
            "spec.shallow.useful_tokens drifted")
    # heterogeneous sampling: the one-jit-cache redesign gate — all
    # hard invariants, no tolerances (counts and booleans only)
    ht_f, ht_b = fresh.get("hetero_sampling"), base.get("hetero_sampling")
    if ht_f is None or ht_b is None:
        fails.append("hetero_sampling section present only in "
                     f"{'baseline' if ht_f is None else 'fresh'}")
    else:
        chk(ht_f["decode_retraces"] == 0,
            f"heterogeneous SamplingParams retraced the jit "
            f"{ht_f['decode_retraces']} times (must be 0)")
        chk(ht_f["greedy_rows_bitwise"],
            "greedy rows diverged inside a mixed-sampling batch")
        chk(ht_f["seeded_repro"],
            "seeded sampled stream depended on batch composition")
        chk(ht_f["useful_tokens"] == ht_b["useful_tokens"],
            f"hetero_sampling.useful_tokens: fresh "
            f"{ht_f['useful_tokens']} != baseline "
            f"{ht_b['useful_tokens']}")
    # prefix cache + best-of-n: hard invariants (hits, strict prefill
    # reduction, identity, branch divergence) plus exact count equality
    # with the baseline — all deterministic, no tolerances
    pc_f, pc_b = fresh.get("prefix_cache"), base.get("prefix_cache")
    if pc_f is None or pc_b is None:
        fails.append("prefix_cache section present only in "
                     f"{'baseline' if pc_f is None else 'fresh'}")
    else:
        chk(pc_f["tokens_identical"],
            "prefix cache changed the token streams")
        chk(pc_f["hits"] > 0,
            "shared-system-prompt trace produced no prefix-cache hits")
        chk(pc_f["prefill_tokens_on"] < pc_f["prefill_tokens_off"],
            f"suffix-only prefill did not reduce prefill compute "
            f"({pc_f['prefill_tokens_on']} vs "
            f"{pc_f['prefill_tokens_off']} without the cache)")
        chk(pc_f["bestofn_distinct"] > 1,
            "best-of-n branches collapsed to one stream")
        for key in ("hits", "cached_tokens", "prefill_tokens_on",
                    "prefill_tokens_off", "bestofn_n",
                    "bestofn_distinct"):
            chk(pc_f[key] == pc_b[key],
                f"prefix_cache.{key}: fresh {pc_f[key]} != "
                f"baseline {pc_b[key]}")
    # megakernel: the one-launch-per-token claim, hard-gated — launch
    # counts are static jaxpr properties, so exact equality with the
    # baseline and the strict reduction vs the fused path both hold on
    # any backend
    mk_f, mk_b = fresh.get("megakernel"), base.get("megakernel")
    if mk_f is None or mk_b is None:
        fails.append("megakernel section present only in "
                     f"{'baseline' if mk_f is None else 'fresh'}")
    else:
        chk(mk_f["tokens_identical"],
            "megakernel decode diverged from per-layer fused tokens")
        chk(mk_f["launches_per_token"]
            < mk_f["fused_launches_per_token"],
            f"megakernel did not reduce Pallas dispatches "
            f"({mk_f['launches_per_token']} vs fused "
            f"{mk_f['fused_launches_per_token']} per token)")
        for key in ("launches_per_token", "fused_launches_per_token"):
            chk(mk_f[key] == mk_b[key],
                f"megakernel.{key}: fresh {mk_f[key]} != "
                f"baseline {mk_b[key]}")
    # quantized weights: hard floors (bytes reduction, agreement) plus
    # exact equality with the baseline for the layout counts — param
    # bytes are static properties of the quantization recipe
    wq_f, wq_b = fresh.get("weight_quant"), base.get("weight_quant")
    if wq_f is None or wq_b is None:
        fails.append("weight_quant section present only in "
                     f"{'baseline' if wq_f is None else 'fresh'}")
    else:
        chk(wq_f["bytes_reduction"] >= MIN_WEIGHT_BYTES_REDUCTION,
            f"int8 weight-bytes reduction {wq_f['bytes_reduction']}x "
            f"< required {MIN_WEIGHT_BYTES_REDUCTION}x")
        chk(wq_f["token_agreement_vs_f32"] >= MIN_WEIGHT_AGREEMENT,
            f"int8-weight token agreement "
            f"{wq_f['token_agreement_vs_f32']} < floor "
            f"{MIN_WEIGHT_AGREEMENT}")
        for key in ("useful_tokens", "weight_bytes_per_token_f32",
                    "weight_bytes_per_token_int8", "state_bytes_per_slot"):
            chk(wq_f[key] == wq_b[key],
                f"weight_quant.{key}: fresh {wq_f[key]} != "
                f"baseline {wq_b[key]}")
        da = abs(wq_f["token_agreement_vs_f32"]
                 - wq_b["token_agreement_vs_f32"])
        chk(da <= AGREEMENT_TOL,
            f"weight_quant.token_agreement_vs_f32 drifted {da:.3f} "
            f"(> {AGREEMENT_TOL}): fresh "
            f"{wq_f['token_agreement_vs_f32']} vs baseline "
            f"{wq_b['token_agreement_vs_f32']}")
    # multi-tenant admission: hard invariants (the flood's tail sheds,
    # none of it from the protected tenants, no starvation beyond the
    # weighted SFQ bound) plus exact count equality with the baseline —
    # every decision is submission-order arithmetic, so any drift is a
    # policy change that must regenerate the baseline
    fs_f, fs_b = fresh.get("frontend_sched"), base.get("frontend_sched")
    if fs_f is None or fs_b is None:
        fails.append("frontend_sched section present only in "
                     f"{'baseline' if fs_f is None else 'fresh'}")
    else:
        chk(fs_f["shed"] > 0,
            "bursty trace shed nothing — admission control never fired")
        chk(fs_f["shed_per_tenant"].get("steady", 0) == 0
            and fs_f["shed_per_tenant"].get("premium", 0) == 0,
            f"protected tenants were shed: {fs_f['shed_per_tenant']}")
        chk(fs_f["starvation_bound"] <= 5,
            f"WFQ starvation bound {fs_f['starvation_bound']} exceeds "
            "the weighted SFQ limit (5 pass-overs)")
        chk(fs_f["finished"] == fs_f["admitted"],
            "an admitted request never finished")
        for key in ("admitted", "shed", "degraded", "starvation_bound",
                    "admitted_per_tenant", "shed_per_tenant",
                    "useful_tokens", "finished"):
            chk(fs_f[key] == fs_b[key],
                f"frontend_sched.{key}: fresh {fs_f[key]} != "
                f"baseline {fs_b[key]}")
    # disaggregation: hard invariants (bitwise identity, no local
    # prefill on the decode pool, bounded queue respected) plus exact
    # wire-accounting equality — bytes-per-snapshot is state-block
    # layout arithmetic, so a change means the handoff payload changed
    dg_f, dg_b = fresh.get("disagg"), base.get("disagg")
    if dg_f is None or dg_b is None:
        fails.append("disagg section present only in "
                     f"{'baseline' if dg_f is None else 'fresh'}")
    else:
        chk(dg_f["tokens_identical"],
            "disaggregated streams diverged from the monolithic engine")
        chk(dg_f["decode_prefill_tokens"] == 0,
            f"decode pool ran {dg_f['decode_prefill_tokens']} local "
            "prefill tokens (must admit snapshots only)")
        chk(dg_f["max_queue_depth"] <= dg_f["queue_depth_bound"],
            f"transfer queue overflowed its bound "
            f"({dg_f['max_queue_depth']} > {dg_f['queue_depth_bound']})")
        for key in ("requests", "transfers", "transfer_bytes",
                    "bytes_per_snapshot", "max_queue_depth",
                    "queue_depth_bound", "snapshot_admits",
                    "snapshot_tokens", "useful_tokens"):
            chk(dg_f[key] == dg_b[key],
                f"disagg.{key}: fresh {dg_f[key]} != "
                f"baseline {dg_b[key]}")
    # tensor-parallel sharded serving: hard invariants (token identity,
    # no per-step resharding, per-device bytes strictly below the
    # single-device pool) plus exact equality with the baseline for the
    # collective counts/bytes of the compiled decode step and the
    # capacity accounting — all static properties of the partitioned
    # program, deterministic on any host
    sh_f, sh_b = fresh.get("sharded_serving"), base.get("sharded_serving")
    if sh_f is None or sh_b is None:
        fails.append("sharded_serving section present only in "
                     f"{'baseline' if sh_f is None else 'fresh'}")
    else:
        chk(sh_f["tokens_identical"],
            "sharded greedy streams diverged from single-device streams")
        chk(sh_f["no_per_step_resharding"],
            "compiled decode step resharded the cache between steps")
        chk(sh_f["device_bytes_sharded"] < sh_f["device_bytes_single"],
            f"sharded pool did not shrink per-device slot bytes "
            f"({sh_f['device_bytes_sharded']} vs "
            f"{sh_f['device_bytes_single']} single-device)")
        for key in ("tp", "useful_tokens", "cache_leaves",
                    "sharded_cache_leaves", "state_bytes_per_slot",
                    "device_bytes_single", "device_bytes_sharded",
                    "decode_collective_bytes", "decode_collectives",
                    "device_slots_per_gb_sharded"):
            chk(sh_f.get(key) == sh_b.get(key),
                f"sharded_serving.{key}: fresh {sh_f.get(key)} != "
                f"baseline {sh_b.get(key)}")
    # union, not base-only: a dtype added to the sweep without a
    # baseline regeneration must fail, not silently pass unchecked
    all_dtypes = sorted(set(base["state_dtypes"])
                        | set(fresh["state_dtypes"]))
    for sd in all_dtypes:
        b = base["state_dtypes"].get(sd)
        f = fresh["state_dtypes"].get(sd)
        if b is None or f is None:
            fails.append(f"state dtype {sd} present only in "
                         f"{'fresh' if b is None else 'baseline'}")
            continue
        for key in ("useful_tokens", "state_bytes_per_slot"):
            chk(f[key] == b[key],
                f"{sd}.{key}: fresh {f[key]} != baseline {b[key]}")
        da = abs(f["token_agreement_vs_f32"] - b["token_agreement_vs_f32"])
        chk(da <= AGREEMENT_TOL,
            f"{sd}.token_agreement_vs_f32 drifted {da:.3f} "
            f"(> {AGREEMENT_TOL}): fresh {f['token_agreement_vs_f32']} "
            f"vs baseline {b['token_agreement_vs_f32']}")
    # iterate the union so a dtype missing from either side is a
    # reported failure, never a KeyError traceback or a silent pass
    all_kernel = sorted(set(base["kernel_vs_oracle"])
                        | set(fresh["kernel_vs_oracle"]))
    for sd in all_kernel:
        b = base["kernel_vs_oracle"].get(sd)
        f = fresh["kernel_vs_oracle"].get(sd)
        if b is None or f is None:
            fails.append(f"kernel_vs_oracle[{sd}] present only in "
                         f"{'fresh' if b is None else 'baseline'}")
            continue
        chk(f["payload_within_tol"],
            f"{sd}: fused payload drifted beyond 2.5 codes from oracle "
            f"(max err {f['payload_max_err']:.2e})")
        chk(f["roundtrip_within_bound"],
            f"{sd}: quantize round-trip error exceeded the scale bound")
        bound = max(2.0 * b["y_max_err"], 1e-4)
        chk(f["y_max_err"] <= bound,
            f"{sd}.y_max_err {f['y_max_err']:.2e} > {bound:.2e}")
    return fails


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true",
                    help="regenerate the committed baseline")
    ap.add_argument("--baseline", type=pathlib.Path, default=BASELINE)
    args = ap.parse_args()

    fresh = collect()
    if args.update:
        args.baseline.write_text(json.dumps(fresh, indent=2,
                                            sort_keys=True) + "\n")
        print(f"[bench_ci] wrote {args.baseline}")
        return 0
    if not args.baseline.exists():
        print(f"[bench_ci] FATAL: baseline {args.baseline} missing; "
              "run with --update and commit it", file=sys.stderr)
        return 2
    base = json.loads(args.baseline.read_text())
    fails = compare(fresh, base)
    print(json.dumps(fresh["state_dtypes"], indent=2, sort_keys=True))
    print(f"[bench_ci] int8 capacity gain "
          f"{fresh['int8_capacity_gain_vs_f32']}x "
          f"(floor {MIN_INT8_CAPACITY_GAIN}x)")
    print(f"[bench_ci] spec decode accepted/pass: full "
          f"{fresh['spec_decode']['full']['accepted_per_pass']} "
          f"(floor {MIN_SPEC_ACCEPTED_PER_PASS}), shallow "
          f"{fresh['spec_decode']['shallow']['accepted_per_pass']}")
    ht = fresh["hetero_sampling"]
    print(f"[bench_ci] hetero sampling: {ht['decode_retraces']} "
          f"retraces (must be 0), greedy bitwise "
          f"{ht['greedy_rows_bitwise']}, seeded repro "
          f"{ht['seeded_repro']}")
    mk = fresh["megakernel"]
    print(f"[bench_ci] megakernel: {mk['launches_per_token']} Pallas "
          f"launches/token vs {mk['fused_launches_per_token']} fused "
          f"(must be strictly fewer), token streams identical")
    pc = fresh["prefix_cache"]
    print(f"[bench_ci] prefix cache: {pc['hits']} hits "
          f"(rate {pc['hit_rate']}), prefill tokens "
          f"{pc['prefill_tokens_on']} vs {pc['prefill_tokens_off']} "
          f"without (must be strictly less), best-of-"
          f"{pc['bestofn_n']}: {pc['bestofn_distinct']} distinct "
          f"branches")
    wq = fresh["weight_quant"]
    print(f"[bench_ci] weight quant: "
          f"{wq['weight_bytes_per_token_int8']} weight B/token vs "
          f"{wq['weight_bytes_per_token_f32']} f32 "
          f"({wq['bytes_reduction']}x reduction, floor "
          f"{MIN_WEIGHT_BYTES_REDUCTION}x), agreement "
          f"{wq['token_agreement_vs_f32']} (floor "
          f"{MIN_WEIGHT_AGREEMENT})")
    fs = fresh["frontend_sched"]
    print(f"[bench_ci] multi-tenant admission: {fs['admitted']} admitted "
          f"{fs['admitted_per_tenant']}, {fs['shed']} shed "
          f"{fs['shed_per_tenant']}, {fs['degraded']} degraded, "
          f"starvation bound {fs['starvation_bound']} (limit 5)")
    dg = fresh["disagg"]
    print(f"[bench_ci] disagg: tokens identical {dg['tokens_identical']}, "
          f"{dg['transfers']} snapshots x {dg['bytes_per_snapshot']} B, "
          f"queue depth {dg['max_queue_depth']}/"
          f"{dg['queue_depth_bound']}, decode-pool prefill tokens "
          f"{dg['decode_prefill_tokens']} (must be 0)")
    sh = fresh["sharded_serving"]
    print(f"[bench_ci] sharded serving: tp={sh['tp']}, tokens identical "
          f"{sh['tokens_identical']}, no per-step resharding "
          f"{sh['no_per_step_resharding']}, "
          f"{sh['sharded_cache_leaves']}/{sh['cache_leaves']} cache "
          f"leaves sharded, decode collectives {sh['decode_collectives']} "
          f"({sh['decode_collective_bytes']} B), per-device slot bytes "
          f"{sh['device_bytes_sharded']} vs {sh['device_bytes_single']} "
          f"single-device")
    if fails:
        for f in fails:
            print(f"[bench_ci] FAIL: {f}", file=sys.stderr)
        return 1
    print("[bench_ci] OK — deterministic counts match the baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
