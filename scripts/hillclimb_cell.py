import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Hillclimb driver: lower one (arch x shape) cell with config/rule
overrides, print the three roofline terms + top byte contributors.

Usage: PYTHONPATH=src python scripts/hillclimb_cell.py <arch> <shape> \
         [k=v ...]   (k=v are ModelConfig overrides; rule:k=v for rules)
"""
import json
import sys
import time

from repro import configs
from repro.configs import shapes as shp
from repro.launch import hlo_cost
from repro.launch.dryrun import build_cell, rules_for
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.parallel import sharding


def parse_overrides(args):
    cfg_kw, rule_kw = {}, {}
    for a in args:
        k, v = a.split("=", 1)
        target = cfg_kw
        if k.startswith("rule:"):
            k = k[5:]
            target = rule_kw
        if v in ("True", "False"):
            v = v == "True"
        elif v == "None":
            v = None
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        target[k] = v
    return cfg_kw, rule_kw


def main():
    arch, shape_name = sys.argv[1], sys.argv[2]
    cfg_kw, rule_kw = parse_overrides(sys.argv[3:])
    import dataclasses
    cfg = configs.get_config(arch)
    if cfg_kw:
        cfg = dataclasses.replace(cfg, **cfg_kw)
    shape = shp.SHAPES[shape_name]
    mesh = make_production_mesh()
    rules = rules_for(cfg, shape, rule_kw or None)
    t0 = time.time()
    with sharding.use_mesh(mesh, rules):
        fn, args = build_cell(cfg, shape, mesh, rules)
        compiled = fn.lower(*args).compile()
    hlo = compiled.as_text()
    cost = hlo_cost.analyze(hlo)
    chips = mesh.devices.size
    n_act = registry.count_params(cfg, active_only=True)
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * n_act * tokens
    from repro.launch.hlo_analysis import roofline_terms
    r = roofline_terms(cost.flops * chips, cost.bytes * chips,
                       cost.collective_bytes * chips, chips, model_flops)
    try:
        mem = compiled.memory_analysis()
        mem_gb = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                  + mem.output_size_in_bytes
                  - mem.alias_size_in_bytes) / 1e9
    except Exception:
        mem_gb = float("nan")
    print(json.dumps({
        "cfg": cfg_kw, "rules": rule_kw,
        "compute_s": round(r.compute_s, 3),
        "memory_s": round(r.memory_s, 3),
        "collective_s": round(r.collective_s, 3),
        "dominant": r.dominant,
        "useful_ratio": round(r.useful_flops_ratio, 3),
        "frac": round(r.roofline_fraction, 4),
        "mem_gb": round(mem_gb, 1),
        "compile_s": round(time.time() - t0, 1),
    }))
    gb = 1e9
    print("bytes_by_op (GB/chip):",
          {k: round(v / gb, 1) for k, v in sorted(
              cost.bytes_by_op.items(), key=lambda kv: -kv[1])[:8]})
    print("coll_by_kind (GB/chip):",
          {k: round(v / gb, 2) for k, v in sorted(
              cost.coll_by_kind.items(), key=lambda kv: -kv[1])})
    print("flops_by_op (Tflop/chip):",
          {k: round(v / 1e12, 2) for k, v in sorted(
              cost.flops_by_op.items(), key=lambda kv: -kv[1])[:8]},
          "| total %.2f Tflop/chip, dot share %.2f" % (
              cost.flops / 1e12,
              cost.flops_by_op.get("dot", 0) / max(cost.flops, 1)))


if __name__ == "__main__":
    main()
