#!/usr/bin/env bash
# Tier-1 test entry point.  Fails fast — and loudly — on collection
# errors so "suite can't import" is never mistaken for "suite passes".
#
#   scripts/test.sh                full tier-1 suite
#   scripts/test.sh --fast         skip the slow training-integration tier
#                                  (end-to-end Trainer runs; minutes on
#                                  CPU) and the multi-device tier (its
#                                  own CI job runs it per PR)
#   scripts/test.sh --multidevice  ONLY the multi-device tier: every
#                                  case subprocesses onto 8 fake host
#                                  devices (tests/_multidevice.py), so
#                                  this tier needs no special env
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Known-red ledger.  Every entry is a test we KNOW fails and have chosen
# to ship anyway; since the grad-accum fix (PR 2) the list is empty, and
# this gate keeps it that way: adding an entry fails the suite loudly
# instead of quietly normalizing red.
KNOWN_RED=()
if [ "${#KNOWN_RED[@]}" -ne 0 ]; then
    echo "FATAL: known-red list must stay empty; fix or delete the tests" >&2
    printf '  known-red: %s\n' "${KNOWN_RED[@]}" >&2
    exit 3
fi

FAST=0
MULTIDEVICE=0
ARGS=()
for a in "$@"; do
    case "$a" in
        --fast) FAST=1 ;;
        --multidevice) MULTIDEVICE=1 ;;
        *) ARGS+=("$a") ;;
    esac
done

PYTEST_ARGS=(-x -q)
if [ "$MULTIDEVICE" -eq 1 ]; then
    PYTEST_ARGS+=(tests/test_distributed.py tests/test_sharded_serving.py)
elif [ "$FAST" -eq 1 ]; then
    PYTEST_ARGS+=(--ignore=tests/test_train_integration.py
                  --ignore=tests/test_distributed.py
                  --ignore=tests/test_sharded_serving.py)
fi

if ! python -m pytest -q --collect-only >collect.err 2>&1; then
    echo "FATAL: test collection failed" >&2
    cat collect.err >&2
    rm -f collect.err
    exit 2
fi
rm -f collect.err

exec python -m pytest "${PYTEST_ARGS[@]}" ${ARGS[@]+"${ARGS[@]}"}
