#!/usr/bin/env bash
# Tier-1 test entry point.  Fails fast — and loudly — on collection
# errors so "suite can't import" is never mistaken for "suite passes".
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if ! python -m pytest -q --collect-only >collect.err 2>&1; then
    echo "FATAL: test collection failed" >&2
    cat collect.err >&2
    rm -f collect.err
    exit 2
fi
rm -f collect.err

exec python -m pytest -x -q "$@"
