"""Fig. 7: compute intensity & read/write ratio spread across op classes.

Checks the paper's claims: ~3 orders of magnitude compute-intensity
variance and >3 orders of read/write-ratio variance between linear and
element-wise ops.
"""
from __future__ import annotations

import math

from repro import configs
from repro.core import op_graph
from benchmarks.common import emit


def run():
    cfg = configs.get_config("mamba-2.8b")
    spreads = []
    for L in [1, 128, 2048]:
        ops = op_graph.mamba_block_ops(cfg, L)
        by_cls: dict = {}
        for op in ops:
            by_cls.setdefault(op.cls, []).append(op)
        intens = {}
        rw = {}
        for cls, lst in by_cls.items():
            fl = sum(o.flops for o in lst)
            rd = sum(o.read for o in lst)
            wr = sum(o.write for o in lst)
            intens[cls] = fl / max(rd + wr, 1)
            rw[cls] = rd / max(wr, 1)
            emit(f"fig7.L{L}.{cls}", 0.0,
                 f"intensity={intens[cls]:.3f};rw_ratio={rw[cls]:.3f}")
        i_spread = math.log10(max(intens.values()) /
                              max(min(intens.values()), 1e-12))
        r_spread = math.log10(max(rw.values()) /
                              max(min(rw.values()), 1e-12))
        spreads.append((L, i_spread, r_spread))
        emit(f"fig7.L{L}.spread", 0.0,
             f"intensity_decades={i_spread:.1f};rw_decades={r_spread:.1f}")
    # paper: ~3 decades of intensity variance, >3 decades of r/w variance
    # (the r/w extreme is the decode/GEMV regime, L=1)
    ok = (max(s[1] for s in spreads) >= 2.5
          and max(s[2] for s in spreads) >= 3.0)
    emit("fig7.claim.spreads", 0.0,
         f"max_intensity_decades={max(s[1] for s in spreads):.1f};"
         f"max_rw_decades={max(s[2] for s in spreads):.1f};paper~3/3;"
         f"{'OK' if ok else 'MISS'}")


if __name__ == "__main__":
    run()
