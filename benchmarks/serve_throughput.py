"""Serving throughput: continuous-batching engine vs static-batch baseline.

A Poisson arrival trace of variable-length prompts with per-request token
budgets is served twice:

  * engine  — runtime.engine.Engine: slots refill the moment a sequence
    finishes; exact-length prefills; no padding.
  * static  — the pre-engine Server semantics, reimplemented here as the
    baseline: FIFO groups of ``--slots`` requests, prompts right-padded to
    the group max length (pad tokens burn prefill compute), the whole
    group decoded for max(max_new) steps (early finishers burn decode
    compute until the slowest request is done).

Reported tokens/sec counts only *useful* tokens (tokens a request asked
for and received), so both padding waste and dead-slot decode steps show
up as throughput loss.  Both paths are warmed up (jit compile excluded).

  PYTHONPATH=src python benchmarks/serve_throughput.py --arch mamba-130m
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import registry
from repro.parallel import sharding
from repro.runtime.engine import Engine, EngineConfig

LEN_CHOICES = (8, 12, 16, 24)      # small set -> bounded prefill compiles


def build_trace(n_requests, rate, seed, max_new_lo, max_new_hi, vocab,
                tail_frac=0.25):
    """Poisson arrivals (exp inter-arrival at ``rate`` req/s), prompt
    lengths from LEN_CHOICES, heavy-tailed per-request token budgets:
    most requests draw from the short end of [lo, hi], a ``tail_frac``
    minority from the long end — the length-variance regime (chat-like
    traffic) where a static batch pays the group max while continuous
    batching pays the mean."""
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    span = max(1, (max_new_hi - max_new_lo) // 4)
    reqs = []
    for i in range(n_requests):
        lp = int(rng.choice(LEN_CHOICES))
        if rng.random() < tail_frac:
            m = int(rng.integers(max_new_hi - span, max_new_hi + 1))
        else:
            m = int(rng.integers(max_new_lo, max_new_lo + span + 1))
        reqs.append({
            "arrival": float(t[i]),
            "prompt": rng.integers(0, vocab, size=(lp,)).astype(np.int32),
            "max_new": m,
        })
    return reqs


# ---------------------------------------------------------------------------
# Static-batch baseline (the old runtime/serve.py loop)
# ---------------------------------------------------------------------------

class StaticBatchBaseline:
    def __init__(self, cfg, params, slots, max_seq):
        self.cfg, self.params = cfg, params
        self.slots, self.max_seq = slots, max_seq
        self._prefill = jax.jit(
            lambda p, c, b: registry.prefill(cfg, p, c, b))
        self._decode = jax.jit(
            lambda p, c, b: registry.decode_step(cfg, p, c, b))

    def _generate_group(self, group):
        lmax = max(r["prompt"].size for r in group)
        n_steps = max(r["max_new"] for r in group)
        b = len(group)
        toks = np.zeros((b, lmax), np.int32)        # right-pad with 0
        for i, r in enumerate(group):
            toks[i, :r["prompt"].size] = r["prompt"]
        cache = sharding.tree_values(
            registry.init_cache(self.cfg, self.slots, self.max_seq))
        batch = np.zeros((self.slots, lmax), np.int32)
        batch[:b] = toks                            # fixed batch shape
        logits, cache = self._prefill(self.params, cache,
                                      {"tokens": jnp.asarray(batch)})
        tok = jnp.argmax(logits[:, -1:, :].astype(jnp.float32), axis=-1)
        for _ in range(n_steps - 1):
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": tok})
            tok = jnp.argmax(logits.astype(jnp.float32)[:, -1:, :], axis=-1)
        jax.block_until_ready(tok)

    def run(self, trace):
        """FIFO groups of ``slots``; a group launches when its last member
        has arrived.  Returns (useful_tokens, wall_s)."""
        useful = 0
        t0 = time.perf_counter()
        for g0 in range(0, len(trace), self.slots):
            group = trace[g0:g0 + self.slots]
            ready_at = max(r["arrival"] for r in group)
            wait = ready_at - (time.perf_counter() - t0)
            if wait > 0:
                time.sleep(wait)
            self._generate_group(group)
            useful += sum(r["max_new"] for r in group)
        return useful, time.perf_counter() - t0


def _compare(arch, slots, requests, rate, max_new_lo, max_new_hi, seed,
             reps, quiet=False):
    cfg = configs.smoke_variant(configs.get_config(arch))
    cfg = dataclasses.replace(cfg, vocab=256, dtype="float32")
    params = sharding.tree_values(
        registry.init_params(cfg, jax.random.key(0)))
    max_seq = max(LEN_CHOICES) + max_new_hi + 8
    trace = build_trace(requests, rate, seed, max_new_lo, max_new_hi,
                        cfg.vocab)

    # -- warmup: compile every prefill length + the decode steps ----------
    warm = Engine(cfg, params, EngineConfig(n_slots=slots, max_seq=max_seq))
    for lp in LEN_CHOICES:
        warm.submit(np.zeros((lp,), np.int32), max_new=2)
    warm.run()
    static = StaticBatchBaseline(cfg, params, slots, max_seq)
    for lp in LEN_CHOICES:        # one group per length: compile all lmax
        static.run([{"arrival": 0.0, "prompt": np.zeros((lp,), np.int32),
                     "max_new": 2}])

    # -- timed runs (alternating, best-of-reps per side) ------------------
    es, s_wall, s_useful = None, None, None
    for _ in range(max(1, reps)):
        eng = Engine(cfg, params, EngineConfig(n_slots=slots,
                                               max_seq=max_seq))
        for r in trace:
            eng.submit(r["prompt"], max_new=r["max_new"],
                       arrival=r["arrival"])
        eng.run()
        cur = eng.stats.summary()
        if es is None or cur["wall_s"] < es["wall_s"]:
            es = cur
        useful, wall = static.run(trace)
        if s_wall is None or wall < s_wall:
            s_useful, s_wall = useful, wall
    s_tps = s_useful / s_wall

    if not quiet:
        print(f"[serve_throughput] arch={arch} slots={slots} "
              f"requests={requests} rate={rate}/s")
        print(f"  static  : {s_useful:5d} useful tok in {s_wall:6.2f}s "
              f"-> {s_tps:7.1f} tok/s")
        print(f"  engine  : {es['useful_tokens']:5d} useful tok in "
              f"{es['wall_s']:6.2f}s -> {es['tokens_per_s']:7.1f} tok/s "
              f"(occupancy {es['occupancy']:.2f}, "
              f"ttft p95 {es['ttft_p95_s'] * 1e3:.0f}ms)")
        print(f"  speedup : {es['tokens_per_s'] / s_tps:0.2f}x")
    return {"engine_wall": es["wall_s"], "useful": es["useful_tokens"],
            "engine_tps": es["tokens_per_s"], "static_tps": s_tps,
            "speedup": es["tokens_per_s"] / s_tps}


def run():
    """benchmarks/run.py protocol: quick saturated comparison, CSV row."""
    from benchmarks import common
    stats = _compare(arch="mamba-130m", slots=4, requests=16, rate=1000.0,
                     max_new_lo=4, max_new_hi=48, seed=0, reps=2,
                     quiet=True)
    us_per_tok = 1e6 * stats["engine_wall"] / stats["useful"]
    common.emit("serve_throughput_engine", us_per_tok,
                f"speedup_vs_static={stats['speedup']:.2f}x")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba-130m")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=1000.0,
                    help="Poisson arrival rate (req/s); the default "
                         "saturates the pool so tokens/sec is "
                         "service-bound (at low rates both sides are "
                         "arrival-bound and differ in TTFT instead)")
    ap.add_argument("--max-new-lo", type=int, default=4)
    ap.add_argument("--max-new-hi", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reps", type=int, default=3,
                    help="repetitions per side; best wall time is scored "
                         "(CPU timing noise easily exceeds 20%%)")
    args = ap.parse_args()
    stats = _compare(args.arch, args.slots, args.requests, args.rate,
                     args.max_new_lo, args.max_new_hi, args.seed, args.reps)
    return 0 if stats["engine_tps"] >= stats["static_tps"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
