"""Serving throughput: continuous-batching engine vs static-batch baseline.

A Poisson arrival trace of variable-length prompts with per-request token
budgets is served twice:

  * engine  — runtime.engine.Engine: slots refill the moment a sequence
    finishes; exact-length prefills; no padding.
  * static  — the pre-engine Server semantics, reimplemented here as the
    baseline: FIFO groups of ``--slots`` requests, prompts right-padded to
    the group max length (pad tokens burn prefill compute), the whole
    group decoded for max(max_new) steps (early finishers burn decode
    compute until the slowest request is done).

Reported tokens/sec counts only *useful* tokens (tokens a request asked
for and received), so both padding waste and dead-slot decode steps show
up as throughput loss.  Both paths are warmed up (jit compile excluded).

Also reported: fused vs unfused per-token decode (cfg.step_impl) — the
same engine and trace served with the single-launch fused decode-step
kernel vs the unfused per-op XLA chain.  Token streams must match
exactly (greedy decode, same math); the timing ratio is the kernel's
win.  On CPU the "fused" kernel runs under the Pallas interpreter, so
its timing is meaningless there and is reported but never asserted.

Also reported: megakernel vs per-layer fused decode — the same trace
served with the cross-layer megakernel (the whole layer stack as ONE
Pallas launch per token) vs the per-layer fused path.  Token streams
must match exactly, and the statically counted launches-per-token
(core.dispatch_count) must drop; both are deterministic and gated.

Also reported: speculative decoding (EngineConfig.draft) — the same
trace served with fork/draft/verify/rollback passes.  Greedy token
streams must match plain decode exactly, and the deterministic
accepted-tokens-per-target-pass counter (not wall-clock) is the gated
speedup proxy.

Also reported: heterogeneous sampling (per-request SamplingParams) —
a mixed greedy/temperature/top-k/top-p trace served by ONE jit cache:
zero retraces after a greedy warmup (jit cache-miss counting via
sampling.TRACE_COUNTS), greedy rows bitwise vs the all-greedy engine,
and seeded sampled streams reproduced independent of batch
composition.

Also reported: prefix cache (EngineConfig.prefix_cache) — a shared-
system-prompt trace served with and without the prompt-prefix state
cache.  Token streams must match exactly (f32 cached admission is
bitwise the cold prefill) and the cache must strictly reduce the
prefill tokens actually computed (suffix-only prefill); a best-of-n
rider on the same fork primitive checks branch divergence + ranking.

Also reported: tensor-parallel sharded serving (EngineConfig.mesh) —
the same greedy trace served single-device and on a tp-way "model"
mesh.  Token streams must match exactly; the compiled pooled decode
step must consume and produce the cache at identical shardings (no
per-step resharding) with pinned per-step collective counts
(launch/hlo_cost over the compiled HLO — the collective analogue of
core/dispatch_count); and per-DEVICE slot bytes must shrink vs the
single-device pool (the TP capacity claim).  Requires
jax.device_count() >= tp, so scripts/bench_ci.py collects this section
in a subprocess with 8 forced host devices.

Also reported: SLO-aware multi-tenant admission (runtime/scheduler.py)
— a bursty adversarial tenant mix served through the WFQ admission
scheduler.  Every gated number is deterministic (submission order +
token counts + config; no wall-clock): exact shed counts with the
flood's tail rejected AT THE DOOR while every admitted request still
receives its full token budget, a no-starvation bound on WFQ
pass-overs, degradation-ladder counts (best-of-n shrunk under
pressure), and per-tenant admission shares.

Also reported: prefill/decode disaggregation (runtime/disagg.py) — the
same trace served monolithic vs prefill-worker -> bounded transfer
queue -> decode pool.  Token streams must match bitwise (the handoff is
the prefix-cache snapshot path: same compiled prefill, scatter of a
gathered state block), and the wire accounting (transfers,
bytes-per-snapshot, max queue depth) is exact layout arithmetic.

Flake policy: pass/fail decisions use deterministic token counts only;
wall-clock (CPU timing noise exceeds 20%) uses median-of-k and is
asserted only off-CPU, with a generous margin.

  PYTHONPATH=src python benchmarks/serve_throughput.py --arch mamba-130m
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import registry
from repro.parallel import sharding
from repro.runtime.engine import Engine, EngineConfig

LEN_CHOICES = (8, 12, 16, 24)      # small set -> bounded prefill compiles


def build_trace(n_requests, rate, seed, max_new_lo, max_new_hi, vocab,
                tail_frac=0.25):
    """Poisson arrivals (exp inter-arrival at ``rate`` req/s), prompt
    lengths from LEN_CHOICES, heavy-tailed per-request token budgets:
    most requests draw from the short end of [lo, hi], a ``tail_frac``
    minority from the long end — the length-variance regime (chat-like
    traffic) where a static batch pays the group max while continuous
    batching pays the mean."""
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    span = max(1, (max_new_hi - max_new_lo) // 4)
    reqs = []
    for i in range(n_requests):
        lp = int(rng.choice(LEN_CHOICES))
        if rng.random() < tail_frac:
            m = int(rng.integers(max_new_hi - span, max_new_hi + 1))
        else:
            m = int(rng.integers(max_new_lo, max_new_lo + span + 1))
        reqs.append({
            "arrival": float(t[i]),
            "prompt": rng.integers(0, vocab, size=(lp,)).astype(np.int32),
            "max_new": m,
        })
    return reqs


# ---------------------------------------------------------------------------
# Static-batch baseline (the old runtime/serve.py loop)
# ---------------------------------------------------------------------------

class StaticBatchBaseline:
    def __init__(self, cfg, params, slots, max_seq):
        self.cfg, self.params = cfg, params
        self.slots, self.max_seq = slots, max_seq
        self._prefill = jax.jit(
            lambda p, c, b: registry.prefill(cfg, p, c, b))
        self._decode = jax.jit(
            lambda p, c, b: registry.decode_step(cfg, p, c, b))

    def _generate_group(self, group):
        lmax = max(r["prompt"].size for r in group)
        n_steps = max(r["max_new"] for r in group)
        b = len(group)
        toks = np.zeros((b, lmax), np.int32)        # right-pad with 0
        for i, r in enumerate(group):
            toks[i, :r["prompt"].size] = r["prompt"]
        cache = sharding.tree_values(
            registry.init_cache(self.cfg, self.slots, self.max_seq))
        batch = np.zeros((self.slots, lmax), np.int32)
        batch[:b] = toks                            # fixed batch shape
        logits, cache = self._prefill(self.params, cache,
                                      {"tokens": jnp.asarray(batch)})
        tok = jnp.argmax(logits[:, -1:, :].astype(jnp.float32), axis=-1)
        for _ in range(n_steps - 1):
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": tok})
            tok = jnp.argmax(logits.astype(jnp.float32)[:, -1:, :], axis=-1)
        jax.block_until_ready(tok)

    def run(self, trace):
        """FIFO groups of ``slots``; a group launches when its last member
        has arrived.  Returns (useful_tokens, wall_s)."""
        useful = 0
        t0 = time.perf_counter()
        for g0 in range(0, len(trace), self.slots):
            group = trace[g0:g0 + self.slots]
            ready_at = max(r["arrival"] for r in group)
            wait = ready_at - (time.perf_counter() - t0)
            if wait > 0:
                time.sleep(wait)
            self._generate_group(group)
            useful += sum(r["max_new"] for r in group)
        return useful, time.perf_counter() - t0


def _setup_model(arch):
    """Shared benchmark model: smoke config + concrete params."""
    cfg = configs.smoke_variant(configs.get_config(arch))
    cfg = dataclasses.replace(cfg, vocab=256, dtype="float32")
    params = sharding.tree_values(
        registry.init_params(cfg, jax.random.key(0)))
    return cfg, params


def _compare(arch, slots, requests, rate, max_new_lo, max_new_hi, seed,
             reps, quiet=False):
    cfg, params = _setup_model(arch)
    max_seq = max(LEN_CHOICES) + max_new_hi + 8
    trace = build_trace(requests, rate, seed, max_new_lo, max_new_hi,
                        cfg.vocab)

    # -- warmup: compile every prefill length + the decode steps ----------
    warm = Engine(cfg, params, EngineConfig(n_slots=slots, max_seq=max_seq))
    for lp in LEN_CHOICES:
        warm.submit(np.zeros((lp,), np.int32), max_new=2)
    warm.run()
    static = StaticBatchBaseline(cfg, params, slots, max_seq)
    for lp in LEN_CHOICES:        # one group per length: compile all lmax
        static.run([{"arrival": 0.0, "prompt": np.zeros((lp,), np.int32),
                     "max_new": 2}])

    # -- timed runs (alternating, median-of-reps per side) ----------------
    # Median, not best-of: a single lucky rep under CPU scheduling noise
    # can flip a ratio by >20%; the median is stable at small k.
    e_runs, s_runs = [], []
    for _ in range(max(1, reps)):
        eng = Engine(cfg, params, EngineConfig(n_slots=slots,
                                               max_seq=max_seq))
        for r in trace:
            eng.submit(r["prompt"], max_new=r["max_new"],
                       arrival=r["arrival"])
        eng.run()
        e_runs.append(eng.stats.summary())
        s_runs.append(static.run(trace))
    es = sorted(e_runs, key=lambda s: s["wall_s"])[len(e_runs) // 2]
    s_useful, s_wall = sorted(s_runs, key=lambda r: r[1])[len(s_runs) // 2]
    s_tps = s_useful / s_wall

    # deterministic invariant (flake-proof): greedy decode with no EOS
    # must deliver every requested token on both paths
    want_useful = sum(r["max_new"] for r in trace)
    assert es["useful_tokens"] == want_useful, \
        (es["useful_tokens"], want_useful)
    assert s_useful == want_useful, (s_useful, want_useful)

    if not quiet:
        print(f"[serve_throughput] arch={arch} slots={slots} "
              f"requests={requests} rate={rate}/s")
        print(f"  static  : {s_useful:5d} useful tok in {s_wall:6.2f}s "
              f"-> {s_tps:7.1f} tok/s")
        print(f"  engine  : {es['useful_tokens']:5d} useful tok in "
              f"{es['wall_s']:6.2f}s -> {es['tokens_per_s']:7.1f} tok/s "
              f"(occupancy {es['occupancy']:.2f}, "
              f"ttft p95 {es['ttft_p95_s'] * 1e3:.0f}ms)")
        print(f"  speedup : {es['tokens_per_s'] / s_tps:0.2f}x")
    return {"engine_wall": es["wall_s"], "useful": es["useful_tokens"],
            "engine_tps": es["tokens_per_s"], "static_tps": s_tps,
            "speedup": es["tokens_per_s"] / s_tps}


# ---------------------------------------------------------------------------
# Fused vs unfused per-token decode (cfg.step_impl routing)
# ---------------------------------------------------------------------------

def _fused_decode_comparison(arch, slots, requests, max_new, reps,
                             seed=0, quiet=False):
    """Serve one saturated trace twice — step_impl="xla" (per-op chain)
    vs "fused" (single Pallas launch per layer per token) — and report
    median decode tokens/sec for each.  Greedy token streams must match
    exactly; that check is deterministic and is the pass/fail signal."""
    cfg, params = _setup_model(arch)
    rng = np.random.default_rng(seed)
    max_seq = max(LEN_CHOICES) + max_new + 8
    prompts = [rng.integers(0, cfg.vocab,
                            size=(int(rng.choice(LEN_CHOICES)),))
               .astype(np.int32) for _ in range(requests)]

    # on CPU the fused timing is interpreter overhead and never asserted,
    # so don't burn reps on it: one serve per impl gives the token streams
    # the deterministic equality check needs
    n_runs = (1 if jax.default_backend() == "cpu"
              else max(1, reps) + 1)             # first rep doubles as warmup
    out = {}
    for label, impl in (("unfused", "xla"), ("fused", "fused")):
        walls, tokens = [], None
        for _ in range(n_runs):
            eng = Engine(cfg, params,
                         EngineConfig(n_slots=slots, max_seq=max_seq,
                                      step_impl=impl))
            reqs = [eng.submit(p, max_new=max_new) for p in prompts]
            eng.run()
            walls.append(eng.stats.summary()["wall_s"])
            tokens = [r.tokens for r in reqs]
        timed = walls[1:] or walls               # CPU: single untimed-ish run
        wall = sorted(timed)[len(timed) // 2]
        out[label] = {"wall_s": wall,
                      "tokens_per_s": requests * max_new / wall,
                      "tokens": tokens}
    assert out["fused"]["tokens"] == out["unfused"]["tokens"], \
        "fused decode diverged from unfused token stream"
    ratio = out["unfused"]["wall_s"] / out["fused"]["wall_s"]
    if not quiet:
        on_cpu = jax.default_backend() == "cpu"
        note = (" (CPU: fused runs under the Pallas interpreter; "
                "timing not meaningful)" if on_cpu else "")
        print(f"[serve_throughput] fused-vs-unfused decode, arch={arch} "
              f"slots={slots} requests={requests} max_new={max_new}")
        print(f"  unfused : {out['unfused']['tokens_per_s']:7.1f} tok/s "
              f"({out['unfused']['wall_s']:6.2f}s)")
        print(f"  fused   : {out['fused']['tokens_per_s']:7.1f} tok/s "
              f"({out['fused']['wall_s']:6.2f}s)")
        print(f"  fused speedup : {ratio:0.2f}x{note} — token streams "
              "identical")
    return {"fused_tps": out["fused"]["tokens_per_s"],
            "unfused_tps": out["unfused"]["tokens_per_s"],
            "fused_speedup": ratio}


# ---------------------------------------------------------------------------
# Megakernel vs per-layer fused decode (cross-layer grid, one launch/token)
# ---------------------------------------------------------------------------

def megakernel_decode_comparison(arch, slots, requests, max_new, reps,
                                 seed=0, quiet=False):
    """Serve one saturated trace twice — step_impl="fused" (one Pallas
    launch per layer per token) vs "megakernel" (the whole layer stack
    as ONE launch, layer axis in the kernel grid) — and report median
    decode tokens/sec plus the statically counted Pallas dispatches per
    token for each.  Two deterministic pass/fail signals: greedy token
    streams identical, and the megakernel's launches-per-token equal to
    its homogeneous-run count (1 for pure stacks; jamba's attention /
    MoE sublayers are excepted by design) vs one-per-layer on the fused
    path.  Timing is informational on CPU (Pallas interpreter)."""
    import functools

    from repro.core.dispatch_count import count_pallas_launches

    cfg, params = _setup_model(arch)
    rng = np.random.default_rng(seed)
    max_seq = max(LEN_CHOICES) + max_new + 8
    prompts = [rng.integers(0, cfg.vocab,
                            size=(int(rng.choice(LEN_CHOICES)),))
               .astype(np.int32) for _ in range(requests)]

    launches = {}
    for impl in ("fused", "megakernel"):
        c = dataclasses.replace(cfg, step_impl=impl)
        cache = sharding.tree_values(registry.init_cache(c, slots, max_seq))
        launches[impl] = count_pallas_launches(
            functools.partial(registry.decode_step, c, params), cache,
            {"tokens": jnp.zeros((slots, 1), jnp.int32)})
    assert launches["megakernel"] < max(launches["fused"], 2), \
        (launches, "megakernel did not reduce per-token dispatches")

    n_runs = (1 if jax.default_backend() == "cpu"
              else max(1, reps) + 1)             # first rep doubles as warmup
    out = {}
    for impl in ("fused", "megakernel"):
        walls, tokens = [], None
        for _ in range(n_runs):
            eng = Engine(cfg, params,
                         EngineConfig(n_slots=slots, max_seq=max_seq,
                                      step_impl=impl))
            reqs = [eng.submit(p, max_new=max_new) for p in prompts]
            eng.run()
            walls.append(eng.stats.summary()["wall_s"])
            tokens = [r.tokens for r in reqs]
        timed = walls[1:] or walls
        wall = sorted(timed)[len(timed) // 2]
        out[impl] = {"wall_s": wall,
                     "tokens_per_s": requests * max_new / wall,
                     "launches_per_token": launches[impl],
                     "tokens": tokens}
    assert out["megakernel"]["tokens"] == out["fused"]["tokens"], \
        "megakernel decode diverged from per-layer fused token stream"
    ratio = out["fused"]["wall_s"] / out["megakernel"]["wall_s"]
    if not quiet:
        on_cpu = jax.default_backend() == "cpu"
        note = (" (CPU: both impls run under the Pallas interpreter; "
                "timing not meaningful)" if on_cpu else "")
        print(f"[serve_throughput] megakernel-vs-fused decode, arch={arch} "
              f"slots={slots} requests={requests} max_new={max_new}")
        for impl in ("fused", "megakernel"):
            o = out[impl]
            print(f"  {impl:10s}: {o['tokens_per_s']:7.1f} tok/s "
                  f"({o['wall_s']:6.2f}s) | "
                  f"{o['launches_per_token']} Pallas launches/token")
        print(f"  megakernel speedup : {ratio:0.2f}x{note} — token "
              "streams identical")
    return {"megakernel_tps": out["megakernel"]["tokens_per_s"],
            "fused_tps": out["fused"]["tokens_per_s"],
            "megakernel_speedup": ratio,
            "launches_fused": launches["fused"],
            "launches_megakernel": launches["megakernel"]}


# ---------------------------------------------------------------------------
# Quantized slot state (cfg.state_dtype): slots-per-GB and tok/s per dtype
# ---------------------------------------------------------------------------

def state_dtype_comparison(arch, slots, requests, max_new,
                           dtypes=("f32", "bf16", "int8"), seed=0,
                           quiet=False):
    """Serve one saturated greedy trace once per state dtype and report
    slots-per-GB (deterministic — pure cache-layout arithmetic) plus
    tokens/sec and the token-stream agreement vs the f32 engine.

    The capacity claim (int8 fits >= 2x the slots of f32 in the same
    pool memory) and the useful-token counts are deterministic and are
    the pass/fail signal; tok/s is reported only (CPU noise >20%)."""
    if "f32" not in dtypes:
        raise ValueError("dtypes must include the 'f32' reference "
                         "(agreement is measured against it)")
    cfg, params = _setup_model(arch)
    rng = np.random.default_rng(seed)
    max_seq = max(LEN_CHOICES) + max_new + 8
    prompts = [rng.integers(0, cfg.vocab,
                            size=(int(rng.choice(LEN_CHOICES)),))
               .astype(np.int32) for _ in range(requests)]
    out = {}
    for sd in dtypes:
        eng = Engine(cfg, params,
                     EngineConfig(n_slots=slots, max_seq=max_seq,
                                  state_dtype=sd))
        reqs = [eng.submit(p, max_new=max_new) for p in prompts]
        eng.run()
        s = eng.stats.summary()
        out[sd] = {
            "tokens": [list(map(int, r.tokens)) for r in reqs],
            "useful_tokens": int(s["useful_tokens"]),
            "tokens_per_s": float(s["tokens_per_s"]),
            "state_bytes_per_slot": int(eng.pool.state_bytes_per_slot()),
            "slots_per_gb": float(eng.pool.slots_per_gb()),
        }
    base = out["f32"]["tokens"]
    n_tok = sum(len(t) for t in base)
    for sd in dtypes:
        same = sum(int(x == y) for a, b in zip(base, out[sd]["tokens"])
                   for x, y in zip(a, b))
        out[sd]["token_agreement_vs_f32"] = same / max(1, n_tok)
    if not quiet:
        print(f"[serve_throughput] state-dtype sweep, arch={arch} "
              f"slots={slots} requests={requests} max_new={max_new}")
        for sd in dtypes:
            o = out[sd]
            print(f"  {sd:5s}: {o['state_bytes_per_slot']:8d} B/slot "
                  f"-> {o['slots_per_gb']:9.0f} slots/GB | "
                  f"{o['tokens_per_s']:7.1f} tok/s | "
                  f"agreement vs f32 {o['token_agreement_vs_f32']:.3f}")
        if "int8" in out:
            ratio = (out['f32']['state_bytes_per_slot']
                     / out['int8']['state_bytes_per_slot'])
            print(f"  int8 capacity gain : {ratio:0.2f}x slots at equal "
                  "pool memory")
    return out


# ---------------------------------------------------------------------------
# Quantized weights (EngineConfig.weight_dtype): bytes-per-token and agreement
# ---------------------------------------------------------------------------

def weight_dtype_comparison(arch, slots, requests, max_new, seed=0,
                            quiet=False):
    """Serve one saturated greedy trace twice — weight_dtype None (f32
    params as handed in) vs "int8" (per-output-channel absmax codes,
    dequantized inside the decode kernels) — and report the weight
    bytes each decoded token streams from memory plus the token-stream
    agreement vs the f32 engine.

    Decode reads every weight once per token, so weight-bytes-per-token
    IS the resident param footprint: sum of param leaf nbytes, a
    deterministic layout count (embed/unembed stay f32 by design — they
    are consumed as raw matrices).  Pass/fail: the int8 reduction
    clears 1.5x, state_bytes_per_slot is IDENTICAL across the two
    serves (weight quant must not touch slot state), and every request
    gets all its tokens.  Agreement is reported here and floor-gated by
    scripts/bench_ci.py; tok/s is reported only (CPU noise >20%)."""
    cfg, params = _setup_model(arch)
    rng = np.random.default_rng(seed)
    max_seq = max(LEN_CHOICES) + max_new + 8
    prompts = [rng.integers(0, cfg.vocab,
                            size=(int(rng.choice(LEN_CHOICES)),))
               .astype(np.int32) for _ in range(requests)]
    out = {}
    for label, wd in (("f32", None), ("int8", "int8")):
        eng = Engine(cfg, params,
                     EngineConfig(n_slots=slots, max_seq=max_seq,
                                  weight_dtype=wd))
        reqs = [eng.submit(p, max_new=max_new) for p in prompts]
        eng.run()
        s = eng.stats.summary()
        assert s["useful_tokens"] == requests * max_new
        out[label] = {
            "tokens": [list(map(int, r.tokens)) for r in reqs],
            "useful_tokens": int(s["useful_tokens"]),
            "tokens_per_s": float(s["tokens_per_s"]),
            "weight_bytes_per_token": int(sum(
                l.nbytes for l in jax.tree.leaves(eng.params))),
            "state_bytes_per_slot": int(eng.pool.state_bytes_per_slot()),
        }
    assert (out["int8"]["state_bytes_per_slot"]
            == out["f32"]["state_bytes_per_slot"]), \
        "weight quantization must not change slot state layout"
    base = out["f32"]["tokens"]
    n_tok = sum(len(t) for t in base)
    for label in out:
        same = sum(int(x == y) for a, b in zip(base, out[label]["tokens"])
                   for x, y in zip(a, b))
        out[label]["token_agreement_vs_f32"] = same / max(1, n_tok)
    reduction = (out["f32"]["weight_bytes_per_token"]
                 / out["int8"]["weight_bytes_per_token"])
    assert reduction >= 1.5, \
        f"int8 weight-bytes reduction {reduction:.2f}x < 1.5x"
    out["reduction"] = reduction
    if not quiet:
        print(f"[serve_throughput] weight-dtype sweep, arch={arch} "
              f"slots={slots} requests={requests} max_new={max_new}")
        for label in ("f32", "int8"):
            o = out[label]
            print(f"  {label:5s}: {o['weight_bytes_per_token']:8d} "
                  f"weight B/token | {o['tokens_per_s']:7.1f} tok/s | "
                  f"agreement vs f32 {o['token_agreement_vs_f32']:.3f}")
        print(f"  int8 weight-stream reduction : {reduction:0.2f}x "
              "bytes/token (slot state layout unchanged)")
    return out


# ---------------------------------------------------------------------------
# Heterogeneous sampling (per-request SamplingParams): one jit cache
# ---------------------------------------------------------------------------

def hetero_sampling_comparison(arch, slots, requests, max_new, seed=0,
                               quiet=False):
    """Serve one saturated trace whose requests cycle through greedy /
    temperature / top-k / top-p SamplingParams and gate the redesign's
    deterministic claims:

      * single compile — after a greedy warmup, the mixed trace
        retraces NOTHING (sampling.TRACE_COUNTS deltas are zero for
        decode and prefill; prompt lengths are drawn from LEN_CHOICES
        so every prefill shape is warmed);
      * greedy rows bitwise — each greedy request's stream equals the
        all-greedy engine's for the same prompt;
      * seeded reproducibility — a seeded sampled request re-served
        alone reproduces its in-crowd stream bit-for-bit;
      * full token accounting — every request receives max_new tokens.

    All four are deterministic counts/booleans (CI-gateable); tok/s is
    reported only."""
    from repro.runtime import sampling
    from repro.runtime.sampling import SamplingParams

    cfg, params = _setup_model(arch)
    rng = np.random.default_rng(seed)
    max_seq = max(LEN_CHOICES) + max_new + 8
    prompts = [rng.integers(0, cfg.vocab,
                            size=(int(rng.choice(LEN_CHOICES)),))
               .astype(np.int32) for _ in range(requests)]
    cycle = [SamplingParams(),
             SamplingParams(temperature=0.8),
             SamplingParams(temperature=1.1, top_k=8),
             SamplingParams(temperature=0.7, top_p=0.9)]
    mix = [dataclasses.replace(cycle[i % len(cycle)], seed=100 + i)
           for i in range(requests)]

    # all-greedy reference (doubles as the jit warmup for every prompt
    # length in the trace)
    ref_eng = Engine(cfg, params, EngineConfig(n_slots=slots,
                                               max_seq=max_seq))
    ref = [ref_eng.submit(p, max_new=max_new) for p in prompts]
    ref_eng.run()

    before = dict(sampling.TRACE_COUNTS)
    eng = Engine(cfg, params, EngineConfig(n_slots=slots,
                                           max_seq=max_seq))
    reqs = [eng.submit(p, params=sp, max_new=max_new)
            for p, sp in zip(prompts, mix)]
    eng.run()
    after = dict(sampling.TRACE_COUNTS)
    retraces = sum(after.get(k, 0) - before.get(k, 0)
                   for k in ("decode_step", "prefill_admit"))
    assert retraces == 0, \
        f"heterogeneous SamplingParams forced {retraces} retraces"

    greedy_idx = [i for i in range(requests) if i % len(cycle) == 0]
    greedy_bitwise = all(reqs[i].tokens == ref[i].tokens
                         for i in greedy_idx)
    assert greedy_bitwise, "greedy rows diverged in the mixed batch"

    # seeded reproducibility: re-serve one sampled request alone
    probe = next(i for i in range(requests) if i % len(cycle) == 1)
    solo = Engine(cfg, params, EngineConfig(n_slots=slots,
                                            max_seq=max_seq))
    r_solo = solo.submit(prompts[probe], params=mix[probe],
                         max_new=max_new)
    solo.run()
    seeded_repro = r_solo.tokens == reqs[probe].tokens
    assert seeded_repro, "seeded stream depended on batch composition"

    s = eng.stats.summary()
    assert s["useful_tokens"] == requests * max_new
    sampled_distinct = sum(int(reqs[i].tokens != ref[i].tokens)
                           for i in range(requests)
                           if i not in greedy_idx)
    out = {"useful_tokens": int(s["useful_tokens"]),
           "decode_retraces": int(retraces),
           "greedy_rows_bitwise": bool(greedy_bitwise),
           "seeded_repro": bool(seeded_repro),
           "n_greedy": len(greedy_idx),
           "sampled_rows_distinct_from_greedy": int(sampled_distinct),
           "tokens_per_s": float(s["tokens_per_s"])}
    if not quiet:
        print(f"[serve_throughput] heterogeneous sampling, arch={arch} "
              f"slots={slots} requests={requests} max_new={max_new}")
        print(f"  mixed greedy/temp/top-k/top-p trace: "
              f"{out['useful_tokens']} useful tok at "
              f"{out['tokens_per_s']:.1f} tok/s")
        print(f"  jit retraces after greedy warmup : "
              f"{out['decode_retraces']} (one compile serves all "
              "SamplingParams)")
        print(f"  greedy rows bitwise vs all-greedy: "
              f"{out['greedy_rows_bitwise']}; seeded stream "
              f"batch-independent: {out['seeded_repro']}")
    return out


# ---------------------------------------------------------------------------
# Speculative decoding (EngineConfig.draft): accepted tokens per target pass
# ---------------------------------------------------------------------------

def spec_decode_comparison(arch, slots, requests, max_new, k=3,
                           shallow_layers=None, seed=0, quiet=False):
    """Serve one saturated greedy trace three ways — plain decode, spec
    decode with the full-depth self-draft (every proposal accepted by
    construction: gates the accept/rollback accounting with fully
    deterministic counts), and spec decode with a shallow
    ``shallow_layers``-deep draft (real speculation, real rejections) —
    and report accepted-tokens-per-target-pass for each.

    Pass/fail signals (all deterministic): the three token streams are
    IDENTICAL (greedy spec decode is exact — speculation changes
    throughput, never tokens), and the full-depth draft clears
    accepted-tokens-per-target-pass > 1.0.  Wall-clock is reported but
    never asserted (CPU noise >20%; on CPU the draft/verify jits add
    dispatch overhead that says nothing about accelerator behavior)."""
    from repro.runtime.spec_decode import (DraftConfig,
                                           default_shallow_layers)
    cfg, params = _setup_model(arch)
    if cfg.is_moe:
        # MoE routes tokens through shared expert capacity, so logits
        # depend on batch composition at tight capacity_factor — and a
        # spec engine's pool has scratch rows a plain engine lacks.
        # Lift capacity so routing is slot-independent and the
        # exactness contract applies (see engine.py's MoE caveat).
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.n_experts))
    if shallow_layers is None:
        # family-aware: jamba drafts whole groups, so "half depth"
        # rounds to a group multiple (its one-group smoke config
        # degrades to full depth)
        shallow_layers = default_shallow_layers(cfg)
    rng = np.random.default_rng(seed)
    max_seq = max(LEN_CHOICES) + max_new + 8
    prompts = [rng.integers(0, cfg.vocab,
                            size=(int(rng.choice(LEN_CHOICES)),))
               .astype(np.int32) for _ in range(requests)]
    out = {}
    for label, draft in (("plain", None),
                         ("spec_full", DraftConfig(k=k, layers=0)),
                         ("spec_shallow",
                          DraftConfig(k=k, layers=shallow_layers))):
        eng = Engine(cfg, params,
                     EngineConfig(n_slots=slots, max_seq=max_seq,
                                  draft=draft))
        reqs = [eng.submit(p, max_new=max_new) for p in prompts]
        eng.run()
        s = eng.stats.summary()
        out[label] = {
            "tokens": [list(map(int, r.tokens)) for r in reqs],
            "useful_tokens": int(s["useful_tokens"]),
            "tokens_per_s": float(s["tokens_per_s"]),
            "target_passes": int(s["spec_target_passes"]),
            "accepted_per_pass": float(s["spec_accepted_per_pass"]),
            "acceptance_rate": float(s["spec_acceptance_rate"]),
        }
    for label in ("spec_full", "spec_shallow"):
        assert out[label]["tokens"] == out["plain"]["tokens"], \
            f"greedy {label} decode diverged from plain decode"
    assert out["spec_full"]["accepted_per_pass"] > 1.0, \
        out["spec_full"]["accepted_per_pass"]
    if not quiet:
        print(f"[serve_throughput] speculative decode, arch={arch} "
              f"slots={slots} requests={requests} max_new={max_new} "
              f"k={k} shallow_layers={shallow_layers}")
        for label in ("plain", "spec_full", "spec_shallow"):
            o = out[label]
            extra = ("" if label == "plain" else
                     f" | {o['accepted_per_pass']:.2f} tok/target-pass "
                     f"({o['target_passes']} passes, accept rate "
                     f"{o['acceptance_rate']:.2f})")
            print(f"  {label:12s}: {o['tokens_per_s']:7.1f} tok/s{extra}")
        print("  token streams identical across all three (greedy spec "
              "decode is exact)")
    return out


# ---------------------------------------------------------------------------
# Prefix cache (EngineConfig.prefix_cache): shared-system-prompt trace
# ---------------------------------------------------------------------------

def prefix_cache_comparison(arch, slots, requests, max_new, block=8,
                            sys_len=24, seed=0, quiet=False):
    """Serve one shared-system-prompt trace (every prompt = the same
    ``sys_len``-token system prefix + a short distinct user suffix)
    twice — prefix cache off vs on — and report the cache's win as
    prefill-compute savings.

    Pass/fail signals (all deterministic): token streams IDENTICAL
    between the two serves (the benchmark model is f32, where cached
    admission is bitwise the single-shot prefill), cache hits > 0 on
    the shared trace, prefill_tokens (tokens actually computed) with
    the cache STRICTLY below without, and prefix_cached_tokens > 0.
    Wall-clock is reported only.

    Rider on the same fork primitive: one best-of-n request (sampled,
    n > 1) must return n distinct ranked branches — cum_logprobs
    non-increasing — while consuming a single queue slot.
    """
    from repro.runtime.prefix_cache import PrefixCacheConfig
    from repro.runtime.sampling import SamplingParams

    cfg, params = _setup_model(arch)
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, cfg.vocab,
                              size=(sys_len,)).astype(np.int32)
    prompts = [np.concatenate([sys_prompt, rng.integers(
        0, cfg.vocab, size=(int(rng.integers(3, 9)),)).astype(np.int32)])
        for _ in range(requests)]
    max_seq = sys_len + 16 + max_new + 8
    out = {}
    for label, pcc in (("off", None),
                       ("on", PrefixCacheConfig(block=block,
                                                max_entries=32))):
        eng = Engine(cfg, params,
                     EngineConfig(n_slots=slots, max_seq=max_seq,
                                  prefix_cache=pcc))
        reqs = [eng.submit(p, max_new=max_new) for p in prompts]
        eng.run()
        s = eng.stats.summary()
        out[label] = {
            "tokens": [list(map(int, r.tokens)) for r in reqs],
            "prefill_tokens": int(s["prefill_tokens"]),
            "tokens_per_s": float(s["tokens_per_s"]),
            "hits": int(s["prefix_hits"]),
            "hit_rate": float(s["prefix_hit_rate"]),
            "cached_tokens": int(s["prefix_cached_tokens"]),
        }
    assert out["on"]["tokens"] == out["off"]["tokens"], \
        "prefix cache changed the token streams"
    assert out["on"]["hits"] > 0, "shared-prefix trace produced no hits"
    assert out["on"]["cached_tokens"] > 0
    assert out["on"]["prefill_tokens"] < out["off"]["prefill_tokens"], \
        (out["on"]["prefill_tokens"], out["off"]["prefill_tokens"])

    n = min(slots, 3)
    bo = Engine(cfg, params,
                EngineConfig(n_slots=slots, max_seq=max_seq,
                             prefix_cache=PrefixCacheConfig(block=block)))
    rq = bo.submit(prompts[0],
                   params=SamplingParams(temperature=0.9, seed=7, n=n,
                                         max_new=max_new))
    bo.run()
    streams = [tuple(c.tokens) for c in rq.branches]
    cums = [c.cum_logprob for c in rq.branches]
    assert len(streams) == n
    distinct = len(set(streams))
    assert distinct > 1, "best-of-n branches collapsed to one stream"
    assert all(a >= b for a, b in zip(cums, cums[1:])), \
        "best-of-n branches not ranked by cumulative logprob"
    assert rq.tokens == list(rq.branches[0].tokens)
    out["bestofn"] = {"n": n, "distinct": distinct,
                      "cum_logprobs": [float(c) for c in cums]}

    if not quiet:
        saved = out["off"]["prefill_tokens"] - out["on"]["prefill_tokens"]
        print(f"[serve_throughput] prefix cache, arch={arch} "
              f"slots={slots} requests={requests} sys_len={sys_len} "
              f"block={block}")
        print(f"  cache off: {out['off']['prefill_tokens']:5d} prefill "
              f"tok computed | {out['off']['tokens_per_s']:7.1f} tok/s")
        print(f"  cache on : {out['on']['prefill_tokens']:5d} prefill "
              f"tok computed | {out['on']['tokens_per_s']:7.1f} tok/s | "
              f"{out['on']['hits']} hits "
              f"(rate {out['on']['hit_rate']:.2f})")
        print(f"  suffix-only prefill saved {saved} prompt tokens "
              f"({out['on']['cached_tokens']} restored from snapshots); "
              "token streams identical")
        print(f"  best-of-{n} rider: {distinct}/{n} distinct branches, "
              f"ranked cum_logprobs "
              f"{[round(c, 2) for c in out['bestofn']['cum_logprobs']]}")
    return out


# ---------------------------------------------------------------------------
# Tensor-parallel sharded serving (EngineConfig.mesh): identity + counts
# ---------------------------------------------------------------------------

def sharded_serving_comparison(arch, slots, requests, max_new, tp=2,
                               seed=0, quiet=False):
    """Serve one saturated greedy trace twice — single-device vs a
    tp-way "model" mesh (launch/mesh.make_serving_mesh) — and gate the
    sharded-serving claims, all deterministic:

      * token identity — the sharded engine's greedy streams are
        exactly the single-device engine's;
      * no per-step resharding — the compiled pooled decode step's
        cache output shardings equal its input shardings, so chained
        burst steps never move state between devices;
      * pinned collectives — per-decode-step collective counts from
        the compiled HLO (launch/hlo_cost), exact-gated like the
        megakernel's launches-per-token;
      * per-device capacity — global slot bytes unchanged, per-DEVICE
        slot bytes strictly smaller, so device_slots_per_gb grows.

    Requires ``jax.device_count() >= tp`` (CI and bench_ci run this in
    a subprocess under XLA_FLAGS=--xla_force_host_platform_device_count=8).
    Wall-clock is never asserted (CPU; GSPMD emulation says nothing
    about real-interconnect behavior)."""
    from repro.launch import hlo_cost
    from repro.launch.mesh import make_serving_mesh

    if jax.device_count() < tp:
        raise RuntimeError(
            f"sharded_serving_comparison needs {tp} devices, have "
            f"{jax.device_count()}; run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    cfg, params = _setup_model(arch)
    rng = np.random.default_rng(seed)
    max_seq = max(LEN_CHOICES) + max_new + 8
    prompts = [rng.integers(0, cfg.vocab,
                            size=(int(rng.choice(LEN_CHOICES)),))
               .astype(np.int32) for _ in range(requests)]

    out, tokens, engines = {}, {}, {}
    for label, mesh in (("single", None), ("sharded",
                                           make_serving_mesh(tp))):
        eng = Engine(cfg, params,
                     EngineConfig(n_slots=slots, max_seq=max_seq,
                                  mesh=mesh))
        reqs = [eng.submit(p, max_new=max_new) for p in prompts]
        eng.run()
        s = eng.stats.summary()
        engines[label] = eng
        tokens[label] = [list(map(int, r.tokens)) for r in reqs]
        out[label] = {
            "useful_tokens": int(s["useful_tokens"]),
            "tokens_per_s": float(s["tokens_per_s"]),
            "state_bytes_per_slot": int(eng.pool.state_bytes_per_slot()),
            "device_state_bytes_per_slot":
                int(eng.pool.device_state_bytes_per_slot()),
            "device_slots_per_gb": float(eng.pool.device_slots_per_gb()),
        }
    assert tokens["sharded"] == tokens["single"], \
        "sharded serving diverged from single-device token streams"
    assert (out["sharded"]["state_bytes_per_slot"]
            == out["single"]["state_bytes_per_slot"])
    assert (out["sharded"]["device_state_bytes_per_slot"]
            < out["single"]["device_state_bytes_per_slot"]), \
        "sharding did not reduce per-device slot bytes"

    # compiled-decode inspection: in/out cache shardings + collectives
    eng = engines["sharded"]
    comp = eng._decode.lower(
        eng.params, eng.pool.cache, jnp.asarray(eng._next_tok),
        jnp.asarray(eng.pool.active_mask()), eng.pool.params.device(),
        jnp.zeros((eng.pool.n_total,), jnp.int32)).compile()
    cache_in = jax.tree.leaves(comp.input_shardings[0][1])
    cache_out = jax.tree.leaves(comp.output_shardings[4])
    leaves = jax.tree.leaves(eng.pool.cache)
    # equivalence, not ==: GSPMD may drop trailing replicated axes from
    # a spec (P(None, 'model', None) vs P(None, 'model')) — identical
    # placement, so no transfer happens between chained steps
    no_reshard = (len(cache_in) == len(cache_out) == len(leaves)
                  and all(a.is_equivalent_to(b, x.ndim)
                          for a, b, x in zip(cache_in, cache_out,
                                             leaves)))
    assert no_reshard, "decode step reshards the pool cache"
    n_sharded = sum(int(not s.is_fully_replicated) for s in cache_in)
    assert n_sharded >= 1, "no cache leaf is sharded on the serving mesh"
    cost = hlo_cost.analyze(comp.as_text())
    res = {
        "tokens_identical": True,
        "tp": tp,
        "no_per_step_resharding": True,
        "cache_leaves": len(cache_in),
        "sharded_cache_leaves": n_sharded,
        "decode_collectives": {k: int(v)
                               for k, v in sorted(cost.coll_count.items())},
        "decode_collective_bytes": float(cost.collective_bytes),
        "useful_tokens": out["single"]["useful_tokens"],
        "state_bytes_per_slot": out["single"]["state_bytes_per_slot"],
        "device_bytes_single":
            out["single"]["device_state_bytes_per_slot"],
        "device_bytes_sharded":
            out["sharded"]["device_state_bytes_per_slot"],
        "device_slots_per_gb_sharded": round(
            out["sharded"]["device_slots_per_gb"], 1),
        "single_tps": out["single"]["tokens_per_s"],
        "sharded_tps": out["sharded"]["tokens_per_s"],
    }
    if not quiet:
        print(f"[serve_throughput] sharded serving, arch={arch} tp={tp} "
              f"slots={slots} requests={requests} max_new={max_new}")
        print(f"  single  : {res['single_tps']:7.1f} tok/s | "
              f"{res['device_bytes_single']:8d} B/slot/device")
        print(f"  sharded : {res['sharded_tps']:7.1f} tok/s | "
              f"{res['device_bytes_sharded']:8d} B/slot/device "
              f"({res['sharded_cache_leaves']}/{res['cache_leaves']} "
              "cache leaves sharded)")
        print(f"  decode-step collectives: {res['decode_collectives']} "
              f"({res['decode_collective_bytes']:.0f} B); cache in/out "
              "shardings identical — token streams identical")
    return res


# ---------------------------------------------------------------------------
# SLO-aware multi-tenant admission (runtime/scheduler.py): bursty trace
# ---------------------------------------------------------------------------

def frontend_sched_comparison(arch, slots=2, max_new=8, seed=0,
                              quiet=False):
    """Serve one adversarial multi-tenant trace through the WFQ
    admission scheduler: tenant "burst" floods 10 standard-class
    requests (two of them sampled best-of-2) before "steady" and
    "premium" (non-sheddable gold class; premium at 4x weight) submit
    3 each.  All submissions land before the engine runs, so every
    admission decision is a pure function of (order, token counts,
    config) — no wall-clock anywhere.

    Pass/fail signals (all deterministic, pinned by bench_ci):
      * shed-before-violation — the flood's tail is rejected at the
        door (exact shed count, all of it tenant "burst"), and every
        ADMITTED request still receives its full token budget: the
        residents never pay for the burst;
      * no starvation — every steady/premium request is admitted and
        the WFQ pass-over bound stays small;
      * degradation ladder — the best-of-2 submitted inside the
        degrade window is admitted at n=1 (exact degraded count);
      * per-tenant admission/shed counts (ServeStats breakdowns).
    """
    from repro.runtime.sampling import SamplingParams
    from repro.runtime.scheduler import SchedConfig, SLOClass, SLOScheduler

    cfg, params = _setup_model(arch)
    max_seq = max(LEN_CHOICES) + max_new + 8
    eng = Engine(cfg, params, EngineConfig(n_slots=slots, max_seq=max_seq,
                                           seed=seed))
    sched = SLOScheduler(eng, SchedConfig(
        weights={"burst": 1.0, "steady": 1.0, "premium": 4.0},
        classes=(SLOClass(name="standard", ttft_budget=64),
                 SLOClass(name="gold", ttft_budget=10_000,
                          sheddable=False)),
        default_class="standard"))
    rng = np.random.default_rng(seed)

    def prompt():
        return rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)

    bo2 = SamplingParams(temperature=0.9, n=2, max_new=max_new, seed=5)
    for i in range(10):                       # the flood, first in line
        if i in (6, 7):                       # land inside the ladder
            sched.submit(prompt(), dataclasses.replace(bo2, seed=5 + i),
                         tenant="burst")
        else:
            sched.submit(prompt(), tenant="burst", max_new=max_new)
    for _ in range(3):
        sched.submit(prompt(), tenant="steady", max_new=max_new,
                     slo="gold")
    for _ in range(3):
        sched.submit(prompt(), tenant="premium", max_new=max_new,
                     slo="gold")
    done = sched.run()
    c = sched.counters()
    s = eng.stats.summary()

    # hard invariants (exact counts are additionally pinned by bench_ci)
    assert c["shed"] > 0, "the flood's tail was not shed"
    assert s["per_tenant"].get("steady", {}).get("shed", 0) == 0
    assert s["per_tenant"].get("premium", {}).get("shed", 0) == 0
    assert c["admitted_per_tenant"]["steady"] == 3
    assert c["admitted_per_tenant"]["premium"] == 3
    assert all(len(r.tokens) == max_new for r in done), \
        "an admitted request was short-changed by the burst"
    # SFQ pass-over bound is weight-relative: between two of burst's
    # (w=1) admissions, steady (w=1) admits <= 1 and premium (w=4)
    # admits <= 4, so <= 5 pass-overs; exact value pinned by bench_ci
    assert c["starvation_bound"] <= 5
    out = {
        "admitted": c["admitted"],
        "shed": c["shed"],
        "degraded": c["degraded"],
        "starvation_bound": c["starvation_bound"],
        "admitted_per_tenant": dict(sorted(
            c["admitted_per_tenant"].items())),
        "shed_per_tenant": {t: int(s["per_tenant"][t]["shed"])
                            for t in sorted(s["per_tenant"])},
        "useful_tokens": int(s["useful_tokens"]),
        "finished": len(done),
    }
    if not quiet:
        print(f"[serve_throughput] multi-tenant admission, arch={arch} "
              f"slots={slots} max_new={max_new}")
        print(f"  admitted {out['admitted']} "
              f"({out['admitted_per_tenant']}), shed {out['shed']} "
              f"(all burst: {out['shed_per_tenant']}), degraded "
              f"{out['degraded']} best-of-n -> n=1")
        print(f"  starvation bound {out['starvation_bound']} pass-overs; "
              f"every admitted request got its full {max_new} tokens")
    return out


# ---------------------------------------------------------------------------
# Prefill/decode disaggregation (runtime/disagg.py): handoff exactness
# ---------------------------------------------------------------------------

def disagg_comparison(arch, slots=2, requests=6, max_new=8,
                      queue_depth=2, seed=0, quiet=False):
    """Serve one mixed greedy/sampled trace twice — monolithic Engine
    vs DisaggPipeline (1-slot prefill worker -> bounded transfer queue
    -> decode pool) — and gate the handoff claims, all deterministic:

      * token identity — every disaggregated stream (and its cumulative
        logprob) is BITWISE the monolithic engine's: the worker runs
        the same compiled prefill with the same derived seed, and the
        handoff is scatter(gather(state)) — exact data movement;
      * no local prefill — the decode pool admits snapshots only
        (prefill_tokens == 0, snapshot_admits == requests);
      * wire accounting — transfers, bytes-per-snapshot (fixed state
        block layout arithmetic) and the bounded queue's max depth.

    Wall-clock is never asserted (two pools on one CPU say nothing
    about a real two-pool deployment's latency)."""
    from repro.runtime.disagg import DisaggConfig, DisaggPipeline
    from repro.runtime.sampling import SamplingParams

    cfg, params = _setup_model(arch)
    rng = np.random.default_rng(seed)
    max_seq = max(LEN_CHOICES) + max_new + 8
    trace = []
    for i in range(requests):
        p = rng.integers(0, cfg.vocab,
                         size=(int(rng.choice(LEN_CHOICES)),)) \
            .astype(np.int32)
        sp = (SamplingParams(max_new=max_new) if i % 2 == 0 else
              SamplingParams(temperature=0.9, top_k=12, max_new=max_new))
        trace.append((p, sp))

    mono = Engine(cfg, params, EngineConfig(n_slots=slots,
                                            max_seq=max_seq, seed=seed))
    for p, sp in trace:
        mono.submit(p, sp)
    ref = {r.req_id: (r.tokens, r.cum_logprob) for r in mono.run()}

    pipe = DisaggPipeline(cfg, params,
                          EngineConfig(n_slots=slots, max_seq=max_seq,
                                       seed=seed),
                          DisaggConfig(queue_depth=queue_depth))
    items = [pipe.submit(p, sp) for p, sp in trace]
    pipe.run()
    identical = all(
        item.req.tokens == ref[i][0]
        and item.req.cum_logprob == ref[i][1]
        for i, item in enumerate(items))
    assert identical, "disaggregated stream diverged from monolithic"
    s = pipe.decode.stats.summary()
    assert s["prefill_tokens"] == 0, \
        "decode pool ran a local prefill instead of a snapshot admit"
    assert s["snapshot_admits"] == requests
    c = pipe.counters()
    assert c["transfers"] == requests
    assert c["max_queue_depth"] <= queue_depth
    out = {
        "tokens_identical": True,
        "requests": requests,
        "transfers": c["transfers"],
        "transfer_bytes": c["transfer_bytes"],
        "bytes_per_snapshot": c["transfer_bytes"] // max(1, c["transfers"]),
        "max_queue_depth": c["max_queue_depth"],
        "queue_depth_bound": queue_depth,
        "snapshot_admits": int(s["snapshot_admits"]),
        "snapshot_tokens": int(s["snapshot_tokens"]),
        "decode_prefill_tokens": int(s["prefill_tokens"]),
        "useful_tokens": int(s["useful_tokens"]),
    }
    if not quiet:
        print(f"[serve_throughput] disaggregated serving, arch={arch} "
              f"slots={slots} requests={requests} max_new={max_new} "
              f"queue_depth={queue_depth}")
        print(f"  handoff: {out['transfers']} snapshots, "
              f"{out['bytes_per_snapshot']} B each "
              f"({out['transfer_bytes']} B total), queue depth peaked "
              f"at {out['max_queue_depth']}/{queue_depth}")
        print(f"  decode pool: {out['snapshot_admits']} snapshot admits, "
              f"0 local prefill tokens — token streams and cumulative "
              "logprobs identical to monolithic")
    return out


def run():
    """benchmarks/run.py protocol: quick saturated comparison, CSV rows."""
    from benchmarks import common
    stats = _compare(arch="mamba-130m", slots=4, requests=16, rate=1000.0,
                     max_new_lo=4, max_new_hi=48, seed=0, reps=3,
                     quiet=True)
    us_per_tok = 1e6 * stats["engine_wall"] / stats["useful"]
    common.emit("serve_throughput_engine", us_per_tok,
                f"speedup_vs_static={stats['speedup']:.2f}x")
    fused = _fused_decode_comparison(arch="mamba-130m", slots=4,
                                     requests=8, max_new=16, reps=3,
                                     quiet=True)
    # on CPU the fused kernel runs under the Pallas interpreter, so tag
    # the row — the trajectory must not read interpreter overhead as a
    # kernel regression/improvement
    tag = (";cpu_interpret=1" if jax.default_backend() == "cpu" else "")
    common.emit("serve_decode_fused_step",
                1e6 / max(fused["fused_tps"], 1e-9),
                f"speedup_vs_unfused={fused['fused_speedup']:.2f}x{tag}")
    # launches/token is a static jaxpr property (backend-independent);
    # tok/s rides the same cpu_interpret caveat as the fused row
    mega = megakernel_decode_comparison(arch="mamba-130m", slots=4,
                                        requests=8, max_new=16, reps=3,
                                        quiet=True)
    common.emit("serve_decode_megakernel_launches",
                float(mega["launches_megakernel"]),
                f"fused_launches={mega['launches_fused']};"
                f"speedup_vs_fused={mega['megakernel_speedup']:.2f}x"
                f"{tag};tokens_identical=1")
    sweep = state_dtype_comparison(arch="mamba-130m", slots=4, requests=8,
                                   max_new=16, quiet=True)
    gain = (sweep["f32"]["state_bytes_per_slot"]
            / sweep["int8"]["state_bytes_per_slot"])
    common.emit("serve_state_int8_slots_per_gb",
                sweep["int8"]["slots_per_gb"],
                f"capacity_gain_vs_f32={gain:.2f}x;"
                f"agreement={sweep['int8']['token_agreement_vs_f32']:.3f}")
    wq = weight_dtype_comparison(arch="mamba-130m", slots=4, requests=8,
                                 max_new=16, quiet=True)
    common.emit("serve_weight_int8_bytes_per_token",
                float(wq["int8"]["weight_bytes_per_token"]),
                f"reduction_vs_f32={wq['reduction']:.2f}x;"
                f"agreement={wq['int8']['token_agreement_vs_f32']:.3f}")
    hetero = hetero_sampling_comparison(arch="mamba-130m", slots=4,
                                        requests=8, max_new=16,
                                        quiet=True)
    common.emit("serve_hetero_sampling_retraces",
                float(hetero["decode_retraces"]),
                f"greedy_bitwise={int(hetero['greedy_rows_bitwise'])};"
                f"seeded_repro={int(hetero['seeded_repro'])}")
    # no cpu_interpret tag here: accepted-per-pass is a deterministic
    # trace count, independent of backend/interpreter
    spec = spec_decode_comparison(arch="mamba-130m", slots=4, requests=6,
                                  max_new=12, k=3, quiet=True)
    common.emit("serve_spec_accepted_per_pass",
                spec["spec_full"]["accepted_per_pass"],
                f"shallow={spec['spec_shallow']['accepted_per_pass']:.2f};"
                f"shallow_accept_rate="
                f"{spec['spec_shallow']['acceptance_rate']:.2f};"
                f"tokens_identical=1")
    # prefill-token savings are a deterministic count (no cpu_interpret
    # tag needed); tokens_identical=1 is asserted inside the comparison
    pc = prefix_cache_comparison(arch="mamba-130m", slots=4, requests=8,
                                 max_new=12, quiet=True)
    common.emit("serve_prefix_cached_tokens",
                float(pc["on"]["cached_tokens"]),
                f"hit_rate={pc['on']['hit_rate']:.2f};"
                f"prefill_saved="
                f"{pc['off']['prefill_tokens'] - pc['on']['prefill_tokens']};"
                f"bestofn_distinct={pc['bestofn']['distinct']};"
                "tokens_identical=1")
    # admission + disagg counts are deterministic (no cpu_interpret tag)
    fs = frontend_sched_comparison(arch="mamba-130m", slots=2, quiet=True)
    common.emit("serve_multi_tenant_shed", float(fs["shed"]),
                f"admitted={fs['admitted']};degraded={fs['degraded']};"
                f"starvation_bound={fs['starvation_bound']}")
    dg = disagg_comparison(arch="mamba-130m", slots=2, quiet=True)
    common.emit("serve_disagg_bytes_per_snapshot",
                float(dg["bytes_per_snapshot"]),
                f"transfers={dg['transfers']};"
                f"max_queue_depth={dg['max_queue_depth']};"
                "tokens_identical=1")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba-130m")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=1000.0,
                    help="Poisson arrival rate (req/s); the default "
                         "saturates the pool so tokens/sec is "
                         "service-bound (at low rates both sides are "
                         "arrival-bound and differ in TTFT instead)")
    ap.add_argument("--max-new-lo", type=int, default=4)
    ap.add_argument("--max-new-hi", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reps", type=int, default=3,
                    help="repetitions per side; median wall time is "
                         "scored (CPU timing noise easily exceeds 20%%)")
    ap.add_argument("--spec-k", type=int, default=3,
                    help="speculative draft depth for the spec-decode "
                         "comparison")
    args = ap.parse_args()
    stats = _compare(args.arch, args.slots, args.requests, args.rate,
                     args.max_new_lo, args.max_new_hi, args.seed, args.reps)
    _fused_decode_comparison(args.arch, args.slots,
                             requests=min(args.requests, 8),
                             max_new=16, reps=args.reps, seed=args.seed)
    megakernel_decode_comparison(args.arch, args.slots,
                                 requests=min(args.requests, 8),
                                 max_new=16, reps=args.reps,
                                 seed=args.seed)
    state_dtype_comparison(args.arch, args.slots,
                           requests=min(args.requests, 8),
                           max_new=16, seed=args.seed,
                           dtypes=("f32", "bf16", "int8", "fp8"))
    weight_dtype_comparison(args.arch, args.slots,
                            requests=min(args.requests, 8),
                            max_new=16, seed=args.seed)
    hetero_sampling_comparison(args.arch, args.slots,
                               requests=min(args.requests, 8),
                               max_new=16, seed=args.seed)
    spec_decode_comparison(args.arch, args.slots,
                           requests=min(args.requests, 8),
                           max_new=16, k=args.spec_k, seed=args.seed)
    prefix_cache_comparison(args.arch, args.slots,
                            requests=min(args.requests, 8),
                            max_new=16, seed=args.seed)
    frontend_sched_comparison(args.arch, slots=2)
    disagg_comparison(args.arch, slots=2,
                      requests=min(args.requests, 6))
    # Exit status: deterministic token accounting already asserted above;
    # the timing ratio is only asserted off-CPU, and generously — a
    # same-order engine is not a regression, a 2x slowdown is.
    if jax.default_backend() == "cpu":
        return 0
    return 0 if stats["engine_tps"] >= 0.5 * stats["static_tps"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
