"""Shared helpers for the benchmark suite (one module per paper figure)."""
from __future__ import annotations

import time

import jax


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall time of a jit'd call in microseconds (CPU timings are
    functional only — TPU numbers come from the dry-run roofline)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.2f},{derived}")
