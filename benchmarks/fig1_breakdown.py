"""Fig. 1: runtime breakdown (linear vs element-wise vs other) vs seq len.

Reproduced on the modeled Mamba-GPU baseline (the paper's profiling
platform).  Checks the headline claim: element-wise share exceeds 60% by
L = 2048.
"""
from __future__ import annotations

from repro import configs
from repro.core import marca_model as mm, op_graph
from benchmarks.common import emit


def run():
    cfg = configs.get_config("mamba-2.8b")
    rows = []
    for L in [64, 128, 256, 512, 1024, 2048, 4096]:
        ops = op_graph.mamba_model_ops(cfg, L)
        t = mm.model_time(ops, mm.GPU)
        tot = t["seconds"]
        ew = (t["by_group"].get("element-wise", 0)
              + t["by_group"].get("nonlinear", 0)) / tot
        lin = t["by_group"].get("linear", 0) / tot
        rows.append((L, lin, ew))
        emit(f"fig1.breakdown.L{L}", tot * 1e6,
             f"linear={lin:.2f};elementwise={ew:.2f}")
    ew_2048 = dict((r[0], r[2]) for r in rows)[2048]
    ok = ew_2048 > 0.60
    emit("fig1.claim.ew_gt_60pct_at_2048", 0.0,
         f"ew_share={ew_2048:.2f};paper>0.60;{'OK' if ok else 'MISS'}")
    return rows


if __name__ == "__main__":
    run()
