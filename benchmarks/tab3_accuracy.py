"""Table 3: accuracy under the approximation algorithms.

The paper evaluates WikiText/Lambada ppl + 4 zero-shot suites on released
Mamba checkpoints (no network access here).  Same protocol, two in-repo
surrogates (DESIGN.md §7):

  (a) function-level error on the paper's stated input distributions
      (density set x=-7/n for exp; [-5, 4] for SiLU);
  (b) end-to-end: train a tiny Mamba on the synthetic corpus with exact
      nonlinearities, then evaluate held-out ppl with each approximation
      swapped in (fast_exp / our_exp / our_silu / ours-full) — mirroring
      Table 3's rows.  Claim checked: our_exp degrades ppl far less than
      plain fast_exp, and the full approx stack stays within a few percent.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import approx
from repro.data import SyntheticLM
from repro.models import registry
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.parallel import sharding
from benchmarks.common import emit


def _function_level():
    xs = jnp.asarray(approx.exp_density_set())
    t = np.exp(np.asarray(xs, np.float64))
    for name, fn in [("fast_exp", approx.fast_exp),
                     ("our_exp", approx.our_exp)]:
        y = np.asarray(fn(xs), np.float64)
        emit(f"tab3.fn.{name}", 0.0,
             f"mean_rel_err={np.mean(np.abs(y - t) / t):.4f};"
             f"max_rel_err={np.max(np.abs(y - t) / t):.4f}")
    x = jnp.linspace(-5, 4, 30001)
    for name, fn in [("silu_paper_eq3", approx.piecewise_silu_paper),
                     ("silu_ours", approx.piecewise_silu)]:
        err = np.asarray(jnp.abs(fn(x) - jax.nn.silu(x)))
        emit(f"tab3.fn.{name}", 0.0,
             f"max_abs_err={err.max():.4f};mean_abs_err={err.mean():.5f}")


def _train_tiny_mamba(steps=220):
    cfg = configs.smoke_variant(configs.get_config("mamba-130m"))
    cfg = dataclasses.replace(cfg, vocab=128, n_layers=2, d_model=64,
                              dt_rank=8, dtype="float32")
    params = sharding.tree_values(registry.init_params(cfg,
                                                       jax.random.key(0)))
    ocfg = AdamWConfig(lr=3e-3, weight_decay=0.01)
    state = adamw_init(params, ocfg)
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=64, seed=0)

    @jax.jit
    def step(p, s, b):
        (_, m), g = jax.value_and_grad(
            lambda q: registry.loss_fn(cfg, q, b), has_aux=True)(p)
        p, s, _ = adamw_update(g, s, p, ocfg)
        return p, s, m

    for i in range(steps):
        b = ds.batch_at(i, 0, 1, 16)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, state, m = step(params, state, b)
    return cfg, params, ds


def _eval_ppl(cfg, params, ds, exp_impl, silu_impl, n_batches=8):
    cfg2 = dataclasses.replace(cfg, exp_impl=exp_impl, silu_impl=silu_impl)

    @jax.jit
    def nll(p, b):
        return registry.loss_fn(cfg2, p, b)[1]["nll"]

    tot = 0.0
    for i in range(n_batches):
        b = ds.batch_at(10_000 + i, 0, 1, 16)     # held-out steps
        b = {k: jnp.asarray(v) for k, v in b.items()}
        tot += float(nll(params, b))
    return float(np.exp(tot / n_batches))


def _scan_fidelity(L=512, d=64, n=16):
    """Long-memory probe: h decay error compounds over L steps.  exact
    exp(~0)=1 preserves state; Schraudolph variants decay it — the
    mechanism behind the paper's fast_exp Lambada blow-up (300 vs 8.1)."""
    from repro.kernels import ref as kref
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, L, d)).astype(np.float32))
    # realistic selective-scan stats: small dt (long memory), A ~ -[1, n]
    dt = jax.nn.softplus(jnp.asarray(
        rng.normal(loc=-4.0, size=(1, L, d)).astype(np.float32)))
    A = -jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (d, 1)) / n
    B = jnp.asarray(rng.normal(size=(1, L, n)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(1, L, n)).astype(np.float32))
    y0, h0 = kref.selective_scan(x, dt, A, B, C, exp_impl="exact")
    out = {}
    for name in ["fast", "ours"]:
        y1, h1 = kref.selective_scan(x, dt, A, B, C, exp_impl=name)
        out[name] = float(jnp.linalg.norm(h1 - h0) /
                          jnp.maximum(jnp.linalg.norm(h0), 1e-9))
        emit(f"tab3.scan_fidelity.{name}", 0.0,
             f"h_rel_err_after_{L}_steps={out[name]:.4f}")
    ok = out["ours"] < out["fast"]
    emit("tab3.scan_fidelity.claim", 0.0,
         f"ours_better_than_fast={'OK' if ok else 'MISS'};"
         f"ratio={out['fast'] / max(out['ours'], 1e-12):.2f}x")
    return ok


def run(steps=220):
    _function_level()
    _scan_fidelity()
    cfg, params, ds = _train_tiny_mamba(steps)
    rows = [
        ("exact", "exact", "exact"),
        ("fast_exp", "fast", "exact"),
        ("our_exp", "ours", "exact"),
        ("our_silu", "exact", "ours"),
        ("ours_full", "ours", "ours"),
        ("paper_silu_eq3", "ours", "paper"),
    ]
    ppl = {}
    for name, e, s in rows:
        ppl[name] = _eval_ppl(cfg, params, ds, e, s)
        emit(f"tab3.e2e.{name}", 0.0, f"ppl={ppl[name]:.4f}")
    base = ppl["exact"]
    ours_delta = (ppl["ours_full"] - base) / base
    fast_delta = (ppl["fast_exp"] - base) / base
    our_exp_delta = (ppl["our_exp"] - base) / base
    # on the short-memory synthetic corpus the deltas are expected ~0
    # (no long-range state to corrupt); the claim is carried by the
    # scan-fidelity probe + function-level errors above.
    ok = abs(ours_delta) < 0.05
    emit("tab3.claim.e2e_ppl", 0.0,
         f"fast_exp_ppl_delta={fast_delta:+.4f};"
         f"our_exp_ppl_delta={our_exp_delta:+.4f};"
         f"ours_full_ppl_delta={ours_delta:+.4f};"
         f"paper:approx_loss_small;{'OK' if ok else 'MISS'}")
    return ppl


if __name__ == "__main__":
    run()
