"""Microbenchmarks of the JAX/Pallas layers (functional timings on CPU;
TPU perf comes from the dry-run roofline, EXPERIMENTS.md §Roofline).

Compares the scan implementations (the MARCA fusion story at XLA level):
assoc (unfused baseline, O(L*d*n) traffic) vs chunked (state-resident)
vs the Pallas kernel (interpret mode — correctness/lowering path only).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import selective_scan as css
from repro.kernels import ops as kops
from benchmarks.common import emit, timed


def _inputs(b=2, L=512, d=256, n=16):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(b, L, d)).astype(np.float32))
    dt = jax.nn.softplus(jnp.asarray(
        rng.normal(size=(b, L, d)).astype(np.float32)))
    A = -jnp.exp(jnp.asarray(rng.normal(size=(d, n)).astype(np.float32))
                 * 0.5)
    B = jnp.asarray(rng.normal(size=(b, L, n)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(b, L, n)).astype(np.float32))
    D = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    z = jnp.asarray(rng.normal(size=(b, L, d)).astype(np.float32))
    return x, dt, A, B, C, D, z


def run():
    args = _inputs()

    for impl in ["seq", "assoc", "chunked"]:
        fn = jax.jit(lambda *a, _i=impl: css.get_scan(_i)(*a))
        us = timed(fn, *args)
        emit(f"kernels.scan.{impl}", us, "b2xL512xd256xn16,f32,xla-cpu")

    # element-wise approx kernels vs exact
    x = jnp.asarray(np.random.default_rng(1).normal(
        size=(1024, 1024)).astype(np.float32))
    for name, fn in [
            ("exp.exact", jax.jit(jnp.exp)),
            ("exp.ours_jnp", jax.jit(lambda v: kops.exp(v, "ours"))),
            ("silu.exact", jax.jit(jax.nn.silu)),
            ("silu.ours_jnp", jax.jit(lambda v: kops.silu(v, "ours")))]:
        emit(f"kernels.{name}", timed(fn, x), "1Melem,f32,xla-cpu")


if __name__ == "__main__":
    run()
