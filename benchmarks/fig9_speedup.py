"""Fig. 9: MARCA speedup & energy efficiency vs Mamba-CPU / Mamba-GPU,
across the Mamba family x sequence lengths (cycle-approximate models,
constants documented in core/marca_model.py + EXPERIMENTS.md).

Paper targets: speedup up to 463.22x / 11.66x (CPU / GPU), average
194.26x / 4.93x; energy up to 9761.42x / 242.52x, average 3415.55x /
42.49x.
"""
from __future__ import annotations

import numpy as np

from repro import configs
from repro.configs.zoo import MAMBA_FAMILY
from repro.core import marca_model as mm, op_graph
from benchmarks.common import emit

SEQ_LENS = [64, 256, 1024, 2048, 4096]


def run():
    s_cpu, s_gpu, e_cpu, e_gpu = [], [], [], []
    for name in MAMBA_FAMILY:
        cfg = configs.get_config(name)
        for L in SEQ_LENS:
            ops = op_graph.mamba_model_ops(cfg, L)
            t_marca = mm.model_time(ops, mm.MARCA)["seconds"]
            sc = mm.speedup(ops, mm.CPU)
            sg = mm.speedup(ops, mm.GPU)
            ec = mm.energy_ratio(ops, mm.CPU)
            eg = mm.energy_ratio(ops, mm.GPU)
            s_cpu.append(sc); s_gpu.append(sg)
            e_cpu.append(ec); e_gpu.append(eg)
            emit(f"fig9.{name}.L{L}", t_marca * 1e6,
                 f"speedup_cpu={sc:.1f};speedup_gpu={sg:.2f};"
                 f"energy_cpu={ec:.0f};energy_gpu={eg:.1f}")
    emit("fig9.summary.speedup_cpu", 0.0,
         f"max={max(s_cpu):.1f};avg={np.mean(s_cpu):.1f};"
         f"paper_max=463.22;paper_avg=194.26")
    emit("fig9.summary.speedup_gpu", 0.0,
         f"max={max(s_gpu):.2f};avg={np.mean(s_gpu):.2f};"
         f"paper_max=11.66;paper_avg=4.93")
    emit("fig9.summary.energy_cpu", 0.0,
         f"max={max(e_cpu):.0f};avg={np.mean(e_cpu):.0f};"
         f"paper_max=9761;paper_avg=3416")
    emit("fig9.summary.energy_gpu", 0.0,
         f"max={max(e_gpu):.1f};avg={np.mean(e_gpu):.1f};"
         f"paper_max=242.5;paper_avg=42.5")


if __name__ == "__main__":
    run()
