"""Roofline report (deliverable g): reads experiments/dryrun/*.json and
renders the per-(arch x shape x mesh) three-term table + dominant
bottleneck + what-would-move-it-down, in markdown (EXPERIMENTS.md §Roofline)
and as CSV rows for benchmarks.run.
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

_FIX_HINTS = {
    ("memory", "train"): ("bf16 score/softmax tensors + flash-attention "
                          "kernel (kills fusion-boundary spills)"),
    ("memory", "prefill"): ("flash/fused attention + bf16 intermediates; "
                            "avoid f32 logits materialization"),
    ("memory", "decode"): ("weight-stationary sharding (drop FSDP gathers "
                           "at decode); fuse the per-token EW chain"),
    ("compute", "train"): "less remat recompute; larger per-chip batch",
    ("compute", "prefill"): "MXU-aligned tiles; bf16 everywhere",
    ("compute", "decode"): "batch more requests per step",
    ("collective", "train"): ("reduce-scatter+all-gather instead of "
                              "all-reduce; overlap FSDP gathers with scan"),
    ("collective", "prefill"): "TP-block collectives in bf16",
    ("collective", "decode"): "replicate small weights; kill per-token AG",
}


def load_cells(tag: str = "") -> list[dict]:
    cells = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(p) as f:
            d = json.load(f)
        if d.get("tag", "") != tag:
            continue
        cells.append(d)
    return cells


def _fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def markdown_table(cells, mesh="single") -> str:
    from repro.configs import shapes as shp
    rows = ["| arch | shape | status | compute | memory | collective | "
            "dominant | useful/HLO | roofline frac | mem/chip | fix |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    kind_of = {k: v.kind for k, v in shp.SHAPES.items()}
    for c in cells:
        if c["mesh"] != mesh:
            continue
        if c["status"] == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | SKIP "
                        f"({c['reason'][:40]}...) | — | — | — | — | — | — "
                        f"| — |")
            continue
        if c["status"] != "ok":
            rows.append(f"| {c['arch']} | {c['shape']} | ERROR | — | — | — "
                        f"| — | — | — | — | {c.get('error', '')[:60]} |")
            continue
        r = c["roofline"]
        kind = kind_of[c["shape"]]
        fix = _FIX_HINTS.get((r["dominant"], kind), "")
        frac = (r["roofline_fraction"] if kind != "decode"
                else c.get("memory_fraction", 0.0))
        frac_s = (f"{frac:.3f}" if kind != "decode"
                  else f"{frac:.3f} (mem)")
        rows.append(
            f"| {c['arch']} | {c['shape']} | ok "
            f"| {_fmt_s(r['compute_s'])} | {_fmt_s(r['memory_s'])} "
            f"| {_fmt_s(r['collective_s'])} | **{r['dominant']}** "
            f"| {r['useful_flops_ratio']:.2f} | {frac_s} "
            f"| {c.get('mem_per_device_gb', '?')}GB | {fix} |")
    return "\n".join(rows)


def run():
    from benchmarks.common import emit
    cells = load_cells()
    for c in cells:
        if c["status"] != "ok":
            emit(f"roofline.{c['arch']}.{c['shape']}.{c['mesh']}", 0.0,
                 f"status={c['status']}")
            continue
        r = c["roofline"]
        emit(f"roofline.{c['arch']}.{c['shape']}.{c['mesh']}",
             r["compute_s"] * 1e6 if r else 0.0,
             f"dominant={r['dominant']};compute_s={r['compute_s']:.4f};"
             f"memory_s={r['memory_s']:.4f};"
             f"collective_s={r['collective_s']:.4f};"
             f"useful_ratio={r['useful_flops_ratio']:.3f};"
             f"frac={r['roofline_fraction']:.4f}")


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    print(markdown_table(load_cells(), mesh))
