"""Benchmark runner (deliverable d): one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  fig1  runtime breakdown vs seq len          (paper Fig. 1)
  fig7  compute intensity / r-w ratio spread  (paper Fig. 7)
  fig9  speedup + energy vs CPU/GPU           (paper Fig. 9)
  fig10 RCU-vs-TC + buffer-management ablation(paper Fig. 10)
  tab3  approximation accuracy                (paper Table 3)
  kernels  scan/exp/silu microbenchmarks      (functional, CPU)
  roofline per-(arch x shape x mesh) terms    (from experiments/dryrun)
  serve    continuous-batching vs static-batch serving throughput
"""
from __future__ import annotations

import sys


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    from benchmarks import (fig1_breakdown, fig7_intensity, fig9_speedup,
                            fig10_ablation, kernel_bench, roofline,
                            serve_throughput, tab3_accuracy)
    mods = {
        "fig1": fig1_breakdown, "fig7": fig7_intensity,
        "fig9": fig9_speedup, "fig10": fig10_ablation,
        "tab3": tab3_accuracy, "kernels": kernel_bench,
        "roofline": roofline, "serve": serve_throughput,
    }
    for name, mod in mods.items():
        if only and name != only:
            continue
        mod.run()


if __name__ == "__main__":
    main()
