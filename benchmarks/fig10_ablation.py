"""Fig. 10 ablations:
  (1) RCU vs Tensor-Core-only speedup across seq lens (paper: 1.41x-11.95x),
  (2) normalized RPE area (paper constants mirrored; no RTL here),
  (3) intra-/inter-BM memory-access reduction (paper: -73% short-seq intra,
      -49% long-seq inter).
"""
from __future__ import annotations

from repro import configs
from repro.core import buffer_manager as bm, marca_model as mm, op_graph
from benchmarks.common import emit


def run():
    cfg = configs.get_config("mamba-2.8b")
    # (1) RCU vs TC-only
    ratios = []
    for L in [64, 256, 1024, 2048, 4096, 8192]:
        ops = op_graph.mamba_model_ops(cfg, L)
        r = mm.speedup(ops, mm.TENSOR_CORE_ONLY)
        ratios.append(r)
        emit(f"fig10.rcu_vs_tc.L{L}", 0.0, f"speedup={r:.2f}")
    emit("fig10.rcu_vs_tc.summary", 0.0,
         f"min={min(ratios):.2f};max={max(ratios):.2f};paper=1.41-11.95")

    # (2) area: paper Table/Fig numbers mirrored (no synthesis possible)
    emit("fig10.rpe_area", 0.0,
         "reusable_rpe_overhead=+14%(paper);dedicated_nonlinear=+30%(paper);"
         "not_synthesizable_in_jax=TRUE")

    # (3) memory-access reduction by policy
    for L, focus in [(64, "intra"), (128, "intra"), (2048, "inter"),
                     (4096, "inter")]:
        ops = op_graph.mamba_model_ops(cfg, L)
        t = bm.policy_table(ops)
        red_intra = 1 - t["intra"].total / t["none"].total
        red_inter = 1 - t["inter"].total / t["none"].total
        red_both = 1 - t["both"].total / t["none"].total
        emit(f"fig10.bm.L{L}", 0.0,
             f"intra={red_intra:.2f};inter={red_inter:.2f};"
             f"both={red_both:.2f};paper_intra~0.73@short;"
             f"paper_inter~0.49@long")


if __name__ == "__main__":
    run()
