"""Logical-axis sharding: params and activations carry *logical* axis names;
rules map them onto mesh axes (MaxText-style), so the same model code runs on
1 CPU device, the 256-chip single-pod mesh, and the 512-chip multi-pod mesh.

Param logical axes
  vocab   embedding rows / unembed cols          -> TP ("model")
  embed   d_model                                -> FSDP ("data")
  heads   flattened q-projection out dim         -> TP
  kv      flattened kv-projection out dim        -> TP
  ffn     MLP hidden / mamba d_inner             -> TP
  expert  MoE expert dim                         -> EP ("model")
  layers  stacked-scan layer dim                 -> never sharded

Activation logical axes
  act_batch  -> ("pod", "data") when the batch is shardable
  act_seq    -> "data" only for long-context batch=1 shapes (SP)
  act_ffn / act_heads -> "model" (TP interior)

Cross-pod policy (DESIGN.md §4): parameters are *not* sharded over "pod";
FSDP gathers stay on intra-pod ICI and the only DCN collective is the
gradient/step all-reduce over "pod".
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@jax.tree_util.register_pytree_node_class
class Param:
    """A parameter bundled with its logical axis names.

    Registered as a pytree *node* whose only child is ``value`` and whose
    aux data is ``axes`` — so vmap/eval_shape/scan treat the axes as static
    metadata (stacking a Param under vmap batches the value and keeps axes).
    """
    __slots__ = ("value", "axes")

    def __init__(self, value, axes: tuple):
        self.value = value
        self.axes = tuple(axes)

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)

    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"Param(shape={shape}, axes={self.axes})"


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical-name -> mesh-axis mapping. None = replicated."""
    vocab: Any = "model"
    embed: Any = "data"          # FSDP; set None to replicate params over data
    heads: Any = "model"
    kv: Any = "model"
    ffn: Any = "model"
    expert: Any = "model"
    layers: Any = None
    conv: Any = None
    state: Any = None
    act_batch: Any = ("pod", "data")
    act_seq: Any = None
    act_ffn: Any = "model"
    act_heads: Any = "model"
    act_embed: Any = None
    act_vocab: Any = "model"
    act_expert: Any = "model"

    def resolve(self, name, mesh_axes) -> Any:
        """Logical name -> mesh axis (dropping axes absent from the mesh)."""
        if name is None:
            return None
        target = getattr(self, name)
        if target is None:
            return None
        if isinstance(target, (tuple, list)):
            kept = tuple(t for t in target if t in mesh_axes)
            return kept if kept else None
        return target if target in mesh_axes else None


#: Rules for long-context batch=1 decode: shard along sequence instead.
LONG_CONTEXT_OVERRIDES = dict(act_batch=None, act_seq="data")

_CTX: dict = {"mesh": None, "rules": ShardingRules()}


def set_mesh_and_rules(mesh: Optional[Mesh], rules: Optional[ShardingRules]):
    _CTX["mesh"] = mesh
    _CTX["rules"] = rules or ShardingRules()


class use_mesh:
    """Context manager installing (mesh, rules) for logical constraints."""

    def __init__(self, mesh, rules=None):
        self.new = (mesh, rules or ShardingRules())

    def __enter__(self):
        self.old = (_CTX["mesh"], _CTX["rules"])
        _CTX["mesh"], _CTX["rules"] = self.new
        return self

    def __exit__(self, *exc):
        _CTX["mesh"], _CTX["rules"] = self.old
        return False


def shard_ctx(shard):
    """``use_mesh`` for an optional ``(mesh, rules)`` pair.

    The serving stack keys its shared jit caches on such a pair (both
    halves are hashable) and enters this context INSIDE the traced
    function body, so logical ``constrain`` calls bake the mesh at
    trace time — a sharded engine and a single-device engine can never
    alias one trace.  ``None`` is a true no-op: the single-device path
    traces byte-identical jaxprs to the pre-mesh code.
    """
    if shard is None:
        return contextlib.nullcontext()
    return use_mesh(shard[0], shard[1])


def logical_to_spec(axes, mesh=None, rules=None) -> P:
    mesh = mesh or _CTX["mesh"]
    rules = rules or _CTX["rules"]
    if mesh is None:
        return P()
    mesh_axes = set(mesh.axis_names)
    return P(*(rules.resolve(a, mesh_axes) for a in axes))


def constrain(x, *axes):
    """Apply a logical sharding constraint; no-op without an active mesh."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    spec = logical_to_spec(axes, mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Param-tree utilities
# ---------------------------------------------------------------------------

def pcast_varying(x, axis_name: str):
    """Mark ``x`` varying over ``axis_name`` for shard_map's vma type
    system.  On jax versions without lax.pcast (pre-vma) this is the
    identity — values there are implicitly varying."""
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is None:
        return x
    return pcast(x, (axis_name,), to="varying")


def is_param(x) -> bool:
    return isinstance(x, Param)


def tree_values(params):
    """Strip Param wrappers -> plain value pytree (idempotent)."""
    return jax.tree.map(lambda p: p.value if is_param(p) else p, params,
                        is_leaf=is_param)


def tree_axes(params):
    """Param tree -> logical-axes pytree (leaves are tuples)."""
    return jax.tree.map(lambda p: p.axes, params, is_leaf=is_param)


def spec_for_shape(shape, axes, mesh, rules=None) -> P:
    """Shape-aware spec: jit in_shardings demand exact divisibility, so for
    each dim keep the greedy prefix of mesh axes that divides it (e.g. a
    4-head xlstm param under a 16-way 'model' axis falls back to replicated;
    a batch of 2 under ('pod','data') keeps just 'pod')."""
    rules = rules or _CTX["rules"] or ShardingRules()
    mesh_axes = set(mesh.axis_names)
    entries = []
    for dim, name in zip(shape, axes):
        t = rules.resolve(name, mesh_axes)
        if t is None:
            entries.append(None)
            continue
        axs = t if isinstance(t, tuple) else (t,)
        chosen, size = [], 1
        for a in axs:
            if dim % (size * mesh.shape[a]) == 0:
                chosen.append(a)
                size *= mesh.shape[a]
            else:
                break
        entries.append(tuple(chosen) if len(chosen) > 1
                       else (chosen[0] if chosen else None))
    return P(*entries)


def tree_shardings(params, mesh, rules=None):
    """Param tree (or axes tree) -> NamedSharding pytree for pjit
    (shape-aware when the leaf carries a shape)."""
    rules = rules or _CTX["rules"] or ShardingRules()

    def _one(p):
        axes = p.axes if is_param(p) else p
        shape = getattr(getattr(p, "value", None), "shape", None)
        if shape is not None:
            spec = spec_for_shape(shape, axes, mesh, rules)
        else:
            spec = logical_to_spec(axes, mesh, rules)
        return NamedSharding(mesh, spec)

    return jax.tree.map(_one, params,
                        is_leaf=lambda x: is_param(x) or isinstance(x, tuple))


def constrain_tree(values, axes_tree, mesh=None, rules=None):
    """Constrain every leaf of a plain-value pytree to its logical axes
    (shape-aware, the in-jit counterpart of ``tree_shardings`` +
    ``device_put``).  ``axes_tree`` is a congruent pytree of logical-axis
    tuples (``tree_axes``).  No-op without a mesh, so an unsharded trace
    is untouched.  The serving engine constrains its jit outputs (the
    pooled cache) with this so every step's output sharding equals its
    input sharding — decode bursts, forks and eviction scatters chain
    with zero per-step resharding."""
    mesh = mesh or _CTX["mesh"]
    if mesh is None:
        return values
    rules = rules or _CTX["rules"] or ShardingRules()

    def _one(v, a):
        spec = spec_for_shape(v.shape, a, mesh, rules)
        return jax.lax.with_sharding_constraint(v, NamedSharding(mesh, spec))

    return jax.tree.map(_one, values, axes_tree)


def rejoin(values, axes):
    """Zip a value pytree with an axes pytree back into Params."""
    return jax.tree.map(lambda v, a: Param(v, a), values, axes,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def validate_divisibility(params, mesh, rules=None, warn=print):
    """Report param dims not divisible by their mesh-axis size (GSPMD pads
    these; they surface as wasted FLOPs in the roofline table)."""
    rules = rules or ShardingRules()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    bad = []

    def _check(path, p):
        if not is_param(p):
            return
        shape = getattr(p.value, "shape", None)
        if shape is None:
            return
        for dim, name in zip(shape, p.axes):
            tgt = rules.resolve(name, set(mesh.axis_names))
            if tgt is None:
                continue
            n = (np.prod([sizes[t] for t in tgt])
                 if isinstance(tgt, tuple) else sizes[tgt])
            if dim % n:
                bad.append((jax.tree_util.keystr(path), dim, name, int(n)))

    jax.tree_util.tree_map_with_path(_check, params, is_leaf=is_param)
    for b in bad:
        warn(f"[sharding] non-divisible: {b[0]} dim={b[1]} "
             f"logical={b[2]} shards={b[3]}")
    return bad
