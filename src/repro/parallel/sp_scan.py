"""Sequence-parallel selective scan (SP for SSM long-context training).

The selective-SSM recurrence is an affine monoid, so a sequence sharded
over an ``sp`` mesh axis can be scanned in two local passes plus one tiny
cross-device exchange of *segment summaries*:

  pass 1 (local):  scan the local chunk from h0=0 -> y_local, and the
                   summary (A_seg, b_seg) where A_seg = exp(A * sum_t dt_t)
                   (the product of the per-step decays collapses to one exp)
                   and b_seg = local h_last.
  exchange:        exclusive prefix-combine of summaries across devices
                   (all_gather of (d, n)-sized summaries — bytes ~ d*n*S,
                   independent of L).
  pass 2 (local):  h0 = prefix; y_t += C_t . (Acum_t @ h0) correction,
                   where Acum_t = exp(A * cumsum(dt)_t) (recomputed locally,
                   never materialized across devices).

Validated against the sequential reference in the 8-device subprocess
suite (tests/test_distributed.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import selective_scan as css
from repro.parallel import sharding


def _local(x, dt, A, B, C, D, z, axis_name: str):
    """Runs inside shard_map; x/dt (b, l_loc, d); B/C (b, l_loc, n)."""
    S = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)

    # pass 1: local scan from zero + segment summary (h0 pcast to varying
    # so the inner lax.scan carry type matches under shard_map's vma rules)
    h0_zero = sharding.pcast_varying(
        jnp.zeros((x.shape[0], x.shape[2], A.shape[1]), jnp.float32),
        axis_name)
    y_local, b_seg = css.selective_scan_chunked(x, dt, A, B, C, D=None,
                                                z=None, h0=h0_zero)
    dt_sum = jnp.sum(dt.astype(jnp.float32), axis=1)          # (b, d)
    A_seg = jnp.exp(dt_sum[..., None] * A[None])              # (b, d, n)

    # exchange: gather all summaries, exclusive prefix-combine locally
    A_all = jax.lax.all_gather(A_seg, axis_name)              # (S, b, d, n)
    b_all = jax.lax.all_gather(b_seg, axis_name)
    h0 = jnp.zeros_like(b_seg)
    Acum = jnp.ones_like(A_seg)

    def combine(carry, i):
        h0, Acum = carry
        take = i < idx
        h0 = jnp.where(take, A_all[i] * h0 + b_all[i], h0)
        Acum = jnp.where(take, A_all[i] * Acum, Acum)
        return (h0, Acum), None

    (h0, _), _ = jax.lax.scan(combine, (h0, Acum), jnp.arange(S))

    # pass 2: correction y_t += C_t . (Acum_t * h0); Acum_t = exp(A*cumdt)
    cum_dt = jnp.cumsum(dt.astype(jnp.float32), axis=1)       # (b, l, d)
    Acum_t = jnp.exp(cum_dt[..., None] * A[None, None])       # (b,l,d,n)
    corr = jnp.einsum("bldn,bdn,bln->bld", Acum_t, h0,
                      C.astype(jnp.float32))
    y = y_local.astype(jnp.float32) + corr
    # replicated h_last = the last shard's (psum of a one-hot selection)
    h_mine = A_seg * h0 + b_seg                               # (b, d, n)
    h_last = jax.lax.psum(
        jnp.where(idx == S - 1, h_mine, jnp.zeros_like(h_mine)), axis_name)
    if D is not None:
        y = y + D[None, None, :] * x.astype(jnp.float32)
    if z is not None:
        y = y * jax.nn.silu(z.astype(jnp.float32))
    return y.astype(x.dtype), h_last


def sp_selective_scan(mesh: Mesh, x, dt, A, B, C, D=None, z=None,
                      axis_name: str = "sp"):
    """x/dt (b, L, d) with L sharded over ``axis_name``; semantics equal to
    kernels.ref.selective_scan (h_last from the final shard)."""
    seq = P(None, axis_name, None)
    has_d, has_z = D is not None, z is not None

    def wrapped(x, dt, A, B, C, D, z):
        return _local(x, dt, A, B, C, D if has_d else None,
                      z if has_z else None, axis_name)

    fn = shard_map(
        wrapped, mesh=mesh,
        in_specs=(seq, seq, P(), seq, seq, P(), seq),
        out_specs=(seq, P()),
    )
    D_in = D if has_d else jnp.zeros((x.shape[2],), jnp.float32)
    z_in = z if has_z else jnp.zeros_like(x)
    return fn(x, dt, A, B, C, D_in, z_in)
