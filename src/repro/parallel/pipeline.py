"""Pipeline parallelism: GPipe-style microbatch pipelining under shard_map.

Each device along the ``pipe`` mesh axis owns one stage's params; activations
rotate stage-to-stage with ``ppermute``.  Because ppermute is differentiable,
``jax.grad`` through the pipelined forward yields the reverse-schedule
backward automatically (1F1B-equivalent wall-clock under XLA latency hiding).

This is a selectable feature with its own mesh axis — the 40-cell production
dry-run uses FSDPxTP only (DESIGN.md §4); tests exercise PP on a small
8-device host mesh.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.parallel import sharding


def _pipeline_local(stage_fn, params_local, mb_local, *, axis_name: str,
                    n_micro: int):
    """Runs inside shard_map.  params_local: this stage's params (leading
    stage dim of size 1).  mb_local: (n_micro, mb, ...) replicated inputs
    (only stage 0 ingests).  Returns (n_micro, mb, ...) outputs (only the
    last stage's are real; others zero)."""
    S = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    params_local = jax.tree.map(lambda x: x[0], params_local)

    x0 = jnp.zeros_like(mb_local[0])
    outputs0 = jnp.zeros((n_micro,) + mb_local.shape[1:],
                         mb_local.dtype)
    # the carry becomes device-varying after the first ppermute; mark the
    # initial zeros as varying over the pipe axis for the vma type system
    x0 = sharding.pcast_varying(x0, axis_name)
    outputs0 = sharding.pcast_varying(outputs0, axis_name)
    total = n_micro + S - 1

    def step(carry, t):
        state, outputs = carry
        inject = mb_local[jnp.clip(t, 0, n_micro - 1)]
        x = jnp.where(idx == 0, inject, state)
        y = stage_fn(params_local, x)
        # the last stage emits microbatch t-(S-1) once it exists
        out_t = jnp.maximum(t - (S - 1), 0)
        is_emit = jnp.logical_and(idx == S - 1, t - (S - 1) >= 0)
        cur = jax.lax.dynamic_slice_in_dim(outputs, out_t, 1, axis=0)[0]
        new = jnp.where(is_emit, y.astype(outputs.dtype), cur)
        outputs = jax.lax.dynamic_update_slice_in_dim(
            outputs, new[None], out_t, axis=0)
        state = jax.lax.ppermute(
            y, axis_name, [(i, (i + 1) % S) for i in range(S)])
        return (state, outputs), None

    (_, outputs), _ = jax.lax.scan(step, (x0, outputs0),
                                   jnp.arange(total))
    # broadcast the last stage's outputs to every stage
    outputs = jax.lax.psum(
        jnp.where(idx == S - 1, outputs, 0), axis_name)
    return outputs


def pipeline_apply(mesh: Mesh, stage_fn, stacked_params, inputs, *,
                   n_micro: int, axis_name: str = "pipe"):
    """Run ``stage_fn`` as an S-stage pipeline over the mesh's pipe axis.

    stacked_params: pytree with leading stage dim S (sharded over pipe).
    inputs: (batch, ...) — split into n_micro microbatches.
    Returns outputs (batch, ...) after all S stages.
    """
    S = mesh.shape[axis_name]
    b = inputs.shape[0]
    assert b % n_micro == 0
    mb = inputs.reshape(n_micro, b // n_micro, *inputs.shape[1:])

    p_spec = jax.tree.map(lambda _: P(axis_name), stacked_params)
    fn = shard_map(
        functools.partial(_pipeline_local, stage_fn, axis_name=axis_name,
                          n_micro=n_micro),
        mesh=mesh,
        in_specs=(p_spec, P()),
        out_specs=P(),
    )
    out = fn(stacked_params, mb)
    return out.reshape(b, *out.shape[2:])
