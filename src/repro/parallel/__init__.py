"""Distribution layer: logical-axis sharding rules, pipeline parallelism,
sequence-parallel scan, and compressed collectives."""
