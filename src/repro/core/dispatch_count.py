"""Static Pallas-launch accounting by jaxpr walk.

The megakernel decode path exists to cut per-token kernel dispatches
from L (one fused launch per layer) to 1 (the whole stack in one grid).
That claim is cheap to PIN statically: trace the step function once,
walk the jaxpr, and count ``pallas_call`` equations weighted by the trip
counts of the scans enclosing them.  No profiler, no runtime hooks — the
count is a property of the traced program, identical on CPU interpret
mode and real TPU lowering.

Counting rules:

  pallas_call          -> + multiplier
  scan                 -> walk body with multiplier * length
  while                -> walk cond+body with multiplier * 1 (a lower
                          bound; the serving code has no pallas_call
                          under data-dependent while loops)
  cond                 -> + multiplier * max over branches (an upper
                          bound: one branch runs per step)
  anything else        -> walk any jaxpr found in its params (pjit,
                          remat, custom_jvp/vjp, vmap-of-closed-call...)

Used by tests/test_megakernel.py and benchmarks/serve_throughput.py to
assert "1 launch per decoded token" for the megakernel path vs L for the
per-layer fused path.
"""
from __future__ import annotations

import jax


def _subjaxprs(params):
    """Yield every (closed) jaxpr buried in an eqn's params."""
    for v in params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for u in vs:
            if hasattr(u, "jaxpr"):          # ClosedJaxpr
                yield u.jaxpr
            elif hasattr(u, "eqns"):         # raw Jaxpr
                yield u


def _walk(jaxpr, mult: int) -> int:
    total = 0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "pallas_call":
            total += mult
        elif name == "scan":
            body = eqn.params["jaxpr"].jaxpr
            total += _walk(body, mult * int(eqn.params["length"]))
        elif name == "cond":
            total += mult * max(
                (_walk(b.jaxpr, 1) for b in eqn.params["branches"]),
                default=0)
        else:
            for sub in _subjaxprs(eqn.params):
                total += _walk(sub, mult)
    return total


def count_pallas_launches(fn, *args, **kwargs) -> int:
    """Number of Pallas kernel dispatches one call of ``fn(*args)``
    issues (statically, from the traced jaxpr — scans multiply, cond
    takes the max branch).  Args may be concrete arrays or
    ShapeDtypeStructs (tracing never executes the function)."""
    closed = jax.make_jaxpr(
        lambda *a, **k: fn(*a, **k))(*args, **kwargs)
    return _walk(closed.jaxpr, 1)
