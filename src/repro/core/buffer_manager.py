"""Intra-/inter-operation buffer management simulator (MARCA §6, Fig. 10).

Counts HBM traffic for the op stream from ``op_graph`` under the paper's two
policies.  The dataflow is tiled along the sequence dim (the RCUs stream
L-tiles), so "inter-op" residency is an *edge* property: a tensor produced
by an element-wise-class op is consumed tile-by-tile out of the on-chip
buffer and never round-trips HBM (dA, dBx, h in Fig. 8); capacity is
checked on the per-tile working set, not the full tensor.

  intra=True   linear ops are input-tiled: each operand read from HBM once.
  intra=False  the stationary operand (weights) is re-fetched once per
               output row-tile, bounded by a cache-absorption cap (the
               baseline platforms still have caches): refetch =
               min(ceil(rows/TILE), REFETCH_CAP).
  inter=True   EW-produced tensors stay on chip (fused chain).
  inter=False  every intermediate round-trips HBM (unfused baseline).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from repro.core.op_graph import Op, BYTES

BUFFER_BYTES = 24 * 1024 * 1024      # MARCA on-chip buffer (Table 2)
TILE = 16                            # RCU tile edge (16x16 PEs)
REFETCH_CAP = 4                      # baseline cache absorption bound

EW_CLASSES = {"ew1", "ew2", "exp", "silu", "softplus", "norm", "update"}


@dataclasses.dataclass
class Traffic:
    read: float = 0.0
    write: float = 0.0

    @property
    def total(self) -> float:
        return self.read + self.write


def per_op_traffic(ops: Iterable[Op], intra: bool, inter: bool,
                   buffer_bytes: int = BUFFER_BYTES):
    """Yields (op, read_bytes, write_bytes) under the policy."""
    producer_cls: dict[str, str] = {}
    out = []
    for op in ops:
        read = write = 0.0
        is_linear = op.cls == "linear"
        n_out = sum(e for _, e in op.outputs)
        for i, (name, elems) in enumerate(op.inputs):
            nbytes = elems * BYTES
            # per-L-tile slice of an EW-produced tensor stays on chip
            if inter and producer_cls.get(name) in EW_CLASSES \
                    and nbytes / max(op.steps, TILE) * TILE < buffer_bytes:
                continue
            if is_linear and not intra and i > 0 and op.inputs:
                # stationary operand (weights) re-fetched per output
                # row-tile: rows = sqrt(elems_act * n_out / elems_w)
                e0 = op.inputs[0][1]
                rows = math.sqrt(max(e0 * n_out / max(elems, 1), 1.0))
                refetch = min(max(1.0, rows / TILE), REFETCH_CAP)
                read += nbytes * refetch
            else:
                read += nbytes
        for name, elems in op.outputs:
            producer_cls[name] = op.cls
            nbytes = elems * BYTES
            if inter and op.cls in EW_CLASSES \
                    and nbytes / max(op.steps, TILE) * TILE < buffer_bytes:
                continue                 # consumed downstream from buffer
            write += nbytes
        if op.cls == "update" and not inter and op.inputs:
            # unfused sequential recurrence: h round-trips HBM every step
            h_bytes = op.inputs[-1][1] * BYTES
            read += op.steps * h_bytes
            write += op.steps * h_bytes
        out.append((op, read, write))
    return out


def simulate(ops: Iterable[Op], intra: bool = True, inter: bool = True,
             buffer_bytes: int = BUFFER_BYTES) -> Traffic:
    tr = Traffic()
    for _, r, w in per_op_traffic(list(ops), intra, inter, buffer_bytes):
        tr.read += r
        tr.write += w
    return tr


def policy_table(ops) -> dict:
    ops = list(ops)
    return {
        "none": simulate(ops, intra=False, inter=False),
        "intra": simulate(ops, intra=True, inter=False),
        "inter": simulate(ops, intra=False, inter=True),
        "both": simulate(ops, intra=True, inter=True),
    }
