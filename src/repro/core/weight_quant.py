"""Quantized weight storage (cfg.weight_dtype): W8A8 decode.

Single-token decode is memory-bound and PR 3's state quantization only
cut the *state* stream — the weights still ride HBM at f32 every token,
which is the dominant bandwidth term MARCA's buffer-management analysis
targets.  FastMamba (W8A8 FPGA Mamba) and eMamba both show per-channel
int8 weights hold Mamba accuracy, so the dense projection matrices (and
mamba's A) are stored int8 with f32 absmax scales; the matmul inputs
dequantize where they are consumed — inside the decode kernels for the
fused and megakernel paths.

The quantization is deliberately DECODE-side: prefill is compute-bound
and touches the weights once per request, so the serving engine keeps
the caller's f32 tree aliased for prefill (``Engine.prefill_params``)
and streams the int8 tree only on the per-token decode/verify path
where the bandwidth win lives.  That also means a request's first
token (sampled from prefill logits) is exact, and quantization error
only enters through per-decode-step rounding.

Scale layout
------------
Same leaf-travels-with-scale invariant as ``core.state_quant``: a
quantized payload's f32 scale lives as a SIBLING pytree leaf ("w" gets
"w_scale" next to it; mamba's "A_log" becomes "A_q" + "A_scale"), so
every tree operation the serving stack performs — stacked-layer vmap
init, megakernel restacking, draft-view slicing (``p["layers"][:n]``),
mesh device_put — moves payload and scale together with no special
cases.

Granularity is per OUTPUT channel for dense ``w`` (absmax over the
input dim, one scale per column: each output feature keeps its own
dynamic range, the standard W8A8 recipe) and per row for mamba's
``A = -exp(A_log)`` (one scale per d_inner channel over its d_state
entries — matching the decode kernels' channel blocking so in-kernel
dequant is grid-local).  Weights are static, so scales are one-shot
absmax — no running update, no EMA.

Sharding: a scale leaf's logical axes are derived from its payload's
(``axes[:-2] + (axes[-1],)`` for dense, ``axes[:-1]`` for A), so under
a TP mesh the scales shard on the same "model" axes as the output
channels they describe and every matmul stays shard-local.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.parallel import sharding

#: storage dtypes accepted by cfg.weight_dtype
WEIGHT_DTYPES = ("f32", "int8")

#: largest int8 code magnitude the absmax is mapped to (symmetric)
QMAX = 127.0

#: absmax floor — an all-zero column still gets a positive scale so
#: quantization never divides by zero
EPS_AMAX = 1e-30

#: param subtrees the quantization walk must NOT descend into:
#: embed/unembed are consumed as raw matrices (tied-embedding transpose,
#: direct ``p["w"]`` access in unembed_apply), and MoE expert weights /
#: the router feed shard_map einsums that index the dict directly.
SKIP_KEYS = frozenset({"embed", "unembed", "moe", "router"})


def is_quantized(weight_dtype: str) -> bool:
    """True for the scale-carrying dtypes; f32 is the baseline."""
    if weight_dtype not in WEIGHT_DTYPES:
        raise KeyError(
            f"unknown weight_dtype {weight_dtype!r}; one of {WEIGHT_DTYPES}")
    return weight_dtype == "int8"


def storage_dtype(weight_dtype: str):
    """jnp dtype the weight payload is stored as."""
    if weight_dtype not in WEIGHT_DTYPES:
        raise KeyError(
            f"unknown weight_dtype {weight_dtype!r}; one of {WEIGHT_DTYPES}")
    return {"f32": jnp.float32, "int8": jnp.int8}[weight_dtype]


# ---------------------------------------------------------------------------
# Dense matrices: (..., d_in, d_out) payload, (..., d_out) scales
# ---------------------------------------------------------------------------

def quantize_w(w):
    """Per-output-channel symmetric absmax: (..., d_in, d_out) ->
    (int8 codes, f32 scale (..., d_out)).  Works unchanged on stacked
    leaves ((L, d_in, d_out) -> (L, d_out) scales)."""
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=-2)
    scale = jnp.maximum(amax, EPS_AMAX) / QMAX
    codes = jnp.clip(jnp.round(wf / scale[..., None, :]),
                     -QMAX, QMAX).astype(jnp.int8)
    return codes, scale


def dequantize_w(q, scale):
    """Inverse of quantize_w (up to rounding): (..., d_in, d_out) f32."""
    return q.astype(jnp.float32) * scale[..., None, :]


# ---------------------------------------------------------------------------
# Row-scaled matrices (mamba A): (..., r, c) payload, (..., r) scales
# ---------------------------------------------------------------------------

def quantize_rows(x):
    """Per-row symmetric absmax over the LAST axis: (..., r, c) ->
    (int8 codes, f32 scale (..., r)).  For mamba's A (d_inner, d_state)
    each d_inner channel keeps its own range — the orientation the
    decode kernels' channel blocking dequantizes grid-locally."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.maximum(amax, EPS_AMAX) / QMAX
    codes = jnp.clip(jnp.round(xf / scale[..., None]),
                     -QMAX, QMAX).astype(jnp.int8)
    return codes, scale


def dequantize_rows(q, scale):
    """Inverse of quantize_rows (up to rounding): f32.  This is THE
    scale multiply — the fused kernel's dequant phase, the megakernel
    body, and the XLA reference all compute exactly ``code_f32 * scale``
    per element, so the three step impls see bit-identical A values."""
    return q.astype(jnp.float32) * scale[..., None]


# ---------------------------------------------------------------------------
# Param-tree transform
# ---------------------------------------------------------------------------

def _is_param(x):
    return isinstance(x, sharding.Param)


def _val(x):
    return x.value if _is_param(x) else x


def _dense_like(node):
    """A blocks.dense param dict: {"w": (..., d_in, d_out)} (+ "b")."""
    if not isinstance(node, dict) or "w" not in node:
        return False
    if not set(node) <= {"w", "b"}:
        return False
    return getattr(_val(node["w"]), "ndim", 0) >= 2


def _quantize_dense(node):
    w = node["w"]
    q, s = quantize_w(_val(w))
    if _is_param(w):
        out = {"w": sharding.Param(q, w.axes),
               "w_scale": sharding.Param(s, w.axes[:-2] + (w.axes[-1],))}
    else:
        out = {"w": q, "w_scale": s}
    if "b" in node:
        out["b"] = node["b"]
    return out


def _quantize_a(a_log):
    """mamba A_log -> (A_q, A_scale): codes of A = -exp(A_log)."""
    q, s = quantize_rows(-jnp.exp(_val(a_log).astype(jnp.float32)))
    if _is_param(a_log):
        return (sharding.Param(q, a_log.axes),
                sharding.Param(s, a_log.axes[:-1]))
    return q, s


def quantize_tree(params):
    """Quantize every dense projection (and mamba A) in a param tree.

    Works on Param trees (init path: scale leaves get derived logical
    axes) and plain-value trees (serving path: Engine quantizing the
    caller's weights) alike, and under ``jax.eval_shape`` (abstract
    params keep structural parity with real ones).  Subtrees under
    ``SKIP_KEYS`` and non-dense leaves (norms, biases, convs, einsum
    weights) pass through untouched at f32.  Raises on an
    already-quantized tree — double-quantization silently destroys the
    weights."""
    def rec(node):
        if isinstance(node, dict):
            if "w_scale" in node or "A_q" in node:
                raise ValueError(
                    "param tree is already weight-quantized "
                    "(found w_scale/A_q leaves)")
            if _dense_like(node):
                return _quantize_dense(node)
            out = {}
            for k, v in node.items():
                if k in SKIP_KEYS:
                    out[k] = v
                elif k == "A_log":
                    out["A_q"], out["A_scale"] = _quantize_a(v)
                else:
                    out[k] = rec(v)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(rec(v) for v in node)
        return node

    return rec(params)
