"""Selective-SSM scan algorithms (the compute core MARCA accelerates).

Three implementations with identical semantics (tests assert equivalence):

  * ``selective_scan_seq``     — lax.scan over time; the semantic reference.
  * ``selective_scan_assoc``   — jax.lax.associative_scan over the (a, b)
    affine monoid; O(log L) depth but materializes (B, L, D, N) — the
    "unfused XLA" baseline whose HBM traffic MARCA's fusion removes.
  * ``selective_scan_chunked`` — lax.scan over chunks of length `chunk`,
    associative scan inside a chunk, state carried across chunks.  This is
    the framework-level realization of MARCA's *inter-operation buffer
    management*: the recurrent state (and the chunk's dA/dBx intermediates)
    stay in registers/VMEM instead of round-tripping HBM per operation.
    With ``remat=True`` the inner chunk is wrapped in jax.checkpoint so
    training saves only chunk-boundary states (the paper's "cache h in the
    buffer" applied to the backward pass).

The Pallas kernel (repro.kernels.selective_scan) implements the fully fused
single-pass version for TPU and is validated against ``selective_scan_seq``.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import approx
from repro.kernels import ref as kref

selective_scan_seq = kref.selective_scan
selective_state_step = kref.selective_state_step


def _affine_combine(left, right):
    """Monoid for h_t = a_t h_{t-1} + b_t (left = older, right = newer)."""
    a1, b1 = left
    a2, b2 = right
    return a2 * a1, a2 * b1 + b2


def _scan_inner(xf, dtf, Bf, Cf, Af, h_in, exp):
    """Associative scan over one chunk.  xf/dtf (b,ck,d); Bf/Cf (b,ck,n)."""
    dA = exp(dtf[..., None] * Af)                       # (b,ck,d,n)
    dBx = (dtf * xf)[..., None] * Bf[:, :, None, :]     # (b,ck,d,n)
    Acum, Bcum = jax.lax.associative_scan(
        _affine_combine, (dA, dBx), axis=1)
    h_all = Acum * h_in[:, None] + Bcum                 # (b,ck,d,n)
    y = jnp.einsum("bldn,bln->bld", h_all, Cf)
    return y, h_all[:, -1]


def _scan_inner_seq(xf, dtf, Bf, Cf, Af, h_in, exp):
    """Sequential scan over one chunk: per-step (b,d,n) intermediates fuse
    into the loop body — no (b,ck,d,n) materialization.  With the chunk
    wrapped in jax.checkpoint this is the MARCA dataflow at XLA level:
    state resident, inputs streamed (in their storage dtype — cast to f32
    per step so the streamed tensors stay bf16), residuals only at chunk
    boundaries."""
    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        dA = exp(dt_t[..., None] * Af)
        h = dA * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        return h, jnp.einsum("bdn,bn->bd", h, C_t)

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xf, dtf, Bf, Cf))
    h_last, ys = jax.lax.scan(step, h_in, xs)
    return jnp.moveaxis(ys, 0, 1), h_last


def selective_scan_assoc(x, dt, A, B, C, D=None, z=None, h0=None,
                         exp_impl: str = "exact", silu_impl: str = "exact"):
    """Single associative scan over the full length (XLA baseline)."""
    return selective_scan_chunked(x, dt, A, B, C, D=D, z=z, h0=h0,
                                  chunk=x.shape[1], remat=False,
                                  exp_impl=exp_impl, silu_impl=silu_impl)


def selective_scan_chunked(x, dt, A, B, C, D=None, z=None, h0=None,
                           chunk: int = 64, remat: bool = True,
                           exp_impl: str = "exact",
                           silu_impl: str = "exact",
                           inner: str = "assoc"):
    """Chunked scan: state carried across chunks (inter-op buffer mgmt).

    Same signature/semantics as kernels.ref.selective_scan.
    """
    exp = approx.get_exp(exp_impl)
    silu = approx.get_silu(silu_impl)
    bsz, L, d = x.shape
    n = A.shape[1]
    chunk = min(chunk, L)
    pad = (-L) % chunk
    nc = (L + pad) // chunk

    def _pad(t):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))

    xf = _pad(x.astype(jnp.float32))
    dtf = _pad(dt.astype(jnp.float32))
    Bf = _pad(B.astype(jnp.float32))
    Cf = _pad(C.astype(jnp.float32))
    Af = A.astype(jnp.float32)
    h_init = (jnp.zeros((bsz, d, n), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def _resh(t):
        return t.reshape(bsz, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    inner_fn = _scan_inner if inner == "assoc" else _scan_inner_seq
    if remat:
        inner_fn = jax.checkpoint(inner_fn, static_argnums=(6,))

    def chunk_step(h, inp):
        xc, dtc, Bc, Cc = inp
        y, h_new = inner_fn(xc, dtc, Bc, Cc, Af, h, exp)
        return h_new, y

    h_last, ys = jax.lax.scan(
        chunk_step, h_init, (_resh(xf), _resh(dtf), _resh(Bf), _resh(Cf)))
    y = ys.swapaxes(0, 1).reshape(bsz, nc * chunk, d)[:, :L]
    if D is not None:
        y = y + D.astype(jnp.float32)[None, None, :] * x.astype(jnp.float32)
    if z is not None:
        y = y * silu(z.astype(jnp.float32))
    return y.astype(x.dtype), h_last


IMPLS = {
    "seq": selective_scan_seq,
    "assoc": selective_scan_assoc,
    "chunked": selective_scan_chunked,
}


def get_scan(name: str):
    if name in IMPLS:
        return IMPLS[name]
    if name == "pallas":    # resolved lazily to avoid import cycle
        from repro.kernels import selective_scan as ssk
        return ssk.selective_scan
    raise KeyError(f"unknown scan impl {name!r}")


# ---------------------------------------------------------------------------
# Single-token decode step (the serving engine's per-layer hot path)
# ---------------------------------------------------------------------------

def resolve_step_impl(name: str, needs_pallas: bool = True) -> str:
    """Resolve cfg.step_impl to a concrete impl.

    "auto" picks the cross-layer megakernel where Pallas compiles
    natively (TPU) and otherwise the per-layer fused kernel / XLA
    reference split that served before: fused where it is pure XLA
    (``needs_pallas=False``, e.g. xLSTM's chained paths), the XLA
    reference elsewhere.  The ``REPRO_STEP_IMPL`` env var overrides
    "auto" only — explicit config always wins — so CI can sweep the
    whole suite over an impl without touching configs.  Callers can
    force any impl with "megakernel" / "fused" / "xla" (parity tests
    and TPU-less benchmarking do)."""
    if name == "auto":
        name = os.environ.get("REPRO_STEP_IMPL", "auto")
    if name == "auto":
        if jax.default_backend() == "tpu":
            return "megakernel"
        if not needs_pallas:
            return "fused"
        return "xla"
    if name == "megakernel":
        return "megakernel"
    if name in ("fused", "pallas"):
        return "fused"
    if name == "xla":
        return "xla"
    raise KeyError(f"unknown step impl {name!r}")


def resolve_cell_impl(name: str, needs_pallas: bool = True) -> str:
    """Resolve cfg.step_impl for a PER-LAYER call site.

    The megakernel is a whole-stack launch; block-level entry points
    (single-layer steps, verify windows, drafts running a layer slice
    chained) can't use it directly — under a megakernel config they run
    the per-layer fused cell, which computes bit-identical values (the
    megakernel body is the same cell skeleton at the same shapes)."""
    r = resolve_step_impl(name, needs_pallas)
    return "fused" if r == "megakernel" else r


def decode_step(h, x_t, dt_t, A, B_t, C_t, D=None, z_t=None,
                impl: str = "xla",
                exp_impl: str = "exact", silu_impl: str = "exact",
                a_scale=None):
    """One fused-or-reference SSM decode step over the (pooled) batch.

    h (b, d, n) f32; x_t/dt_t (b, d); A (d, n); B_t/C_t (b, n).
    Returns (y (b, d), h_new (b, d, n) f32).  ``impl="fused"`` runs the
    single-launch Pallas kernel (interpret-mode on CPU); "xla" the
    pure-jnp reference with identical semantics.

    ``a_scale`` (d,) marks A as int8 weight codes (cfg.weight_dtype):
    the fused kernel dequantizes in its dequant phase; the XLA path runs
    the identical ``weight_quant.dequantize_rows`` multiply up front, so
    both impls consume bit-identical A values."""
    if a_scale is not None and impl == "xla":
        from repro.core import weight_quant
        A = weight_quant.dequantize_rows(A, a_scale)
        a_scale = None
    if impl in ("fused", "pallas"):
        from repro.kernels import decode_step as dsk   # lazy: import cycle
        return dsk.selective_state_step(
            h, x_t, dt_t, A, B_t, C_t, D=D, z_t=z_t,
            exp_impl=exp_impl, silu_impl=silu_impl, a_scale=a_scale)
    if impl != "xla":
        # "auto" must go through resolve_step_impl first; a typo or raw
        # cfg string silently falling back to the unfused path would eat
        # the fused kernel's win with no error anywhere
        raise KeyError(f"unknown step impl {impl!r}")
    return kref.selective_state_step(
        h, x_t, dt_t, A, B_t, C_t, D=D, z_t=z_t,
        exp_impl=exp_impl, silu_impl=silu_impl)


def decode_step_q(hq, h_scale, x_t, dt_t, A, B_t, C_t, D=None, z_t=None,
                  state_dtype: str = "int8", impl: str = "xla",
                  exp_impl: str = "exact", silu_impl: str = "exact",
                  a_scale=None):
    """Quantized-state decode step (cfg.state_dtype in {int8, fp8}).

    hq (b, d, n) storage payload, h_scale (b, g) f32 group scales (see
    core.state_quant); returns (y (b, d), hq_new, scale_new).  "fused"
    dequantizes/requantizes inside the single Pallas launch; "xla" is
    the dequant -> ref step -> requant oracle with identical scale math
    (the two match to within one quantization code — XLA may contract
    da*h + dbx into an FMA, which can flip a value sitting exactly on a
    rounding boundary)."""
    if a_scale is not None and impl == "xla":
        from repro.core import weight_quant
        A = weight_quant.dequantize_rows(A, a_scale)
        a_scale = None
    if impl in ("fused", "pallas"):
        from repro.kernels import decode_step as dsk   # lazy: import cycle
        return dsk.selective_state_step_q(
            hq, h_scale, x_t, dt_t, A, B_t, C_t, D=D, z_t=z_t,
            state_dtype=state_dtype, exp_impl=exp_impl,
            silu_impl=silu_impl, a_scale=a_scale)
    if impl != "xla":
        raise KeyError(f"unknown step impl {impl!r}")
    return kref.selective_state_step_q(
        hq, h_scale, x_t, dt_t, A, B_t, C_t, D=D, z_t=z_t,
        state_dtype=state_dtype, exp_impl=exp_impl, silu_impl=silu_impl)


# ---------------------------------------------------------------------------
# K-step verify micro-scan (speculative decode)
#
# Verifying K drafted tokens means running the target's per-token step K
# times from a known state and keeping EVERY intermediate state: the
# accepted prefix length is only known after the pass, and rollback
# needs the state after exactly that many steps.  Each micro-scan step
# is the SAME decode_step dispatch the serving burst uses (one fused
# Pallas launch per step under impl="fused"), so verify-pass numerics
# are the per-token decode numerics — the property the token-identical
# spec-decode gate rests on.
# ---------------------------------------------------------------------------

def decode_scan(h, x_seq, dt_seq, A, B_seq, C_seq, D=None, z_seq=None,
                impl: str = "xla",
                exp_impl: str = "exact", silu_impl: str = "exact",
                a_scale=None):
    """Chain ``decode_step`` over a K-token window.

    h (b, d, n) f32 start state; x_seq/dt_seq (b, K, d); B_seq/C_seq
    (b, K, n); z_seq (b, K, d)|None.  Returns (y_seq (b, K, d),
    h_all (b, K, d, n)) — h_all[:, t] is the state after consuming
    token t (rollback picks an index into it)."""
    has_z = z_seq is not None

    def step(h_c, inp):
        x_t, dt_t, B_t, C_t = inp[:4]
        z_t = inp[4] if has_z else None
        y, h_new = decode_step(h_c, x_t, dt_t, A, B_t, C_t, D=D, z_t=z_t,
                               impl=impl, exp_impl=exp_impl,
                               silu_impl=silu_impl, a_scale=a_scale)
        return h_new, (y, h_new)

    seqs = (x_seq, dt_seq, B_seq, C_seq) + ((z_seq,) if has_z else ())
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in seqs)
    _, (ys, hs) = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1), jnp.moveaxis(hs, 0, 1)


def decode_scan_q(hq, h_scale, x_seq, dt_seq, A, B_seq, C_seq, D=None,
                  z_seq=None, state_dtype: str = "int8", impl: str = "xla",
                  exp_impl: str = "exact", silu_impl: str = "exact",
                  a_scale=None):
    """Quantized-state K-step micro-scan: chains ``decode_step_q`` so the
    storage round-trip (dequant on read, decayed-absmax requant on
    write) happens per step exactly as in serving — the per-step
    payloads AND scales come back stacked, because rolling back to step
    t must restore both together.

    Returns (y_seq (b, K, d), hq_all (b, K, d, n), scale_all (b, K, g)).
    """
    has_z = z_seq is not None

    def step(carry, inp):
        hq_c, s_c = carry
        x_t, dt_t, B_t, C_t = inp[:4]
        z_t = inp[4] if has_z else None
        y, hq_new, s_new = decode_step_q(
            hq_c, s_c, x_t, dt_t, A, B_t, C_t, D=D, z_t=z_t,
            state_dtype=state_dtype, impl=impl, exp_impl=exp_impl,
            silu_impl=silu_impl, a_scale=a_scale)
        return (hq_new, s_new), (y, hq_new, s_new)

    seqs = (x_seq, dt_seq, B_seq, C_seq) + ((z_seq,) if has_z else ())
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in seqs)
    _, (ys, hqs, ss) = jax.lax.scan(step, (hq, h_scale), xs)
    return (jnp.moveaxis(ys, 0, 1), jnp.moveaxis(hqs, 0, 1),
            jnp.moveaxis(ss, 0, 1))
