"""Quantized storage for pooled decode state (cfg.state_dtype).

MARCA's buffer-management insight — shrink the recurrent working set so
more of it lives close to the PEs — applied to the serving tier: the
slot pool holds one f32 ``(layers, d_inner, d_state)`` SSM state per
in-flight sequence, and slot count is bounded by device memory.
FastMamba/eMamba show these states tolerate low-precision storage with
per-tensor scales, so storing them int8 (or fp8) with f32 absmax scales
multiplies slot capacity ~4x while decode math stays f32: dequantize on
read, step in f32, requantize on write — the f32 state exists only
inside the step, never in HBM.

Scale layout
------------
Scales are symmetric-linear absmax (dequant is ``q * scale``), f32, kept
as ordinary cache-pytree leaves *next to* the quantized payload so every
slot operation (gather/scatter/mask, eviction's fresh-state reset) moves
payload and scale together — a freed slot can never leak a stale scale.

Granularity: per slot, per layer, per channel group of ``D_BLOCK``
channels (all ``d_state`` entries of a group share one scale).  For the
SSM ``h`` this matches the decode kernel's channel blocking, so the
fused step requantizes each grid cell locally with no cross-block
reduction; for xLSTM's matrix memory ``C`` the group is one head's
(dh, dh) block.

Scale dynamics
--------------
The per-step scale update is a decayed running absmax:

    amax_run' = max(amax(h_new), EMA_DECAY * amax_run)

Growth is tracked immediately (requantization never clips: the write
scale is >= the step's true absmax), shrinkage is tracked with a decay
so a transient near-zero state does not collapse the scale and destroy
resolution for the next step.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

#: storage dtypes accepted by cfg.state_dtype
STATE_DTYPES = ("f32", "bf16", "int8", "fp8")

#: channel-group size for SSM h scales; matches the fused decode
#: kernel's block_d so requantization is local to one grid cell
D_BLOCK = 512

#: decayed-running-absmax rate (see module docstring)
EMA_DECAY = 0.99

#: absmax floor — a slot whose state is exactly zero (fresh slot, first
#: step) still gets a positive, tiny scale so requant never divides by 0
EPS_AMAX = 1e-30


def is_quantized(state_dtype: str) -> bool:
    """True for the scale-carrying dtypes (int8/fp8); bf16 is a plain
    storage cast and f32 is the unquantized baseline."""
    if state_dtype not in STATE_DTYPES:
        raise KeyError(
            f"unknown state_dtype {state_dtype!r}; one of {STATE_DTYPES}")
    return state_dtype in ("int8", "fp8")


def storage_dtype(state_dtype: str):
    """jnp dtype the state payload is stored as."""
    if state_dtype not in STATE_DTYPES:
        raise KeyError(
            f"unknown state_dtype {state_dtype!r}; one of {STATE_DTYPES}")
    return {"f32": jnp.float32, "bf16": jnp.bfloat16, "int8": jnp.int8,
            "fp8": jnp.float8_e4m3fn}[state_dtype]


def qmax(state_dtype: str) -> float:
    """Largest representable code magnitude the absmax is mapped to."""
    return {"int8": 127.0, "fp8": 448.0}[state_dtype]


def n_groups(d: int) -> int:
    """Number of channel-scale groups for a d-channel state tensor."""
    return max(1, math.ceil(d / D_BLOCK))


def encode(x, state_dtype: str):
    """f32 values already divided by scale -> storage codes."""
    if state_dtype == "int8":
        return jnp.clip(jnp.round(x), -127.0, 127.0).astype(jnp.int8)
    return x.astype(jnp.float8_e4m3fn)


def update_scale(amax, prev_scale, state_dtype: str):
    """Decayed-running-absmax scale update (shared by the XLA path and
    the fused kernel so the two quantize identically up to float
    reassociation — payloads match to within one code).

    ``amax`` is this step's true absmax per group; ``prev_scale`` (or
    None) the scale the group was last stored with."""
    qm = qmax(state_dtype)
    if prev_scale is not None:
        amax = jnp.maximum(amax, EMA_DECAY * (prev_scale * qm))
    return jnp.maximum(amax, EPS_AMAX) / qm


# ---------------------------------------------------------------------------
# SSM h: (..., d, n) payload, (..., g) scales (g = n_groups(d))
# ---------------------------------------------------------------------------

def _group_h(x):
    """(..., d, n) -> (..., g, blk, n) with zero padding; blk = group."""
    *lead, d, n = x.shape
    g = n_groups(d)
    blk = min(D_BLOCK, d) if g == 1 else D_BLOCK
    pad = g * blk - d
    if pad:
        x = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad), (0, 0)])
    return x.reshape(*lead, g, blk, n), d


def quantize_h(h, state_dtype: str, prev_scale=None):
    """Quantize an SSM state (..., d, n) -> (payload, scale (..., g)).

    ``prev_scale`` feeds the decayed-running-absmax update; None means
    cold start (prefill of a fresh slot) and uses the step's absmax."""
    grouped, d = _group_h(h.astype(jnp.float32))
    amax = jnp.max(jnp.abs(grouped), axis=(-2, -1))         # (..., g)
    scale = update_scale(amax, prev_scale, state_dtype)
    codes = encode(grouped / scale[..., None, None], state_dtype)
    *lead, g, blk, n = codes.shape
    return codes.reshape(*lead, g * blk, n)[..., :d, :], scale


def dequantize_h(q, scale):
    """Inverse of quantize_h (up to rounding): (..., d, n) f32."""
    grouped, d = _group_h(q.astype(jnp.float32))
    out = grouped * scale[..., None, None]
    *lead, g, blk, n = out.shape
    return out.reshape(*lead, g * blk, n)[..., :d, :]


# ---------------------------------------------------------------------------
# Matrix memory (xLSTM C): (..., dh, dh) payload, (..., dh) scales — one
# scale per matrix row.  Rows of C are written by different keys
# (C' = f (*) C + i (*) k (x) v), so row magnitudes span decades and a
# single per-matrix scale floors the quiet rows to zero; per-row scales
# keep the relative error uniform at ~dh f32 words per dh*dh payload.
# ---------------------------------------------------------------------------

def quantize_mat(x, state_dtype: str, prev_scale=None):
    """Quantize (..., r, c) -> (payload, scale (..., r))."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = update_scale(amax, prev_scale, state_dtype)
    return encode(xf / scale[..., None], state_dtype), scale


def dequantize_mat(q, scale):
    return q.astype(jnp.float32) * scale[..., None]
