"""Mamba inference op graph: op class, FLOPs, reads/writes per op.

This is the workload description that drives the MARCA cycle model, the
CPU/GPU baselines, the buffer-management simulator (Fig. 10) and the
compute-intensity / read-write-ratio analysis (Figs. 1 & 7).

Op classes follow the paper (§2.2, §6.1):
  linear — matmul/conv with a reduction dim (MM-RCU; intra-op input sharing)
  ew1    — element-wise map over equal-shaped operands (EW-RCU; no sharing):
           reads ~2N, writes N
  ew2    — element-wise *outer product* (EW-RCU): reads 2N, writes N^2
  exp / silu / softplus — nonlinear element-wise (EXP-/SiLU-RCU)
  norm   — RMSNorm (normalization unit)
  update — the L-step recurrent h update (the inter-op-BM target)
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

BYTES = 4          # the paper computes in 32-bit fixed point


@dataclasses.dataclass
class Op:
    name: str
    cls: str                   # linear | ew1 | ew2 | exp | silu | softplus | norm | update
    flops: float
    read: float                # bytes from memory hierarchy (pre-policy)
    write: float
    #: tensors produced/consumed for the buffer-manager simulation
    inputs: tuple = ()
    outputs: tuple = ()
    #: recurrence length: >1 marks the sequential h-update (baseline
    #: platforms execute it as `steps` separate dispatches; MARCA streams it)
    steps: int = 1
    #: output rows of a linear op (GEMM M-dim; drives utilization ramp)
    rows: int = 0

    @property
    def intensity(self) -> float:
        return self.flops / max(self.read + self.write, 1)

    @property
    def rw_ratio(self) -> float:
        return self.read / max(self.write, 1)


def t(name, *dims):
    """Tensor descriptor: (name, n_elements)."""
    n = 1
    for d in dims:
        n *= d
    return (name, n)


def mamba_block_ops(cfg, L: int, layer: int = 0) -> list:
    """One Mamba block forward at sequence length L (batch 1)."""
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.d_state
    r = cfg.dt_rank
    k = cfg.d_conv
    p = f"L{layer}."
    ops = []

    def add(name, cls, flops, inputs, outputs, steps=1):
        read = sum(x[1] for x in inputs) * BYTES
        write = sum(x[1] for x in outputs) * BYTES
        ops.append(Op(p + name, cls, flops, read, write,
                      tuple(inputs), tuple(outputs), steps,
                      rows=L if cls == "linear" else 0))

    x = t(p + "x", L, d)
    add("norm", "norm", 4 * L * d, [x], [t(p + "xn", L, d)])
    add("in_proj", "linear", 2 * L * d * 2 * di,
        [t(p + "xn", L, d), t(p + "Win", d, 2 * di)],
        [t(p + "xz", L, 2 * di)])
    add("conv1d", "linear", 2 * L * di * k,
        [t(p + "xz_x", L, di), t(p + "Wc", k, di)],
        [t(p + "xc", L, di)])
    add("silu_conv", "silu", 2 * L * di,
        [t(p + "xc", L, di)], [t(p + "xa", L, di)])
    add("x_proj", "linear", 2 * L * di * (r + 2 * n),
        [t(p + "xa", L, di), t(p + "Wx", di, r + 2 * n)],
        [t(p + "dbc", L, r + 2 * n)])
    add("dt_proj", "linear", 2 * L * r * di,
        [t(p + "dt_low", L, r), t(p + "Wdt", r, di)],
        [t(p + "dt_pre", L, di)])
    add("softplus", "softplus", 4 * L * di,
        [t(p + "dt_pre", L, di)], [t(p + "dt", L, di)])
    # dA = exp(dt (x) A): element-wise outer product then exp (EW2 + EXP)
    add("dA_outer", "ew2", L * di * n,
        [t(p + "dt", L, di), t(p + "A", di, n)],
        [t(p + "dA_pre", L, di, n)])
    add("dA_exp", "exp", 4 * L * di * n,
        [t(p + "dA_pre", L, di, n)], [t(p + "dA", L, di, n)])
    # dBx = (dt * x) (x) B  (EW1 then EW2)
    add("dtx", "ew1", L * di,
        [t(p + "dt", L, di), t(p + "xa", L, di)], [t(p + "dtx", L, di)])
    add("dBx_outer", "ew2", L * di * n,
        [t(p + "dtx", L, di), t(p + "B", L, n)],
        [t(p + "dBx", L, di, n)])
    # recurrent update h = dA*h + dBx over L steps (EW1 chain, the
    # inter-op-BM target: h + per-step slices of dA/dBx)
    add("h_update", "update", 2 * L * di * n,
        [t(p + "dA", L, di, n), t(p + "dBx", L, di, n),
         t(p + "h", di, n)],
        [t(p + "hs", L, di, n)], steps=L)
    # y = h . C (reduction over n=16 -> linear class, tiny K)
    add("yC", "linear", 2 * L * di * n,
        [t(p + "hs", L, di, n), t(p + "C", L, n)], [t(p + "y", L, di)])
    add("D_skip", "ew1", 2 * L * di,
        [t(p + "y", L, di), t(p + "xa", L, di), t(p + "D", di)],
        [t(p + "yd", L, di)])
    add("silu_z", "silu", 2 * L * di,
        [t(p + "xz_z", L, di)], [t(p + "zg", L, di)])
    add("gate", "ew1", L * di,
        [t(p + "yd", L, di), t(p + "zg", L, di)], [t(p + "yg", L, di)])
    add("out_proj", "linear", 2 * L * di * d,
        [t(p + "yg", L, di), t(p + "Wo", di, d)], [t(p + "out", L, d)])
    add("residual", "ew1", L * d,
        [t(p + "out", L, d), x], [t(p + "x_next", L, d)])
    return ops


def mamba_model_ops(cfg, L: int) -> list:
    """Full model forward (all layers + embed/unembed)."""
    ops = []
    ops.append(Op("embed", "linear", 0, L * 4, L * cfg.d_model * BYTES,
                  (t("tokens", L),), (t("emb", L, cfg.d_model),)))
    for i in range(cfg.n_layers):
        ops.extend(mamba_block_ops(cfg, L, i))
    ops.append(Op("lm_head", "linear", 2 * L * cfg.d_model * cfg.vocab,
                  cfg.d_model * cfg.vocab * BYTES + L * cfg.d_model * BYTES,
                  L * cfg.vocab * BYTES,
                  (t("xf", L, cfg.d_model), t("Wemb", cfg.vocab,
                                              cfg.d_model)),
                  (t("logits", L, cfg.vocab),), rows=L))
    return ops


CLASS_GROUPS = {
    "linear": ("linear",),
    "element-wise": ("ew1", "ew2", "update"),
    "nonlinear": ("exp", "silu", "softplus"),
    "other": ("norm",),
}


def group_of(cls: str) -> str:
    for g, members in CLASS_GROUPS.items():
        if cls in members:
            return g
    return "other"


def summarize(ops: Iterable[Op]) -> dict:
    out: dict = {}
    for op in ops:
        g = group_of(op.cls)
        d = out.setdefault(g, {"flops": 0.0, "read": 0.0, "write": 0.0,
                               "count": 0})
        d["flops"] += op.flops
        d["read"] += op.read
        d["write"] += op.write
        d["count"] += 1
    return out
