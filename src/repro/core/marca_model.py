"""Cycle-approximate performance/energy models: MARCA, CPU, GPU (§7).

MARCA (Table 2): 32 RCUs x (16x16 RPEs) @ 1 GHz, 24 MB buffer, HBM1.0
256 GB/s, 10.44 W core power + 7 pJ/bit HBM energy.  Per op:
``cycles = max(compute_cycles, hbm_bytes/256B-per-cycle)`` with HBM bytes
from the buffer-management policy (buffer_manager.simulate).

Compute rates per mode (paper §4.3/§5.3):
  MM-RCU   16x16 MACs/RCU/cycle       -> 8192 MAC/cyc  = 16.4 TFLOP/s
  EW-RCU   1 op/RPE/cycle             -> 8192 op/cyc   =  8.2 Top/s
  EXP-RCU  4 cycles/element            (fast biased exp, Fig. 6)
  SiLU-RCU ~2.5 cycles/element         (0/2/4 EW ops per segment, eq. 3)

CPU (Xeon 8358P): 32c x 2.6 GHz x AVX-512 (2x16 f32 FMA) = 5.3 TFLOP/s
peak, 136.5 GB/s DDR4, ~10 us/op dispatch overhead (eager framework),
230 W package. GPU (A100): 19.5 TFLOP/s CUDA-core f32 for element-wise,
156 TFLOP/s effective TF32 tensor core for linears, 2039 GB/s HBM2e,
~5 us/kernel launch, 400 W.  Baselines run UNFUSED (policy "none"), which
is what the paper's Mamba-GPU measurement (pre-fused-kernel era) reflects.

These constants reproduce the paper's Fig. 9 speedups to within ~2x; the
calibration is documented in benchmarks/fig9_speedup.py and EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

from repro.core import buffer_manager as bm
from repro.core.op_graph import Op


@dataclasses.dataclass(frozen=True)
class Platform:
    name: str
    linear_flops: float          # FLOP/s for reduction ops
    ew_flops: float              # FLOP/s for element-wise ops
    exp_flops: float             # FLOP/s for exp-class ops
    mem_bw: float                # B/s
    op_overhead_s: float         # per-op dispatch/launch overhead
    power_w: float               # core power
    hbm_pj_per_bit: float        # memory energy
    intra: bool                  # buffer policies in effect
    inter: bool
    #: reference-implementation sequential scan: the h-recurrence runs as
    #: `steps x scan_ops_per_step` separate dispatches (Mamba's
    #: selective_scan_ref loops over L in Python)
    sequential_scan: bool = False
    scan_ops_per_step: int = 6
    #: GEMM M-dim needed to saturate the linear unit (dataflow ~ a tile;
    #: GPU/CPU need hundreds of rows to fill SMs/cores)
    linear_sat_rows: int = 1


MARCA = Platform(
    name="MARCA",
    linear_flops=16.4e12,        # 8192 MAC/cyc * 2 * 1 GHz
    ew_flops=8.2e12,             # 8192 op/cyc (1 elem/RPE/cyc)
    exp_flops=8.2e12,            # EXP-RCU: 4-cycle latency, pipelined
    mem_bw=256e9,                # HBM1.0
    op_overhead_s=0.1e-6,        # decoded-instruction issue, no host
    power_w=10.44,               # Table 4
    hbm_pj_per_bit=7.0,          # [31]
    intra=True, inter=True,
    linear_sat_rows=16)          # systolic tile fills immediately

# Baseline derates calibrated against the paper's Fig. 9 envelope (the
# paper does not specify its software baselines beyond "Mamba-CPU" /
# "Mamba-GPU"; the reference Mamba release runs the scan as unfused eager
# element-wise ops, which is what these constants model — see
# EXPERIMENTS.md "Fig. 9 calibration").
CPU = Platform(
    name="Mamba-CPU",
    linear_flops=1.0e12,         # fp32 eager GEMM at bs=1 (no TF32 on CPU)
    ew_flops=0.15e12,            # eager EW chains: alloc+dispatch bound
    exp_flops=0.08e12,           # libm exp
    mem_bw=136.5e9 / 2,          # eager temporaries double the traffic
    op_overhead_s=60e-6,         # torch-CPU eager dispatch+alloc
    power_w=230.0,
    hbm_pj_per_bit=15.0,         # DDR4
    intra=True, inter=False,     # BLAS tiles; no cross-op fusion
    sequential_scan=True,        # selective_scan_ref: python loop over L
    linear_sat_rows=256)

GPU = Platform(
    name="Mamba-GPU",
    linear_flops=6.0e12,         # fp32 eager (TF32 off), bs=1 utilization
    ew_flops=9.7e12,             # CUDA cores, f32
    exp_flops=4.8e12,            # SFU
    mem_bw=2039e9,
    op_overhead_s=4e-6,          # kernel launch + framework
    power_w=400.0,
    hbm_pj_per_bit=7.0,
    intra=True, inter=False,     # cuBLAS tiles; unfused element-wise
    sequential_scan=True,        # selective_scan_ref: python loop over L
    linear_sat_rows=128)

#: Tensor-Core-only ablation (Fig. 10 top-left): element-wise ops forced
#: through the reduction array at 1/16 of the EW rate (paper §1/§4.1) and
#: no element-wise output-buffer policy (a TC pipeline has no EW residency).
TENSOR_CORE_ONLY = dataclasses.replace(
    MARCA, name="TensorCore-only", ew_flops=MARCA.ew_flops / 16,
    exp_flops=MARCA.ew_flops / 16, inter=False)


_CLS_RATE = {
    "linear": "linear_flops",
    "norm": "ew_flops",
    "ew1": "ew_flops",
    "ew2": "ew_flops",
    "update": "ew_flops",
    "exp": "exp_flops",
    "softplus": "exp_flops",
    "silu": "ew_flops",
}


def op_time(op: Op, plat: Platform, mem_bytes: float) -> float:
    rate = getattr(plat, _CLS_RATE.get(op.cls, "ew_flops"))
    if op.cls == "silu" and plat.name == "MARCA":
        rate = plat.ew_flops / 2.5 * 2.0     # ~2.5 cyc/elem on 2-op basis
    if op.cls == "linear" and op.rows and plat.linear_sat_rows > 1:
        rate = rate * min(1.0, op.rows / plat.linear_sat_rows)
    t_compute = op.flops / rate
    t_mem = mem_bytes / plat.mem_bw
    n_dispatch = 1
    if op.cls == "update" and plat.sequential_scan:
        n_dispatch = op.steps * plat.scan_ops_per_step
    return max(t_compute, t_mem) + plat.op_overhead_s * n_dispatch


def model_time(ops: Iterable[Op], plat: Platform) -> dict:
    """Returns dict with total seconds + per-class-group breakdown."""
    from repro.core.op_graph import group_of
    ops = list(ops)
    total = 0.0
    by_group: dict = {}
    energy_j = 0.0
    for op, read, write in bm.per_op_traffic(ops, plat.intra, plat.inter):
        mem = read + write
        dt = op_time(op, plat, mem)
        total += dt
        g = group_of(op.cls)
        by_group[g] = by_group.get(g, 0.0) + dt
        energy_j += dt * plat.power_w + mem * 8 * plat.hbm_pj_per_bit * 1e-12
    return {"seconds": total, "by_group": by_group, "energy_j": energy_j,
            "platform": plat.name}


def speedup(ops, base: Platform, target: Platform = MARCA) -> float:
    return model_time(ops, base)["seconds"] / \
        model_time(ops, target)["seconds"]


def energy_ratio(ops, base: Platform, target: Platform = MARCA) -> float:
    return model_time(ops, base)["energy_j"] / \
        model_time(ops, target)["energy_j"]
