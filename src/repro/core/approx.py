"""MARCA §5 — reusable nonlinear functions decomposed into element-wise ops.

The paper replaces dedicated exp/SiLU hardware with:

  * a *fast biased exponential*: Schraudolph's IEEE-754 exponent-field trick
    (one FP multiply-add = "element-wise ops" + one int shift/bitcast = the
    "exponential shift unit" of Fig. 6), with the affine bias re-calibrated on
    the empirical input distribution of exp in Mamba (the outer product dt*A,
    concentrated in [-7, 0) and dense near 0 — modeled in the paper by the
    density set x = -7/n, n = 1..200);

  * a *piecewise SiLU*: a 4-segment range-detect + polynomial evaluation
    (paper eq. 3).  We ship the paper's verbatim coefficients
    (``piecewise_silu_paper``) and a least-squares refit with two extra
    positive-side segments (``piecewise_silu``) whose max error is ~4x lower
    at identical per-element cost class (range detect + quadratic).

Everything here is pure jnp so it can be called from inside Pallas kernels
(the bitcast lowers to the TPU's bit-manipulation path) as well as from
regular jitted code.  Calibration helpers are numpy so tests can re-derive
the hard-coded constants.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

LN2 = 0.6931471805599453
_S23 = float(2**23)

# ---------------------------------------------------------------------------
# Calibrated constants.  Regenerate with calibrate_exp_bias() /
# fit_piecewise_silu(); tests assert the hard-coded values stay optimal.
# ---------------------------------------------------------------------------

#: Plain Schraudolph baseline ("fast_exp" row of Table 3): exponent-field
#: shift minimizing relative RMS over a generic range [-10, 10].
FAST_EXP_B_SHIFT = -0.065

#: Our biased exp ("Our_exp" row): calibrated on the paper's density set
#: x = -7/n (n = 1..200) for minimum mean *relative* error.
OUR_EXP_B_SHIFT = -0.03475
#: Final additive bias c (paper Fig. 6 "bias unit").  The relative-error
#: calibration drives it to ~0; it is kept as an explicit knob because the
#: paper's hardware has it.
OUR_EXP_C = 5.6e-07

#: Hard clamp so the bit trick never leaves the normalized-float range.
_EXP_CLAMP = 80.0

# 6-segment quadratic refit of SiLU (ours). Breakpoints chosen to keep the
# paper's three interior knots (-5, -1.5, 0.75) and add two positive-side
# knots; below -9 -> 0, above 9 -> identity (both exact to <2e-3).
SILU_BREAKS = (-9.0, -5.0, -1.5, 0.75, 2.25, 4.5, 9.0)
SILU_COEFS = (
    (-0.0026606, -0.0442494, -0.1855941),   # [-9, -5)
    (-0.0117359, -0.1503727, -0.4880836),   # [-5, -1.5)
    (0.2163049, 0.4986513, 0.0058849),      # [-1.5, 0.75]
    (0.0813905, 0.7826839, -0.1309739),     # (0.75, 2.25]
    (-0.0164214, 1.1849977, -0.5492407),    # (2.25, 4.5]
    (-0.0033375, 1.0541269, -0.2208955),    # (4.5, 9]
)

# 5-segment quadratic sigmoid (for xLSTM gates under approx mode);
# below -9 -> 0, above 9 -> 1.
SIGMOID_BREAKS = (-9.0, -4.0, -1.5, 1.5, 4.0, 9.0)
SIGMOID_COEFS = (
    (0.0011309, 0.0173485, 0.0662357),
    (0.0255878, 0.2028679, 0.4243576),
    (0.0, 0.2257178, 0.5),
    (-0.0255878, 0.2028679, 0.5756424),
    (-0.0011309, 0.0173485, 0.9337643),
)


# ---------------------------------------------------------------------------
# Fast biased exponential (paper §5.3, Fig. 6)
# ---------------------------------------------------------------------------

def fast_exp(x: jax.Array, b_shift: float = FAST_EXP_B_SHIFT,
             c: float = 0.0) -> jax.Array:
    """exp(x) via the exponent-field bit trick.

    i = int32(x * 2^23/ln2 + (127 + b_shift) * 2^23);  y = bitcast_f32(i) + c

    One FP fused-multiply-add, one float->int conversion (the paper's "shift
    unit" — the multiply by 2^23 IS a left shift of the exponent field), one
    int->float bitcast and one FP add.  All element-wise; no transcendental
    hardware.
    """
    dt = x.dtype
    x32 = jnp.clip(x.astype(jnp.float32), -_EXP_CLAMP, _EXP_CLAMP)
    i = (x32 * np.float32(_S23 / LN2)
         + np.float32((127.0 + b_shift) * _S23)).astype(jnp.int32)
    y = jax.lax.bitcast_convert_type(i, jnp.float32) + np.float32(c)
    return y.astype(dt)


def our_exp(x: jax.Array) -> jax.Array:
    """The paper's *biased* fast exp ("Our_exp"), calibrated for dt*A inputs."""
    return fast_exp(x, OUR_EXP_B_SHIFT, OUR_EXP_C)


def exp_density_set(n: int = 200) -> np.ndarray:
    """The paper's calibration distribution: x = -7/n, density rising to 0-."""
    return np.array([-7.0 / k for k in range(1, n + 1)], dtype=np.float32)


def calibrate_exp_bias(xs: np.ndarray | None = None,
                       n_grid: int = 561) -> tuple[float, float]:
    """Re-derive (OUR_EXP_B_SHIFT, OUR_EXP_C): min mean relative error on xs."""
    if xs is None:
        xs = exp_density_set()
    t = np.exp(xs.astype(np.float64))
    w = 1.0 / t

    def _raw(x, b):
        i = (np.clip(x, -_EXP_CLAMP, _EXP_CLAMP).astype(np.float32)
             * np.float32(_S23 / LN2)
             + np.float32((127.0 + b) * _S23)).astype(np.int32)
        return i.view(np.float32).astype(np.float64)

    def _weighted_median(vals, ww):
        idx = np.argsort(vals)
        cw = np.cumsum(ww[idx])
        return float(vals[idx][np.searchsorted(cw, cw[-1] / 2)])

    best = (np.inf, 0.0, 0.0)
    for b in np.linspace(-0.12, 0.02, n_grid):
        e = _raw(xs, b) - t
        c = _weighted_median(-e, w)
        m = float((np.abs(e + c) / t).mean())
        if m < best[0]:
            best = (m, float(b), c)
    return best[1], best[2]


# ---------------------------------------------------------------------------
# Piecewise SiLU (paper §5.3 eq. 3) and friends
# ---------------------------------------------------------------------------

def _piecewise_quad(x32: jax.Array, breaks, coefs,
                    low_fn, high_fn) -> jax.Array:
    """Range detector + per-segment quadratic (the SiLU-RCU datapath)."""
    y = low_fn(x32)
    for i, (a2, a1, a0) in enumerate(coefs):
        seg = (np.float32(a2) * x32 + np.float32(a1)) * x32 + np.float32(a0)
        y = jnp.where(x32 >= np.float32(breaks[i]), seg, y)
    return jnp.where(x32 > np.float32(breaks[-1]), high_fn(x32), y)


def piecewise_silu(x: jax.Array) -> jax.Array:
    """Refit 6-segment SiLU; max |err| ~0.018, mean ~3e-3 on [-5, 4]."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = _piecewise_quad(x32, SILU_BREAKS, SILU_COEFS,
                        lambda v: jnp.zeros_like(v), lambda v: v)
    return y.astype(dt)


def piecewise_silu_paper(x: jax.Array) -> jax.Array:
    """Paper eq. (3), coefficients verbatim (4 segments)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = jnp.where(
        x32 < -5.0, np.float32(-0.0135),
        jnp.where(
            x32 < -1.5, np.float32(-0.06244) * x32 + np.float32(-0.3457),
            jnp.where(
                x32 <= 0.75,
                np.float32(0.232) * (x32 + np.float32(1.181)) ** 2
                + np.float32(-0.275),
                np.float32(1.05) * x32 + np.float32(-0.2781))))
    return y.astype(dt)


def piecewise_sigmoid(x: jax.Array) -> jax.Array:
    """5-segment sigmoid (same datapath class); max |err| ~0.021."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = _piecewise_quad(x32, SIGMOID_BREAKS, SIGMOID_COEFS,
                        lambda v: jnp.zeros_like(v), lambda v: jnp.ones_like(v))
    return y.astype(dt)


def fit_piecewise_silu(breaks=SILU_BREAKS) -> np.ndarray:
    """Re-derive SILU_COEFS by per-segment least squares."""
    out = []
    for lo, hi in zip(breaks[:-1], breaks[1:]):
        xs = np.linspace(lo, hi, 20001)
        out.append(np.polyfit(xs, xs / (1 + np.exp(-xs)), 2))
    return np.asarray(out)


# ---------------------------------------------------------------------------
# Dispatch table used by models: "exact" | "ours" | "fast" (exp),
# "exact" | "ours" | "paper" (silu).
# ---------------------------------------------------------------------------

EXP_IMPLS = {
    "exact": jnp.exp,
    "ours": our_exp,
    "fast": fast_exp,
}

SILU_IMPLS = {
    "exact": jax.nn.silu,
    "ours": piecewise_silu,
    "paper": piecewise_silu_paper,
}

SIGMOID_IMPLS = {
    "exact": jax.nn.sigmoid,
    "ours": piecewise_sigmoid,
}


def get_exp(name: str):
    return EXP_IMPLS[name]


def get_silu(name: str):
    return SILU_IMPLS[name]


def get_sigmoid(name: str):
    return SIGMOID_IMPLS[name]
