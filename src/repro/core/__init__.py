"""MARCA's primary contributions, realized in JAX (see DESIGN.md §2):

  * ``approx``          — fast biased exp + piecewise SiLU/sigmoid (§5).
  * ``selective_scan``  — seq/assoc/chunked scan algorithms (§4 + §6).
  * ``buffer_manager``  — intra-/inter-op buffer policy simulator (§6).
  * ``op_graph``        — Mamba op-graph (op class, FLOPs, bytes) (§2/Fig. 7).
  * ``marca_model``     — cycle-approximate MARCA/CPU/GPU perf-energy models
                          (§7, Figs. 1/9/10).
"""
from repro.core import approx  # noqa: F401
