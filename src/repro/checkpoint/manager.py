"""Checkpoint manager: async save, atomic publish, keep-last-k, and
mesh-independent restore (elastic scaling).

Format: one directory per step containing
  * ``meta.json``   — step, leaf paths, shapes, dtypes
  * ``arrays.npz``  — full (unsharded) leaf arrays keyed by flattened path

The on-disk format is intentionally *mesh-independent*: restore takes an
optional pytree of target shardings and uses ``jax.device_put`` against the
new mesh, so a checkpoint written on the 256-chip mesh restores onto 512
chips (or 1 CPU) unchanged — the elasticity story of DESIGN.md §4.  In a
true multi-host deployment each process would write its addressable shards
(same directory layout, one npz per process); this container is
single-process so the degenerate single-writer path is exercised.

Atomicity: writes go to ``<dir>/tmp.<step>`` and are ``os.rename``d into
place (rename is atomic on POSIX); readers only ever see complete
checkpoints.  Async: the serialization runs on a worker thread; ``wait()``
blocks (called before exit and by tests).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


_NATIVE_KINDS = set("biufc?")


def _encode_np(v: np.ndarray) -> tuple[np.ndarray, str]:
    """ml_dtypes (bf16 etc.) are not npz-serializable: store raw bytes."""
    if v.dtype.kind in _NATIVE_KINDS:
        return v, str(v.dtype)
    return np.frombuffer(v.tobytes(), np.uint8), str(v.dtype)


def _decode_np(raw: np.ndarray, dtype_str: str, shape) -> np.ndarray:
    import ml_dtypes  # noqa: F401  (registers bf16 etc. with numpy)
    if raw.dtype != np.uint8 or np.dtype(dtype_str).kind in _NATIVE_KINDS:
        return raw
    return np.frombuffer(raw.tobytes(),
                         np.dtype(dtype_str)).reshape(shape)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save

    def save(self, step: int, tree: Any, blocking: bool = False):
        """Snapshot to host memory synchronously, write asynchronously."""
        flat = _flatten_with_paths(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}  # device->host now
        self.wait()                                          # one in flight
        if self.async_save and not blocking:
            self._pending = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._pending.start()
        else:
            self._write(step, host)

    def _write(self, step: int, host: dict):
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"step_{step:012d}")
        os.makedirs(tmp, exist_ok=True)
        enc = {k: _encode_np(v) for k, v in host.items()}
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: v[0] for k, v in enc.items()})
        meta = {"step": step,
                "leaves": {k: {"shape": list(host[k].shape),
                               "dtype": enc[k][1]}
                           for k, v in host.items()}}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:012d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.dir, d, "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, int]:
        """Restore into the structure of ``template``.

        ``shardings``: optional pytree (matching template) of
        jax.sharding.Sharding — enables elastic reshard-on-load.  Returns
        (tree, step)."""
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:012d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        if shardings is None:
            flat_s = [None] * len(flat_t)
        else:
            flat_s = jax.tree_util.tree_structure(template).flatten_up_to(
                shardings)
        leaves = []
        for (kpath, tmpl), shd in zip(flat_t, flat_s):
            key = "/".join(_path_str(p) for p in kpath)
            info = meta["leaves"][key]
            arr = _decode_np(data[key], info["dtype"], tuple(info["shape"]))
            want = np.dtype(getattr(tmpl, "dtype", arr.dtype))
            if arr.dtype != want:
                arr = arr.astype(want)
            if shd is not None:
                leaves.append(jax.device_put(arr, shd))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return treedef.unflatten(leaves), step
