"""Fault-tolerant checkpointing: async, atomic, keep-k, elastic reshard."""
from repro.checkpoint.manager import CheckpointManager
