"""Deterministic, shardable data pipeline.

Design (mirrors production text loaders):

  * The dataset is *stateless*: ``batch_at(step, shard, num_shards)`` is a
    pure function of its arguments, so resuming after preemption needs only
    the step counter from the checkpoint — no loader state to save (the
    fault-tolerance story of DESIGN.md §4).
  * ``SyntheticLM`` generates a corpus with learnable structure: a Zipf
    unigram marginal + an order-2 deterministic mixing rule, so small
    models trained for a few hundred steps show a clearly decreasing loss
    (integration tests assert this).
  * ``Prefetcher`` overlaps host batch assembly with device compute via a
    background thread + bounded queue.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


class SyntheticLM:
    """Order-2 synthetic language: next = f(prev, prev2) with noise."""

    def __init__(self, vocab: int, seq_len: int, seed: int = 0,
                 noise: float = 0.1):
        self.vocab = vocab
        self.seq_len = seq_len
        self.seed = seed
        self.noise = noise
        rng = np.random.default_rng(seed)
        # deterministic order-2 transition table (the learnable structure)
        self.table = rng.integers(0, vocab, size=(vocab,), dtype=np.int64)
        self.mix = rng.integers(1, vocab, size=(), dtype=np.int64)
        # Zipf-ish unigram for the noise tokens
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.unigram = p / p.sum()

    def sample(self, rng: np.random.Generator, batch: int) -> np.ndarray:
        L = self.seq_len + 1
        out = np.empty((batch, L), dtype=np.int64)
        out[:, 0] = rng.integers(0, self.vocab, size=batch)
        out[:, 1] = rng.integers(0, self.vocab, size=batch)
        noise_mask = rng.random((batch, L)) < self.noise
        noise_tok = rng.choice(self.vocab, size=(batch, L), p=self.unigram)
        for t in range(2, L):
            nxt = self.table[(out[:, t - 1] + self.mix * out[:, t - 2])
                             % self.vocab]
            out[:, t] = np.where(noise_mask[:, t], noise_tok[:, t], nxt)
        return out

    def batch_at(self, step: int, shard: int, num_shards: int,
                 batch_per_shard: int) -> dict:
        """Pure function of (step, shard): deterministic + resumable."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + shard * 2_654_435_761
            % (2 ** 63))
        toks = self.sample(rng, batch_per_shard)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


class ShardedLoader:
    """Iterator over deterministic global batches for one data shard."""

    def __init__(self, dataset: SyntheticLM, global_batch: int,
                 shard: int = 0, num_shards: int = 1, start_step: int = 0):
        assert global_batch % num_shards == 0
        self.ds = dataset
        self.bps = global_batch // num_shards
        self.shard = shard
        self.num_shards = num_shards
        self.step = start_step

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = self.ds.batch_at(self.step, self.shard, self.num_shards,
                             self.bps)
        self.step += 1
        return b


class Prefetcher:
    """Background-thread prefetch with a bounded queue."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.it = it
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.done = object()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        try:
            for item in self.it:
                self.q.put(item)
        finally:
            self.q.put(self.done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is self.done:
            raise StopIteration
        return item


def make_train_iterator(cfg, global_batch: int, seq_len: int,
                        start_step: int = 0, seed: int = 0,
                        prefetch: int = 2):
    """End-to-end: synthetic corpus sized to cfg.vocab -> prefetched iter."""
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=seq_len, seed=seed)
    loader = ShardedLoader(ds, global_batch, start_step=start_step)
    return Prefetcher(loader, depth=prefetch) if prefetch else loader
