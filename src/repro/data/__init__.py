"""Data pipeline: synthetic structured LM corpus + deterministic sharded
loader with background prefetch (stateless indexing -> free fault-tolerant
resume)."""
from repro.data.pipeline import (SyntheticLM, ShardedLoader, Prefetcher,
                                 make_train_iterator)
