"""Functional AdamW with selectable moment precision (f32 | bf16 | int8).

ZeRO comes for free: moments are created with the same sharding as the
(FSDP x TP)-sharded params, so optimizer state is fully partitioned.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import quantized_state as qs


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4                 # peak lr (schedule multiplies)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"    # float32 | bfloat16 | int8


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def _encode(x, dtype, power=1.0):
    if dtype == "int8":
        return qs.quantize(x, power=power)
    return x.astype(jnp.dtype(dtype))


def _decode(x, power=1.0):
    if qs.is_qtensor(x):
        return qs.dequantize(x, power=power)
    return x.astype(jnp.float32)


#: power-law exponents for int8 moments (8-bit-Adam style): mu is signed
#: and mildly heavy-tailed (p=2); nu spans decades (p=4).
MU_POWER = 2.0
NU_POWER = 4.0


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    def z(power):
        return lambda p: _encode(jnp.zeros(p.shape, jnp.float32),
                                 cfg.moment_dtype, power)

    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(z(MU_POWER), params),
                      jax.tree.map(z(NU_POWER), params))


def clip_by_global_norm(grads, max_norm):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig,
                 lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(g, m_enc, v_enc, p):
        m = cfg.b1 * _decode(m_enc, MU_POWER) + (1 - cfg.b1) * g
        v = cfg.b2 * _decode(v_enc, NU_POWER) + (1 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                       # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, _encode(m, cfg.moment_dtype, MU_POWER), _encode(
            v, cfg.moment_dtype, NU_POWER)

    g_leaves, treedef = jax.tree.flatten(grads)
    m_leaves = treedef.flatten_up_to(state.mu)
    v_leaves = treedef.flatten_up_to(state.nu)
    p_leaves = treedef.flatten_up_to(params)
    outs = [upd(g, m, v, p) for g, m, v, p in
            zip(g_leaves, m_leaves, v_leaves, p_leaves)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])
    metrics = {"grad_norm": gnorm, "lr": jnp.float32(lr)}
    return new_p, AdamWState(step, new_m, new_v), metrics
