"""Blockwise 8-bit state quantization (8-bit-Adam style).

Needed to fit arctic-480b training on 16 GB/chip: Adam moments at int8 +
per-block f32 absmax scales cut optimizer memory ~4x vs f32 (see DESIGN.md
§4).  Quantization is symmetric linear per contiguous block of the
flattened tensor; dequant-update-requant per step (error stays bounded
because Adam moments are EMAs — tests check convergence parity).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 256


@jax.tree_util.register_pytree_node_class
class QTensor:
    """int8 payload + per-block scales; original shape is static aux."""
    __slots__ = ("q", "scale", "shape")

    def __init__(self, q, scale, shape):
        self.q = q                # int8 (n_blocks, BLOCK)
        self.scale = scale        # f32  (n_blocks, 1)
        self.shape = tuple(shape)

    def tree_flatten(self):
        return (self.q, self.scale), self.shape

    @classmethod
    def tree_unflatten(cls, shape, children):
        return cls(children[0], children[1], shape)

    def __repr__(self):
        return f"QTensor(shape={self.shape}, blocks={self.q.shape[0]})"


def quantize(x: jax.Array, power: float = 1.0) -> QTensor:
    """power=1: linear.  power>1: power-law code (8-bit-Adam style dynamic
    map) — code = round(127 * sign(u) * |u|^(1/power)) with u = x/absmax.
    Resolution near zero improves by ~127^(power-1); essential for Adam's
    second moment whose per-block dynamic range spans many decades (linear
    int8 floors small entries to 0 -> 1/sqrt(v) blows up; tests cover)."""
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    absmax = jnp.maximum(jnp.max(jnp.abs(flat), axis=1, keepdims=True),
                         1e-30)
    u = flat / absmax
    if power != 1.0:
        u = jnp.sign(u) * jnp.abs(u) ** (1.0 / power)
    q = jnp.clip(jnp.round(u * 127.0), -127, 127).astype(jnp.int8)
    # store absmax/127 so linear decode keeps the legacy contract
    return QTensor(q, absmax * np.float32(1 / 127.0), shape) \
        if power == 1.0 else QTensor(q, absmax, shape)


def dequantize(t: QTensor, power: float = 1.0) -> jax.Array:
    if power == 1.0:
        flat = t.q.astype(jnp.float32) * t.scale
    else:
        u = t.q.astype(jnp.float32) / 127.0
        flat = jnp.sign(u) * jnp.abs(u) ** power * t.scale
    n = 1
    for s in t.shape:
        n *= s
    return flat.reshape(-1)[:n].reshape(t.shape)


def is_qtensor(x) -> bool:
    return isinstance(x, QTensor)
