"""Int8 error-feedback gradient compression for the cross-pod all-reduce.

At multi-pod scale the only DCN collective is the gradient all-reduce over
the "pod" axis (DESIGN.md §4).  Int8 + per-tensor scale cuts that traffic
4x vs f32 / 2x vs bf16.  Error feedback (Seide et al. / EF-SGD) keeps the
quantization residual locally and re-adds it next step, which preserves
convergence (tests check parity on a quadratic problem).

Two entry points:

  * ``ef_compress_decompress(g, err)`` — the lossy channel + residual
    bookkeeping, composable inside any pjit step (GSPMD then all-reduces
    the already-quantized-then-decoded values; the wire format in a real
    deployment is the int8 payload, summed in int32).
  * ``compressed_psum(g, axis)`` — explicit shard_map building block that
    performs quantize -> int32 psum -> dequantize, for manual-collective
    pipelines and the multi-device tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quant(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_compress_decompress(g, err):
    """Returns (g_hat, new_err): g_hat = Q(g + err), new_err = g + err - g_hat."""
    x = g.astype(jnp.float32) + err
    q, scale = _quant(x)
    g_hat = q.astype(jnp.float32) * scale
    return g_hat, x - g_hat


def ef_init(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def ef_apply(grads, err_state):
    """Tree version: compress every leaf with error feedback."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    outs = [ef_compress_decompress(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def compressed_psum(x, axis_name: str):
    """Quantize -> int32 psum -> dequantize (mean).  Call under shard_map.

    The max-scale is itself psum-maxed so all participants share one scale
    (required for a linear int32 reduction)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)) / 127.0, 1e-12)
    scale = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total.astype(jnp.float32) * scale / n
