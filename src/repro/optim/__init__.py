"""Optimizers + distributed-optimization tricks: AdamW (fp32/bf16/int8
moments), schedules, global-norm clip, int8 error-feedback gradient
compression for the cross-pod all-reduce."""
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm)
from repro.optim.schedule import cosine_schedule
from repro.optim import compression, quantized_state  # noqa: F401
