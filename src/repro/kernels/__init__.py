"""Pallas TPU kernels for MARCA hot spots + pure-jnp oracles.

Kernels (each validated against ``ref.py`` with interpret=True on CPU):

  * ``selective_scan`` — fused selective-SSM scan (the paper's core).
  * ``fast_exp``       — biased Schraudolph exponential (EXP-RCU).
  * ``piecewise_silu`` — range-detect + quadratic SiLU (SiLU-RCU).
  * ``conv1d``         — causal depthwise conv (Mamba short conv).
  * ``flash_attention``— online-softmax GQA attention (prefill_32k).
"""
from repro.kernels import ops, ref  # noqa: F401
