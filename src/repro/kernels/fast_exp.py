"""Pallas TPU kernel: fast biased exponential (MARCA EXP-RCU mode).

The paper's EXP-RCU reconfigures the PE array so each PE does one FP
multiply, one FP add, then routes through the "exponential shift unit"
(Fig. 6).  On TPU the same decomposition maps onto the VPU: the multiply-add
is a vector FMA and the shift unit is an f32->i32 convert + bitcast, all
8x128-lane element-wise ops.  No transcendental unit is involved.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pallas_compat

from repro.core import approx

_LANES = pallas_compat.LANES
_DEFAULT_COLS = pallas_compat.DEFAULT_COLS
_DEFAULT_ROWS = pallas_compat.DEFAULT_ROWS


def _fast_exp_kernel(x_ref, o_ref, *, b_shift: float, c: float):
    # the bit-trick formula lives ONLY in core.approx; the kernel body is
    # just the block load/store around it
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = approx.fast_exp(x, b_shift, c).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("b_shift", "c", "block_rows",
                                             "cols", "interpret"))
def fast_exp_2d(x, b_shift=approx.OUR_EXP_B_SHIFT, c=approx.OUR_EXP_C,
                block_rows=_DEFAULT_ROWS, cols=_DEFAULT_COLS,
                interpret=True):
    """Element-wise biased exp over a 2D array (rows, cols)."""
    rows = x.shape[0]
    grid = (pl.cdiv(rows, block_rows),)
    return pl.pallas_call(
        functools.partial(_fast_exp_kernel, b_shift=b_shift, c=c),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, cols), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((block_rows, cols), lambda r: (r, 0)),
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="marca_fast_exp",
    )(x)


def fast_exp(x, b_shift=approx.OUR_EXP_B_SHIFT, c=approx.OUR_EXP_C,
             interpret=True):
    """Shape-polymorphic wrapper: flatten -> pad -> tile -> kernel -> unpad."""
    n = x.size
    cols = _DEFAULT_COLS if n >= _DEFAULT_COLS else _LANES
    rows = -(-n // cols)
    pad = rows * cols - n
    flat = jnp.pad(x.reshape(-1), (0, pad))
    block_rows = min(_DEFAULT_ROWS, rows)
    y = fast_exp_2d(flat.reshape(rows, cols), b_shift=float(b_shift),
                    c=float(c), block_rows=block_rows, cols=cols,
                    interpret=interpret)
    return y.reshape(-1)[:n].reshape(x.shape)
