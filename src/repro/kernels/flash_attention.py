"""Pallas TPU kernel: causal GQA flash attention (online softmax).

Not a MARCA contribution (Mamba has no attention) but a hot spot for the
assigned *attention* architectures at prefill_32k: materializing 32k x 32k
scores is impossible, so scores are computed block-wise with the running
(max, sum) rescaling trick, accumulator resident in VMEM — the same
"intermediates never leave the buffer" discipline as the scan kernel.

Layout: q/k/v as (b, h, l, dh); grid (b, hq, lq/BQ, lk/BK) with the KV axis
innermost ("arbitrary") so m/l/acc scratch persists across KV blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import pallas_compat

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, bq: int, bk: int, scale: float, causal: bool,
                  q_offset: int):
    kv_idx = pl.program_id(3)
    q_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * np.float32(scale)   # (BQ, dh)
    k = k_ref[0, 0].astype(jnp.float32)                        # (BK, dh)
    v = v_ref[0, 0].astype(jnp.float32)                        # (BK, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (BQ, BK)
    if causal:
        rows = q_offset + q_idx * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 0)
        cols = kv_idx * bk + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 1)
        s = jnp.where(rows >= cols, s, _NEG_INF)

    m_prev = m_scr[...]                                        # (BQ, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                     # (BQ, BK)
    corr = jnp.exp(m_prev - m_new)                             # (BQ, 1)
    l_new = corr * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_new = corr * acc_scr[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(kv_idx == pl.num_programs(3) - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_q", "block_k", "causal", "scale", "q_offset", "interpret"))
def _flash_bhld(q, k, v, block_q: int, block_k: int, causal: bool,
                scale: float, q_offset: int, interpret: bool):
    """q (b, hq, lq, dh); k/v (b, hkv, lk, dh); lq % bq == lk % bk == 0."""
    b, hq, lq, dh = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    lk = k.shape[2]
    grid = (b, hq, lq // block_q, lk // block_k)
    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=block_q, bk=block_k, scale=scale,
                          causal=causal, q_offset=q_offset),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda bb, hh, qq, kk: (bb, hh, qq, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bb, hh, qq, kk, _rep=rep:
                         (bb, hh // _rep, kk, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bb, hh, qq, kk, _rep=rep:
                         (bb, hh // _rep, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda bb, hh, qq, kk: (bb, hh, qq, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)


def flash_attention(q, k, v, causal: bool = True, scale=None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q (b, lq, hq, dh); k/v (b, lk, hkv, dh) — matches kernels.ref.attention.

    Handles lq < lk (q is the suffix of the sequence, decode-chunk style).
    """
    b, lq, hq, dh = q.shape
    lk = k.shape[1]
    if scale is None:
        scale = dh ** -0.5
    block_q = min(block_q, lq)
    block_k = min(block_k, lk)
    pad_q = (-lq) % block_q
    pad_k = (-lk) % block_k
    qt = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    kt = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    vt = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    if pad_k:
        # mask padded keys with causal-style column bound: rows >= cols fails
        # automatically only in causal mode; for non-causal, bias via value 0
        # and score -inf is needed — implemented by causal=True requirement.
        assert causal, "non-causal with padded kv not supported"
    o = _flash_bhld(qt, kt, vt, block_q=block_q, block_k=block_k,
                    causal=causal, scale=float(scale),
                    q_offset=lk - lq, interpret=interpret)
    return o.transpose(0, 2, 1, 3)[:, :lq]
