"""Public jit'd wrappers over the Pallas kernels with impl dispatch.

Models call these; ``impl`` selects between the Pallas kernel ("pallas",
interpret-mode on CPU, compiled on real TPU) and the pure-jnp oracle
("xla").  The oracle is also what autodiff differentiates through for
training paths (the Pallas forward is inference/serving + perf analysis;
see DESIGN.md §2).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import approx
from repro.kernels import ref
from repro.kernels import conv1d as _conv1d_k
from repro.kernels import fast_exp as _fast_exp_k
from repro.kernels import flash_attention as _flash_k
from repro.kernels import piecewise_silu as _silu_k
from repro.kernels import selective_scan as _scan_k


def exp(x, impl: str = "exact", backend: str = "xla"):
    """impl in {exact, ours, fast}; backend in {xla, pallas}."""
    if impl == "exact":
        return jnp.exp(x)
    if backend == "pallas":
        if impl == "ours":
            return _fast_exp_k.fast_exp(x)
        return _fast_exp_k.fast_exp(x, b_shift=approx.FAST_EXP_B_SHIFT, c=0.0)
    return approx.get_exp(impl)(x)


def silu(x, impl: str = "exact", backend: str = "xla"):
    """impl in {exact, ours, paper}; backend in {xla, pallas}."""
    if impl == "exact":
        import jax
        return jax.nn.silu(x)
    if backend == "pallas":
        return _silu_k.piecewise_silu(x, variant=impl)
    return approx.get_silu(impl)(x)


def selective_scan(x, dt, A, B, C, D=None, z=None, h0=None,
                   impl: str = "chunked", chunk: int = 64,
                   exp_impl: str = "exact", silu_impl: str = "exact"):
    """impl in {seq, assoc, chunked, chunked_seq, pallas, pallas_vjp}."""
    if impl == "pallas":
        return _scan_k.selective_scan(x, dt, A, B, C, D=D, z=z, h0=h0,
                                      exp_impl=exp_impl, silu_impl=silu_impl)
    if impl == "pallas_vjp":
        # trainable kernel path: custom VJP covers the recurrence core;
        # D-skip and z-gate stay in autodiff-able jnp
        import jax
        assert h0 is None, "pallas_vjp path starts from h0=0 (training)"
        y, h_last = _scan_k.selective_scan_trainable(x, dt, A, B, C,
                                                     chunk, True)
        if D is not None:
            y = y + D.astype(jnp.float32)[None, None, :] \
                * x.astype(jnp.float32)
        if z is not None:
            y = y * approx.get_silu(silu_impl)(z.astype(jnp.float32))
        return y.astype(x.dtype), h_last
    from repro.core import selective_scan as css
    if impl in ("chunked", "chunked_seq"):
        return css.selective_scan_chunked(
            x, dt, A, B, C, D=D, z=z, h0=h0, chunk=chunk,
            exp_impl=exp_impl, silu_impl=silu_impl,
            inner="seq" if impl == "chunked_seq" else "assoc")
    if impl == "assoc":
        return css.selective_scan_assoc(x, dt, A, B, C, D=D, z=z, h0=h0,
                                        exp_impl=exp_impl,
                                        silu_impl=silu_impl)
    return ref.selective_scan(x, dt, A, B, C, D=D, z=z, h0=h0,
                              exp_impl=exp_impl, silu_impl=silu_impl)


def selective_state_step(h, x_t, dt_t, A, B_t, C_t, D=None, z_t=None,
                         impl: str = "xla",
                         exp_impl: str = "exact", silu_impl: str = "exact",
                         a_scale=None):
    """Single-token decode step; impl in {xla, fused/pallas}.

    The fused impl is one Pallas launch for the whole state-update /
    contraction / gate chain (interpret-mode on CPU); xla is the ref.py
    oracle with identical semantics.  ``a_scale`` (d,) marks A as int8
    weight codes (cfg.weight_dtype="int8") dequantized at the point of
    consumption — in-kernel for the fused impl."""
    from repro.core import selective_scan as css
    return css.decode_step(h, x_t, dt_t, A, B_t, C_t, D=D, z_t=z_t,
                           impl=impl, exp_impl=exp_impl,
                           silu_impl=silu_impl, a_scale=a_scale)


def selective_state_step_q(hq, h_scale, x_t, dt_t, A, B_t, C_t, D=None,
                           z_t=None, state_dtype: str = "int8",
                           impl: str = "xla", exp_impl: str = "exact",
                           silu_impl: str = "exact", a_scale=None):
    """Quantized-state single-token decode step; impl in {xla, fused}.

    Same chain as selective_state_step but the state payload stays in
    its int8/fp8 storage dtype across the HBM round-trip: dequant on
    read, requant on write with a decayed-running-absmax scale (inside
    the kernel for the fused impl)."""
    from repro.core import selective_scan as css
    return css.decode_step_q(hq, h_scale, x_t, dt_t, A, B_t, C_t, D=D,
                             z_t=z_t, state_dtype=state_dtype, impl=impl,
                             exp_impl=exp_impl, silu_impl=silu_impl,
                             a_scale=a_scale)


def causal_conv1d(x, w, b=None, x_prev=None, impl: str = "xla"):
    if impl == "pallas":
        return _conv1d_k.causal_conv1d(x, w, b=b, x_prev=x_prev)
    return ref.causal_conv1d(x, w, b=b, x_prev=x_prev)


def attention(q, k, v, causal: bool = True, impl: str = "xla"):
    if impl == "pallas":
        return _flash_k.flash_attention(q, k, v, causal=causal)
    return ref.attention(q, k, v, causal=causal)
