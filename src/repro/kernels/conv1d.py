"""Pallas TPU kernel: causal depthwise conv1d (Mamba short conv, k=4).

MARCA executes this with the CONV instruction on the same PE arrays.  On TPU
it is another element-wise-class op (depthwise = no channel reduction), so it
belongs on the VPU.  The (k-1)-sample history is carried across sequence
blocks in a VMEM scratch — the same inter-operation buffer-residency idea as
the scan kernel's hidden state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import pallas_compat


def _conv_kernel(x_ref, w_ref, b_ref, xprev_ref, y_ref, tail_ref, hist,
                 *, bl: int, k: int, has_bias: bool):
    l_idx = pl.program_id(2)

    @pl.when(l_idx == 0)
    def _init():
        hist[...] = xprev_ref[0].astype(jnp.float32)   # (k-1, BD)

    x = x_ref[0].astype(jnp.float32)                   # (BL, BD)
    w = w_ref[...].astype(jnp.float32)                 # (k, BD)
    xp = jnp.concatenate([hist[...], x], axis=0)       # (BL+k-1, BD)
    y = jnp.zeros_like(x)
    for i in range(k):
        y = y + xp[i:i + bl, :] * w[i][None, :]
    if has_bias:
        y = y + b_ref[0].astype(jnp.float32)[None, :]
    y_ref[0] = y.astype(y_ref.dtype)
    hist[...] = xp[bl:, :]
    tail_ref[0] = xp[bl:, :].astype(tail_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "block_l",
                                             "interpret"))
def _conv_padded(x, w, b, x_prev, block_d: int, block_l: int,
                 interpret: bool):
    bsz, L, d = x.shape
    k = w.shape[0]
    has_bias = b is not None
    grid = (bsz, d // block_d, L // block_l)
    in_specs = [
        pl.BlockSpec((1, block_l, block_d), lambda bb, dd, ll: (bb, ll, dd)),
        pl.BlockSpec((k, block_d), lambda bb, dd, ll: (0, dd)),
    ]
    args = [x, w]
    if has_bias:
        in_specs.append(pl.BlockSpec((1, block_d), lambda bb, dd, ll: (0, dd)))
        args.append(b.reshape(1, -1))
    else:
        in_specs.append(pl.BlockSpec((1, 1), lambda bb, dd, ll: (0, 0)))
        args.append(jnp.zeros((1, 1), jnp.float32))
    in_specs.append(
        pl.BlockSpec((1, k - 1, block_d), lambda bb, dd, ll: (bb, 0, dd)))
    args.append(x_prev)

    out_shapes = (
        jax.ShapeDtypeStruct((bsz, L, d), x.dtype),
        jax.ShapeDtypeStruct((bsz, k - 1, d), x.dtype),
    )
    out_specs = (
        pl.BlockSpec((1, block_l, block_d), lambda bb, dd, ll: (bb, ll, dd)),
        pl.BlockSpec((1, k - 1, block_d), lambda bb, dd, ll: (bb, 0, dd)),
    )
    return pl.pallas_call(
        functools.partial(_conv_kernel, bl=block_l, k=k, has_bias=has_bias),
        out_shape=out_shapes,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((k - 1, block_d), jnp.float32)],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="marca_causal_conv1d",
    )(*args)


def causal_conv1d(x, w, b=None, x_prev=None, block_d: int = 256,
                  block_l: int = 256, interpret: bool = True):
    """x (b, L, d); w (k, d); b (d,)|None; x_prev (b, k-1, d)|None.

    Returns (y (b, L, d), new_state (b, k-1, d)) matching
    kernels.ref.causal_conv1d.
    """
    bsz, L, d = x.shape
    k = w.shape[0]
    block_d = min(block_d, d)
    block_l = min(block_l, L)
    pad_l = (-L) % block_l
    pad_d = (-d) % block_d
    xp = jnp.pad(x, ((0, 0), (0, pad_l), (0, pad_d)))
    wp = jnp.pad(w, ((0, 0), (0, pad_d)))
    bp = None if b is None else jnp.pad(b, (0, pad_d))
    if x_prev is None:
        x_prev = jnp.zeros((bsz, k - 1, d), x.dtype)
    xprev_p = jnp.pad(x_prev, ((0, 0), (0, 0), (0, pad_d)))
    y, tail = _conv_padded(xp, wp, bp, xprev_p, block_d=block_d,
                           block_l=block_l, interpret=interpret)
    y = y[:, :L, :d]
    # new state = last k-1 *true* inputs (padding-safe reconstruction)
    full = jnp.concatenate([x_prev, x], axis=1)
    new_state = full[:, full.shape[1] - (k - 1):, :]
    return y, new_state
