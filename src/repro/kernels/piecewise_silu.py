"""Pallas TPU kernel: piecewise SiLU (MARCA SiLU-RCU mode).

The SiLU-RCU adds a range detector + constant unit to each PE and evaluates
a per-segment polynomial (paper eq. 3).  On the TPU VPU the range detector
is a chain of vector compares feeding selects, and the polynomial is two
FMAs -- everything stays on the 8x128 element-wise path, no divider and no
transcendental unit (the point of the paper's decomposition).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pallas_compat

from repro.core import approx

_LANES = pallas_compat.LANES
_DEFAULT_COLS = pallas_compat.DEFAULT_COLS
_DEFAULT_ROWS = pallas_compat.DEFAULT_ROWS


def _silu_kernel(x_ref, o_ref, *, variant: str):
    x = x_ref[...].astype(jnp.float32)
    if variant == "paper":
        y = approx.piecewise_silu_paper(x)
    else:
        y = approx.piecewise_silu(x)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("variant", "block_rows", "cols",
                                             "interpret"))
def piecewise_silu_2d(x, variant="ours", block_rows=_DEFAULT_ROWS,
                      cols=_DEFAULT_COLS, interpret=True):
    rows = x.shape[0]
    grid = (pl.cdiv(rows, block_rows),)
    return pl.pallas_call(
        functools.partial(_silu_kernel, variant=variant),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, cols), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((block_rows, cols), lambda r: (r, 0)),
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="marca_piecewise_silu",
    )(x)


def piecewise_silu(x, variant="ours", interpret=True):
    """Shape-polymorphic wrapper (flatten -> pad -> tile)."""
    n = x.size
    cols = _DEFAULT_COLS if n >= _DEFAULT_COLS else _LANES
    rows = -(-n // cols)
    pad = rows * cols - n
    flat = jnp.pad(x.reshape(-1), (0, pad))
    block_rows = min(_DEFAULT_ROWS, rows)
    y = piecewise_silu_2d(flat.reshape(rows, cols), variant=variant,
                          block_rows=block_rows, cols=cols,
                          interpret=interpret)
    return y.reshape(-1)[:n].reshape(x.shape)
