"""Pallas TPU kernel: fused single-token SSM decode step.

This is the serving-engine counterpart of kernels/selective_scan.py:
where the scan kernel fuses the recurrence over the *time* axis for
prefill/training, this kernel fuses the entire per-token chain the
engine's decode burst executes per layer:

    h' = exp(dt * A) (*) h + (dt * x) (*) B        state update (EW FMA)
    y  = sum_n C_n * h'_n + D * x                  output contraction
    out = y * silu(z)                              gate

MARCA's point (Fig. 1 / §4) is that this chain is element-wise with a
single tiny N=d_state reduction, so dispatching it as a dozen separate
XLA ops per layer per token pays kernel-launch + HBM round-trip for
every arrow in the chain.  Here the whole chain — including the fast
biased exp and the piecewise SiLU when approx mode is on — is one
kernel over the slot-pooled state: state in, token out, one launch.

Layout mirrors the scan kernel: channels D on lanes (128-aligned),
state N on sublanes; h is carried as (slots, N, D).  Grid is
(slots, D-blocks), both parallel — a decode step has no sequential
axis, which is exactly why it fuses so cleanly.

``interpret=True`` (the default) is the CPU fallback: the same kernel
body runs under the Pallas interpreter, so every CPU test exercises
the fused path; on real TPU callers pass interpret=False.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import approx, state_quant
from repro.kernels import pallas_compat


# ---------------------------------------------------------------------------
# Cell skeleton — MARCA's reconfigurable PE, expressed as code.
#
# Every recurrent decode cell this repo serves is the same three-phase
# shape (the paper's Fig. 1 regime):
#
#   state_update  — an element-wise FMA on the carried state
#                   (S6: exp(dt*A) (*) h + (dt*x) (*) B;
#                    mLSTM: f (*) C + i (*) k (x) v;  sLSTM: f (*) c + i*z)
#   contract      — a tiny reduction (or identity) producing the output
#                   (S6: sum_n C_n h_n;  mLSTM: q-query + normalizer;
#                    sLSTM: scalar memory, no reduction)
#   gate          — an element-wise epilogue
#                   (S6: D-skip + SiLU(z);  sLSTM: sigmoid output gate)
#
# The decomposed nonlinearities (fast biased exp, piecewise SiLU) plug
# into the phases via core.approx, so "reconfiguring" a PE is picking a
# phase function, exactly the paper's RCU modes.  Phase functions use
# ``...`` broadcasting so ONE implementation serves both the per-layer
# kernel's unbatched (N, BD) grid cell and the megakernel's batched
# (b, N, D) block — the two paths cannot drift.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CellSkeleton:
    """A recurrent decode cell as three pluggable phases.

    ``state_update(state, ins) -> state_new``;
    ``contract(state_new, ins) -> y``;
    ``gate(y, state_new, ins) -> y`` (None = identity).  ``state`` is an
    array or tuple of arrays; ``ins`` a dict of per-token inputs.

    ``dequant(ins) -> ins`` (None = identity) is a fourth, *leading*
    phase: when weights are stored quantized (cfg.weight_dtype="int8")
    the per-channel scale multiply expanding int8 codes into the f32
    operands the other phases consume runs here — inside the kernel, on
    the grid cell's own weight block — so weight bytes cross HBM at
    int8.  In MARCA terms, one more reconfigured PE mode ahead of the
    FMA."""
    name: str
    state_update: Callable
    contract: Callable
    gate: Optional[Callable] = None
    dequant: Optional[Callable] = None

    def __call__(self, state, ins):
        if self.dequant is not None:
            ins = self.dequant(ins)
        state_new = self.state_update(state, ins)
        y = self.contract(state_new, ins)
        if self.gate is not None:
            y = self.gate(y, state_new, ins)
        return y, state_new


@functools.lru_cache(maxsize=None)
def s6_cell(exp_impl: str, silu_impl: str, has_d: bool,
            has_z: bool, wq: bool = False) -> CellSkeleton:
    """The mamba/jamba selective-SSM cell.  State (..., N, D) f32; ins:
    x/dt (..., D), at (N, D) [A transposed], b/c (..., N), d (D,)|None,
    z (..., D)|None — all f32.

    ``wq=True``: ``at`` holds int8 codes cast to f32 and ``ins`` carries
    ``at_scale`` (D,) — the per-d_inner-channel absmax scales from
    core.weight_quant — which the dequant phase multiplies back in.  The
    broadcasting serves the per-layer kernel's (N, BD) block and the
    megakernel's (n, d_inner) slice with the same line, and the multiply
    is element-for-element the one ``weight_quant.dequantize_rows`` runs
    on the XLA path, so all step impls see bit-identical A."""
    exp = approx.get_exp(exp_impl)
    silu = approx.get_silu(silu_impl)

    def dequant(ins):
        out = dict(ins)
        out["at"] = ins["at"] * ins["at_scale"][..., None, :]
        return out

    def state_update(h, ins):
        da = exp(ins["dt"][..., None, :] * ins["at"])     # EW + "shift"
        dbx = ((ins["dt"] * ins["x"])[..., None, :]
               * ins["b"][..., :, None])                  # EW outer prod
        return da * h + dbx                               # EW FMA

    def contract(h_new, ins):
        # tiny N-reduction: y_d = sum_n C_n h_nd
        return jnp.sum(h_new * ins["c"][..., :, None], axis=-2)

    def gate(y, _state, ins):
        if has_d:
            y = y + ins["d"] * ins["x"]
        if has_z:
            y = y * silu(ins["z"])
        return y

    return CellSkeleton("s6", state_update, contract,
                        gate if (has_d or has_z) else None,
                        dequant if wq else None)


@functools.lru_cache(maxsize=None)
def mlstm_cell(dh: int) -> CellSkeleton:
    """The xLSTM matrix-memory cell.  State (C (..., dh, dh),
    n (..., dh), m (...,)); ins: q/k/v (..., dh), i/f (...,) — all f32.
    The gate stabilizers pin exact exp/log-sigmoid (approximating the
    max-subtracted exponents breaks the stabilization contract); the
    MARCA approximations enter through the block front-end instead."""
    def state_update(state, ins):
        C, n, m = state
        logf = jax.nn.log_sigmoid(ins["f"])
        m_new = jnp.maximum(logf + m, ins["i"])
        i_p = jnp.exp(ins["i"] - m_new)
        f_p = jnp.exp(logf + m - m_new)
        kv = ins["k"][..., :, None] * ins["v"][..., None, :]
        C = f_p[..., None, None] * C + i_p[..., None, None] * kv
        n = f_p[..., None] * n + i_p[..., None] * ins["k"]
        return (C, n, m_new)

    def contract(state, ins):
        C, n, _ = state
        qn = ins["q"] * (dh ** -0.5)
        num = jnp.einsum("...de,...d->...e", C, qn)
        den = jnp.abs(jnp.einsum("...d,...d->...", n, qn))
        return num / jnp.maximum(den, 1.0)[..., None]

    return CellSkeleton("mlstm", state_update, contract, None)


@functools.lru_cache(maxsize=None)
def slstm_cell() -> CellSkeleton:
    """The xLSTM scalar-memory cell.  State (c, n, m) each (..., nh, dh);
    ins: g (..., 4, nh, dh) combined pre-activations [z, i, f, o]."""
    def state_update(state, ins):
        c, n, m = state
        g = ins["g"]
        z_t = jnp.tanh(g[..., 0, :, :])
        i_t = g[..., 1, :, :]
        f_t = g[..., 2, :, :]
        logf = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(logf + m, i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(logf + m - m_new)
        c_new = f_p * c + i_p * z_t
        n_new = f_p * n + i_p
        return (c_new, n_new, m_new)

    def contract(state, _ins):
        # scalar memory: no reduction, the cell output IS the state
        return state[0]

    def gate(y, state, ins):
        _, n_new, _ = state
        o_t = jax.nn.sigmoid(ins["g"][..., 3, :, :])
        return o_t * y / jnp.maximum(n_new, 1.0)

    return CellSkeleton("slstm", state_update, contract, gate)


def _chain(h, x_ref, dt_ref, at_ref, at_scale_ref, b_ref, c_ref, d_ref,
           z_ref, *, exp_impl: str, silu_impl: str, has_d: bool,
           has_z: bool, wq: bool):
    """The fused per-token chain on one (slot, D-block) grid cell:
    block loads + f32 casts around the S6 cell skeleton.
    h (N, BD) f32 already dequantized; with ``wq`` the At block holds
    int8 codes and at_scale_ref the (1, BD) per-channel scales the
    cell's dequant phase expands them with.
    Returns (y (BD,), h_new (N, BD))."""
    cell = s6_cell(exp_impl, silu_impl, has_d, has_z, wq)
    ins = {
        "x": x_ref[0, :].astype(jnp.float32),          # (BD,)
        "dt": dt_ref[0, :].astype(jnp.float32),        # (BD,)
        "at": at_ref[...].astype(jnp.float32),         # (N, BD)
        "b": b_ref[0, :].astype(jnp.float32),          # (N,)
        "c": c_ref[0, :].astype(jnp.float32),          # (N,)
        "d": d_ref[0, :].astype(jnp.float32) if has_d else None,
        "z": z_ref[0, :].astype(jnp.float32) if has_z else None,
    }
    if wq:
        ins["at_scale"] = at_scale_ref[0, :].astype(jnp.float32)  # (BD,)
    return cell(h, ins)


def _step_kernel(h_ref, x_ref, dt_ref, at_ref, at_scale_ref, b_ref, c_ref,
                 d_ref, z_ref, y_ref, hout_ref, *, exp_impl: str,
                 silu_impl: str, has_d: bool, has_z: bool, wq: bool):
    h = h_ref[0].astype(jnp.float32)               # (N, BD)
    y, h_new = _chain(h, x_ref, dt_ref, at_ref, at_scale_ref, b_ref, c_ref,
                      d_ref, z_ref, exp_impl=exp_impl, silu_impl=silu_impl,
                      has_d=has_d, has_z=has_z, wq=wq)
    y_ref[0, :] = y.astype(y_ref.dtype)
    hout_ref[0] = h_new.astype(hout_ref.dtype)


def _step_kernel_q(h_ref, scale_ref, x_ref, dt_ref, at_ref, at_scale_ref,
                   b_ref, c_ref, d_ref, z_ref, y_ref, hout_ref,
                   scale_out_ref, *, exp_impl: str, silu_impl: str,
                   has_d: bool, has_z: bool, state_dtype: str, wq: bool):
    """Quantized-state variant: the int8/fp8 payload is dequantized on
    read and requantized on write *inside* the kernel, so the f32 state
    lives only in VMEM/registers — never in HBM.  Each grid cell owns
    one channel group's scale (scale blocking == channel blocking), so
    the running-absmax update needs no cross-block reduction."""
    s_in = scale_ref[0, 0]
    h = h_ref[0].astype(jnp.float32) * s_in        # dequant on read
    y, h_new = _chain(h, x_ref, dt_ref, at_ref, at_scale_ref, b_ref, c_ref,
                      d_ref, z_ref, exp_impl=exp_impl, silu_impl=silu_impl,
                      has_d=has_d, has_z=has_z, wq=wq)
    y_ref[0, :] = y.astype(y_ref.dtype)
    amax = jnp.max(jnp.abs(h_new))
    s_out = state_quant.update_scale(amax, s_in, state_dtype)
    hout_ref[0] = state_quant.encode(h_new / s_out, state_dtype)
    scale_out_ref[0, 0] = s_out


@functools.partial(
    jax.jit,
    static_argnames=("block_d", "exp_impl", "silu_impl", "interpret"))
def _step_padded(h, x_t, dt_t, at, at_scale, b_t, c_t, d_skip, z_t,
                 block_d: int, exp_impl: str, silu_impl: str,
                 interpret: bool):
    """All channel-dim inputs pre-padded: D % block_d == 0.  ``at_scale``
    (1, D) rides the same d_skip-style per-channel blocking; None means
    f32 weights (placeholder block, dequant phase compiled out)."""
    bsz, n, d_in = h.shape
    has_d = d_skip is not None
    has_z = z_t is not None
    wq = at_scale is not None
    grid = (bsz, d_in // block_d)

    def _row(_):
        return pl.BlockSpec((1, block_d), lambda bb, dd: (bb, dd))

    in_specs = [
        pl.BlockSpec((1, n, block_d), lambda bb, dd: (bb, 0, dd)),   # h
        _row("x"), _row("dt"),
        pl.BlockSpec((n, block_d), lambda bb, dd: (0, dd)),          # At
    ]
    args = [h, x_t, dt_t, at]
    if wq:
        in_specs.append(pl.BlockSpec((1, block_d), lambda bb, dd: (0, dd)))
        args.append(at_scale)
    else:
        in_specs.append(pl.BlockSpec((1, 1), lambda bb, dd: (0, 0)))
        args.append(jnp.zeros((1, 1), jnp.float32))
    in_specs += [
        pl.BlockSpec((1, n), lambda bb, dd: (bb, 0)),                # B_t
        pl.BlockSpec((1, n), lambda bb, dd: (bb, 0)),                # C_t
    ]
    args += [b_t, c_t]
    if has_d:
        in_specs.append(pl.BlockSpec((1, block_d), lambda bb, dd: (0, dd)))
        args.append(d_skip)
    else:
        in_specs.append(pl.BlockSpec((1, 1), lambda bb, dd: (0, 0)))
        args.append(jnp.zeros((1, 1), jnp.float32))
    if has_z:
        in_specs.append(_row("z"))
        args.append(z_t)
    else:
        in_specs.append(pl.BlockSpec((1, 1), lambda bb, dd: (0, 0)))
        args.append(jnp.zeros((1, 1), jnp.float32))

    out_shapes = (
        jax.ShapeDtypeStruct((bsz, d_in), x_t.dtype),
        jax.ShapeDtypeStruct((bsz, n, d_in), jnp.float32),
    )
    out_specs = (
        pl.BlockSpec((1, block_d), lambda bb, dd: (bb, dd)),
        pl.BlockSpec((1, n, block_d), lambda bb, dd: (bb, 0, dd)),
    )

    kernel = functools.partial(
        _step_kernel, exp_impl=exp_impl, silu_impl=silu_impl,
        has_d=has_d, has_z=has_z, wq=wq)

    return pl.pallas_call(
        kernel,
        out_shape=out_shapes,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name="marca_decode_step",
    )(*args)


@functools.partial(
    jax.jit,
    static_argnames=("block_d", "exp_impl", "silu_impl", "state_dtype",
                     "interpret"))
def _step_padded_q(h, h_scale, x_t, dt_t, at, at_scale, b_t, c_t, d_skip,
                   z_t, block_d: int, exp_impl: str, silu_impl: str,
                   state_dtype: str, interpret: bool):
    """Quantized-state launch: D % block_d == 0 and the scale array has
    exactly one entry per (slot, D-block).  ``at_scale`` as in
    ``_step_padded`` — W8A8 composes with the quantized state."""
    bsz, n, d_in = h.shape
    has_d = d_skip is not None
    has_z = z_t is not None
    wq = at_scale is not None
    g = d_in // block_d
    grid = (bsz, g)

    def _row(_):
        return pl.BlockSpec((1, block_d), lambda bb, dd: (bb, dd))

    in_specs = [
        pl.BlockSpec((1, n, block_d), lambda bb, dd: (bb, 0, dd)),   # h
        pl.BlockSpec((1, 1), lambda bb, dd: (bb, dd)),               # scale
        _row("x"), _row("dt"),
        pl.BlockSpec((n, block_d), lambda bb, dd: (0, dd)),          # At
    ]
    args = [h, h_scale, x_t, dt_t, at]
    if wq:
        in_specs.append(pl.BlockSpec((1, block_d), lambda bb, dd: (0, dd)))
        args.append(at_scale)
    else:
        in_specs.append(pl.BlockSpec((1, 1), lambda bb, dd: (0, 0)))
        args.append(jnp.zeros((1, 1), jnp.float32))
    in_specs += [
        pl.BlockSpec((1, n), lambda bb, dd: (bb, 0)),                # B_t
        pl.BlockSpec((1, n), lambda bb, dd: (bb, 0)),                # C_t
    ]
    args += [b_t, c_t]
    if has_d:
        in_specs.append(pl.BlockSpec((1, block_d), lambda bb, dd: (0, dd)))
        args.append(d_skip)
    else:
        in_specs.append(pl.BlockSpec((1, 1), lambda bb, dd: (0, 0)))
        args.append(jnp.zeros((1, 1), jnp.float32))
    if has_z:
        in_specs.append(_row("z"))
        args.append(z_t)
    else:
        in_specs.append(pl.BlockSpec((1, 1), lambda bb, dd: (0, 0)))
        args.append(jnp.zeros((1, 1), jnp.float32))

    out_shapes = (
        jax.ShapeDtypeStruct((bsz, d_in), x_t.dtype),
        jax.ShapeDtypeStruct((bsz, n, d_in),
                             state_quant.storage_dtype(state_dtype)),
        jax.ShapeDtypeStruct((bsz, g), jnp.float32),
    )
    out_specs = (
        pl.BlockSpec((1, block_d), lambda bb, dd: (bb, dd)),
        pl.BlockSpec((1, n, block_d), lambda bb, dd: (bb, 0, dd)),
        pl.BlockSpec((1, 1), lambda bb, dd: (bb, dd)),
    )

    kernel = functools.partial(
        _step_kernel_q, exp_impl=exp_impl, silu_impl=silu_impl,
        has_d=has_d, has_z=has_z, state_dtype=state_dtype, wq=wq)

    return pl.pallas_call(
        kernel,
        out_shape=out_shapes,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name="marca_decode_step_q",
    )(*args)


# ---------------------------------------------------------------------------
# Cross-layer megakernel launcher
# ---------------------------------------------------------------------------

def stacked_layer_launch(body, x0, stacked, out_structs, *,
                         interpret: bool | None = None,
                         name: str = "marca_megakernel"):
    """Run ``body`` once per layer inside a SINGLE Pallas launch.

    The layer axis becomes the kernel grid ((L,), semantics "arbitrary" —
    it is sequential: layer l reads the residual stream layer l-1 wrote).
    The residual stream is a *revisited output block*: its BlockSpec index
    map is constant, so Pallas keeps the same block resident across grid
    steps and the kernel carries ``x`` through it — seeded from ``x0``
    at l == 0.  Per-layer operands (weights + recurrent state) arrive as
    pytrees with a stacked leading L axis; each grid step sees its own
    (1, ...) slice with the leading axis dropped.

    The issue sketches a (L, slots, d-block) grid; slots and d stay folded
    into the block here because the in-body projections couple the full
    channel dimension (and bitwise identity with the per-layer path needs
    the matmuls at identical shapes).  On real TPU the intra-layer split
    is the obvious follow-on once weights are resident per-core.

    body(x, ins) -> (x_new, outs):  ``x`` (b, 1, d_model) residual stream;
    ``ins`` one layer's slice of ``stacked``; ``outs`` a flat list/tuple of
    arrays matching ``out_structs`` (ShapeDtypeStructs of the PER-LAYER
    shapes — the launch returns them stacked to (L, ...)).

    Returns (x_final, tuple(stacked_outs)).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    leaves, treedef = jax.tree.flatten(stacked)
    n_layers = leaves[0].shape[0]
    for lf in leaves:
        assert lf.shape[0] == n_layers, (lf.shape, n_layers)
    out_structs = tuple(out_structs)

    x_nz = (0,) * x0.ndim

    def _const_map(l):
        return x_nz

    in_specs = [pl.BlockSpec(x0.shape, _const_map)]
    for lf in leaves:
        rest = lf.shape[1:]
        in_specs.append(pl.BlockSpec(
            (1,) + rest,
            lambda l, _nz=(0,) * len(rest): (l,) + _nz))

    out_shapes = [jax.ShapeDtypeStruct(x0.shape, x0.dtype)]
    out_specs = [pl.BlockSpec(x0.shape, _const_map)]
    for s in out_structs:
        out_shapes.append(
            jax.ShapeDtypeStruct((n_layers,) + s.shape, s.dtype))
        out_specs.append(pl.BlockSpec(
            (1,) + s.shape,
            lambda l, _nz=(0,) * len(s.shape): (l,) + _nz))

    n_in = len(leaves)

    def kernel(x0_ref, *refs):
        in_refs = refs[:n_in]
        x_ref = refs[n_in]
        out_refs = refs[n_in + 1:]
        l = pl.program_id(0)

        @pl.when(l == 0)
        def _seed():
            x_ref[...] = x0_ref[...]

        x = x_ref[...]
        ins = treedef.unflatten([r[0] for r in in_refs])
        x_new, outs = body(x, ins)
        x_ref[...] = x_new.astype(x_ref.dtype)
        for o_ref, o in zip(out_refs, outs):
            o_ref[0] = o.astype(o_ref.dtype)

    res = pl.pallas_call(
        kernel,
        out_shape=tuple(out_shapes),
        grid=(n_layers,),
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name=name,
    )(x0, *leaves)
    return res[0], tuple(res[1:])


def selective_state_step_q(hq, h_scale, x_t, dt_t, A, B_t, C_t, D=None,
                           z_t=None, state_dtype: str = "int8",
                           exp_impl: str = "exact",
                           silu_impl: str = "exact",
                           a_scale=None,
                           interpret: bool | None = None):
    """Fused quantized-state decode step.  Same semantics as
    kernels.ref.selective_state_step_q.

    hq (b, d, n) int8/fp8 payload; h_scale (b, g) f32 with one scale per
    ``state_quant.D_BLOCK`` channel group; other args as in
    selective_state_step.  Returns (y (b, d), hq_new, scale_new (b, g)).

    The channel blocking is pinned to the scale grouping (block_d =
    min(D_BLOCK, d)), so dequant/requant stay local to one grid cell.
    Note: int8/fp8 HBM tiles want (32, 128) alignment on real TPU; the
    d_state sublane dim of small configs is below that, which costs
    padding, not correctness."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bsz, d_in, n = hq.shape
    block_d = min(state_quant.D_BLOCK, d_in)
    g = state_quant.n_groups(d_in)
    pad_d = g * block_d - d_in
    assert h_scale.shape == (bsz, g), (h_scale.shape, (bsz, g))

    def _pad_row(t):
        if t is None:
            return None
        return jnp.pad(t, ((0, 0), (0, pad_d)))

    hp = jnp.pad(hq.swapaxes(1, 2), ((0, 0), (0, 0), (0, pad_d)))
    at = jnp.pad(A.astype(jnp.float32), ((0, pad_d), (0, 0))).T  # (n, Dp)
    asp = (None if a_scale is None
           else jnp.pad(a_scale.astype(jnp.float32),
                        (0, pad_d)).reshape(1, -1))
    dp = (None if D is None
          else jnp.pad(D.astype(jnp.float32), (0, pad_d)).reshape(1, -1))

    y, hq_new, scale_new = _step_padded_q(
        hp, h_scale, _pad_row(x_t), _pad_row(dt_t), at, asp, B_t, C_t, dp,
        _pad_row(z_t), block_d=block_d, exp_impl=exp_impl,
        silu_impl=silu_impl, state_dtype=state_dtype, interpret=interpret)
    return (y[:, :d_in], hq_new[:, :, :d_in].swapaxes(1, 2), scale_new)


def selective_state_step(h, x_t, dt_t, A, B_t, C_t, D=None, z_t=None,
                         block_d: int = 512,
                         exp_impl: str = "exact", silu_impl: str = "exact",
                         a_scale=None,
                         interpret: bool | None = None):
    """Fused decode step.  Same semantics as kernels.ref.selective_state_step.

    h (b, d, n) f32 pooled state; x_t/dt_t (b, d); A (d, n); B_t/C_t (b, n);
    D (d,)|None; z_t (b, d)|None.
    With ``a_scale`` (d,) set, A holds int8 codes (cfg.weight_dtype) and
    the kernel's dequant phase expands them per channel in VMEM — the A
    matrix streams from HBM at one byte per entry.
    Returns (y (b, d) in x_t.dtype, h_new (b, d, n) f32).

    ``interpret=None`` resolves per backend: compiled on TPU, the Pallas
    interpreter elsewhere — so the serving hot path is never accidentally
    interpreted on the hardware the kernel targets.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bsz, d_in, n = h.shape
    block_d = min(block_d, d_in)
    pad_d = (-d_in) % block_d

    def _pad_row(t):
        if t is None:
            return None
        return jnp.pad(t, ((0, 0), (0, pad_d)))

    hp = jnp.pad(h.astype(jnp.float32).swapaxes(1, 2),
                 ((0, 0), (0, 0), (0, pad_d)))                  # (b, n, Dp)
    at = jnp.pad(A.astype(jnp.float32), ((0, pad_d), (0, 0))).T  # (n, Dp)
    asp = (None if a_scale is None
           else jnp.pad(a_scale.astype(jnp.float32),
                        (0, pad_d)).reshape(1, -1))
    dp = (None if D is None
          else jnp.pad(D.astype(jnp.float32), (0, pad_d)).reshape(1, -1))

    y, h_new = _step_padded(
        hp, _pad_row(x_t), _pad_row(dt_t), at, asp, B_t, C_t, dp,
        _pad_row(z_t), block_d=block_d, exp_impl=exp_impl,
        silu_impl=silu_impl, interpret=interpret)
    return y[:, :d_in], h_new[:, :, :d_in].swapaxes(1, 2)
