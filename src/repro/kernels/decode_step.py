"""Pallas TPU kernel: fused single-token SSM decode step.

This is the serving-engine counterpart of kernels/selective_scan.py:
where the scan kernel fuses the recurrence over the *time* axis for
prefill/training, this kernel fuses the entire per-token chain the
engine's decode burst executes per layer:

    h' = exp(dt * A) (*) h + (dt * x) (*) B        state update (EW FMA)
    y  = sum_n C_n * h'_n + D * x                  output contraction
    out = y * silu(z)                              gate

MARCA's point (Fig. 1 / §4) is that this chain is element-wise with a
single tiny N=d_state reduction, so dispatching it as a dozen separate
XLA ops per layer per token pays kernel-launch + HBM round-trip for
every arrow in the chain.  Here the whole chain — including the fast
biased exp and the piecewise SiLU when approx mode is on — is one
kernel over the slot-pooled state: state in, token out, one launch.

Layout mirrors the scan kernel: channels D on lanes (128-aligned),
state N on sublanes; h is carried as (slots, N, D).  Grid is
(slots, D-blocks), both parallel — a decode step has no sequential
axis, which is exactly why it fuses so cleanly.

``interpret=True`` (the default) is the CPU fallback: the same kernel
body runs under the Pallas interpreter, so every CPU test exercises
the fused path; on real TPU callers pass interpret=False.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import approx, state_quant
from repro.kernels import pallas_compat


def _chain(h, x_ref, dt_ref, at_ref, b_ref, c_ref, d_ref, z_ref, *,
           exp_impl: str, silu_impl: str, has_d: bool, has_z: bool):
    """The fused per-token chain on one (slot, D-block) grid cell.
    h (N, BD) f32 already dequantized; returns (y (BD,), h_new (N, BD))."""
    exp = approx.get_exp(exp_impl)
    silu = approx.get_silu(silu_impl)
    x = x_ref[0, :].astype(jnp.float32)            # (BD,)
    dt = dt_ref[0, :].astype(jnp.float32)          # (BD,)
    at = at_ref[...].astype(jnp.float32)           # (N, BD)
    b_t = b_ref[0, :].astype(jnp.float32)          # (N,)
    c_t = c_ref[0, :].astype(jnp.float32)          # (N,)
    da = exp(dt[None, :] * at)                     # (N, BD)  EW + "shift"
    dbx = (dt * x)[None, :] * b_t[:, None]         # (N, BD)  EW outer prod
    h_new = da * h + dbx                           # (N, BD)  EW FMA
    y = jnp.sum(h_new * c_t[:, None], axis=0)      # (BD,) tiny N-reduction
    if has_d:
        y = y + d_ref[0, :].astype(jnp.float32) * x
    if has_z:
        y = y * silu(z_ref[0, :].astype(jnp.float32))
    return y, h_new


def _step_kernel(h_ref, x_ref, dt_ref, at_ref, b_ref, c_ref, d_ref, z_ref,
                 y_ref, hout_ref, *, exp_impl: str, silu_impl: str,
                 has_d: bool, has_z: bool):
    h = h_ref[0].astype(jnp.float32)               # (N, BD)
    y, h_new = _chain(h, x_ref, dt_ref, at_ref, b_ref, c_ref, d_ref,
                      z_ref, exp_impl=exp_impl, silu_impl=silu_impl,
                      has_d=has_d, has_z=has_z)
    y_ref[0, :] = y.astype(y_ref.dtype)
    hout_ref[0] = h_new.astype(hout_ref.dtype)


def _step_kernel_q(h_ref, scale_ref, x_ref, dt_ref, at_ref, b_ref, c_ref,
                   d_ref, z_ref, y_ref, hout_ref, scale_out_ref, *,
                   exp_impl: str, silu_impl: str, has_d: bool, has_z: bool,
                   state_dtype: str):
    """Quantized-state variant: the int8/fp8 payload is dequantized on
    read and requantized on write *inside* the kernel, so the f32 state
    lives only in VMEM/registers — never in HBM.  Each grid cell owns
    one channel group's scale (scale blocking == channel blocking), so
    the running-absmax update needs no cross-block reduction."""
    s_in = scale_ref[0, 0]
    h = h_ref[0].astype(jnp.float32) * s_in        # dequant on read
    y, h_new = _chain(h, x_ref, dt_ref, at_ref, b_ref, c_ref, d_ref,
                      z_ref, exp_impl=exp_impl, silu_impl=silu_impl,
                      has_d=has_d, has_z=has_z)
    y_ref[0, :] = y.astype(y_ref.dtype)
    amax = jnp.max(jnp.abs(h_new))
    s_out = state_quant.update_scale(amax, s_in, state_dtype)
    hout_ref[0] = state_quant.encode(h_new / s_out, state_dtype)
    scale_out_ref[0, 0] = s_out


@functools.partial(
    jax.jit,
    static_argnames=("block_d", "exp_impl", "silu_impl", "interpret"))
def _step_padded(h, x_t, dt_t, at, b_t, c_t, d_skip, z_t,
                 block_d: int, exp_impl: str, silu_impl: str,
                 interpret: bool):
    """All channel-dim inputs pre-padded: D % block_d == 0."""
    bsz, n, d_in = h.shape
    has_d = d_skip is not None
    has_z = z_t is not None
    grid = (bsz, d_in // block_d)

    def _row(_):
        return pl.BlockSpec((1, block_d), lambda bb, dd: (bb, dd))

    in_specs = [
        pl.BlockSpec((1, n, block_d), lambda bb, dd: (bb, 0, dd)),   # h
        _row("x"), _row("dt"),
        pl.BlockSpec((n, block_d), lambda bb, dd: (0, dd)),          # At
        pl.BlockSpec((1, n), lambda bb, dd: (bb, 0)),                # B_t
        pl.BlockSpec((1, n), lambda bb, dd: (bb, 0)),                # C_t
    ]
    args = [h, x_t, dt_t, at, b_t, c_t]
    if has_d:
        in_specs.append(pl.BlockSpec((1, block_d), lambda bb, dd: (0, dd)))
        args.append(d_skip)
    else:
        in_specs.append(pl.BlockSpec((1, 1), lambda bb, dd: (0, 0)))
        args.append(jnp.zeros((1, 1), jnp.float32))
    if has_z:
        in_specs.append(_row("z"))
        args.append(z_t)
    else:
        in_specs.append(pl.BlockSpec((1, 1), lambda bb, dd: (0, 0)))
        args.append(jnp.zeros((1, 1), jnp.float32))

    out_shapes = (
        jax.ShapeDtypeStruct((bsz, d_in), x_t.dtype),
        jax.ShapeDtypeStruct((bsz, n, d_in), jnp.float32),
    )
    out_specs = (
        pl.BlockSpec((1, block_d), lambda bb, dd: (bb, dd)),
        pl.BlockSpec((1, n, block_d), lambda bb, dd: (bb, 0, dd)),
    )

    kernel = functools.partial(
        _step_kernel, exp_impl=exp_impl, silu_impl=silu_impl,
        has_d=has_d, has_z=has_z)

    return pl.pallas_call(
        kernel,
        out_shape=out_shapes,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name="marca_decode_step",
    )(*args)


@functools.partial(
    jax.jit,
    static_argnames=("block_d", "exp_impl", "silu_impl", "state_dtype",
                     "interpret"))
def _step_padded_q(h, h_scale, x_t, dt_t, at, b_t, c_t, d_skip, z_t,
                   block_d: int, exp_impl: str, silu_impl: str,
                   state_dtype: str, interpret: bool):
    """Quantized-state launch: D % block_d == 0 and the scale array has
    exactly one entry per (slot, D-block)."""
    bsz, n, d_in = h.shape
    has_d = d_skip is not None
    has_z = z_t is not None
    g = d_in // block_d
    grid = (bsz, g)

    def _row(_):
        return pl.BlockSpec((1, block_d), lambda bb, dd: (bb, dd))

    in_specs = [
        pl.BlockSpec((1, n, block_d), lambda bb, dd: (bb, 0, dd)),   # h
        pl.BlockSpec((1, 1), lambda bb, dd: (bb, dd)),               # scale
        _row("x"), _row("dt"),
        pl.BlockSpec((n, block_d), lambda bb, dd: (0, dd)),          # At
        pl.BlockSpec((1, n), lambda bb, dd: (bb, 0)),                # B_t
        pl.BlockSpec((1, n), lambda bb, dd: (bb, 0)),                # C_t
    ]
    args = [h, h_scale, x_t, dt_t, at, b_t, c_t]
    if has_d:
        in_specs.append(pl.BlockSpec((1, block_d), lambda bb, dd: (0, dd)))
        args.append(d_skip)
    else:
        in_specs.append(pl.BlockSpec((1, 1), lambda bb, dd: (0, 0)))
        args.append(jnp.zeros((1, 1), jnp.float32))
    if has_z:
        in_specs.append(_row("z"))
        args.append(z_t)
    else:
        in_specs.append(pl.BlockSpec((1, 1), lambda bb, dd: (0, 0)))
        args.append(jnp.zeros((1, 1), jnp.float32))

    out_shapes = (
        jax.ShapeDtypeStruct((bsz, d_in), x_t.dtype),
        jax.ShapeDtypeStruct((bsz, n, d_in),
                             state_quant.storage_dtype(state_dtype)),
        jax.ShapeDtypeStruct((bsz, g), jnp.float32),
    )
    out_specs = (
        pl.BlockSpec((1, block_d), lambda bb, dd: (bb, dd)),
        pl.BlockSpec((1, n, block_d), lambda bb, dd: (bb, 0, dd)),
        pl.BlockSpec((1, 1), lambda bb, dd: (bb, dd)),
    )

    kernel = functools.partial(
        _step_kernel_q, exp_impl=exp_impl, silu_impl=silu_impl,
        has_d=has_d, has_z=has_z, state_dtype=state_dtype)

    return pl.pallas_call(
        kernel,
        out_shape=out_shapes,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name="marca_decode_step_q",
    )(*args)


def selective_state_step_q(hq, h_scale, x_t, dt_t, A, B_t, C_t, D=None,
                           z_t=None, state_dtype: str = "int8",
                           exp_impl: str = "exact",
                           silu_impl: str = "exact",
                           interpret: bool | None = None):
    """Fused quantized-state decode step.  Same semantics as
    kernels.ref.selective_state_step_q.

    hq (b, d, n) int8/fp8 payload; h_scale (b, g) f32 with one scale per
    ``state_quant.D_BLOCK`` channel group; other args as in
    selective_state_step.  Returns (y (b, d), hq_new, scale_new (b, g)).

    The channel blocking is pinned to the scale grouping (block_d =
    min(D_BLOCK, d)), so dequant/requant stay local to one grid cell.
    Note: int8/fp8 HBM tiles want (32, 128) alignment on real TPU; the
    d_state sublane dim of small configs is below that, which costs
    padding, not correctness."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bsz, d_in, n = hq.shape
    block_d = min(state_quant.D_BLOCK, d_in)
    g = state_quant.n_groups(d_in)
    pad_d = g * block_d - d_in
    assert h_scale.shape == (bsz, g), (h_scale.shape, (bsz, g))

    def _pad_row(t):
        if t is None:
            return None
        return jnp.pad(t, ((0, 0), (0, pad_d)))

    hp = jnp.pad(hq.swapaxes(1, 2), ((0, 0), (0, 0), (0, pad_d)))
    at = jnp.pad(A.astype(jnp.float32), ((0, pad_d), (0, 0))).T  # (n, Dp)
    dp = (None if D is None
          else jnp.pad(D.astype(jnp.float32), (0, pad_d)).reshape(1, -1))

    y, hq_new, scale_new = _step_padded_q(
        hp, h_scale, _pad_row(x_t), _pad_row(dt_t), at, B_t, C_t, dp,
        _pad_row(z_t), block_d=block_d, exp_impl=exp_impl,
        silu_impl=silu_impl, state_dtype=state_dtype, interpret=interpret)
    return (y[:, :d_in], hq_new[:, :, :d_in].swapaxes(1, 2), scale_new)


def selective_state_step(h, x_t, dt_t, A, B_t, C_t, D=None, z_t=None,
                         block_d: int = 512,
                         exp_impl: str = "exact", silu_impl: str = "exact",
                         interpret: bool | None = None):
    """Fused decode step.  Same semantics as kernels.ref.selective_state_step.

    h (b, d, n) f32 pooled state; x_t/dt_t (b, d); A (d, n); B_t/C_t (b, n);
    D (d,)|None; z_t (b, d)|None.
    Returns (y (b, d) in x_t.dtype, h_new (b, d, n) f32).

    ``interpret=None`` resolves per backend: compiled on TPU, the Pallas
    interpreter elsewhere — so the serving hot path is never accidentally
    interpreted on the hardware the kernel targets.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bsz, d_in, n = h.shape
    block_d = min(block_d, d_in)
    pad_d = (-d_in) % block_d

    def _pad_row(t):
        if t is None:
            return None
        return jnp.pad(t, ((0, 0), (0, pad_d)))

    hp = jnp.pad(h.astype(jnp.float32).swapaxes(1, 2),
                 ((0, 0), (0, 0), (0, pad_d)))                  # (b, n, Dp)
    at = jnp.pad(A.astype(jnp.float32), ((0, pad_d), (0, 0))).T  # (n, Dp)
    dp = (None if D is None
          else jnp.pad(D.astype(jnp.float32), (0, pad_d)).reshape(1, -1))

    y, h_new = _step_padded(
        hp, _pad_row(x_t), _pad_row(dt_t), at, B_t, C_t, dp, _pad_row(z_t),
        block_d=block_d, exp_impl=exp_impl, silu_impl=silu_impl,
        interpret=interpret)
    return y[:, :d_in], h_new[:, :, :d_in].swapaxes(1, 2)
