"""Version shim for the Pallas TPU API.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``;
kernels import the name from here so both jax generations work.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or _pltpu.TPUCompilerParams
