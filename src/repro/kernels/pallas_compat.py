"""Version shim + shared tiling defaults for the Pallas TPU API.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``;
kernels import the name from here so both jax generations work.

The tiling constants are the single source for the element-wise kernel
wrappers (fast_exp, piecewise_silu): the VPU is 8x128 lanes, so blocks
are LANES-wide with DEFAULT_COLS/DEFAULT_ROWS sizing the 2D tiles the
shape-polymorphic wrappers pad to.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or _pltpu.TPUCompilerParams

#: VPU lane width — min last-dim tile for element-wise kernels
LANES = 128

#: default 2D tile the flatten->pad->tile wrappers reshape to
DEFAULT_COLS = 1024
DEFAULT_ROWS = 256
