"""Pallas TPU kernel: fused selective-SSM scan (MARCA's core, TPU-native).

MARCA's three insights, re-derived for the TPU memory hierarchy:

  * C1 (reduction-alternative PE array): the SSM recurrence is a chain of
    element-wise ops with *no* reduction over a contraction dim (the only
    reduction is the tiny N=d_state sum for y_t).  Running it through
    MXU-shaped HLOs wastes the systolic array exactly like the paper's
    "1/16 normalized speed" on Tensor Cores.  This kernel keeps the whole
    chain on the VPU (8x128 element-wise datapath = the reduction-disabled
    PE array) while matmuls elsewhere in the block stay on the MXU.

  * C2 (reusable nonlinear unit): exp inside the recurrence is the fast
    biased exponential (bitcast shift) and the output gate uses the
    piecewise SiLU — both plain element-wise sequences, selectable per call
    (``exp_impl`` / ``silu_impl``; "exact" uses the VPU transcendental).

  * C3 (inter-operation buffer management): the hidden state h and the
    intermediates dA/dBx never leave VMEM between time steps.  One HBM pass
    over x/dt/B/C/z in, one pass of y out.  The XLA associative-scan
    baseline writes/reads O(B·L·D·N) intermediates — this kernel's traffic
    is O(B·L·D), an N-fold (16x) reduction, mirroring the paper's -49%
    DRAM traffic inter-op result.

Layout: channels D on lanes (128-aligned), state N on sublanes.  Grid is
(batch, D-blocks, L-chunks) with the time axis marked "arbitrary" so the
VMEM scratch h (N, BD) persists across L-chunks for a given (b, d) block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import pallas_compat

from repro.core import approx


def _scan_kernel(x_ref, dt_ref, at_ref, b_ref, c_ref, d_ref, z_ref, h0_ref,
                 y_ref, hlast_ref, h_scr, *, bl: int, l_true: int,
                 exp_impl: str, silu_impl: str, has_z: bool, has_d: bool):
    l_idx = pl.program_id(2)
    exp = approx.get_exp(exp_impl)
    silu = approx.get_silu(silu_impl)

    @pl.when(l_idx == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    at = at_ref[...].astype(jnp.float32)            # (N, BD)
    if has_d:
        d_skip = d_ref[0, :].astype(jnp.float32)    # (BD,)

    def body(t, h):
        x_t = x_ref[0, t, :].astype(jnp.float32)    # (BD,)
        dt_t = dt_ref[0, t, :].astype(jnp.float32)  # (BD,)
        b_t = b_ref[0, t, :].astype(jnp.float32)    # (N,)
        c_t = c_ref[0, t, :].astype(jnp.float32)    # (N,)
        da = exp(dt_t[None, :] * at)                # (N, BD)  EW + "shift"
        dbx = (dt_t * x_t)[None, :] * b_t[:, None]  # (N, BD)  EW outer prod
        # Padded tail must be a no-op on h even under approximate exp
        # (fast_exp(0) != 1 exactly, which would decay h through padding).
        valid = (l_idx * bl + t) < l_true
        da = jnp.where(valid, da, 1.0)
        dbx = jnp.where(valid, dbx, 0.0)
        h = da * h + dbx                            # (N, BD)  EW FMA
        y_t = jnp.sum(h * c_t[:, None], axis=0)     # (BD,) tiny N-reduction
        if has_d:
            y_t = y_t + d_skip * x_t
        if has_z:
            z_t = z_ref[0, t, :].astype(jnp.float32)
            y_t = y_t * silu(z_t)
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bl, body, h_scr[...])
    h_scr[...] = h
    hlast_ref[0] = h.astype(hlast_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_d", "block_l", "l_true", "exp_impl", "silu_impl",
                     "interpret"))
def _selective_scan_padded(x, dt, at, b, c, d_skip, z, h0,
                           block_d: int, block_l: int, l_true: int,
                           exp_impl: str, silu_impl: str, interpret: bool):
    """All inputs pre-padded: L % block_l == 0, D % block_d == 0."""
    bsz, L, d_in = x.shape
    n = at.shape[0]
    has_z = z is not None
    has_d = d_skip is not None
    grid = (bsz, d_in // block_d, L // block_l)

    def _ld(_):
        return pl.BlockSpec((1, block_l, block_d), lambda bb, dd, ll: (bb, ll, dd))

    in_specs = [
        _ld("x"), _ld("dt"),
        pl.BlockSpec((n, block_d), lambda bb, dd, ll: (0, dd)),      # At
        pl.BlockSpec((1, block_l, n), lambda bb, dd, ll: (bb, ll, 0)),  # B
        pl.BlockSpec((1, block_l, n), lambda bb, dd, ll: (bb, ll, 0)),  # C
    ]
    args = [x, dt, at, b, c]
    if has_d:
        in_specs.append(pl.BlockSpec((1, block_d), lambda bb, dd, ll: (0, dd)))
        args.append(d_skip)
    else:
        in_specs.append(pl.BlockSpec((1, 1), lambda bb, dd, ll: (0, 0)))
        args.append(jnp.zeros((1, 1), jnp.float32))
    if has_z:
        in_specs.append(_ld("z"))
        args.append(z)
    else:
        in_specs.append(pl.BlockSpec((1, 1), lambda bb, dd, ll: (0, 0)))
        args.append(jnp.zeros((1, 1), jnp.float32))
    in_specs.append(
        pl.BlockSpec((1, n, block_d), lambda bb, dd, ll: (bb, 0, dd)))  # h0
    args.append(h0)

    out_shapes = (
        jax.ShapeDtypeStruct((bsz, L, d_in), x.dtype),
        jax.ShapeDtypeStruct((bsz, n, d_in), jnp.float32),
    )
    out_specs = (
        pl.BlockSpec((1, block_l, block_d), lambda bb, dd, ll: (bb, ll, dd)),
        pl.BlockSpec((1, n, block_d), lambda bb, dd, ll: (bb, 0, dd)),
    )

    kernel = functools.partial(
        _scan_kernel, bl=block_l, l_true=l_true, exp_impl=exp_impl,
        silu_impl=silu_impl, has_z=has_z, has_d=has_d)

    y, h_last = pl.pallas_call(
        kernel,
        out_shape=out_shapes,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((n, block_d), jnp.float32)],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="marca_selective_scan",
    )(*args)
    return y, h_last


def selective_scan(x, dt, A, B, C, D=None, z=None, h0=None,
                   block_d: int = 256, block_l: int = 128,
                   exp_impl: str = "exact", silu_impl: str = "exact",
                   interpret: bool = True):
    """Fused selective scan.  Same semantics as kernels.ref.selective_scan.

    x, dt: (b, L, d); A: (d, n); B, C: (b, L, n); D: (d,)|None;
    z: (b, L, d)|None; h0: (b, d, n)|None.
    Returns (y (b, L, d), h_last (b, d, n) f32).
    """
    bsz, L, d_in = x.shape
    n = A.shape[1]
    block_d = min(block_d, d_in)
    block_l = min(block_l, L)
    pad_l = (-L) % block_l
    pad_d = (-d_in) % block_d

    def _pad3(t):
        if t is None:
            return None
        return jnp.pad(t, ((0, 0), (0, pad_l), (0, pad_d)))

    xp = _pad3(x)
    dtp = _pad3(dt)
    zp = _pad3(z)
    bp = jnp.pad(B, ((0, 0), (0, pad_l), (0, 0)))
    cp = jnp.pad(C, ((0, 0), (0, pad_l), (0, 0)))
    at = jnp.pad(A, ((0, pad_d), (0, 0))).T            # (n, Dp)
    dp = (None if D is None
          else jnp.pad(D, (0, pad_d)).reshape(1, -1))  # (1, Dp)
    h0p = (jnp.zeros((bsz, n, d_in + pad_d), jnp.float32) if h0 is None
           else jnp.pad(h0.astype(jnp.float32).swapaxes(1, 2),
                        ((0, 0), (0, 0), (0, pad_d))))

    y, h_last = _selective_scan_padded(
        xp, dtp, at, bp, cp, dp, zp, h0p,
        block_d=block_d, block_l=block_l, l_true=L,
        exp_impl=exp_impl, silu_impl=silu_impl, interpret=interpret)
    y = y[:, :L, :d_in]
    h_last = h_last[:, :, :d_in].swapaxes(1, 2)        # (b, d, n)
    return y, h_last


# ---------------------------------------------------------------------------
# Trainable wrapper: Pallas forward + chunk-recompute backward (custom VJP).
#
# XLA autodiff of any scan implementation stacks O(B*L*D*N) residuals to HBM
# (EXPERIMENTS.md §Perf Cell M: the 6.6 TB/chip wall).  This wrapper saves
# only the *inputs* plus chunk-boundary states, and the backward pass
# recomputes h within each chunk while running the reverse recurrence:
#
#   ghat_t = C_t (x) ybar_t + dA_{t+1} * ghat_{t+1}
#   dtbar  += sum_n ghat*(h_{t-1}*dA*A + x*B);  Abar += sum_l ghat*h_{t-1}*dA*dt
#   xbar   += sum_n ghat*dt*B;  Bbar += sum_d ghat*dt*x;  Cbar = sum_d h*ybar
#
# Traffic: forward streams + one recompute — the MARCA inter-op-BM story
# applied to training.  D-skip and z-gate are handled OUTSIDE (plain jnp,
# autodiff-able), so the custom VJP covers exactly the recurrence core.
# ---------------------------------------------------------------------------


def _fwd_boundaries(x, dt, A, B, C, chunk):
    """Forward over chunks, returning (y, h_last, h_bounds) where
    h_bounds[i] is the state ENTERING chunk i."""
    from repro.core import selective_scan as css
    bsz, L, d = x.shape
    n = A.shape[1]
    nc = -(-L // chunk)
    pad = nc * chunk - L

    def _pad(t):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))

    xs = tuple(_pad(t.astype(jnp.float32)).reshape(
        bsz, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
        for t in (x, dt, B, C))
    Af = A.astype(jnp.float32)

    def step(h, inp):
        xc, dtc, Bc, Cc = inp
        y, h_new = css._scan_inner_seq(xc, dtc, Bc, Cc, Af, h, jnp.exp)
        return h_new, (y, h)

    h0 = jnp.zeros((bsz, d, n), jnp.float32)
    h_last, (ys, h_bounds) = jax.lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1).reshape(bsz, nc * chunk, d)[:, :L]
    return y, h_last, h_bounds          # h_bounds (nc, b, d, n)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def selective_scan_trainable(x, dt, A, B, C, chunk: int = 128,
                             interpret: bool = True):
    """Recurrence core with kernel forward + memory-lean backward.
    x/dt (b,L,d); A (d,n); B/C (b,L,n) -> (y (b,L,d) f32, h_last f32)."""
    y, h_last = selective_scan(x, dt, A, B, C, interpret=interpret)
    return y.astype(jnp.float32), h_last


def _sst_fwd(x, dt, A, B, C, chunk, interpret):
    y, h_last = selective_scan(x, dt, A, B, C, interpret=interpret)
    return ((y.astype(jnp.float32), h_last), (x, dt, A, B, C))


def _sst_bwd(chunk, interpret, res, cts):
    from repro.core.selective_scan import _affine_combine as css_affine
    x, dt, A, B, C = res
    ybar, hbar_last = cts
    bsz, L, d = x.shape
    n = A.shape[1]
    nc = -(-L // chunk)
    pad = nc * chunk - L
    # recompute chunk-boundary states (one extra forward, streams only)
    _, _, h_bounds = _fwd_boundaries(x, dt, A, B, C, chunk)

    def _pad(t):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))

    def _chunks(t):
        return _pad(t.astype(jnp.float32)).reshape(
            bsz, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs, dts, Bs, Cs, ybars = map(_chunks, (x, dt, B, C, ybar))
    Af = A.astype(jnp.float32)

    def chunk_bwd(ghat, inp):
        """Reverse over one chunk.  ghat (b,d,n) = dL/dh at chunk end."""
        xc, dtc, Bc, Cc, ybc, h_in = inp
        # rematerialize h_t within the chunk (chunk-sized, not L-sized)
        dA = jnp.exp(dtc[..., None] * Af)                  # (b,ck,d,n)
        dBx = (dtc * xc)[..., None] * Bc[:, :, None, :]
        Acum, Bcum = jax.lax.associative_scan(
            css_affine, (dA, dBx), axis=1)
        h_all = Acum * h_in[:, None] + Bcum                # h_t per step
        h_prev = jnp.concatenate([h_in[:, None], h_all[:, :-1]], axis=1)

        def step(g, t):
            # t runs reversed within the chunk
            ghat_t = Cc[:, t][:, None, :] * ybc[:, t][..., None] + g
            dA_t = dA[:, t]
            gh_prev = ghat_t * dA_t                        # to t-1
            ddA = ghat_t * h_prev[:, t]                    # bar(dA_t)
            ddt = jnp.sum(ddA * dA_t * Af[None], -1) \
                + jnp.sum(ghat_t * Bc[:, t][:, None, :], -1) * xc[:, t]
            dAbar = jnp.sum(ddA * dA_t * dtc[:, t][..., None], 0)
            dx = jnp.sum(ghat_t * Bc[:, t][:, None, :], -1) * dtc[:, t]
            dB = jnp.sum(ghat_t * (dtc[:, t] * xc[:, t])[..., None], 1)
            dC = jnp.sum(h_all[:, t] * ybc[:, t][..., None], 1)
            return gh_prev, (ddt, dAbar, dx, dB, dC)

        ghat_in, outs = jax.lax.scan(step, ghat,
                                     jnp.arange(chunk - 1, -1, -1))
        ddt_r, dAbar_c, dx_r, dB_r, dC_r = outs           # (ck, ...) reversed
        rev = jnp.arange(chunk - 1, -1, -1)
        return ghat_in, (ddt_r[rev].swapaxes(0, 1),
                         dAbar_c.sum(0),
                         dx_r[rev].swapaxes(0, 1),
                         dB_r[rev].swapaxes(0, 1),
                         dC_r[rev].swapaxes(0, 1))

    ghat_L = hbar_last.astype(jnp.float32)
    rev_idx = jnp.arange(nc - 1, -1, -1)
    ghat0, outs = jax.lax.scan(
        chunk_bwd, ghat_L,
        tuple(t[rev_idx] for t in (xs, dts, Bs, Cs, ybars, h_bounds)))
    ddt_c, dA_c, dx_c, dB_c, dC_c = outs                  # (nc, ...) reversed

    def _join(t):
        return t[rev_idx].swapaxes(0, 1).reshape(
            bsz, nc * chunk, *t.shape[3:])[:, :L]

    dxo = _join(dx_c).astype(x.dtype)
    ddto = _join(ddt_c).astype(dt.dtype)
    dBo = _join(dB_c).astype(B.dtype)
    dCo = _join(dC_c).astype(C.dtype)
    dAo = dA_c.sum(0).astype(A.dtype)
    return (dxo, ddto, dAo, dBo, dCo)


selective_scan_trainable.defvjp(_sst_fwd, _sst_bwd)
