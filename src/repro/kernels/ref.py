"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's tests sweep shapes/dtypes and assert allclose against the
function here.  These are also the implementations XLA runs when a model is
configured with ``kernel_impl="xla"``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import approx


# ---------------------------------------------------------------------------
# Element-wise nonlinearities (MARCA §5): the oracle IS the jnp algorithm.
# ---------------------------------------------------------------------------

def fast_exp(x, b_shift=approx.FAST_EXP_B_SHIFT, c=0.0):
    return approx.fast_exp(x, b_shift, c)


def our_exp(x):
    return approx.our_exp(x)


def piecewise_silu(x):
    return approx.piecewise_silu(x)


def piecewise_silu_paper(x):
    return approx.piecewise_silu_paper(x)


# ---------------------------------------------------------------------------
# Selective scan (Mamba S6 recurrence) — the reference semantics.
# ---------------------------------------------------------------------------

def selective_scan(x, dt, A, B, C, D=None, z=None, h0=None,
                   exp_impl: str = "exact", silu_impl: str = "exact"):
    """Sequential reference of the selective-SSM recurrence.

    Shapes:
      x, dt:  (batch, L, d)      -- dt already softplus'd
      A:      (d, n)             -- negative real
      B, C:   (batch, L, n)
      D:      (d,) or None       -- skip connection
      z:      (batch, L, d) or None -- SiLU gate
      h0:     (batch, d, n) or None -- initial state
    Returns (y, h_last): y (batch, L, d) in x.dtype, h_last (batch, d, n) f32.

      h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) B_t^T
      y_t = h_t C_t + D * x_t ;  out_t = y_t * silu(z_t)
    """
    exp = approx.get_exp(exp_impl)
    silu = approx.get_silu(silu_impl)
    bsz, L, d = x.shape
    n = A.shape[1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    h_init = (jnp.zeros((bsz, d, n), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp          # (b,d) (b,d) (b,n) (b,n)
        dA = exp(dt_t[..., None] * Af)     # (b,d,n)
        dBx = (dt_t * x_t)[..., None] * B_t[:, None, :]
        h = dA * h + dBx
        y_t = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y_t

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    h_last, ys = jax.lax.scan(step, h_init, xs)
    y = jnp.moveaxis(ys, 0, 1)             # (b, L, d)
    if D is not None:
        y = y + D.astype(jnp.float32)[None, None, :] * xf
    if z is not None:
        y = y * silu(z.astype(jnp.float32))
    return y.astype(x.dtype), h_last


def selective_state_step(h, x_t, dt_t, A, B_t, C_t, D=None, z_t=None,
                         exp_impl: str = "exact", silu_impl: str = "exact"):
    """Single decode step.  h (b,d,n) f32; x_t/dt_t (b,d); B_t/C_t (b,n)."""
    exp = approx.get_exp(exp_impl)
    silu = approx.get_silu(silu_impl)
    xf = x_t.astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    dA = exp(dtf[..., None] * A.astype(jnp.float32))
    dBx = (dtf * xf)[..., None] * B_t.astype(jnp.float32)[:, None, :]
    h = dA * h + dBx
    y = jnp.einsum("bdn,bn->bd", h, C_t.astype(jnp.float32))
    if D is not None:
        y = y + D.astype(jnp.float32)[None, :] * xf
    if z_t is not None:
        y = y * silu(z_t.astype(jnp.float32))
    return y.astype(x_t.dtype), h


def selective_state_step_q(hq, h_scale, x_t, dt_t, A, B_t, C_t, D=None,
                           z_t=None, state_dtype: str = "int8",
                           exp_impl: str = "exact",
                           silu_impl: str = "exact"):
    """Quantized-state decode step (oracle for the fused q-kernel).

    hq (b,d,n) int8/fp8 payload, h_scale (b,g) f32 group scales (see
    core.state_quant).  Dequantize -> f32 step -> requantize with the
    decayed-running-absmax scale update; the f32 state exists only
    between those two lines.  Returns (y, hq_new, scale_new)."""
    from repro.core import state_quant
    h = state_quant.dequantize_h(hq, h_scale)
    y, h_new = selective_state_step(h, x_t, dt_t, A, B_t, C_t, D=D,
                                    z_t=z_t, exp_impl=exp_impl,
                                    silu_impl=silu_impl)
    hq_new, scale_new = state_quant.quantize_h(h_new, state_dtype,
                                               prev_scale=h_scale)
    return y, hq_new, scale_new


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (Mamba short conv).
# ---------------------------------------------------------------------------

def causal_conv1d(x, w, b=None, x_prev=None):
    """x (batch, L, d), w (k, d) depthwise causal, optional bias (d,).

    x_prev (batch, k-1, d) supplies state for chunked/streaming use.
    Returns (y, new_state) with y same shape as x.
    """
    bsz, L, d = x.shape
    k = w.shape[0]
    if x_prev is None:
        x_prev = jnp.zeros((bsz, k - 1, d), x.dtype)
    xp = jnp.concatenate([x_prev, x], axis=1)        # (b, L+k-1, d)
    y = jnp.zeros((bsz, L, d), jnp.float32)
    for i in range(k):
        y = y + xp[:, i:i + L, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    new_state = xp[:, L:, :]
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Attention (causal, GQA) — oracle for the flash kernel.
# ---------------------------------------------------------------------------

def attention(q, k, v, causal=True, scale=None, kv_seg=None):
    """q (b, lq, hq, dh); k/v (b, lk, hkv, dh); GQA by head repetition.

    Returns (b, lq, hq, dh).  Computed in f32 with full materialization --
    only usable for small L (that is the point of the flash kernel).
    """
    b, lq, hq, dh = q.shape
    hkv = k.shape[2]
    rep = hq // hkv
    if scale is None:
        scale = dh ** -0.5
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kf) * scale
    if causal:
        lk = k.shape[1]
        mask = jnp.tril(jnp.ones((lq, lk), bool), k=lk - lq)
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return o.astype(q.dtype)
