"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis maps to
DCN, so the sharding rules keep parameters off it (DESIGN.md §4).

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py does this automatically)")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_local_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many devices exist (tests)."""
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(f"need {need} devices, have {len(devices)}")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_serving_mesh(tp: int = 2, axis: str = "model"):
    """1-D tensor-parallel mesh for the serving engine
    (``EngineConfig.mesh``): ``tp`` devices on the "model" axis, so the
    default ShardingRules put stacked weights (ffn/heads/vocab) and the
    pool's TP-interior cache leaves on it, while slot (batch) axes stay
    replicated — admission/eviction scatters touch every shard locally.
    """
    if tp < 1:
        raise ValueError(f"tp must be >= 1; got {tp}")
    devices = jax.devices()
    if len(devices) < tp:
        raise RuntimeError(
            f"serving mesh ({axis}={tp}) needs {tp} devices, have "
            f"{len(devices)}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={tp} "
            "(CPU) or on a host with enough accelerators")
    return jax.make_mesh((tp,), (axis,), devices=devices[:tp])
