import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines: jax locks the device count on first init.
# The dry-run (and ONLY the dry-run) fakes 512 host devices so the
# production meshes (16x16 single-pod, 2x16x16 multi-pod) can be built.
"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape x mesh) cell:
  1. build the production mesh,
  2. construct abstract params / optimizer state / caches
     (ShapeDtypeStructs with NamedShardings — zero allocation),
  3. ``jax.jit(step, in_shardings=..., out_shardings=...).lower(...)``
     then ``.compile()``,
  4. record memory_analysis, cost_analysis, and the collective schedule
     (parsed from the post-SPMD HLO) into a JSON cache that
     benchmarks/roofline.py and EXPERIMENTS.md read.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import shapes as shp
from repro.configs.zoo import ASSIGNED
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.parallel import sharding
from repro.parallel.sharding import ShardingRules

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def rules_for(cfg, shape: shp.ShapeSpec, overrides=None) -> ShardingRules:
    """Shape-dependent rules (DESIGN.md §4):
      * train/prefill: activations additionally sharded over `model` on
        d_model (scan-carry residency; required to fit 16 GB at
        65k tokens/device),
      * long-context batch=1: shard along sequence instead of batch."""
    kw = {}
    if shape.kind in ("train", "prefill"):
        kw["act_embed"] = "model"
    if shape.name == "long_500k":
        kw.update(sharding.LONG_CONTEXT_OVERRIDES)
    if overrides:
        kw.update(overrides)
    return ShardingRules(**kw)


def config_for(cfg, shape) -> "configs.ModelConfig":
    """Production defaults per shape kind: int8 KV cache for transformer
    decode (halves+ cache HBM; fits the MHA decode_32k cells — see
    EXPERIMENTS.md §Perf KV iteration)."""
    if shape.kind == "decode" and cfg.family == "transformer" \
            and cfg.kv_cache_dtype == "model":
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    return cfg


def optimizer_for(cfg) -> AdamWConfig:
    """arctic-480b needs sub-f32 moments to fit (DESIGN.md §4)."""
    if cfg.name.startswith("arctic"):
        return AdamWConfig(moment_dtype="bfloat16")
    return AdamWConfig()


def _qtensor_sharding(mesh, q):
    """Flat-block int8 moments: shard dim0 over (data, model) if divisible."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = [a for a in ("data", "model") if a in mesh.axis_names]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if q.shape[0] % n == 0:
        return NamedSharding(mesh, P(tuple(axes)))
    return NamedSharding(mesh, P())


def opt_shardings(mesh, rules, params_p, opt_abstract):
    """Moments follow param sharding; QTensor blocks shard flat."""
    from repro.optim import quantized_state as qs
    from jax.sharding import NamedSharding, PartitionSpec as P
    p_sh = sharding.tree_shardings(params_p, mesh, rules)

    def per_moment(tree):
        def one(ps, leaf):
            if qs.is_qtensor(leaf):
                return qs.QTensor(_qtensor_sharding(mesh, leaf.q),
                                  NamedSharding(mesh, P()), leaf.shape)
            return ps
        return jax.tree.map(one, p_sh, tree,
                            is_leaf=lambda x: qs.is_qtensor(x) or hasattr(
                                x, "spec"))

    return type(opt_abstract)(
        NamedSharding(mesh, P()),
        per_moment(opt_abstract.mu), per_moment(opt_abstract.nu))


def build_cell(cfg, shape: shp.ShapeSpec, mesh, rules):
    """Returns (jitted_fn, example_args_abstract) for the cell."""
    from jax.sharding import NamedSharding

    params_p = registry.abstract_params(cfg)
    params = sharding.tree_values(params_p)
    p_sh = sharding.tree_shardings(params_p, mesh, rules)
    ocfg = optimizer_for(cfg)

    def to_sharding(axes_tree, struct_tree):
        return jax.tree.map(
            lambda ax, s: NamedSharding(
                mesh, sharding.spec_for_shape(s.shape, ax, mesh, rules)),
            axes_tree, struct_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))

    if shape.kind == "train":
        batch = registry.batch_struct(cfg, shape.global_batch, shape.seq_len)
        b_sh = to_sharding(registry.batch_axes(cfg, batch), batch)
        opt_abs = jax.eval_shape(lambda p: adamw_init(p, ocfg), params)
        o_sh = opt_shardings(mesh, rules, params_p, opt_abs)

        def train_step(p, opt, b):
            (loss, metrics), grads = jax.value_and_grad(
                lambda q: registry.loss_fn(cfg, q, b), has_aux=True)(p)
            p, opt, om = adamw_update(grads, opt, p, ocfg)
            metrics.update(om)
            return p, opt, metrics

        fn = jax.jit(train_step,
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
        return fn, (params, opt_abs, batch)

    cache_len = shape.seq_len + cfg.img_tokens    # vlm: image prefix in cache
    if shape.kind == "prefill":
        batch = registry.batch_struct(cfg, shape.global_batch,
                                      shape.seq_len, with_labels=False)
        b_sh = to_sharding(registry.batch_axes(cfg, batch), batch)
        cache_p = registry.abstract_cache(cfg, shape.global_batch,
                                          cache_len)
        cache = sharding.tree_values(cache_p)
        c_sh = sharding.tree_shardings(cache_p, mesh, rules)

        def prefill_step(p, c, b):
            return registry.prefill(cfg, p, c, b)

        fn = jax.jit(prefill_step, in_shardings=(p_sh, c_sh, b_sh),
                     out_shardings=(None, c_sh), donate_argnums=(1,))
        return fn, (params, cache, batch)

    # decode
    batch = registry.decode_batch_struct(cfg, shape.global_batch)
    b_sh = to_sharding(registry.batch_axes(cfg, batch), batch)
    cache_p = registry.abstract_cache(cfg, shape.global_batch, cache_len)
    cache = sharding.tree_values(cache_p)
    c_sh = sharding.tree_shardings(cache_p, mesh, rules)

    def serve_step(p, c, b):
        logits, new_c = registry.decode_step(cfg, p, c, b)
        return jnp.argmax(logits[:, -1], axis=-1), new_c

    fn = jax.jit(serve_step, in_shardings=(p_sh, c_sh, b_sh),
                 out_shardings=(None, c_sh), donate_argnums=(1,))
    return fn, (params, cache, batch)


def analyze(compiled, lowered, cfg, shape, mesh) -> dict:
    from repro.launch import hlo_cost
    chips = mesh.devices.size
    out: dict = {"chips": int(chips)}
    # XLA's own numbers (while bodies counted once) kept for reference
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        out["xla_flops_raw"] = float(ca.get("flops", 0.0) or 0.0)
        out["xla_bytes_raw"] = float(ca.get("bytes accessed", 0.0) or 0.0)
    except Exception:
        pass

    try:
        mem = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                out[f"mem_{k}"] = int(v)
        out["mem_per_device_gb"] = round(
            (out.get("mem_argument_size_in_bytes", 0)
             + out.get("mem_temp_size_in_bytes", 0)
             + out.get("mem_output_size_in_bytes", 0)
             - out.get("mem_alias_size_in_bytes", 0)) / 1e9, 3)
    except Exception as e:
        out["mem_error"] = repr(e)

    hlo = compiled.as_text()
    # static analysis with loop trip counts (per-partition numbers)
    cost = hlo_cost.analyze(hlo)
    out["hlo_flops"] = cost.flops * chips          # totals across chips
    out["hlo_bytes"] = cost.bytes * chips
    out["hlo_transcendentals"] = cost.transcendentals * chips
    out["collective_bytes"] = cost.collective_bytes * chips
    out["collective_by_kind"] = {k: float(v * chips)
                                 for k, v in cost.coll_by_kind.items()}
    out["collective_counts"] = {k: int(v)
                                for k, v in cost.coll_count.items()}
    out["unknown_trip_whiles"] = cost.unknown_trip_whiles
    out["op_census"] = hlo_analysis.op_census(hlo)
    out["hlo_size_chars"] = len(hlo)

    n = registry.count_params(cfg)
    n_act = registry.count_params(cfg, active_only=True)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    out["n_params"] = int(n)
    out["n_params_active"] = int(n_act)
    out["model_flops"] = float(mult * n_act * tokens)
    # memory-side floor: one pass over params (+cache for decode) per step
    bytes_per_param = 2.0
    min_bytes = n * bytes_per_param
    if shape.kind == "decode":
        hkv, dh = cfg.n_kv_heads, cfg.head_dim
        kv_bytes = (2 * cfg.n_layers * hkv * dh * shape.seq_len
                    * shape.global_batch * 2.0)
        min_bytes += kv_bytes if cfg.family == "transformer" else 0
    out["min_bytes_floor"] = float(min_bytes)
    out["memory_fraction"] = (min_bytes / out["hlo_bytes"]
                              if out["hlo_bytes"] else 0.0)
    rf = hlo_analysis.roofline_terms(
        out["hlo_flops"], out["hlo_bytes"], out["collective_bytes"], chips,
        out["model_flops"])
    out["roofline"] = {
        "compute_s": rf.compute_s, "memory_s": rf.memory_s,
        "collective_s": rf.collective_s, "dominant": rf.dominant,
        "useful_flops_ratio": rf.useful_flops_ratio,
        "roofline_fraction": rf.roofline_fraction,
    }
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: str = OUT_DIR, overrides=None, tag: str = "",
             cfg_overrides=None) -> dict:
    cfg = configs.get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = shp.SHAPES[shape_name]
    cfg = config_for(cfg, shape)
    reason = shp.skip_reason(cfg, shape_name)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "tag": tag}
    if reason:
        result["status"] = "skipped"
        result["reason"] = reason
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = rules_for(cfg, shape, overrides)
    t0 = time.time()
    try:
        with sharding.use_mesh(mesh, rules):
            fn, args = build_cell(cfg, shape, mesh, rules)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            result.update(analyze(compiled, lowered, cfg, shape, mesh))
            result["status"] = "ok"
            result["t_lower_s"] = round(t_lower, 1)
            result["t_compile_s"] = round(t_compile, 1)
    except Exception as e:
        result["status"] = "error"
        result["error"] = repr(e)[:2000]
        result["traceback"] = traceback.format_exc()[-4000:]
    return result


def cell_path(out_dir, arch, shape_name, mesh_kind, tag=""):
    suffix = f"__{tag}" if tag else ""
    return os.path.join(
        out_dir, f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(shp.SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out-dir", default=OUT_DIR)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    for arch in archs:
        cfg = configs.get_config(arch)
        shape_names = ([args.shape] if args.shape
                       else shp.applicable_shapes(cfg) + [
                           s for s in shp.SHAPES
                           if shp.skip_reason(cfg, s)])
        for shape_name in shape_names:
            for mesh_kind in meshes:
                path = cell_path(args.out_dir, arch, shape_name, mesh_kind,
                                 args.tag)
                if args.skip_existing and os.path.exists(path):
                    print(f"[dryrun] skip existing {path}")
                    continue
                print(f"[dryrun] {arch} x {shape_name} x {mesh_kind} ...",
                      flush=True)
                res = run_cell(arch, shape_name, mesh_kind, args.out_dir,
                               tag=args.tag)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                status = res["status"]
                extra = ""
                if status == "ok":
                    r = res["roofline"]
                    extra = (f" dominant={r['dominant']} "
                             f"frac={r['roofline_fraction']:.3f} "
                             f"compile={res['t_compile_s']}s")
                elif status == "error":
                    extra = " " + res["error"][:200]
                print(f"[dryrun]   -> {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
