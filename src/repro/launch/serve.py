"""Serving launcher CLI: load a checkpoint (or fresh init), serve batched
generation requests from a synthetic request stream.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba-130m \
      --smoke --requests 8 --max-new 32
"""
import argparse
import dataclasses
import time

import jax

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.models import registry
from repro.parallel import sharding
from repro.runtime.serve import ServeConfig, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0,
                    help="sample from the k highest logits (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = off)")
    ap.add_argument("--state-dtype", default=None,
                    choices=["f32", "bf16", "int8", "fp8"],
                    help="pooled decode-state storage dtype; int8 "
                         "multiplies slot capacity ~4x")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch)
    if args.smoke:
        cfg = configs.smoke_variant(cfg)
        cfg = dataclasses.replace(cfg, vocab=256, dtype="float32")
    params = sharding.tree_values(
        registry.init_params(cfg, jax.random.key(0)))
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        (params, _, _), step = mgr.restore((params, None, None))
        print(f"[serve] restored step {step} from {args.ckpt_dir}")

    srv = Server(cfg, params, ServeConfig(
        batch_slots=args.batch_slots,
        max_seq=args.prompt_len + args.max_new + 8,
        temperature=args.temperature,
        top_k=args.top_k, top_p=args.top_p,
        state_dtype=args.state_dtype))

    ds = SyntheticLM(vocab=cfg.vocab, seq_len=args.prompt_len, seed=1)
    done = 0
    t0 = time.perf_counter()
    batch_idx = 0
    while done < args.requests:
        n = min(args.batch_slots, args.requests - done)
        prompts = ds.batch_at(batch_idx, 0, 1, n)["tokens"]
        out = srv.generate(prompts, max_new=args.max_new)
        done += n
        batch_idx += 1
        print(f"[serve] batch {batch_idx}: {n} requests -> "
              f"{out.shape[1]} tokens each")
    dt = time.perf_counter() - t0
    total = done * args.max_new
    print(f"[serve] {done} requests, {total} tokens, {dt:.2f}s "
          f"({total / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
