"""Static cost analysis of post-optimization HLO text with loop trip counts.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, which makes
scan-over-layers programs look ~n_layers x cheaper than they are (and
collectives inside the scanned body disappear from the totals).  This module
parses the HLO text into its computation call graph and accumulates

  * flops            — dots exactly (2*M*N*K from dot dims + shapes),
                       element-wise/reduce approximately (1 flop/element),
  * hbm bytes        — operands+outputs of fusion-boundary ops only
                       (fusion interiors live in registers/VMEM),
  * collective bytes — operand sizes of all-gather/all-reduce/
                       reduce-scatter/all-to-all/collective-permute,

each multiplied by the product of enclosing ``while`` trip counts (parsed
from backend_config known_trip_count or the loop condition's compare
constant).  Numbers are per-partition (post-SPMD HLO is per-device): exactly
what the per-chip roofline terms need.

Validated in tests/test_hlo_cost.py against hand-computable programs
(matmul in fori_loop, scanned layers, psum loops).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

#: ops that move no HBM bytes themselves
_NO_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "opt-barrier", "partition-id", "replica-id", "get-dimension-size",
    "copy-start", "copy-done", "async-start", "async-update", "async-done",
    "all-gather-done", "all-reduce-done", "collective-permute-done",
    "send", "send-done", "recv", "recv-done", "domain", "iota",
}

_SHAPE_ELEM_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(
    r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
# shape is lazily matched up to the first " opcode(" — tuple shapes contain
# parens/spaces but never "word(" sequences, so this is unambiguous.
_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")


def _shape_dims(shape_str):
    """'bf16[8,128]{1,0}' -> ('bf16', [8,128]); tuples -> list of those."""
    out = []
    for dt, dims in _SHAPE_ELEM_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",")] if dims else []
        out.append((dt, d))
    return out


def _shape_bytes(shape_str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel(shape_str) -> int:
    total = 0
    for _, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    rest: str                  # operands + attrs raw text
    operands: list
    is_root: bool = False

    def attr(self, key):
        m = re.search(key + r"=\{([^}]*)\}", self.rest)
        return m.group(1) if m else None

    def callee(self, key):
        m = re.search(key + r"=%?([\w.\-]+)", self.rest)
        return m.group(1) if m else None


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: list


def parse_hlo(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER_RE.match(line)
            if m:
                cur = Computation(m.group(2), bool(m.group(1)), [])
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            root, name, shape, opcode, rest = m.groups()
            ops = re.findall(r"%([\w.\-]+)", rest.split(")")[0])
            cur.instrs.append(Instr(name, shape, opcode, rest, ops,
                                    bool(root)))
    return comps


def _dot_flops(instr: Instr, shapes: dict) -> float:
    """2 * prod(output dims) * prod(lhs contracting dim sizes)."""
    out_elems = _numel(instr.shape)
    lhs = instr.operands[0] if instr.operands else None
    lhs_shape = shapes.get(lhs)
    contract = instr.attr("lhs_contracting_dims")
    k = 1
    if lhs_shape and contract:
        dims = _shape_dims(lhs_shape)
        if dims:
            _, ldims = dims[0]
            for ci in contract.split(","):
                ci = ci.strip()
                if ci and int(ci) < len(ldims):
                    k *= ldims[int(ci)]
    return 2.0 * out_elems * k


def _trip_count(instr: Instr, comps: dict) -> int:
    m = re.search(r'known_trip_count[^0-9]*(\d+)', instr.rest)
    if m:
        return int(m.group(1))
    cond_name = instr.callee("condition")
    cond = comps.get(cond_name)
    if cond:
        consts = []
        for ins in cond.instrs:
            if ins.opcode == "constant":
                mc = re.match(r"(-?\d+)\)", ins.rest)
                if mc:
                    consts.append(int(mc.group(1)))
        pos = [c for c in consts if c > 0]
        if pos:
            return max(pos)
    return 1


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collective_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_count: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    bytes_by_op: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    flops_by_op: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    unknown_trip_whiles: int = 0

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] += v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] += int(v * mult)
        for k, v in other.bytes_by_op.items():
            self.bytes_by_op[k] += v * mult
        for k, v in other.flops_by_op.items():
            self.flops_by_op[k] += v * mult
        self.unknown_trip_whiles += other.unknown_trip_whiles


_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "logistic", "sine", "cosine", "exponential-minus-one",
                   "log-plus-one", "atan2", "cbrt", "erf"}

_SLICERS = {"dynamic-slice", "gather", "slice"}


def _fusion_io_bytes(ins: Instr, caller_shapes: dict, comps: dict):
    """(read, write) bytes of a fusion call.

    * operands consumed *only through slice/gather ops* inside the fusion
      count at the sliced size (scanned bodies slice per-iteration windows
      from stacked tensors);
    * a root dynamic-update-slice into a same-shaped operand is the
      in-place accumulator pattern (loop-carried stacking): the write is
      the update region and the aliased buffer operand is not re-read.
    """
    out_bytes = _shape_bytes(ins.shape)
    callee = ins.callee("calls")
    comp = comps.get(callee)
    if comp is None:
        return (sum(_shape_bytes(caller_shapes[o]) for o in ins.operands
                    if o in caller_shapes), out_bytes)
    param_names = {}
    for i2 in comp.instrs:
        if i2.opcode == "parameter":
            m = re.match(r"(\d+)\)", i2.rest)
            if m:
                param_names[int(m.group(1))] = i2.name
    interior = {i2.name: i2 for i2 in comp.instrs}
    root = next((i2 for i2 in comp.instrs if i2.is_root), None)
    # in-place accumulator: root DUS -> write = update size; buffer not read
    acc_param = None
    if root is not None and root.opcode == "dynamic-update-slice" \
            and root.operands:
        upd = root.operands[1] if len(root.operands) > 1 else None
        out_bytes = _shape_bytes(interior[upd].shape) \
            if upd in interior else out_bytes
        buf = root.operands[0]
        acc_param = buf if interior.get(buf, Instr("", "", "", "", [])
                                        ).opcode == "parameter" else None
    read = 0.0
    for idx, o in enumerate(ins.operands):
        if o not in caller_shapes:
            continue
        full = _shape_bytes(caller_shapes[o])
        pname = param_names.get(idx)
        if pname is None:
            read += full
            continue
        if pname == acc_param:
            continue                      # aliased accumulator buffer
        consumers = [i2 for i2 in comp.instrs if pname in i2.operands]
        if consumers and all(c.opcode in _SLICERS for c in consumers):
            read += sum(_shape_bytes(c.shape) for c in consumers)
        else:
            read += full
    return read, out_bytes


def analyze(text: str) -> HloCost:
    comps = parse_hlo(text)
    shapes_by_comp = {cn: {i.name: i.shape for i in c.instrs}
                      for cn, c in comps.items()}
    memo_flops: dict[str, HloCost] = {}

    def interior_flops(cname: str) -> HloCost:
        """flops-only cost of a fusion interior (bytes don't escape)."""
        if cname in memo_flops:
            return memo_flops[cname]
        c = comps[cname]
        shapes = shapes_by_comp[cname]
        cost = HloCost()
        for ins in c.instrs:
            cost.add(_instr_flops(ins, shapes, interior_flops))
        memo_flops[cname] = cost
        return cost

    def _instr_flops(ins: Instr, shapes, rec) -> HloCost:
        cost = HloCost()
        op = ins.opcode
        if op == "dot":
            df = _dot_flops(ins, shapes)
            cost.flops += df
            cost.flops_by_op["dot"] += df
        elif op == "convolution":
            # 2 * out_elems * kernel_elems/out_feature heuristic
            df = 2.0 * _numel(ins.shape) * 32
            cost.flops += df
            cost.flops_by_op["convolution"] += df
        elif op == "fusion":
            callee = ins.callee("calls")
            if callee in comps:
                cost.add(rec(callee))
        elif op in ("reduce", "reduce-window", "scatter", "select-and-scatter"):
            in_elems = sum(_numel(shapes.get(o, "f32[]"))
                           for o in ins.operands[:1])
            cost.flops += in_elems
            cost.flops_by_op["reduce"] += in_elems
        elif op in _TRANSCENDENTAL:
            n = _numel(ins.shape)
            cost.flops += n
            cost.transcendentals += n
            cost.flops_by_op["transcendental"] += n
        elif op in ("add", "subtract", "multiply", "divide", "maximum",
                    "minimum", "compare", "select", "and", "or", "xor",
                    "negate", "abs", "floor", "ceil", "round-nearest-afz",
                    "round-nearest-even", "clamp", "sign", "remainder",
                    "shift-left", "shift-right-logical",
                    "shift-right-arithmetic", "atan2"):
            cost.flops += _numel(ins.shape)
            cost.flops_by_op["elementwise"] += _numel(ins.shape)
        return cost

    memo_full: dict[str, HloCost] = {}

    def full_cost(cname: str) -> HloCost:
        """flops + bytes + collectives of a top-level computation."""
        if cname in memo_full:
            return memo_full[cname]
        c = comps[cname]
        shapes = shapes_by_comp[cname]
        cost = HloCost()
        for ins in c.instrs:
            op = ins.opcode
            base = op.rstrip(".0123456789")
            if base.endswith("-start"):
                base = base[:-6]
            # --- collectives ---
            if base in COLLECTIVES:
                ob = sum(_shape_bytes(shapes[o]) for o in ins.operands
                         if o in shapes)
                if ob == 0:
                    ob = _shape_bytes(ins.shape)
                cost.collective_bytes += ob
                cost.coll_by_kind[base] += ob
                cost.coll_count[base] += 1
                cost.bytes += ob  # they also move HBM
                cost.bytes_by_op[base] += ob
                continue
            # --- control flow ---
            if op == "while":
                trips = _trip_count(ins, comps)
                if trips == 1:
                    cost.unknown_trip_whiles += 1
                body = ins.callee("body")
                cond = ins.callee("condition")
                sub = HloCost()
                if body in comps:
                    sub.add(full_cost(body))
                if cond in comps:
                    sub.add(full_cost(cond))
                cost.add(sub, mult=trips)
                continue
            if op == "conditional":
                branches = re.findall(r"%([\w.\-]+)",
                                      ins.attr("branch_computations") or "")
                subs = [full_cost(b) for b in branches if b in comps]
                if subs:
                    biggest = max(subs, key=lambda s: s.flops + s.bytes)
                    cost.add(biggest)
                continue
            if op == "call":
                callee = ins.callee("to_apply")
                if callee in comps:
                    cost.add(full_cost(callee))
                continue
            # --- flops ---
            cost.add(_instr_flops(ins, shapes, interior_flops))
            # --- bytes at fusion boundaries ---
            if op not in _NO_BYTES:
                if op in ("dynamic-slice", "gather", "slice"):
                    # only the sliced/gathered region moves, not the operand
                    tot = 2 * _shape_bytes(ins.shape)
                elif op == "dynamic-update-slice":
                    # in-place update: the update region moves (read+write)
                    upd = (ins.operands[1] if len(ins.operands) > 1
                           else None)
                    ub = _shape_bytes(shapes.get(upd, ins.shape))
                    tot = 2 * ub
                elif op == "scatter":
                    upd = (ins.operands[2] if len(ins.operands) > 2
                           else None)
                    ub = _shape_bytes(shapes.get(upd, ins.shape))
                    tot = 2 * ub + _shape_bytes(ins.shape)
                elif op == "fusion":
                    fr, fw = _fusion_io_bytes(ins, shapes, comps)
                    tot = fr + fw
                else:
                    ob = sum(_shape_bytes(shapes[o]) for o in ins.operands
                             if o in shapes)
                    tot = ob + _shape_bytes(ins.shape)
                cost.bytes += tot
                cost.bytes_by_op[op] += tot
        memo_full[cname] = cost
        return cost

    entry = next((c.name for c in comps.values() if c.is_entry), None)
    if entry is None:
        return HloCost()
    # fusion interiors must not also be counted as top-level computations:
    # full_cost is only invoked from the entry's call graph.
    return full_cost(entry)
