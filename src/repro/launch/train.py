"""Training launcher CLI.

Single-host CPU (tests/examples):
  PYTHONPATH=src python -m repro.launch.train --arch mamba-130m \
      --preset tiny --steps 100

Production mesh (TPU pod or the 512-fake-device dry environment):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b \
      --mesh single --global-batch 256 --seq 4096 ...

On a real multi-host TPU deployment this process runs once per host after
``jax.distributed.initialize()``; the data pipeline shards by
(process_index, process_count) and the checkpoint manager writes per-host
shards — both already structured for that (see their docstrings).
"""
import argparse
import dataclasses

import jax

from repro import configs
from repro.optim import AdamWConfig
from repro.parallel import sharding
from repro.runtime.train_loop import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--weight-decay", type=float, default=0.1)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--moment-dtype", default="float32",
                    choices=["float32", "bfloat16", "int8"])
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi", "local"])
    ap.add_argument("--scan-impl", default=None)
    ap.add_argument("--dtype", default=None)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch)
    over = {}
    if args.scan_impl:
        over["scan_impl"] = args.scan_impl
    if args.dtype:
        over["dtype"] = args.dtype
    if over:
        cfg = dataclasses.replace(cfg, **over)

    mesh = None
    rules = None
    if args.mesh in ("single", "multi"):
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        rules = sharding.ShardingRules(act_embed="model")
    elif args.mesh == "local":
        from repro.launch.mesh import make_local_mesh
        n = jax.device_count()
        mesh = make_local_mesh((max(n // 2, 1), min(2, n)),
                               ("data", "model"))
        rules = sharding.ShardingRules()

    tcfg = TrainConfig(
        total_steps=args.steps, warmup_steps=args.warmup,
        global_batch=args.global_batch, seq_len=args.seq,
        ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
        log_every=args.log_every, grad_accum=args.grad_accum,
        grad_compression=args.grad_compression,
        optimizer=AdamWConfig(lr=args.lr, weight_decay=args.weight_decay,
                              moment_dtype=args.moment_dtype))
    trainer = Trainer(cfg, tcfg, mesh=mesh, rules=rules)
    _, _, losses = trainer.run(resume=not args.no_resume)
    print(f"[launch.train] {args.arch}: loss {losses[0]:.4f} -> "
          f"{losses[-1]:.4f} ({len(losses)} steps)")


if __name__ == "__main__":
    main()
