"""HLO text analysis: collective operand bytes, op census, roofline terms.

``collective_stats(hlo_text)`` parses the post-SPMD HLO, builds a symbol
table of instruction shapes, and sums *operand* sizes of every collective
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute)
— exactly the quantity the roofline collective term needs (cost_analysis
does not report it).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# "%name = bf16[1,2,3]{...} opcode(" or tuple "( ... )"
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*?\)|[\w]+\[[\d,]*\]\S*)\s+"
    r"([\w\-]+)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[8,128]' or tuple '(f32[2], s32[])' -> total bytes."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict
    total_bytes: int

    def __str__(self):
        rows = [f"  {k:<20} n={self.count_by_kind[k]:<5} "
                f"{self.bytes_by_kind[k] / 1e9:.3f} GB"
                for k in sorted(self.bytes_by_kind)]
        return "\n".join(rows + [f"  {'TOTAL':<20} "
                                 f"{self.total_bytes / 1e9:.3f} GB"])


def collective_stats(hlo_text: str) -> CollectiveStats:
    shapes: dict[str, str] = {}
    collect_lines: list[tuple[str, str]] = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape_str, opcode = m.groups()
        shapes[name] = shape_str
        base = opcode.rstrip(".0123456789")
        if base.endswith("-start"):
            base = base[:-6]
        if base in COLLECTIVES:
            collect_lines.append((base, line))

    bytes_by_kind: dict = defaultdict(int)
    count_by_kind: dict = defaultdict(int)
    for kind, line in collect_lines:
        # operands: %name tokens inside the call parens
        call = line.split("(", 1)[1]
        ops = re.findall(r"%([\w.\-]+)", call)
        ob = 0
        for o in ops:
            if o in shapes:
                ob += _shape_bytes(shapes[o])
        if ob == 0:
            # fallback: use the op's own (output) shape
            m = _DEF_RE.match(line)
            ob = _shape_bytes(m.group(2))
        bytes_by_kind[kind] += ob
        count_by_kind[kind] += 1
    return CollectiveStats(dict(bytes_by_kind), dict(count_by_kind),
                           sum(bytes_by_kind.values()))


def op_census(hlo_text: str, top: int = 15) -> dict:
    """Histogram of HLO opcodes (fusion-level, post-optimization)."""
    census: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            census[m.group(3).rstrip(".0123456789")] += 1
    return dict(sorted(census.items(), key=lambda kv: -kv[1])[:top])


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e targets; see EXPERIMENTS.md §Roofline)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    chips: int
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound time: how close the step is to the
        compute roofline on useful FLOPs."""
        if self.bound_time_s == 0:
            return 0.0
        useful_s = self.model_flops / (self.chips * PEAK_FLOPS)
        return useful_s / self.bound_time_s


def roofline_terms(hlo_flops: float, hlo_bytes: float,
                   collective_bytes: float, chips: int,
                   model_flops: float = 0.0) -> Roofline:
    """The three terms in seconds.  flops/bytes are totals across the
    program (cost_analysis convention); collective bytes likewise."""
    return Roofline(
        compute_s=hlo_flops / (chips * PEAK_FLOPS),
        memory_s=hlo_bytes / (chips * HBM_BW),
        collective_s=collective_bytes / (chips * ICI_BW),
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
        collective_bytes=collective_bytes, chips=chips,
        model_flops=model_flops)
