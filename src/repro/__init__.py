"""repro: MARCA (ICCAD '24) reproduced as a multi-pod JAX/TPU framework.

Entry points: repro.configs.get_config, repro.models.registry,
repro.runtime.train_loop.Trainer, repro.runtime.serve.Server,
repro.launch.{train,serve,dryrun}.  See README.md / DESIGN.md.
"""
__version__ = "1.0.0"
