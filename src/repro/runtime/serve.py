"""Batched serving runtime: prefill + decode with fixed batch slots
(continuous-batching lite).

``Server`` owns jit'd prefill/decode step functions and a slot table; new
requests are admitted into free slots (their cache region re-prefilled),
finished requests retire their slot.  Greedy or temperature sampling.
On the production mesh the same functions lower with the decode sharding
rules (see launch/dryrun.py serve_step cells)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.parallel import sharding


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 4
    max_seq: int = 256
    temperature: float = 0.0
    seed: int = 0


class Server:
    def __init__(self, cfg, params, scfg: ServeConfig):
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        self._prefill = jax.jit(
            lambda p, c, b: registry.prefill(cfg, p, c, b))
        self._decode = jax.jit(
            lambda p, c, b: registry.decode_step(cfg, p, c, b))
        self._key = jax.random.key(scfg.seed)

    def _sample(self, logits):
        """logits (b, 1, V) -> tokens (b, 1)."""
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits[:, -1:, :], axis=-1)
        self._key, k = jax.random.split(self._key)
        return jax.random.categorical(
            k, logits[:, -1:, :] / self.scfg.temperature, axis=-1)

    def generate(self, prompts: np.ndarray, max_new: int = 32,
                 eos_id: Optional[int] = None) -> np.ndarray:
        """prompts (b, Lp) int32 -> (b, max_new) generated ids.  b must be
        <= batch_slots; all prompts same length (left-dense)."""
        b, lp = prompts.shape
        cache = sharding.tree_values(
            registry.init_cache(self.cfg, b, self.scfg.max_seq))
        logits, cache = self._prefill(self.params, cache,
                                      {"tokens": jnp.asarray(prompts)})
        tok = self._sample(logits[:, lp - 1:lp, :].astype(jnp.float32))
        out = [tok]
        done = np.zeros((b,), bool)
        for _ in range(max_new - 1):
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": tok})
            tok = self._sample(logits.astype(jnp.float32)[:, -1:, :])
            out.append(tok)
            if eos_id is not None:
                done |= np.asarray(tok[:, 0] == eos_id)
                if done.all():
                    break
        return np.concatenate([np.asarray(t) for t in out], axis=1)
