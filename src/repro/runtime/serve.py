"""Batched serving: thin compatibility wrapper over the continuous-
batching Engine (runtime/engine.py).

``Server.generate`` keeps the original static-batch API — same-length
prompts, b <= batch_slots, (b, max_new) output — but internally submits
each row as an independent request to the engine, so the same jit'd
prefill/decode functions and slot pool serve both entry points.  New
code should use ``Engine`` directly: per-request ``SamplingParams``
(temperature / top-k / top-p / seed / stop ids / budget as data — one
jit cache for heterogeneous traffic), streaming callbacks,
cancellation, priorities, variable-length prompts, arrival traces.

Migration notes (PR 5 generation-API redesign):
  * ``EngineConfig.temperature`` is gone — sampling is per request via
    ``Engine.submit(prompt, SamplingParams(...))``.  ``ServeConfig``
    keeps its engine-wide ``temperature``/``top_k``/``top_p`` fields
    and maps them onto a per-request SamplingParams here, so existing
    Server callers see unchanged behavior (greedy by default).
  * Sampled streams are per-request-seeded (derived from
    ``ServeConfig.seed`` and the row index), so a Server batch is
    reproducible regardless of slot scheduling.

Behavioral note vs the old static loop: with an ``eos_id`` the engine
stops each row at its own EOS and frees the slot; rows that finish early
are right-padded with ``eos_id`` so the rectangular output shape is
preserved (the old loop kept generating until all rows finished)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.prefix_cache import PrefixCacheConfig
from repro.runtime.sampling import SamplingParams


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 4
    max_seq: int = 256
    # engine-wide sampling defaults, applied to every generate() row as
    # its per-request SamplingParams (legacy surface; per-request
    # control lives on Engine.submit)
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    # pooled recurrent-state storage dtype override (cfg.state_dtype):
    # "int8"/"fp8" multiply slot capacity ~4x; None keeps the model cfg
    state_dtype: Optional[str] = None
    # prompt-prefix state cache (EngineConfig.prefix_cache): None
    # disables; a PrefixCacheConfig makes admissions sharing a cached
    # block-aligned prefix restore the snapshot and prefill only the
    # suffix — token-identical to the cold prefill
    prefix_cache: Optional[PrefixCacheConfig] = None


class Server:
    def __init__(self, cfg, params, scfg: ServeConfig):
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        self.engine = Engine(cfg, params, EngineConfig(
            n_slots=scfg.batch_slots, max_seq=scfg.max_seq,
            seed=scfg.seed, state_dtype=scfg.state_dtype,
            prefix_cache=scfg.prefix_cache))

    def generate(self, prompts: np.ndarray, max_new: int = 32,
                 eos_id: Optional[int] = None) -> np.ndarray:
        """prompts (b, Lp) int32 -> (b, <=max_new) generated ids.  b must
        be <= batch_slots; all prompts same length (left-dense)."""
        b = prompts.shape[0]
        if b > self.scfg.batch_slots:
            raise ValueError(f"batch {b} > batch_slots "
                             f"{self.scfg.batch_slots}")
        sp = SamplingParams(temperature=self.scfg.temperature,
                            top_k=self.scfg.top_k, top_p=self.scfg.top_p,
                            max_new=max_new)
        reqs = [self.engine.submit(row, params=sp, eos_id=eos_id)
                for row in np.asarray(prompts)]
        self.engine.run()
        width = max(len(r.tokens) for r in reqs)
        pad = eos_id if eos_id is not None else 0
        out = np.full((b, width), pad, np.int32)
        for i, r in enumerate(reqs):
            out[i, :len(r.tokens)] = r.tokens
        return out
