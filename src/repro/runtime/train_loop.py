"""Production training loop: jit'd step with donated state, auto-resume,
async checkpointing, preemption handling, straggler detection, gradient
accumulation, optional int8 error-feedback gradient compression.

The loop is mesh-agnostic: pass a mesh + ShardingRules to run under pjit
(params sharded FSDPxTP per DESIGN.md §4); pass mesh=None for single-device
CPU runs (tests/examples).
"""
from __future__ import annotations

import dataclasses
import signal
import time
import zipfile
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import make_train_iterator
from repro.models import registry
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule)
from repro.optim import compression
from repro.parallel import sharding
from repro.runtime.metrics import MetricsLogger, StragglerDetector


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    warmup_steps: int = 10
    global_batch: int = 8
    seq_len: int = 64
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3
    log_every: int = 10
    seed: int = 0
    grad_accum: int = 1
    grad_compression: bool = False     # int8 EF on the DP gradient
    optimizer: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


def make_train_step(cfg, tcfg: TrainConfig):
    """Returns step(params, opt_state, ef_err, batch) -> (..., metrics).

    Gradient accumulation: batch leading dim = grad_accum * microbatch;
    lax.scan over microbatches accumulates grads in f32 (comm-free; the
    all-reduce happens once per step — the standard overlap trick)."""
    ocfg = tcfg.optimizer

    def loss_fn(p, b):
        return registry.loss_fn(cfg, p, b)

    def step(params, opt_state, ef_err, batch):
        if tcfg.grad_accum > 1:
            def micro(carry, mb):
                acc, = carry
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), acc, g)
                return (acc,), m

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree.map(
                lambda x: x.reshape(tcfg.grad_accum,
                                    x.shape[0] // tcfg.grad_accum,
                                    *x.shape[1:]), batch)
            (acc,), ms = jax.lax.scan(micro, (zeros,), mbs)
            grads = jax.tree.map(lambda g: g / tcfg.grad_accum, acc)
            # Each microbatch metric is a mean over its rows; with equal
            # microbatch sizes the mean over microbatches IS the full-batch
            # statistic, so grad_accum=k reports the same loss as the
            # single-batch step (reporting ms[-1] — the last microbatch
            # only — made the two paths diverge by O(microbatch noise)).
            metrics = jax.tree.map(
                lambda m: jnp.mean(m.astype(jnp.float32), axis=0), ms)
        else:
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        if tcfg.grad_compression:
            grads, ef_err = compression.ef_apply(grads, ef_err)

        lr_scale = cosine_schedule(opt_state.step, tcfg.warmup_steps,
                                   tcfg.total_steps)
        params, opt_state, om = adamw_update(grads, opt_state, params,
                                             ocfg, lr_scale)
        metrics.update(om)
        return params, opt_state, ef_err, metrics

    return step


class Trainer:
    """Orchestrates the full fault-tolerant loop."""

    def __init__(self, cfg, tcfg: TrainConfig, mesh=None, rules=None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.rules = rules or sharding.ShardingRules()
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)
        self.metrics = MetricsLogger()
        self.straggler = StragglerDetector()
        self._preempted = False

    def _install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGUSR1, handler)
        except ValueError:
            pass                                   # non-main thread (tests)

    # ------------------------------------------------------------------

    def init_state(self):
        params_p = registry.init_params(self.cfg, jax.random.key(
            self.tcfg.seed))
        params = sharding.tree_values(params_p)
        if self.mesh is not None:
            shards = sharding.tree_shardings(params_p, self.mesh, self.rules)
            params = jax.device_put(params, shards)
        opt_state = adamw_init(params, self.tcfg.optimizer)
        ef_err = (compression.ef_init(params)
                  if self.tcfg.grad_compression else
                  jax.tree.map(lambda _: jnp.zeros((), jnp.float32), params))
        return params, opt_state, ef_err

    def run(self, resume: bool = True, max_steps: Optional[int] = None,
            fail_at_step: Optional[int] = None):
        """Train until total_steps (or max_steps), resuming from the latest
        checkpoint.  ``fail_at_step`` injects a crash (fault-tolerance
        tests)."""
        self._install_preemption_handler()
        tcfg = self.tcfg
        params, opt_state, ef_err = self.init_state()
        start_step = 0
        if resume:
            # newest first; a crash or disk fault can leave the latest
            # step dir torn (missing/truncated arrays.npz, meta.json
            # without the needed leaves), so fall back through older
            # intact checkpoints and only then to fresh init — never
            # wedge every restart on one bad directory
            for step in reversed(self.ckpt.all_steps()):
                try:
                    (params, opt_state, ef_err), start_step = \
                        self.ckpt.restore((params, opt_state, ef_err),
                                          step=step)
                    print(f"[trainer] resumed from step {start_step}")
                    break
                except (OSError, EOFError, KeyError, ValueError,
                        zipfile.BadZipFile) as e:
                    print(f"[trainer] checkpoint step {step} in "
                          f"{self.tcfg.ckpt_dir} is unreadable "
                          f"({type(e).__name__}: {e}); trying older")
            else:
                if self.ckpt.all_steps():
                    print("[trainer] no readable checkpoint; starting "
                          "from fresh init")

        step_fn = make_train_step(self.cfg, tcfg)
        donate = (0, 1, 2)
        if self.mesh is not None:
            ctx = sharding.use_mesh(self.mesh, self.rules)
        else:
            import contextlib
            ctx = contextlib.nullcontext()
        with ctx:
            jstep = jax.jit(step_fn, donate_argnums=donate)
            it = make_train_iterator(self.cfg, tcfg.global_batch,
                                     tcfg.seq_len, start_step=start_step,
                                     seed=tcfg.seed)
            end = min(tcfg.total_steps, max_steps or tcfg.total_steps)
            losses = []
            for step in range(start_step, end):
                batch = next(it)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                t0 = time.perf_counter()
                params, opt_state, ef_err, m = jstep(params, opt_state,
                                                     ef_err, batch)
                loss = float(m["loss"])
                dt = time.perf_counter() - t0
                self.straggler.record(step, dt)
                losses.append(loss)
                if step % tcfg.log_every == 0 or step == end - 1:
                    self.metrics.log(step=step, loss=loss,
                                     grad_norm=float(m["grad_norm"]),
                                     step_time=dt)
                next_step = step + 1
                if fail_at_step is not None and next_step == fail_at_step:
                    self.ckpt.save(next_step, (params, opt_state, ef_err),
                                   blocking=True)
                    raise RuntimeError(
                        f"injected failure at step {next_step}")
                if (next_step % tcfg.ckpt_every == 0 or self._preempted
                        or next_step == end):
                    self.ckpt.save(next_step, (params, opt_state, ef_err),
                                   blocking=self._preempted)
                if self._preempted:
                    print(f"[trainer] preempted at step {next_step}; "
                          "checkpoint flushed")
                    break
            self.ckpt.wait()
        return params, opt_state, losses
