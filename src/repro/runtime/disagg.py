"""Prefill/decode disaggregation over the snapshot-admission path.

Prefill and decode want different machines: prefill is one big
compute-bound matmul over the whole prompt, decode is thousands of tiny
bandwidth-bound steps.  Disaggregated serving runs them in different
pools and ships the post-prompt state across.  For transformer serving
that means moving an O(L * max_seq * d) KV cache; for the SSM families
here the entire per-sequence state is a fixed O(d_inner * d_state)
block (plus conv tail / absmax scales / stream position) — the same
tiny pytree the prefix cache already snapshots — so the handoff is one
host round-trip of a few hundred KB regardless of prompt length.

Exactness contract (bitwise, by construction, per family x state_dtype):

  1. The prefill worker is a 1-slot Engine over the same model config —
     it runs the SAME compiled ``_jit_prefill_admit`` /
     ``_jit_suffix_admit`` programs a monolithic engine runs at
     admission, with the same resolved seed and params (seeds derive
     from the submission index via ``engine.derive_seed``, matching the
     monolithic engine's numbering).
  2. The shipped payload is ``snapshot_to_host(pool.read([slot]))`` and
     decode-side admission is ``pool.admit(slot, snapshot_to_device(.))``
     — gather, copy, scatter: exact data movement at any state_dtype
     (quantized payloads and their scales travel in one pytree).
  3. The first token (and its logprob surface) was already sampled by
     the worker's fused prefill under the request's own key at step 0;
     it ships with the snapshot and is installed verbatim.  Decode
     steps >= 1 then run under per-slot counter-based keys
     (fold_in(key(seed), token_index)) — batch-composition-independent
     by the engine's existing PRNG discipline.

So a disaggregated stream is token-identical to the monolithic engine's
stream for the same submission order — not "close", identical — which
``tests/test_disagg.py`` asserts across families and state dtypes.

The transfer queue between the pools is BOUNDED (``queue_depth``):
prefill production stalls rather than buffering unbounded state blocks,
which is the backpressure a real two-pool deployment needs (the queue
stands in for the interconnect; counters expose depth/bytes so the
bench gate can pin them).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import numpy as np

from repro.runtime.engine import Engine, EngineConfig, Request, derive_seed
from repro.runtime.prefix_cache import snapshot_to_host, tree_bytes
from repro.runtime.sampling import SamplingParams


@dataclasses.dataclass
class Snapshot:
    """One prefilled request, ready to decode anywhere: prompt +
    resolved sampling identity (params, seed) + the post-prompt state
    block + the worker-sampled first-token surface."""
    prompt: np.ndarray
    params: SamplingParams
    seed: int
    state: object                 # host-resident batch-1 cache pytree
    tok: int                      # first token (sampled at step 0)
    lp: float                     # its chosen logprob
    tv: np.ndarray                # top-k logprob values row
    ti: np.ndarray                # top-k token id row
    nbytes: int                   # state payload bytes (the wire cost)


class PrefillWorker:
    """A 1-slot prefill pool: admits into its single slot with the
    shared compiled prefill programs, gathers the state back out, and
    never decodes.  Reuses the engine's prefix-cache path, so a worker
    serving prompts with shared prefixes snapshots/restores exactly
    like a monolithic engine would."""

    def __init__(self, cfg, params, ecfg: EngineConfig):
        wcfg = dataclasses.replace(ecfg, n_slots=1, draft=None)
        self.engine = Engine(cfg, params, wcfg)
        self.n_prefilled = 0

    def prefill(self, prompt, params: SamplingParams, seed: int) -> Snapshot:
        """Run one prompt through the fused prefill-admit path and
        export the slot as a host snapshot.  The slot is evicted
        immediately — the worker holds no residency."""
        eng = self.engine
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        slot = eng.pool.alloc()
        assert slot is not None          # 1-slot pool, always drained
        eng.pool.params.set(slot, params, seed)
        req = Request(req_id=self.n_prefilled, prompt=prompt,
                      params=params, seed=seed, max_new=params.max_new,
                      stop_ids=frozenset(params.stop))
        try:
            tok, lp, tv, ti, _ = eng._admit_into_slot(req, slot)
            state = snapshot_to_host(eng.pool.read([slot]))
        finally:
            eng.pool.evict(slot)
        if eng._prefix is not None:
            eng._prefix.flush_pending(limit=None)
            eng.stats.sync_prefix(eng._prefix.counters())
        self.n_prefilled += 1
        return Snapshot(prompt=prompt, params=params, seed=seed,
                        state=state, tok=tok, lp=lp, tv=tv, ti=ti,
                        nbytes=tree_bytes(state))


@dataclasses.dataclass
class DisaggConfig:
    """queue_depth: max prefilled snapshots in flight between the
    pools — prefill production stalls at the bound (backpressure)."""
    queue_depth: int = 8

    def validate(self) -> None:
        if self.queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")


@dataclasses.dataclass(eq=False)
class _Item:
    """A submission moving through the pipeline.  Identity semantics
    (eq=False): tickets are handles, and dataclass field comparison
    would ambiguously compare prompt arrays in ``deque.__contains__``.
    """
    prompt: np.ndarray
    params: SamplingParams
    seed: int
    kw: dict                      # decode-side submit_snapshot kwargs
    snap: Optional[Snapshot] = None
    req: Optional[Request] = None


class DisaggPipeline:
    """Prefill pool -> bounded transfer queue -> decode pool.

    Drop-in for an Engine at the submit/run level: ``submit`` mirrors
    ``Engine.submit`` (minus best-of-n, which forks decode-side state
    that does not exist at prefill time), ``run`` drives both pools to
    completion.  ``step`` interleaves deterministically: fill the
    transfer queue up to its bound, drain into free decode slots, one
    decode scheduler step."""

    def __init__(self, cfg, params, ecfg: EngineConfig,
                 dcfg: Optional[DisaggConfig] = None):
        self.dcfg = dcfg or DisaggConfig()
        self.dcfg.validate()
        self.worker = PrefillWorker(cfg, params, ecfg)
        self.decode = Engine(cfg, params, ecfg)
        self._pending: "collections.deque[_Item]" = collections.deque()
        self._queue: "collections.deque[_Item]" = collections.deque()
        self._next_id = 0
        # wire accounting (the bench gate pins these)
        self.transfers = 0
        self.transfer_bytes = 0
        self.max_queue_depth = 0

    def submit(self, prompt, params: Optional[SamplingParams] = None,
               max_new: Optional[int] = None,
               eos_id: Optional[int] = None,
               stream_cb=None, tenant: Optional[str] = None,
               session: bool = False, priority: int = 0) -> _Item:
        """Mirror of ``Engine.submit`` — including its seed numbering:
        submission i gets ``derive_seed(ecfg.seed, i)`` when unseeded,
        so the pipeline's streams are bitwise a monolithic engine's for
        the same submission order."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        params = (params if params is not None
                  else self.decode.ecfg.default_params)
        if max_new is not None:
            params = dataclasses.replace(params, max_new=max_new)
        if eos_id is not None:
            params = dataclasses.replace(
                params, stop=tuple(params.stop) + (eos_id,))
        params.validate()
        if params.n > 1:
            raise ValueError("disaggregated serving is single-stream "
                             "(best-of-n forks decode-side state)")
        if not session and (prompt.size + params.max_new
                            > self.decode.ecfg.max_seq):
            raise ValueError(
                f"prompt ({prompt.size}) + max_new ({params.max_new}) "
                f"exceeds max_seq ({self.decode.ecfg.max_seq})")
        seed = (params.seed if params.seed is not None
                else derive_seed(self.decode.ecfg.seed, self._next_id))
        self._next_id += 1
        item = _Item(prompt=prompt, params=params, seed=seed,
                     kw=dict(stream_cb=stream_cb, tenant=tenant,
                             session=session, priority=priority))
        self._pending.append(item)
        return item

    def cancel(self, item: _Item) -> bool:
        """Cancel wherever the request currently lives: un-prefilled
        and in-flight snapshots are dropped from the pipeline; admitted
        requests cancel through the decode engine."""
        if item in self._pending:
            self._pending.remove(item)
            return True
        if item in self._queue:
            self._queue.remove(item)
            return True
        if item.req is not None:
            return self.decode.cancel(item.req.req_id)
        return False

    # -- drive --------------------------------------------------------------

    def step(self) -> bool:
        did = False
        # produce: prefill into the transfer queue up to its bound
        while self._pending and len(self._queue) < self.dcfg.queue_depth:
            item = self._pending.popleft()
            item.snap = self.worker.prefill(item.prompt, item.params,
                                            item.seed)
            self._queue.append(item)
            self.transfers += 1
            self.transfer_bytes += item.snap.nbytes
            self.max_queue_depth = max(self.max_queue_depth,
                                       len(self._queue))
            did = True
        # drain: one-scatter admission into free decode slots
        while (self._queue and self.decode.pool.n_free
               > len(self.decode._ready)):
            item = self._queue.popleft()
            item.req = self.decode.submit_snapshot(item.snap, **item.kw)
            did = True
        return self.decode.step() or did

    def busy(self) -> bool:
        return bool(self._pending or self._queue or self.decode._ready
                    or self.decode.pool.n_active)

    def run(self) -> list:
        """Drive both pools until every request retires (sessions must
        be cancelled by the caller, as with ``Engine.run``).  Returns
        the decode engine's finished requests in completion order."""
        self.decode.stats.start()
        self.decode._finished = []
        while self.busy():
            self.step()
        self.decode.stats.stop()
        return self.decode._finished

    def counters(self) -> dict:
        return {
            "transfers": self.transfers,
            "transfer_bytes": self.transfer_bytes,
            "max_queue_depth": self.max_queue_depth,
            "prefilled": self.worker.n_prefilled,
        }
