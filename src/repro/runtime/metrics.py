"""Metrics logging (jsonl) + straggler detection + serving counters.

StragglerDetector: per-step wall time EMA/EMVar; a step whose time exceeds
mean + z*std is flagged.  On a real multi-host deployment the same detector
runs per host on heartbeat files and feeds the microbatch re-balancer; here
it logs and counts (tests inject artificial delays).

ServeStats: throughput/latency counters for the continuous-batching
engine — prefill/decode token counts and wall time, slot occupancy, and
per-request TTFT/TPOT/latency distributions, with per-tenant breakdowns
and SLO-violation / load-shed counters for the front-end scheduler
(runtime/scheduler.py).  Cancelled requests stay out of every
percentile; TPOT (time per OUTPUT token, the decode-side SLO axis) is
measured from first token to completion over the tokens after the
first, so a one-token request has no TPOT sample rather than a zero."""
from __future__ import annotations

import json
import math
import sys
import time
from typing import Optional


class MetricsLogger:
    def __init__(self, path: Optional[str] = None, echo: bool = True):
        self.path = path
        self.echo = echo
        self._fh = open(path, "a") if path else None

    def log(self, **kv):
        kv.setdefault("t", time.time())
        line = json.dumps(kv)
        if self._fh:
            self._fh.write(line + "\n")
            self._fh.flush()
        if self.echo:
            show = {k: (round(v, 4) if isinstance(v, float) else v)
                    for k, v in kv.items() if k != "t"}
            print(f"[metrics] {show}", file=sys.stderr)

    def close(self):
        if self._fh:
            self._fh.close()


def _percentile(sorted_xs: list, q: float) -> float:
    if not sorted_xs:
        return 0.0
    i = min(len(sorted_xs) - 1, max(0, int(round(q * (len(sorted_xs) - 1)))))
    return sorted_xs[i]


class ServeStats:
    """Counters for the serving engine (host-side, cheap per step).

    "useful" tokens are tokens delivered to a live request: one per
    prefill (the first sampled token) and one per active slot per decode
    step — masked/idle slots never count, so tokens_per_s reflects work a
    client actually received."""

    def __init__(self):
        self.prefill_calls = 0
        self.prefill_tokens = 0        # prompt tokens consumed
        self.prefill_time = 0.0
        self.decode_steps = 0
        self.decode_time = 0.0
        self.useful_tokens = 0
        self.slot_steps = 0            # n_slots summed over decode steps
        self.active_steps = 0          # active slots summed (occupancy)
        self.n_requests = 0
        self.n_cancelled = 0           # requests retired via cancel()
        # speculative decoding (deterministic counters — the bench gate
        # diffs these, never wall-clock)
        self.spec_passes = 0           # target verify passes
        self.spec_slot_passes = 0      # sum of active slots over passes
        self.spec_drafted = 0          # draft tokens proposed
        self.spec_accepted = 0         # draft tokens accepted
        self.spec_emitted = 0          # tokens delivered by spec passes
        # prefix cache (deterministic counters; the bench gate asserts
        # hits > 0 and strictly fewer prefilled tokens than no-cache)
        self.prefix_hits = 0           # admissions restored from cache
        self.prefix_misses = 0         # admissions that ran cold
        self.prefix_cached_tokens = 0  # prompt tokens skipped via restore
        self.prefix_inserts = 0        # snapshots stored
        self.prefix_evictions = 0      # snapshots LRU-evicted
        self.prefix_rejects = 0        # snapshots refused (> max_bytes)
        self.prefix_bytes = 0          # bytes currently resident
        # front-end scheduler (runtime/scheduler.py) + disaggregation
        # (runtime/disagg.py) — all deterministic counts
        self.n_shed = 0                # requests rejected by load shedding
        self.n_degraded = 0            # requests admitted with shrunk n
        self.n_slo_ttft_violations = 0
        self.n_slo_tpot_violations = 0
        self.n_callback_errors = 0     # stream_cb raised (request cancelled)
        self.snapshot_admits = 0       # slots admitted from a shipped
        self.snapshot_tokens = 0       #   prefill snapshot (disagg decode
        self.snapshot_bytes = 0        #   side); bytes = transfer payload
        self._ttft: list[float] = []
        self._tpot: list[float] = []
        self._latency: list[float] = []
        self._tenants: dict[str, dict] = {}
        self._t0: Optional[float] = None
        self.wall = 0.0

    def _tenant(self, name: str) -> dict:
        t = self._tenants.get(name)
        if t is None:
            t = self._tenants[name] = {
                "requests": 0, "shed": 0, "degraded": 0,
                "slo_ttft_violations": 0, "slo_tpot_violations": 0,
                "ttft": [], "tpot": [],
            }
        return t

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self):
        if self._t0 is not None:
            self.wall += time.perf_counter() - self._t0
            self._t0 = None

    def record_prefill(self, n_tokens: int, dt: float):
        self.prefill_calls += 1
        self.prefill_tokens += n_tokens
        self.prefill_time += dt
        self.useful_tokens += 1        # the token sampled off the prefill

    def record_decode(self, n_active: int, n_slots: int, dt: float,
                      n_steps: int = 1, n_tokens: Optional[int] = None):
        """One decode burst of ``n_steps`` pooled steps.  ``n_tokens`` is
        the count actually delivered (EOS overshoot trimmed); defaults to
        n_active * n_steps."""
        self.decode_steps += n_steps
        self.decode_time += dt
        self.useful_tokens += (n_tokens if n_tokens is not None
                               else n_active * n_steps)
        self.active_steps += n_active * n_steps
        self.slot_steps += n_slots * n_steps

    def record_spec(self, n_active: int, n_drafted: int, n_accepted: int,
                    n_emitted: int):
        """One speculative pass: ``n_drafted`` proposals over
        ``n_active`` slots, ``n_accepted`` of them accepted,
        ``n_emitted`` tokens delivered (accepted + per-slot correction/
        bonus tokens, after EOS/budget trim)."""
        self.spec_passes += 1
        self.spec_slot_passes += n_active
        self.spec_drafted += n_drafted
        self.spec_accepted += n_accepted
        self.spec_emitted += n_emitted

    def record_prefix(self, hit: bool, n_cached: int):
        """One admission's prefix-cache outcome: ``n_cached`` prompt
        tokens restored from a snapshot instead of prefilled (0 on a
        miss).  Restored tokens are deliberately NOT added to
        prefill_tokens — that counter stays the honest compute count,
        which is what the bench gate diffs against the no-cache run."""
        if hit:
            self.prefix_hits += 1
            self.prefix_cached_tokens += n_cached
        else:
            self.prefix_misses += 1

    def sync_prefix(self, counters: dict):
        """Adopt the PrefixCache's own insert/eviction/bytes counters
        (the cache is the source of truth for its storage accounting)."""
        self.prefix_inserts = counters["inserts"]
        self.prefix_evictions = counters["evictions"]
        self.prefix_rejects = counters.get("rejects", 0)
        self.prefix_bytes = counters["bytes"]

    def record_request(self, ttft: float, latency: float,
                       n_tokens: int = 0, tenant: Optional[str] = None):
        self.n_requests += 1
        self._ttft.append(ttft)
        self._latency.append(latency)
        tpot = None
        if n_tokens > 1:
            tpot = (latency - ttft) / (n_tokens - 1)
            self._tpot.append(tpot)
        if tenant is not None:
            t = self._tenant(tenant)
            t["requests"] += 1
            t["ttft"].append(ttft)
            if tpot is not None:
                t["tpot"].append(tpot)

    def record_shed(self, tenant: Optional[str] = None):
        """A request rejected at admission control — it never entered the
        engine, so it touches no throughput or latency counter."""
        self.n_shed += 1
        if tenant is not None:
            self._tenant(tenant)["shed"] += 1

    def record_degraded(self, tenant: Optional[str] = None):
        """A request admitted with a shrunk sampling budget (best-of-n
        collapsed to 1) instead of being shed."""
        self.n_degraded += 1
        if tenant is not None:
            self._tenant(tenant)["degraded"] += 1

    def record_slo_violation(self, kind: str,
                             tenant: Optional[str] = None):
        """A completed request that blew its wall-clock SLO budget;
        ``kind`` is "ttft" or "tpot".  Decision-making never reads these
        (admission control uses deterministic projected-wait proxies) —
        they are accounting for dashboards and the serve report."""
        if kind == "ttft":
            self.n_slo_ttft_violations += 1
        elif kind == "tpot":
            self.n_slo_tpot_violations += 1
        else:
            raise ValueError(f"unknown SLO kind: {kind!r}")
        if tenant is not None:
            self._tenant(tenant)[f"slo_{kind}_violations"] += 1

    def record_snapshot_admit(self, n_tokens: int, nbytes: int):
        """Decode-side disaggregated admission: a prefill snapshot
        (state block + scales + stream position + first-token surface)
        restored into a slot with one scatter.  ``n_tokens`` is the
        prompt length the prefill worker consumed on our behalf —
        deliberately NOT added to prefill_tokens, which stays the honest
        local compute count.  The first token shipped with the snapshot
        is delivered to the client, hence useful_tokens += 1 (mirroring
        record_prefill)."""
        self.snapshot_admits += 1
        self.snapshot_tokens += n_tokens
        self.snapshot_bytes += nbytes
        self.useful_tokens += 1

    def record_cancelled(self):
        """A cancelled request: its slot time already counted in the
        decode counters, but it never completed — kept out of the
        TTFT/latency distributions so cancellations can't flatter the
        percentiles."""
        self.n_cancelled += 1

    def summary(self) -> dict:
        wall = self.wall if self.wall > 0 else (
            self.prefill_time + self.decode_time)
        ttft = sorted(self._ttft)
        tpot = sorted(self._tpot)
        lat = sorted(self._latency)
        per_tenant = {}
        for name in sorted(self._tenants):
            t = self._tenants[name]
            tt = sorted(t["ttft"])
            tp = sorted(t["tpot"])
            per_tenant[name] = {
                "requests": t["requests"],
                "shed": t["shed"],
                "degraded": t["degraded"],
                "slo_ttft_violations": t["slo_ttft_violations"],
                "slo_tpot_violations": t["slo_tpot_violations"],
                "ttft_p95_s": _percentile(tt, 0.95),
                "tpot_p95_s": _percentile(tp, 0.95),
            }
        return {
            "requests": self.n_requests,
            "cancelled": self.n_cancelled,
            "useful_tokens": self.useful_tokens,
            "prefill_tokens": self.prefill_tokens,
            "decode_steps": self.decode_steps,
            "wall_s": wall,
            "tokens_per_s": self.useful_tokens / wall if wall > 0 else 0.0,
            "occupancy": (self.active_steps / self.slot_steps
                          if self.slot_steps else 0.0),
            "ttft_mean_s": sum(ttft) / len(ttft) if ttft else 0.0,
            "ttft_p95_s": _percentile(ttft, 0.95),
            "tpot_mean_s": sum(tpot) / len(tpot) if tpot else 0.0,
            "tpot_p95_s": _percentile(tpot, 0.95),
            "latency_mean_s": sum(lat) / len(lat) if lat else 0.0,
            "latency_p95_s": _percentile(lat, 0.95),
            # front-end scheduler + disaggregation (deterministic counts)
            "n_shed": self.n_shed,
            "n_degraded": self.n_degraded,
            "slo_ttft_violations": self.n_slo_ttft_violations,
            "slo_tpot_violations": self.n_slo_tpot_violations,
            "callback_errors": self.n_callback_errors,
            "snapshot_admits": self.snapshot_admits,
            "snapshot_tokens": self.snapshot_tokens,
            "snapshot_bytes": self.snapshot_bytes,
            "per_tenant": per_tenant,
            # speculative decode: tokens delivered per slot per target
            # pass (1.0 = plain decode; upper bound draft k + 1) and
            # the draft-token acceptance fraction
            "spec_target_passes": self.spec_passes,
            "spec_accepted_per_pass": (
                self.spec_emitted / self.spec_slot_passes
                if self.spec_slot_passes else 0.0),
            "spec_acceptance_rate": (
                self.spec_accepted / self.spec_drafted
                if self.spec_drafted else 0.0),
            # prefix cache: hit rate over admissions that consulted the
            # cache, and prompt tokens restored instead of prefilled
            "prefix_hits": self.prefix_hits,
            "prefix_hit_rate": (
                self.prefix_hits / (self.prefix_hits + self.prefix_misses)
                if (self.prefix_hits + self.prefix_misses) else 0.0),
            "prefix_cached_tokens": self.prefix_cached_tokens,
            "prefix_inserts": self.prefix_inserts,
            "prefix_evictions": self.prefix_evictions,
            "prefix_rejects": self.prefix_rejects,
            "prefix_bytes": self.prefix_bytes,
        }


class StragglerDetector:
    """EMA-based step-time anomaly detector (z-score threshold)."""

    def __init__(self, alpha: float = 0.1, z: float = 3.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.z = z
        self.warmup = warmup
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            # prime the EMA
            self.mean = (self.mean * (self.n - 1) + dt) / self.n
            self.var = max(self.var, (dt - self.mean) ** 2)
            return False
        # var == 0 after a constant-time warmup is legitimate, not a
        # "not enough data" signal: an inf std would make the detector
        # blind forever (the first genuine straggler passes unflagged
        # AND corrupts the EMA mean/var).  Floor the std relative to
        # the mean instead, so a step several times the steady rate
        # always trips the z-threshold.
        std = math.sqrt(self.var)
        floor = max(1e-9, 0.05 * abs(self.mean))
        is_straggler = dt > self.mean + self.z * max(std, floor)
        if is_straggler:
            self.flagged.append((step, dt))
        else:
            d = dt - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler
