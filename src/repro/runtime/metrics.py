"""Metrics logging (jsonl) + straggler detection.

StragglerDetector: per-step wall time EMA/EMVar; a step whose time exceeds
mean + z*std is flagged.  On a real multi-host deployment the same detector
runs per host on heartbeat files and feeds the microbatch re-balancer; here
it logs and counts (tests inject artificial delays)."""
from __future__ import annotations

import json
import math
import sys
import time
from typing import Optional


class MetricsLogger:
    def __init__(self, path: Optional[str] = None, echo: bool = True):
        self.path = path
        self.echo = echo
        self._fh = open(path, "a") if path else None

    def log(self, **kv):
        kv.setdefault("t", time.time())
        line = json.dumps(kv)
        if self._fh:
            self._fh.write(line + "\n")
            self._fh.flush()
        if self.echo:
            show = {k: (round(v, 4) if isinstance(v, float) else v)
                    for k, v in kv.items() if k != "t"}
            print(f"[metrics] {show}", file=sys.stderr)

    def close(self):
        if self._fh:
            self._fh.close()


class StragglerDetector:
    """EMA-based step-time anomaly detector (z-score threshold)."""

    def __init__(self, alpha: float = 0.1, z: float = 3.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.z = z
        self.warmup = warmup
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            # prime the EMA
            self.mean = (self.mean * (self.n - 1) + dt) / self.n
            self.var = max(self.var, (dt - self.mean) ** 2)
            return False
        std = math.sqrt(self.var) if self.var > 0 else float("inf")
        is_straggler = dt > self.mean + self.z * max(std, 1e-9)
        if is_straggler:
            self.flagged.append((step, dt))
        else:
            d = dt - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler
