"""Metrics logging (jsonl) + straggler detection + serving counters.

StragglerDetector: per-step wall time EMA/EMVar; a step whose time exceeds
mean + z*std is flagged.  On a real multi-host deployment the same detector
runs per host on heartbeat files and feeds the microbatch re-balancer; here
it logs and counts (tests inject artificial delays).

ServeStats: throughput/latency counters for the continuous-batching
engine — prefill/decode token counts and wall time, slot occupancy, and
per-request TTFT/latency distributions."""
from __future__ import annotations

import json
import math
import sys
import time
from typing import Optional


class MetricsLogger:
    def __init__(self, path: Optional[str] = None, echo: bool = True):
        self.path = path
        self.echo = echo
        self._fh = open(path, "a") if path else None

    def log(self, **kv):
        kv.setdefault("t", time.time())
        line = json.dumps(kv)
        if self._fh:
            self._fh.write(line + "\n")
            self._fh.flush()
        if self.echo:
            show = {k: (round(v, 4) if isinstance(v, float) else v)
                    for k, v in kv.items() if k != "t"}
            print(f"[metrics] {show}", file=sys.stderr)

    def close(self):
        if self._fh:
            self._fh.close()


def _percentile(sorted_xs: list, q: float) -> float:
    if not sorted_xs:
        return 0.0
    i = min(len(sorted_xs) - 1, max(0, int(round(q * (len(sorted_xs) - 1)))))
    return sorted_xs[i]


class ServeStats:
    """Counters for the serving engine (host-side, cheap per step).

    "useful" tokens are tokens delivered to a live request: one per
    prefill (the first sampled token) and one per active slot per decode
    step — masked/idle slots never count, so tokens_per_s reflects work a
    client actually received."""

    def __init__(self):
        self.prefill_calls = 0
        self.prefill_tokens = 0        # prompt tokens consumed
        self.prefill_time = 0.0
        self.decode_steps = 0
        self.decode_time = 0.0
        self.useful_tokens = 0
        self.slot_steps = 0            # n_slots summed over decode steps
        self.active_steps = 0          # active slots summed (occupancy)
        self.n_requests = 0
        self.n_cancelled = 0           # requests retired via cancel()
        # speculative decoding (deterministic counters — the bench gate
        # diffs these, never wall-clock)
        self.spec_passes = 0           # target verify passes
        self.spec_slot_passes = 0      # sum of active slots over passes
        self.spec_drafted = 0          # draft tokens proposed
        self.spec_accepted = 0         # draft tokens accepted
        self.spec_emitted = 0          # tokens delivered by spec passes
        # prefix cache (deterministic counters; the bench gate asserts
        # hits > 0 and strictly fewer prefilled tokens than no-cache)
        self.prefix_hits = 0           # admissions restored from cache
        self.prefix_misses = 0         # admissions that ran cold
        self.prefix_cached_tokens = 0  # prompt tokens skipped via restore
        self.prefix_inserts = 0        # snapshots stored
        self.prefix_evictions = 0      # snapshots LRU-evicted
        self.prefix_rejects = 0        # snapshots refused (> max_bytes)
        self.prefix_bytes = 0          # bytes currently resident
        self._ttft: list[float] = []
        self._latency: list[float] = []
        self._t0: Optional[float] = None
        self.wall = 0.0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self):
        if self._t0 is not None:
            self.wall += time.perf_counter() - self._t0
            self._t0 = None

    def record_prefill(self, n_tokens: int, dt: float):
        self.prefill_calls += 1
        self.prefill_tokens += n_tokens
        self.prefill_time += dt
        self.useful_tokens += 1        # the token sampled off the prefill

    def record_decode(self, n_active: int, n_slots: int, dt: float,
                      n_steps: int = 1, n_tokens: Optional[int] = None):
        """One decode burst of ``n_steps`` pooled steps.  ``n_tokens`` is
        the count actually delivered (EOS overshoot trimmed); defaults to
        n_active * n_steps."""
        self.decode_steps += n_steps
        self.decode_time += dt
        self.useful_tokens += (n_tokens if n_tokens is not None
                               else n_active * n_steps)
        self.active_steps += n_active * n_steps
        self.slot_steps += n_slots * n_steps

    def record_spec(self, n_active: int, n_drafted: int, n_accepted: int,
                    n_emitted: int):
        """One speculative pass: ``n_drafted`` proposals over
        ``n_active`` slots, ``n_accepted`` of them accepted,
        ``n_emitted`` tokens delivered (accepted + per-slot correction/
        bonus tokens, after EOS/budget trim)."""
        self.spec_passes += 1
        self.spec_slot_passes += n_active
        self.spec_drafted += n_drafted
        self.spec_accepted += n_accepted
        self.spec_emitted += n_emitted

    def record_prefix(self, hit: bool, n_cached: int):
        """One admission's prefix-cache outcome: ``n_cached`` prompt
        tokens restored from a snapshot instead of prefilled (0 on a
        miss).  Restored tokens are deliberately NOT added to
        prefill_tokens — that counter stays the honest compute count,
        which is what the bench gate diffs against the no-cache run."""
        if hit:
            self.prefix_hits += 1
            self.prefix_cached_tokens += n_cached
        else:
            self.prefix_misses += 1

    def sync_prefix(self, counters: dict):
        """Adopt the PrefixCache's own insert/eviction/bytes counters
        (the cache is the source of truth for its storage accounting)."""
        self.prefix_inserts = counters["inserts"]
        self.prefix_evictions = counters["evictions"]
        self.prefix_rejects = counters.get("rejects", 0)
        self.prefix_bytes = counters["bytes"]

    def record_request(self, ttft: float, latency: float):
        self.n_requests += 1
        self._ttft.append(ttft)
        self._latency.append(latency)

    def record_cancelled(self):
        """A cancelled request: its slot time already counted in the
        decode counters, but it never completed — kept out of the
        TTFT/latency distributions so cancellations can't flatter the
        percentiles."""
        self.n_cancelled += 1

    def summary(self) -> dict:
        wall = self.wall if self.wall > 0 else (
            self.prefill_time + self.decode_time)
        ttft = sorted(self._ttft)
        lat = sorted(self._latency)
        return {
            "requests": self.n_requests,
            "cancelled": self.n_cancelled,
            "useful_tokens": self.useful_tokens,
            "prefill_tokens": self.prefill_tokens,
            "decode_steps": self.decode_steps,
            "wall_s": wall,
            "tokens_per_s": self.useful_tokens / wall if wall > 0 else 0.0,
            "occupancy": (self.active_steps / self.slot_steps
                          if self.slot_steps else 0.0),
            "ttft_mean_s": sum(ttft) / len(ttft) if ttft else 0.0,
            "ttft_p95_s": _percentile(ttft, 0.95),
            "latency_mean_s": sum(lat) / len(lat) if lat else 0.0,
            "latency_p95_s": _percentile(lat, 0.95),
            # speculative decode: tokens delivered per slot per target
            # pass (1.0 = plain decode; upper bound draft k + 1) and
            # the draft-token acceptance fraction
            "spec_target_passes": self.spec_passes,
            "spec_accepted_per_pass": (
                self.spec_emitted / self.spec_slot_passes
                if self.spec_slot_passes else 0.0),
            "spec_acceptance_rate": (
                self.spec_accepted / self.spec_drafted
                if self.spec_drafted else 0.0),
            # prefix cache: hit rate over admissions that consulted the
            # cache, and prompt tokens restored instead of prefilled
            "prefix_hits": self.prefix_hits,
            "prefix_hit_rate": (
                self.prefix_hits / (self.prefix_hits + self.prefix_misses)
                if (self.prefix_hits + self.prefix_misses) else 0.0),
            "prefix_cached_tokens": self.prefix_cached_tokens,
            "prefix_inserts": self.prefix_inserts,
            "prefix_evictions": self.prefix_evictions,
            "prefix_rejects": self.prefix_rejects,
            "prefix_bytes": self.prefix_bytes,
        }


class StragglerDetector:
    """EMA-based step-time anomaly detector (z-score threshold)."""

    def __init__(self, alpha: float = 0.1, z: float = 3.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.z = z
        self.warmup = warmup
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            # prime the EMA
            self.mean = (self.mean * (self.n - 1) + dt) / self.n
            self.var = max(self.var, (dt - self.mean) ** 2)
            return False
        # var == 0 after a constant-time warmup is legitimate, not a
        # "not enough data" signal: an inf std would make the detector
        # blind forever (the first genuine straggler passes unflagged
        # AND corrupts the EMA mean/var).  Floor the std relative to
        # the mean instead, so a step several times the steady rate
        # always trips the z-threshold.
        std = math.sqrt(self.var)
        floor = max(1e-9, 0.05 * abs(self.mean))
        is_straggler = dt > self.mean + self.z * max(std, floor)
        if is_straggler:
            self.flagged.append((step, dt))
        else:
            d = dt - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler
