"""Speculative decoding over the slot state pool.

Because an SSM's whole decode state is a fixed O(d_inner * d_state)
block per layer, a draft fork is ONE gather+scatter of pool slots and a
rollback is one per-slot select — no tree attention, no ragged KV
bookkeeping.  That is the structural advantage this module exploits
(attention-based spec decode spends most of its complexity budget
exactly there), and the part eMamba/FastMamba leave on the table by
targeting single-stream edge inference.

One speculative pass over the live slots:

  1. FORK    — lease one scratch slot per live slot and fork its pooled
               state into it (``SlotStatePool.fork``: payload + absmax
               scales + sampling params move together).
  2. DRAFT   — run K cheap decode steps on the scratch slots with the
               self-speculative draft model: the target's first
               ``DraftConfig.layers`` layers (embed / final norm /
               unembed shared), optionally with a different step_impl
               ("unfused-cheap").  Live slots are mask-frozen.  The
               draft samples with each slot's OWN SamplingParams and
               per-slot key stream (runtime/sampling.py).
  3. VERIFY  — one jit'd target pass: a (K+1)-step micro-scan chaining
               the SAME per-token ``decode_step`` dispatch the normal
               burst runs (fused kernel per layer per step) over
               [pending token, draft_1..draft_K], keeping every
               intermediate cache.
  4. ACCEPT  — per-slot speculative rejection sampling
               (``accept_tokens_hetero``): greedy slots take the greedy
               shortcut (accept while the draft equals the target
               argmax; the first mismatch emits the target's own token
               — bitwise plain greedy decode), sampled slots use
               min(1, p_t/p_d) with both distributions filtered and
               scaled by the slot's params, so one jit'd verify serves
               a batch mixing greedy and sampled requests with zero
               retracing when params change.
  5. ROLLBACK— per-slot select of the cache after each slot's accepted
               prefix (``registry.select_step``).

Exactness contract: the verify micro-scan evaluates the target at the
same shapes and through the same jitted per-token step as plain decode,
so a greedy slot's spec-decoded stream is token-identical to plain
greedy decode — even inside a mixed greedy+sampled batch (gated per
family / state_dtype / step_impl in tests/test_spec_decode.py).  Each
target pass emits between 1 and K+1 tokens per slot; the
accepted-tokens-per-target-pass counter in ServeStats is the speedup
proxy the benchmarks gate on.  ``DraftConfig.adaptive`` clamps each
slot's window to its realized acceptance (depth arithmetic only —
never the token values, so greedy identity survives adaptivity).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import registry
from repro.parallel import sharding
from repro.runtime import sampling


@dataclasses.dataclass(frozen=True)
class DraftConfig:
    """Self-speculative draft settings (``EngineConfig.draft``).

    k: draft depth — tokens proposed per target pass.  Each pass emits
       between 1 and k+1 tokens, so k bounds the per-pass win.
    layers: draft depth in model layers; 0 = full depth (the draft IS
       the target: every proposal is accepted — useful for gating the
       accounting deterministically, pointless for speed).  Jamba
       requires a multiple of its group period.
    step_impl: override for the draft's per-token step routing (e.g.
       "xla" for an unfused-cheap draft while the target runs fused);
       None inherits the target's — including "megakernel", where the
       draft's burst is its own single stacked launch over the
       first-n-layers slice of the same stacked params.
    adaptive: clamp each slot's speculative window to its realized
       acceptance (ceil(accepted/passes) + 1, floored at 1) after
       ``adapt_warmup`` full-depth passes — a low-acceptance slot stops
       paying for drafts it will reject.  Token streams are unchanged
       (the clamp shortens windows, never alters accept/emit math), so
       greedy identity stays bitwise.
    adapt_warmup: target passes at full depth before the clamp engages.
    """
    k: int = 4
    layers: int = 0
    step_impl: Optional[str] = None
    adaptive: bool = False
    adapt_warmup: int = 2


def default_shallow_layers(cfg) -> int:
    """A ~half-depth draft rounded to the family's draft granularity.

    Jamba drafts whole groups (``attn_every`` layers each), so its
    depth must be a group multiple — a config with a single group (the
    smoke config) degrades to full depth.  Other families draft any
    layer prefix."""
    if cfg.family == "jamba":
        period = cfg.attn_every or 8
        groups = cfg.n_layers // period
        return max(1, groups // 2) * period
    return max(1, cfg.n_layers // 2)


# ---------------------------------------------------------------------------
# Acceptance core (pure; property-tested in tests/test_spec_decode.py)
# ---------------------------------------------------------------------------

def accept_tokens_hetero(draft_toks, target_logits, draft_logits, sp,
                         step, depth_limit):
    """Per-slot-parameter speculative acceptance over one window.

    draft_toks (K, b) int32 — the draft's proposals d_1..d_K.
    target_logits (K+1, b, V) — the target's verify micro-scan logits.
    draft_logits (K, b, V) — the draft's logits at each proposal.
    sp — SlotParams dict with b rows (temperature/top_k/top_p/key_data).
    step (b,) int32 — each slot's stream position at pass start (keys
      the per-slot acceptance randomness, batch-independently).
    depth_limit (b,) int32 — per-slot cap on accepted drafts (adaptive
      depth); pass K to disable.

    Returns (emit (K+1, b), n_acc (b,), pending (b,)) with the same
    meaning as the scalar path: n_acc[s] accepted drafts, emit[:j+1, s]
    the emitted stream, pending[s] = emit[j, s] the token whose state
    update is not yet applied.

    Greedy rows (temperature <= 0) reduce EXACTLY to the greedy
    shortcut — emit is the target argmax stream, so a greedy slot in a
    mixed batch is bitwise the all-greedy engine.  Sampled rows use
    rejection sampling with p_t/p_d computed on each slot's OWN
    filtered+scaled distributions (the same ``sampling.sample_dist``
    the draft proposed from), keeping the emitted marginal exactly the
    target sampling distribution.  Clamping n_acc to depth_limit only
    shortens the accepted prefix — every emitted token is still either
    an accepted draft or the target's own — so adaptivity never
    changes token values.
    """
    K = draft_toks.shape[0]
    tgt = jnp.argmax(target_logits.astype(jnp.float32),
                     axis=-1).astype(jnp.int32)             # (K+1, b)
    ok_greedy = draft_toks == tgt[:K]
    sampled = sp["temperature"] > 0

    def _mixed(_):
        # both distributions filtered/scaled per slot, exactly as the
        # draft sampled its proposals
        logp_t = jax.nn.log_softmax(
            jax.vmap(sampling.sample_dist, in_axes=(0, None))(
                target_logits[:K], sp), axis=-1)            # (K, b, V)
        logp_d = jax.nn.log_softmax(
            jax.vmap(sampling.sample_dist, in_axes=(0, None))(
                draft_logits, sp), axis=-1)
        d = draft_toks[..., None]
        lp_t = jnp.take_along_axis(logp_t, d, axis=-1)[..., 0]  # (K, b)
        lp_d = jnp.take_along_axis(logp_d, d, axis=-1)[..., 0]
        base = sampling.slot_keys(sp["key_data"], step)
        k_u, k_res, k_bonus = (sampling.fold_tag(base, t)
                               for t in (1, 2, 3))
        u = jax.vmap(lambda k: jax.random.uniform(k, (K,), minval=1e-20),
                     out_axes=1)(k_u)                       # (K, b)
        # a draft token filtered out of the slot's target dist has
        # lp_t = -inf -> always rejected; lp_d is finite by construction
        # (the draft sampled it from the same filtered support)
        ok_sampled = jnp.log(u) < (lp_t - lp_d)
        # residual resample at the rejection point: max(p_t - p_d, 0),
        # renormalized; degenerate (p_t == p_d exactly) falls back to p_t
        res = jnp.maximum(jnp.exp(logp_t) - jnp.exp(logp_d), 0.0)
        norm = res.sum(axis=-1, keepdims=True)
        safe = jnp.where(norm > 0, res / jnp.maximum(norm, 1e-30),
                         jnp.exp(logp_t))
        corr = jax.vmap(jax.random.categorical,
                        in_axes=(0, 1), out_axes=1)(
            k_res, jnp.log(safe + 1e-30)).astype(jnp.int32)  # (K, b)
        bonus_dist = sampling.sample_dist(target_logits[K], sp)
        bonus = jax.vmap(jax.random.categorical)(
            k_bonus, bonus_dist).astype(jnp.int32)[None]    # (1, b)
        emit_sampled = jnp.concatenate(
            [jnp.where(ok_sampled, draft_toks, corr), bonus], axis=0)
        return (jnp.where(sampled[None, :], emit_sampled, tgt),
                jnp.where(sampled[None, :], ok_sampled, ok_greedy))

    # the whole rejection-sampling battery sits behind a cond on
    # any(sampled): an all-greedy verify pays only the argmax path at
    # runtime, with ONE compiled program (same rationale as
    # sampling.sample)
    emit, ok = jax.lax.cond(jnp.any(sampled), _mixed,
                            lambda _: (tgt, ok_greedy), None)
    acc = jnp.cumprod(ok.astype(jnp.int32), axis=0)
    n_acc = jnp.minimum(acc.sum(axis=0), depth_limit)       # (b,)
    pending = jnp.take_along_axis(emit, n_acc[None], axis=0)[0]
    return emit, n_acc, pending


def accept_tokens(draft_toks, target_logits, temperature: float,
                  draft_logits=None, key=None):
    """Scalar-parameter acceptance (reference entry; the engine's jit
    uses ``accept_tokens_hetero`` with per-slot params).

    Temperature 0 takes the greedy shortcut: accept while the draft
    matches the target argmax; the rejection/bonus token IS the argmax,
    so emit = argmax.  Temperature > 0 delegates to the vectorized path
    with every row carrying the same temperature (no top-k/top-p) and
    per-row keys folded from ``key`` — standard speculative rejection
    sampling whose emitted marginal is exactly the target distribution
    (property-tested in tests/test_spec_decode.py).
    """
    K, b = draft_toks.shape
    if temperature <= 0:
        tgt = jnp.argmax(target_logits.astype(jnp.float32),
                         axis=-1).astype(jnp.int32)         # (K+1, b)
        ok = (draft_toks == tgt[:K])
        acc = jnp.cumprod(ok.astype(jnp.int32), axis=0)      # (K, b)
        n_acc = acc.sum(axis=0)                              # (b,)
        emit = tgt
        pending = jnp.take_along_axis(emit, n_acc[None], axis=0)[0]
        return emit, n_acc, pending

    if draft_logits is None or key is None:
        raise ValueError("sampled acceptance needs draft_logits and key")
    sp = {"temperature": jnp.full((b,), temperature, jnp.float32),
          "top_k": jnp.zeros((b,), jnp.int32),
          "top_p": jnp.ones((b,), jnp.float32),
          "key_data": jnp.tile(jax.random.key_data(key), (b, 1))}
    return accept_tokens_hetero(
        draft_toks, target_logits, draft_logits, sp,
        step=jnp.arange(b, dtype=jnp.int32),
        depth_limit=jnp.full((b,), K, jnp.int32))


# ---------------------------------------------------------------------------
# Jit'd draft / verify passes (shared per config, as in engine.py).
# Sampling params are traced array arguments — never jit cache keys —
# so one compile serves arbitrary heterogeneous traffic.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _jit_draft_step(cfg, dcfg, n_layers: int, shard=None):
    """One draft decode step over the pool: slice the first-n-layers
    cache view, run the draft model's decode_step, merge the updated
    layers back, freeze everything but the scratch slots, sample with
    each slot's own params.  ``shard`` ((mesh, rules) or None) keys a
    separate tensor-parallel trace whose output cache is constrained to
    the pool's sharding (fork/draft/verify chain reshard-free)."""
    full = n_layers == cfg.n_layers and dcfg == cfg
    cax = registry.cache_axes(cfg) if shard is not None else None

    def _fn(pd, cache, toks, scratch_mask, sp, step):
        sampling.TRACE_COUNTS["draft_step"] += 1
        with sharding.shard_ctx(shard):
            cd = (cache if full
                  else registry.draft_cache(cfg, cache, n_layers))
            logits, cd2 = registry.decode_step(dcfg, pd, cd,
                                               {"tokens": toks})
            new_cache = (cd2 if full else
                         registry.draft_cache_merge(cfg, cache, cd2,
                                                    n_layers))
            new_cache = registry.mask_slots(cfg, cache, new_cache,
                                            scratch_mask)
            if shard is not None:
                new_cache = sharding.constrain_tree(new_cache, cax)
            tok = sampling.sample(logits[:, -1, :], sp, step)
        return tok[:, None], logits[:, -1, :], new_cache
    return jax.jit(_fn)


@functools.lru_cache(maxsize=None)
def _jit_verify(cfg, k: int, shard=None):
    """The fused verify pass: (k+1)-step micro-scan over
    [pending, drafts], per-step freeze of inactive slots, per-slot
    acceptance, and the per-slot rollback select — one dispatch, one
    host sync.  Only the window depth k (bounded by DraftConfig.k) and
    the tensor-parallel shard key the compile; sampling params are
    traced arrays."""
    cax = registry.cache_axes(cfg) if shard is not None else None

    def _fn(p, cache, x0, draft_toks, draft_logits, active, sp, step,
            depth_limit):
        sampling.TRACE_COUNTS["verify"] += 1
        with sharding.shard_ctx(shard):
            # x0 (total, 1) pending tokens; draft_toks (k, total)
            inputs = jnp.concatenate(
                [x0, jnp.moveaxis(draft_toks, 0, 1)], axis=1)  # (total, k+1)
            logits, caches = registry.verify_scan(cfg, p, cache, inputs,
                                                  active=active)
            tl = jnp.moveaxis(logits, 1, 0)                  # (k+1, b, V)
            emit, n_acc, pending = accept_tokens_hetero(
                draft_toks, tl, draft_logits, sp, step, depth_limit)
            snap = registry.select_step(cfg, caches, n_acc)
            if shard is not None:
                # the rolled-back cache replaces the pool's — pin its
                # sharding so the next burst starts reshard-free
                snap = sharding.constrain_tree(snap, cax)
            # logprob surface for every emitted position (the engine
            # keeps only the accepted prefix) — raw-logit log-softmax,
            # so the emit/accept math above is untouched and token
            # streams stay bitwise identical to the surface-free verify
            lp, tv, ti = jax.vmap(sampling.token_logprobs)(tl, emit)
        return emit, n_acc, pending, snap, lp, tv, ti
    return jax.jit(_fn)


class SpecDecoder:
    """Per-engine speculative-decode driver (jit caches shared per
    config across instances, like the engine's step functions)."""

    def __init__(self, cfg, params, draft: DraftConfig, shard=None):
        if draft.k < 1:
            raise ValueError("draft.k must be >= 1")
        n = draft.layers or cfg.n_layers
        dcfg = registry.draft_config(cfg, n)
        if draft.step_impl is not None:
            dcfg = dataclasses.replace(dcfg, step_impl=draft.step_impl)
        self.cfg = cfg
        self.dcfg = dcfg
        self.k = draft.k
        self.n_draft = n
        # tensor-parallel shard key ((mesh, rules) or None) — the engine
        # passes already-sharded params, so slicing the draft view below
        # keeps the layer-stacked leaves on their TP placement
        self._shard = shard
        # slice the draft's param view once (host-side, shares buffers)
        self.draft_params = (params if n == cfg.n_layers
                             else registry.draft_params(cfg, params, n))
        self._draft = _jit_draft_step(cfg, dcfg, n, shard)
        # warm the full-depth verify jit cache entry; shallower windows
        # (end-of-request budget clamps, adaptive depth) compile on
        # demand, bounded by the k distinct depths
        _jit_verify(cfg, draft.k, shard)

    def propose(self, cache, toks, scratch_mask, sp, base_step,
                k_eff: int):
        """Run ``k_eff`` draft steps (<= self.k: the engine clamps the
        window to the shortest remaining token budget and the adaptive
        per-slot depth) on the scratch slots.  ``toks`` (total, 1)
        carries the forked slots' pending tokens at their scratch rows;
        ``sp``/``base_step`` are the pool's per-row params and stream
        positions (scratch rows mirror their live slot's, so draft
        proposal i at a slot whose stream position is n draws with the
        same fold_in(key, n + i) a plain burst would use).  Returns
        (cache, draft_toks (K, total), draft_logits (K, total, V)) —
        all device-side, indexed by POOL row (the caller maps scratch
        rows back to their live slots)."""
        d_toks, d_logits = [], []
        for i in range(k_eff):
            toks, lg, cache = self._draft(self.draft_params, cache, toks,
                                          scratch_mask, sp, base_step + i)
            d_toks.append(toks[:, 0])
            d_logits.append(lg)
        return cache, jnp.stack(d_toks), jnp.stack(d_logits)

    def verify(self, params, cache, x0, draft_toks, draft_logits,
               active, sp, step, depth_limit):
        """One batched target pass + per-slot acceptance + rollback
        select.  Returns (emit (K+1, total), n_acc (total,), pending
        (total,), rolled-back cache, chosen-logprobs (K+1, total),
        top-logprob values (K+1, total, TOP), top-logprob ids).  K is
        taken from draft_toks."""
        fn = _jit_verify(self.cfg, int(draft_toks.shape[0]), self._shard)
        return fn(params, cache, x0, draft_toks, draft_logits,
                  active, sp, step, depth_limit)
