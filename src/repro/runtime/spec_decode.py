"""Speculative decoding over the slot state pool.

Because an SSM's whole decode state is a fixed O(d_inner * d_state)
block per layer, a draft fork is ONE gather+scatter of pool slots and a
rollback is one per-slot select — no tree attention, no ragged KV
bookkeeping.  That is the structural advantage this module exploits
(attention-based spec decode spends most of its complexity budget
exactly there), and the part eMamba/FastMamba leave on the table by
targeting single-stream edge inference.

One speculative pass over the live slots:

  1. FORK    — lease one scratch slot per live slot and fork its pooled
               state into it (``SlotStatePool.fork``: payload + absmax
               scales move in the same dispatch).
  2. DRAFT   — run K cheap decode steps on the scratch slots with the
               self-speculative draft model: the target's first
               ``DraftConfig.layers`` layers (embed / final norm /
               unembed shared), optionally with a different step_impl
               ("unfused-cheap").  Live slots are mask-frozen.
  3. VERIFY  — one jit'd target pass: a (K+1)-step micro-scan chaining
               the SAME per-token ``decode_step`` dispatch the normal
               burst runs (fused kernel per layer per step) over
               [pending token, draft_1..draft_K], keeping every
               intermediate cache.
  4. ACCEPT  — standard speculative rejection sampling with the greedy
               shortcut at temperature 0 (accept while the draft equals
               the target's argmax; the first mismatch emits the
               target's own token), so the emitted stream is exactly
               the target model's — speculation changes throughput,
               never tokens.
  5. ROLLBACK— per-slot select of the cache after each slot's accepted
               prefix (``registry.select_step``) — the "single scatter
               of the last-accepted state back into the live slot".

Exactness contract: the verify micro-scan evaluates the target at the
same shapes and through the same jitted per-token step as plain decode,
so greedy spec decode is token-identical to plain greedy decode (gated
per family / state_dtype / step_impl in tests/test_spec_decode.py).
Each target pass emits between 1 and K+1 tokens per slot; the
accepted-tokens-per-target-pass counter in ServeStats is the speedup
proxy the benchmarks gate on.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import registry


def sample_last(logits, temperature: float, key):
    """(b, L, V) logits -> (b, 1) int32 tokens off the last position.
    Runs inside the jit'd step functions (temperature is trace-static).
    Shared with the engine so draft, verify, and plain decode sample
    identically."""
    last = logits.astype(jnp.float32)[:, -1:, :]
    if temperature <= 0:
        return jnp.argmax(last, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, last / temperature, axis=-1).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class DraftConfig:
    """Self-speculative draft settings (``EngineConfig.draft``).

    k: draft depth — tokens proposed per target pass.  Each pass emits
       between 1 and k+1 tokens, so k bounds the per-pass win.
    layers: draft depth in model layers; 0 = full depth (the draft IS
       the target: every proposal is accepted — useful for gating the
       accounting deterministically, pointless for speed).  Jamba
       requires a multiple of its group period.
    step_impl: override for the draft's per-token step routing (e.g.
       "xla" for an unfused-cheap draft while the target runs fused);
       None inherits the target's.
    """
    k: int = 4
    layers: int = 0
    step_impl: Optional[str] = None


def default_shallow_layers(cfg) -> int:
    """A ~half-depth draft rounded to the family's draft granularity.

    Jamba drafts whole groups (``attn_every`` layers each), so its
    depth must be a group multiple — a config with a single group (the
    smoke config) degrades to full depth.  Other families draft any
    layer prefix."""
    if cfg.family == "jamba":
        period = cfg.attn_every or 8
        groups = cfg.n_layers // period
        return max(1, groups // 2) * period
    return max(1, cfg.n_layers // 2)


# ---------------------------------------------------------------------------
# Acceptance core (pure; property-tested in tests/test_spec_decode.py)
# ---------------------------------------------------------------------------

def accept_tokens(draft_toks, target_logits, temperature: float,
                  draft_logits=None, key=None):
    """Speculative acceptance over one verified window.

    draft_toks (K, b) int32 — the draft's proposals d_1..d_K.
    target_logits (K+1, b, V) — the target's logits from the verify
      micro-scan: step i consumed [pending, d_1..d_K][i].
    draft_logits (K, b, V) — the draft's logits at each proposal;
      required when temperature > 0 (rejection-sampling ratio).

    Returns (emit (K+1, b) int32, n_acc (b,), pending (b,)):
      * n_acc[s] = j, the accepted draft prefix length (0..K);
      * emit[:j+1, s] is the emitted stream — the j accepted drafts
        plus one target-sampled token at the rejection point (or the
        bonus token when all K were accepted); entries past j are
        meaningless;
      * pending[s] = emit[j, s], the token whose state update has not
        been applied yet (feeds the next pass / burst).

    Temperature 0 takes the greedy shortcut: accept while the draft
    matches the target argmax.  Temperature > 0 is standard speculative
    rejection sampling (accept w.p. min(1, p_t/p_d); on rejection,
    resample from the normalized residual max(p_t - p_d, 0)), which
    leaves the emitted marginal exactly the target distribution.
    """
    K = draft_toks.shape[0]
    if temperature <= 0:
        tgt = jnp.argmax(target_logits.astype(jnp.float32),
                         axis=-1).astype(jnp.int32)         # (K+1, b)
        ok = (draft_toks == tgt[:K])
        acc = jnp.cumprod(ok.astype(jnp.int32), axis=0)      # (K, b)
        n_acc = acc.sum(axis=0)                              # (b,)
        # greedy emit: accepted positions satisfy d_i == argmax_i, and
        # the rejection/bonus token IS the argmax — so emit = argmax
        emit = tgt
        pending = jnp.take_along_axis(emit, n_acc[None], axis=0)[0]
        return emit, n_acc, pending

    if draft_logits is None or key is None:
        raise ValueError("sampled acceptance needs draft_logits and key")
    k_u, k_res, k_bonus = jax.random.split(key, 3)
    logp_t = jax.nn.log_softmax(
        target_logits[:K].astype(jnp.float32) / temperature, axis=-1)
    logp_d = jax.nn.log_softmax(
        draft_logits.astype(jnp.float32) / temperature, axis=-1)
    d = draft_toks[..., None]
    lp_t = jnp.take_along_axis(logp_t, d, axis=-1)[..., 0]   # (K, b)
    lp_d = jnp.take_along_axis(logp_d, d, axis=-1)[..., 0]
    u = jax.random.uniform(k_u, draft_toks.shape, minval=1e-20)
    ok = jnp.log(u) < (lp_t - lp_d)
    acc = jnp.cumprod(ok.astype(jnp.int32), axis=0)
    n_acc = acc.sum(axis=0)
    # residual resample at the rejection point: max(p_t - p_d, 0),
    # renormalized; degenerate (p_t == p_d exactly) falls back to p_t
    res = jnp.maximum(jnp.exp(logp_t) - jnp.exp(logp_d), 0.0)
    norm = res.sum(axis=-1, keepdims=True)
    safe = jnp.where(norm > 0, res / jnp.maximum(norm, 1e-30),
                     jnp.exp(logp_t))
    corr = jax.random.categorical(
        k_res, jnp.log(safe + 1e-30), axis=-1).astype(jnp.int32)
    bonus = jax.random.categorical(
        k_bonus,
        target_logits[K].astype(jnp.float32) / temperature,
        axis=-1).astype(jnp.int32)[None]                     # (1, b)
    emit = jnp.concatenate(
        [jnp.where(ok, draft_toks, corr), bonus], axis=0)    # (K+1, b)
    pending = jnp.take_along_axis(emit, n_acc[None], axis=0)[0]
    return emit, n_acc, pending


# ---------------------------------------------------------------------------
# Jit'd draft / verify passes (shared per config, as in engine.py)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _jit_draft_step(cfg, dcfg, n_layers: int, temperature: float):
    """One draft decode step over the pool: slice the first-n-layers
    cache view, run the draft model's decode_step, merge the updated
    layers back, freeze everything but the scratch slots, sample."""
    full = n_layers == cfg.n_layers and dcfg == cfg

    def _fn(pd, cache, toks, scratch_mask, key):
        cd = cache if full else registry.draft_cache(cfg, cache, n_layers)
        logits, cd2 = registry.decode_step(dcfg, pd, cd, {"tokens": toks})
        new_cache = (cd2 if full else
                     registry.draft_cache_merge(cfg, cache, cd2, n_layers))
        new_cache = registry.mask_slots(cfg, cache, new_cache,
                                        scratch_mask)
        tok = sample_last(logits, temperature, key)
        return tok, logits[:, -1, :], new_cache
    return jax.jit(_fn)


@functools.lru_cache(maxsize=None)
def _jit_verify(cfg, temperature: float, k: int):
    """The fused verify pass: (k+1)-step micro-scan over
    [pending, drafts], per-step freeze of inactive slots, acceptance,
    and the per-slot rollback select — one dispatch, one host sync."""
    sampled = temperature > 0

    def _fn(p, cache, x0, draft_toks, draft_logits, active, key):
        # x0 (total, 1) pending tokens; draft_toks (k, total) proposals
        inputs = jnp.concatenate(
            [x0, jnp.moveaxis(draft_toks, 0, 1)], axis=1)    # (total, k+1)
        logits, caches = registry.verify_scan(cfg, p, cache, inputs,
                                              active=active)
        tl = jnp.moveaxis(logits, 1, 0)                      # (k+1, b, V)
        emit, n_acc, pending = accept_tokens(
            draft_toks, tl, temperature,
            draft_logits=draft_logits if sampled else None,
            key=key if sampled else None)
        snap = registry.select_step(cfg, caches, n_acc)
        return emit, n_acc, pending, snap
    return jax.jit(_fn)


class SpecDecoder:
    """Per-engine speculative-decode driver (jit caches shared per
    config across instances, like the engine's step functions)."""

    def __init__(self, cfg, params, draft: DraftConfig,
                 temperature: float):
        if draft.k < 1:
            raise ValueError("draft.k must be >= 1")
        n = draft.layers or cfg.n_layers
        dcfg = registry.draft_config(cfg, n)
        if draft.step_impl is not None:
            dcfg = dataclasses.replace(dcfg, step_impl=draft.step_impl)
        self.cfg = cfg
        self.dcfg = dcfg
        self.k = draft.k
        self.n_draft = n
        self.temperature = float(temperature)
        # slice the draft's param view once (host-side, shares buffers)
        self.draft_params = (params if n == cfg.n_layers
                             else registry.draft_params(cfg, params, n))
        self._draft = _jit_draft_step(cfg, dcfg, n, self.temperature)
        # warm the full-depth verify jit cache entry; shallower windows
        # (end-of-request budget clamps) compile on demand, bounded by
        # the k distinct depths
        _jit_verify(cfg, self.temperature, draft.k)

    def propose(self, cache, toks, scratch_mask, keys):
        """Run ``len(keys)`` draft steps (<= self.k: the engine clamps
        the window to the shortest remaining token budget) on the
        scratch slots.  ``toks`` (total, 1) carries the forked slots'
        pending tokens at their scratch rows.  Returns (cache,
        draft_toks (K, total), draft_logits (K, total, V)) — all
        device-side, indexed by POOL row (the caller maps scratch rows
        back to their live slots)."""
        d_toks, d_logits = [], []
        for key in keys:
            toks, lg, cache = self._draft(self.draft_params, cache, toks,
                                          scratch_mask, key)
            d_toks.append(toks[:, 0])
            d_logits.append(lg)
        return cache, jnp.stack(d_toks), jnp.stack(d_logits)

    def verify(self, params, cache, x0, draft_toks, draft_logits,
               active, key):
        """One batched target pass + acceptance + rollback select.
        Returns (emit (K+1, total), n_acc (total,), pending (total,),
        rolled-back cache).  K is taken from draft_toks."""
        fn = _jit_verify(self.cfg, self.temperature,
                         int(draft_toks.shape[0]))
        return fn(params, cache, x0, draft_toks, draft_logits,
                  active, key)
