"""Prompt-prefix state cache — MARCA's buffer-reuse insight at the
admission path.

An SSM slot's whole decode state is a fixed O(d_inner * d_state) block
(plus conv tail / per-slot scales), so "caching a prompt prefix" is a
tiny state *snapshot*, not a length-growing KV strip: the batch-1 cache
pytree a prefill produces, captured at a token boundary, restorable
into any free slot with one scatter.  Payload, absmax scales and stream
position live in the same pytree and move together — exactly the
invariant `SlotStatePool.fork` maintains — so a restored prefix can
never tear quantized payload from scale.

Key semantics
-------------
Entries are keyed on the EXACT prefix token bytes (int32 ``tobytes``):
no hashing collisions, no normalization.  Snapshots are taken at
multiples of ``block`` tokens; a lookup walks boundaries deepest-first
(largest multiple of ``block`` that is <= len(prompt) - 1 — strictly
below the full prompt, so admission always prefills >= 1 suffix token
and the first sampled token's logits exist).  A cold admission inserts
a snapshot at EVERY boundary its prefill crosses, so two prompts
sharing an unaligned prefix still hit at the deepest common boundary.

Bounds & eviction: LRU over an OrderedDict, bounded by ``max_entries``
and optionally ``max_bytes`` (sum of snapshot leaf nbytes).  Eviction
drops the entry's pytree on the floor — slots are never involved, so
churn cannot leak state or scales into live requests.

Store residency: ``store="device"`` keeps snapshots as jnp arrays
(restore is free); ``store="host"`` offloads them to numpy — but the
device->host copy is a sync point, so inserts are queued and drained by
``flush_pending`` at the engine's existing sync boundaries (the
"cache-snapshot deadline" the burst scheduler treats as an uncertain
event).

Exactness: a HIT is token-identical to a COLD admission of the same
prompt for any state_dtype, by construction — a cache-enabled engine
chunks every admission at the same block boundaries (cold = block
prefill + suffix chain, hit = restored snapshot + the same chain), and
the snapshot IS the cold path's state at that boundary.  In f32 the
chunked computation is additionally bitwise the cache-DISABLED engine's
single-shot prefill; with a quantized state_dtype the quantization
points differ between chunked and single-shot prompt processing (the
same reason quantized decode agreement is a floor, not a guarantee),
so cache-on vs cache-off identity is an f32 property.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PrefixCacheConfig:
    """block: snapshot granularity in prompt tokens — snapshots are
    taken (and looked up) at multiples of this.  max_entries /
    max_bytes: LRU bounds (max_bytes=None -> unbounded bytes).
    store: "device" (jnp-resident, free restore) or "host" (numpy-
    resident; inserts deferred to flush_pending, restores copy back)."""
    block: int = 8
    max_entries: int = 32
    max_bytes: Optional[int] = None
    store: str = "device"

    def validate(self) -> None:
        if self.block < 1:
            raise ValueError(f"block must be >= 1; got {self.block}")
        if self.max_entries < 1:
            raise ValueError(f"max_entries must be >= 1; "
                             f"got {self.max_entries}")
        if self.max_bytes is not None and self.max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1; "
                             f"got {self.max_bytes}")
        if self.store not in ("device", "host"):
            raise ValueError(f"store must be 'device' or 'host'; "
                             f"got {self.store!r}")


@dataclasses.dataclass
class _Entry:
    snap: object          # batch-1 cache pytree (jnp or, offloaded, np)
    n_tokens: int         # prefix length the snapshot encodes
    nbytes: int
    on_host: bool


def tree_bytes(tree) -> int:
    """Total leaf nbytes of a cache/snapshot pytree — the honest payload
    size of a snapshot transfer (quantized payloads at storage width,
    absmax scales included)."""
    return sum(leaf.nbytes for leaf in jax.tree.leaves(tree))


# Snapshot transport helpers — shared by the host-store path below and
# by prefill/decode disaggregation (runtime/disagg.py), which ships the
# same batch-1 cache pytrees across a worker boundary.  Keeping both
# directions here means there is exactly one definition of "serialize a
# state snapshot" in the runtime: payload, scales and stream position
# always travel as one pytree.

def snapshot_to_host(snap):
    """Device -> host: one synchronizing device_get of every leaf."""
    return jax.device_get(snap)


def snapshot_to_device(snap):
    """Host -> device: upload every leaf (no-op on jnp-resident trees)."""
    return jax.tree.map(jnp.asarray, snap)


_tree_bytes = tree_bytes  # internal alias (pre-existing call sites)


class PrefixCache:
    """Bounded LRU store of prompt-prefix state snapshots.

    Host-side bookkeeping only — the engine owns all pool scatters.
    Counters (hits/misses/inserts/evictions/n_bytes) feed ServeStats.
    """

    def __init__(self, pcfg: PrefixCacheConfig):
        pcfg.validate()
        self.cfg = pcfg
        self._entries: "collections.OrderedDict[bytes, _Entry]" = \
            collections.OrderedDict()
        # host-store: not yet offloaded.  A deque: flush_pending drains
        # from the left every sync, and list.pop(0) is O(n) per drain.
        self._pending: "collections.deque[bytes]" = collections.deque()
        self.hits = 0
        self.misses = 0
        self.inserts = 0
        self.evictions = 0
        self.rejects = 0                  # snapshots refused (> max_bytes)
        self._bytes = 0

    # -- keys & boundaries --------------------------------------------------

    @staticmethod
    def _key(tokens) -> bytes:
        return np.asarray(tokens, np.int32).tobytes()

    def boundary(self, length: int) -> int:
        """Deepest snapshot boundary usable for a prompt of ``length``
        tokens: the largest multiple of ``block`` STRICTLY below
        ``length`` (so the suffix is never empty), or 0 when none."""
        p = ((length - 1) // self.cfg.block) * self.cfg.block
        return p if p >= self.cfg.block else 0

    # -- queries ------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def n_bytes(self) -> int:
        return self._bytes

    def lookup(self, prompt):
        """Deepest cached prefix of ``prompt`` at a block boundary.

        Returns (n_tokens, snap) with ``snap`` a device-resident batch-1
        cache pytree, or None.  Walks boundaries deepest-first so a
        prompt sharing 3 blocks with one donor and 1 with another takes
        the 3-block snapshot.  A hit refreshes LRU recency.  Exactly one
        of hits/misses is bumped per call (one call per admission).
        """
        prompt = np.asarray(prompt, np.int32)
        p = self.boundary(len(prompt))
        while p >= self.cfg.block:
            ent = self._entries.get(self._key(prompt[:p]))
            if ent is not None:
                self._entries.move_to_end(self._key(prompt[:p]))
                self.hits += 1
                snap = ent.snap
                if ent.on_host:
                    snap = jax.tree.map(jnp.asarray, snap)
                return ent.n_tokens, snap
            p -= self.cfg.block
        self.misses += 1
        return None

    # -- mutation -----------------------------------------------------------

    def insert(self, prefix_tokens, snap) -> None:
        """Cache ``snap`` (batch-1 cache pytree, device-resident) as the
        state after consuming ``prefix_tokens``.  An existing entry is
        refreshed (recency), not replaced — snapshots for the same exact
        prefix are interchangeable by construction."""
        key = self._key(prefix_tokens)
        if key in self._entries:
            self._entries.move_to_end(key)
            return
        ent = _Entry(snap=snap, n_tokens=len(prefix_tokens),
                     nbytes=_tree_bytes(snap), on_host=False)
        if (self.cfg.max_bytes is not None
                and ent.nbytes > self.cfg.max_bytes):
            # a snapshot that can NEVER fit would first evict every
            # older entry and then be evicted itself — a full-cache
            # thrash with zero retained value.  Refuse it up front and
            # count the refusal (surfaced via ServeStats.sync_prefix).
            self.rejects += 1
            return
        self._entries[key] = ent
        self._bytes += ent.nbytes
        self.inserts += 1
        if self.cfg.store == "host":
            self._pending.append(key)
        self._evict_over_bound()

    def _evict_over_bound(self) -> None:
        over_bytes = (self.cfg.max_bytes is not None
                      and self._bytes > self.cfg.max_bytes)
        while self._entries and (len(self._entries) > self.cfg.max_entries
                                 or over_bytes):
            key, ent = self._entries.popitem(last=False)
            self._bytes -= ent.nbytes
            self.evictions += 1
            if key in self._pending:
                self._pending.remove(key)
            over_bytes = (self.cfg.max_bytes is not None
                          and self._bytes > self.cfg.max_bytes)

    # -- deferred host offload ----------------------------------------------

    def has_pending(self) -> bool:
        """True when host-store snapshots still await offload — the
        scheduler's cache-snapshot deadline (an uncertain event: the
        burst must stay quantum-capped so the offload can run at the
        next sync point instead of after an unbounded burst)."""
        return bool(self._pending)

    def flush_pending(self, limit: Optional[int] = 1) -> int:
        """Offload up to ``limit`` pending snapshots to host memory
        (None = all).  Called at existing sync boundaries (the engine
        just device_get'd sampled tokens), so the copy adds no new
        device round trip.  Returns the number offloaded."""
        done = 0
        while self._pending and (limit is None or done < limit):
            key = self._pending.popleft()
            ent = self._entries.get(key)
            if ent is None or ent.on_host:
                # dead key (entry LRU-evicted since it was queued) or
                # already offloaded: skip WITHOUT charging the limit —
                # under churn a run of dead keys must not starve the
                # live snapshots behind them of their offload slot.
                continue
            ent.snap = jax.device_get(ent.snap)
            ent.on_host = True
            done += 1
        return done

    # -- stats --------------------------------------------------------------

    def counters(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "inserts": self.inserts, "evictions": self.evictions,
                "rejects": self.rejects,
                "entries": len(self._entries), "bytes": self._bytes}
