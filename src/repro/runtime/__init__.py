"""Runtime: training loop (resume/preemption/straggler), serving loop,
metrics."""
