"""Runtime: training loop (resume/preemption/straggler), serving engine,
metrics.

Serving request lifecycle (engine.py + state_pool.py + sampling.py):

  1. queue    — Engine.submit(prompt, SamplingParams) enqueues a
                Request; arrival-gated requests wait in a pending list
                until their trace time, ready requests sit in a
                priority queue (highest priority admits first).  Every
                sampling knob — temperature, top-k, top-p, seed, stop
                ids, budget — is per-request DATA: it lands in
                per-slot device arrays, never in a jit cache key, so
                one compiled step serves heterogeneous traffic.
  2. prefill  — when a pool slot is free, the request's prompt runs one
                exact-length batch-1 prefill; the resulting per-layer
                recurrent state (SSM h, conv tail, or KV strip) is
                scattered into the slot and the first token is sampled
                with the request's own params + seeded key stream.
  3. decode   — the slot joins the fixed-shape pooled decode batch; every
                engine step advances all active slots one token, with
                inactive slots masked so their state stays frozen.
                ``stream_cb`` callbacks deliver each request's new
                tokens at every scheduler sync; Engine.cancel()
                reclaims a slot (and any scratch lease) at the next
                sync, without perturbing co-resident streams.
  4. evict    — on a stop token, max_new, or cancellation the slot is
                reset to the init state (sampling-params row included)
                and returned to the free list; the next queued request
                is admitted on the same step.  Throughput/latency
                counters (metrics.ServeStats) track useful tokens,
                occupancy, TTFT, request latency, and cancellations.

With EngineConfig.draft (spec_decode.py), step 3 becomes a speculative
pass instead: fork the slot state into a leased scratch slot, draft K
cheap tokens there with the slot's own sampling params, verify them
with one batched target micro-scan, and roll the slot back to its
accepted prefix — 1..K+1 tokens per target pass, token-identical to
plain decode for greedy slots (even in a mixed greedy+sampled batch).

With EngineConfig.prefix_cache (prefix_cache.py), step 2 consults a
bounded LRU store of prompt-prefix state snapshots (taken at block
boundaries; payload + scales + position move together, like fork): a
hit restores the snapshot and prefills only the suffix via a
decode-step micro-scan — token-identical to the cold prefill.  With
SamplingParams.n > 1 (best-of-n), step 2 prefills once and forks n
branches whose sampling keys are re-derived per branch
(fork(branch_tags=...)); the parent Request returns the highest-
cumulative-logprob branch with all branches ranked in ``branches``.
Per-token logprob surfaces (SamplingParams.logprobs / top_logprobs)
ride every decode path without touching token math.

Serving front-end (PR 10):

  scheduler.py — SLOScheduler holds requests outside the engine and
    releases them by weighted fair queuing (per-tenant virtual-time
    tags; no tenant starves under burst), with per-class TTFT budgets
    in deterministic service steps driving a degradation ladder (cap
    speculative depth -> shrink best-of-n -> shed) that rejects new
    work BEFORE resident requests pay for it.
  frontend.py — AsyncFrontend pumps the engine in an executor and
    exposes submit/stream/cancel as asyncio primitives: per-request
    async token iterators fed via call_soon_threadsafe, per-tenant
    contexts, shed-aware handles.
  disagg.py — prefill/decode disaggregation: a 1-slot PrefillWorker
    runs the same compiled admission programs, ships the O(d_inner *
    d_state) state block (+ scales + position + first-token surface)
    over a bounded queue, and the decode pool restores it with the
    pool's one-scatter admit — token streams bitwise identical to the
    monolithic engine by construction, at any state_dtype.
  Engine.submit(session=True) — infinite-stream sessions: no max_new
    horizon, slot pinned against eviction (state_pool pin/unpin);
    legal only for families whose decode state is max_seq-independent.
"""
