"""Runtime: training loop (resume/preemption/straggler), serving engine,
metrics.

Serving request lifecycle (engine.py + state_pool.py):

  1. queue    — Engine.submit() enqueues a Request; arrival-gated
                requests wait in a pending list until their trace time.
  2. prefill  — when a pool slot is free, the request's prompt runs one
                exact-length batch-1 prefill; the resulting per-layer
                recurrent state (SSM h, conv tail, or KV strip) is
                scattered into the slot and the first token is sampled.
  3. decode   — the slot joins the fixed-shape pooled decode batch; every
                engine step advances all active slots one token, with
                inactive slots masked so their state stays frozen.
  4. evict    — on EOS or max_new the slot is reset to the init state and
                returned to the free list; the next queued request is
                admitted on the same step.  Throughput/latency counters
                (metrics.ServeStats) track useful tokens, occupancy,
                TTFT and request latency throughout.

With EngineConfig.draft (spec_decode.py), step 3 becomes a speculative
pass instead: fork the slot state into a leased scratch slot, draft K
cheap tokens there, verify them with one batched target micro-scan,
and roll the slot back to its accepted prefix — 1..K+1 tokens per
target pass, token-identical to plain decode under greedy sampling.
"""
