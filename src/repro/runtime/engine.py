"""Continuous-batching serving engine over the slot-based state pool.

Request lifecycle (see also runtime/__init__.py):

  submit(prompt, SamplingParams) -> [pending until arrival] -> ready
  queue (priority-ordered) -> prefill-into-slot -> joins the running
  decode batch -> per-slot stop-token / max-token finish (or cancel())
  -> evict (slot reset + freed) -> Request returned with tokens +
  timings.  A ``stream_cb`` receives each request's new tokens at every
  scheduler sync.

Scheduling policy: admit-eagerly, highest priority first (FIFO within a
priority).  Each engine ``step()`` first admits ready requests into
every free slot (one fused exact-length prefill-scatter-sample dispatch
per request), then runs a pooled decode BURST over all ``n_slots``
slots with inactive slots masked.  Sampling is fused into the decode
jit so tokens chain on-device; the host syncs once per burst.  A burst
runs to the next *certain* scheduling event (the shortest remaining
token budget = the next guaranteed eviction), capped by
``sched_quantum`` only when an uncertain event could act sooner (an
active stop token, a streaming callback that must be serviced — it may
cancel — or a free slot with queued work).  Because an SSM slot is
O(d_inner * d_state) regardless of sequence length, admission/eviction
are O(1) scatters and the decode batch shape never changes — no
ragged-batch re-bucketing between steps.

Sampling discipline (runtime/sampling.py): every per-request knob —
temperature, top-k, top-p, seed, stop ids, budget — is DATA.  The pool
carries per-slot parameter arrays that enter the jit'd steps as traced
arguments, so ONE compiled prefill/decode/verify signature serves a
batch mixing greedy and sampled requests and changing any
SamplingParams field never retraces (``sampling.TRACE_COUNTS`` is the
proof hook).  Randomness is per-slot counter-based: token i of request
r is drawn with fold_in(key(seed_r), i), so a sampled stream is
bitwise reproducible regardless of slot placement, batch composition,
or co-resident cancellations.

jit discipline: decode compiles once (fixed pool shape) and is shared
across Engine instances per config; the prefill compiles once per
distinct prompt length (callers that care should quantize prompt
lengths; the benchmark draws from a small set).

Speculative decoding (``EngineConfig.draft``): each scheduler iteration
becomes one fork -> K-draft -> batched-verify -> rollback pass
(runtime/spec_decode.py) instead of a token-by-token burst.  The pool
gains one scratch slot per live slot for draft forks; a greedy slot's
spec decode is token-identical to plain greedy decode — even in a
mixed greedy+sampled batch — and each target pass emits 1..K+1 tokens
per slot.  ``DraftConfig.adaptive`` clamps each slot's window to its
realized acceptance (Request.spec_accepted / spec_passes).

Prefix-state cache (``EngineConfig.prefix_cache``): because an SSM
slot's decode state is a fixed-size block, a prompt prefix is cacheable
as a tiny state *snapshot* — the batch-1 cache pytree (quantized
payload + absmax scales + stream position together, the same invariant
``fork`` keeps) captured at block boundaries into a bounded LRU store
(runtime/prefix_cache.py).  Admission of a prompt sharing a cached
prefix restores the snapshot and prefills only the suffix via a
decode-step micro-scan — the same per-token dispatch the verify scan
chains, so the result is token-identical to the cold full prefill.
Cold admissions snapshot every block boundary they cross, so unaligned
shared prefixes still hit at the deepest common boundary.

Best-of-n (``SamplingParams.n``): one prefill, n forked slots.  The
fork re-derives each branch's key by folding a branch tag into the
source key (``SlotStatePool.fork(branch_tags=...)``) — the fix for the
fork-seed aliasing bug where forked "alternatives" sampled bitwise-
identical streams.  Spec-decode draft forks pass NO tags and keep the
verbatim key copy their exactness contract requires; branch 0 is
bitwise the same request served at n=1.  Branches are ranked by
cumulative logprob (always accumulated, from the raw-logit log-softmax
every step jit now returns) on the parent ``Request``.

Caveat: MoE families route tokens across the batch through shared expert
capacity, so slot composition can perturb logits at tight
capacity_factor.  Pure Mamba / dense attention families are exactly
slot-independent (the engine's correctness tests assert this).

Front-end hooks (PR 10): ``submit(tenant=...)`` threads a tenant label
into per-tenant ServeStats; ``submit(session=True)`` opens an
infinite-stream session (no max_new horizon, slot pinned against
eviction — legal only for families whose decode state does not grow
with max_seq); ``submit_snapshot`` admits a request whose prompt was
prefilled elsewhere (runtime/disagg.py) by restoring the shipped state
block with the pool's one-scatter admit; ``spec_cap`` is the
scheduler's degradation knob (clamps speculative depth under load
without retracing).  A raising ``stream_cb`` no longer propagates into
the scheduler loop: the engine counts it, drops the callback, and
auto-cancels that request — co-resident streams are untouched.
"""
from __future__ import annotations

import bisect
import dataclasses
import functools
import heapq
import math
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.parallel import sharding
from repro.runtime import metrics as metrics_lib
from repro.runtime import sampling
from repro.runtime.prefix_cache import (PrefixCache, PrefixCacheConfig,
                                        snapshot_to_device)
from repro.runtime.sampling import SamplingParams
from repro.runtime.spec_decode import DraftConfig, SpecDecoder
from repro.runtime.state_pool import SlotStatePool


# Per-config jit'd step functions, shared across Engine instances (cfg is
# a frozen dataclass, hence hashable).  Without this every Engine would
# carry its own jit cache and re-trace/compile prefill and decode that an
# earlier engine — or the warmup pass — already compiled.  Sampling
# parameters are traced ARRAY arguments, never part of the cache key:
# heterogeneous per-request settings share one compile.
#
# ``shard`` ((mesh, rules) or None, both halves hashable) keys the
# tensor-parallel traces separately: the body enters sharding.shard_ctx
# so the models' logical ``constrain`` calls bake the mesh at trace
# time, and every returned pool cache is re-constrained to the pool's
# own placement — output sharding == input sharding, so bursts, forks
# and eviction scatters chain with zero per-step resharding.  With
# shard=None the context is a no-op and the traces are byte-identical
# to the pre-mesh engine.
@functools.lru_cache(maxsize=None)
def _jit_prefill_admit(cfg, shard=None):
    """Fused prefill-into-slot: full-seq prefill of one request, scatter
    of its state into the pool slot, and first-token sampling with the
    request's own params — one dispatch per admission.  Also returns
    the logprob surface (chosen + fixed-width top-k over the raw-logit
    log-softmax; token math untouched) and the last-position logits,
    which best-of-n admission samples each forked branch's first token
    from without re-running the prefill."""
    cax = registry.cache_axes(cfg) if shard is not None else None

    def _fn(p, fresh, tokens, pool_cache, slot_id, sp, step):
        sampling.TRACE_COUNTS["prefill_admit"] += 1
        with sharding.shard_ctx(shard):
            logits, sub = registry.prefill(cfg, p, fresh,
                                           {"tokens": tokens})
            new_pool = registry.scatter_slots(cfg, pool_cache, sub,
                                              slot_id)
            if shard is not None:
                new_pool = sharding.constrain_tree(new_pool, cax)
            last = logits[:, -1, :]
            tok = sampling.sample(last, sp, step)
            lp, tv, ti = sampling.token_logprobs(last, tok)
        return tok[:, None], lp, tv, ti, last, new_pool
    return jax.jit(_fn)


@functools.lru_cache(maxsize=None)
def _jit_prefill_prefix(cfg, shard=None):
    """Prefix-only prefill: consume the first ``block`` prompt tokens
    from the init state and return the batch-1 cache — the snapshot a
    cold admission inserts into the prefix cache before chaining the
    remaining tokens through the suffix micro-scan.  No scatter, no
    sampling: the snapshot is position-complete state, nothing else."""
    cax = registry.cache_axes(cfg) if shard is not None else None

    def _fn(p, fresh, tokens):
        sampling.TRACE_COUNTS["prefill_prefix"] += 1
        with sharding.shard_ctx(shard):
            _, sub = registry.prefill(cfg, p, fresh, {"tokens": tokens})
            if shard is not None:
                # batch-1 snapshot: slot axis replicated, TP-interior
                # leaves stay on "model" — restores scatter shard-local
                sub = sharding.constrain_tree(sub, cax)
        return sub
    return jax.jit(_fn)


@functools.lru_cache(maxsize=None)
def _jit_suffix_admit(cfg, m: int, shard=None):
    """Cached-prefix admission: restore a prefix snapshot and prefill
    only the ``m``-token suffix as a decode-step micro-scan — the SAME
    per-token dispatch a decode burst (and the spec-decode verify scan)
    runs, so the resulting state and sampled token are what the cold
    full prefill produces.  One fused dispatch: scan, scatter of the
    final state into the slot, first-token sampling.  The per-step
    cache stack rides back so the engine can insert snapshots at every
    block boundary the chain crossed.  Compiles once per distinct
    suffix length (same discipline as the exact-length prefill)."""
    cax = registry.cache_axes(cfg) if shard is not None else None

    def _fn(p, snap, toks, pool_cache, slot_id, sp, step):
        sampling.TRACE_COUNTS["suffix_admit"] += 1
        with sharding.shard_ctx(shard):
            def body(c, tok_t):
                logits, c2 = registry.decode_step(cfg, p, c,
                                                  {"tokens": tok_t})
                return c2, (logits[:, -1, :], c2)

            xs = jnp.moveaxis(toks[:, :, None], 1, 0)    # (1,m) -> (m,1,1)
            final, (lg, caches) = jax.lax.scan(body, snap, xs)
            new_pool = registry.scatter_slots(cfg, pool_cache, final,
                                              slot_id)
            if shard is not None:
                # pin the pool output only; ``caches`` has an extra
                # leading scan axis and stays wherever GSPMD puts it
                new_pool = sharding.constrain_tree(new_pool, cax)
            last = lg[-1]
            tok = sampling.sample(last, sp, step)
            lp, tv, ti = sampling.token_logprobs(last, tok)
        return tok[:, None], lp, tv, ti, last, new_pool, caches
    return jax.jit(_fn)


@functools.lru_cache(maxsize=None)
def _jit_decode_sample(cfg, shard=None):
    """Fused decode + per-slot sample: tokens stay on device so
    consecutive steps chain without a host round-trip (the burst loop
    syncs once per scheduling quantum, keeping XLA dispatch
    pipelined).  The logprob surface (chosen + top-k over the raw-logit
    log-softmax) rides along; the sampled-token math is untouched, so
    streams are bitwise the surface-free engine's."""
    cax = registry.cache_axes(cfg) if shard is not None else None

    def _decode_fn(p, cache, toks, active, sp, step):
        sampling.TRACE_COUNTS["decode_step"] += 1
        with sharding.shard_ctx(shard):
            logits, new_cache = registry.decode_step(cfg, p, cache,
                                                     {"tokens": toks})
            new_cache = registry.mask_slots(cfg, cache, new_cache,
                                            active)
            if shard is not None:
                new_cache = sharding.constrain_tree(new_cache, cax)
            last = logits[:, -1, :]
            tok = sampling.sample(last, sp, step)
            lp, tv, ti = sampling.token_logprobs(last, tok)
        return tok[:, None], lp, tv, ti, new_cache
    return jax.jit(_decode_fn)


def derive_seed(engine_seed: int, req_id: int) -> int:
    """Per-request seed for unseeded requests — module-level because a
    disaggregated pipeline must derive the SAME seed for request i that
    a monolithic engine would, or the token-identity contract breaks at
    the first sampled request."""
    return (engine_seed * 1_000_003 + req_id) & 0x7FFFFFFF


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 4
    max_seq: int = 256
    # engine seed: derives per-request seeds for requests whose
    # SamplingParams.seed is None (deterministically from the request
    # id, so unseeded streams are still reproducible per trace)
    seed: int = 0
    # default per-request params when submit() gets none (greedy)
    default_params: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    # scheduling quantum: max decode steps per burst between host syncs /
    # admission checks.  Larger = fewer syncs (throughput), smaller =
    # faster admission + tighter stop-token eviction + lower streaming /
    # cancellation latency.
    sched_quantum: int = 8
    # override for the model's per-token step routing (cfg.step_impl):
    # "megakernel" = ONE Pallas launch per token for the whole layer
    # stack (layer axis in the kernel grid, stacked weights/state;
    # jamba's attention sublayers stay on their own path), "fused" = one
    # kernel launch per layer per token for the SSM state-update/
    # contraction/gate chain, "xla" = unfused reference ops, None = keep
    # the model config's setting ("auto" resolves per backend:
    # megakernel on TPU).
    step_impl: Optional[str] = None
    # override for the pooled recurrent-state storage dtype
    # (cfg.state_dtype): "f32" | "bf16" | "int8" | "fp8".  int8/fp8
    # multiply slot capacity ~4x (per-slot absmax scales ride along in
    # the cache pytree); None = keep the model config's setting.
    state_dtype: Optional[str] = None
    # override for the attention KV-cache storage dtype
    # (cfg.kv_cache_dtype): "model" | "int8".  Composes with
    # state_dtype: on jamba, state_dtype covers the recurrent blocks
    # and kv_cache_dtype the per-position KV strips (which dominate
    # slot bytes at long max_seq).  None = keep the model config's.
    kv_cache_dtype: Optional[str] = None
    # override for the weight storage dtype (cfg.weight_dtype):
    # "f32" | "int8".  int8 quantizes the handed-in f32 params
    # per output channel (core/weight_quant.py) so decode streams
    # ~4x fewer weight bytes per token, dequantizing inside the
    # fused/megakernel decode kernels; embed/unembed/MoE stay f32.
    # The quantization is DECODE-side: prefill is compute-bound and
    # runs once per request, so it keeps serving from the caller's
    # f32 master weights (``Engine.prefill_params`` aliases them —
    # no copy) while every per-token decode/verify step streams the
    # int8 tree.  Composes with state_dtype/kv_cache_dtype (W8A8 +
    # quantized state/KV) and with ``mesh`` (scale leaves shard with
    # their payloads).  None = keep the model config's setting — the
    # default leaves engines byte-identical to unquantized serving.
    weight_dtype: Optional[str] = None
    # speculative decoding: None = plain decode bursts; a DraftConfig
    # turns every decode step into a fork -> K-draft -> batched-verify
    # -> rollback pass emitting 1..K+1 tokens per slot per target pass.
    # Greedy slots are token-identical to plain greedy decode; sampled
    # slots preserve their target distribution via per-slot rejection
    # sampling.  The pool grows n_slots scratch slots.
    draft: Optional[DraftConfig] = None
    # prompt-prefix state cache: None = every admission prefills its
    # full prompt; a PrefixCacheConfig snapshots per-block prefix state
    # into a bounded LRU store so admissions sharing a cached prefix
    # restore it with one scatter and prefill only the suffix —
    # token-identical to the cold prefill (gated in tests + bench).
    prefix_cache: Optional[PrefixCacheConfig] = None
    # tensor-parallel serving: a jax.sharding.Mesh (typically
    # launch/mesh.make_serving_mesh(tp) — 1-D over "model") shards the
    # stacked weights on their TP axes (ffn/heads/vocab -> "model") and
    # the pool's state/scale/KV leaves on the matching axes; slot
    # (batch) axes stay replicated, so admit/evict/fork scatters are
    # shard-local and every step chains reshard-free.  None (default)
    # = single-device, bitwise unchanged (the jit caches key on the
    # (mesh, rules) pair, so the unsharded traces are untouched).
    mesh: Optional[jax.sharding.Mesh] = None
    # logical-axis -> mesh-axis rules; None = sharding.ShardingRules()
    rules: Optional[sharding.ShardingRules] = None


@dataclasses.dataclass
class Request:
    """One generation request; engine fills tokens + timing fields."""
    req_id: int
    prompt: np.ndarray                    # (Lp,) int32
    params: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    seed: int = 0                         # resolved per-request PRNG seed
    max_new: int = 32                     # mirrors params.max_new
    stop_ids: frozenset = frozenset()     # params.stop (+ eos_id)
    eos_id: Optional[int] = None          # convenience mirror
    priority: int = 0                     # higher admits earlier
    stream_cb: Optional[Callable] = None  # (req, new_tokens) per sync
    cancelled: bool = False
    arrival: float = 0.0                  # offset (s) from run() start
    tenant: Optional[str] = None          # per-tenant stats label
    # infinite-stream session: no max_new horizon; the slot is pinned
    # (eviction-free lease) until a stop token/sequence or cancel()
    session: bool = False
    # disaggregated admission: a shipped prefill snapshot (state block +
    # scales + position + first-token surface) restored instead of
    # running the prefill locally — see Engine.submit_snapshot
    snapshot: Optional[object] = dataclasses.field(default=None,
                                                   repr=False)
    tokens: list = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_admit: Optional[float] = None       # prefill start
    t_first: Optional[float] = None       # first token out (TTFT anchor)
    t_done: Optional[float] = None
    # per-slot speculative-depth bookkeeping (spec decode only): how
    # many target passes this request's slot took and how many drafted
    # tokens were accepted — accepted/passes is the request's realized
    # speculative depth (and drives DraftConfig.adaptive).
    spec_passes: int = 0
    spec_accepted: int = 0
    # logprob return surface (params.logprobs / params.top_logprobs):
    # per-emitted-token chosen logprob and [(token_id, logprob)] top
    # alternatives, from the raw-logit log-softmax.  cum_logprob is
    # ALWAYS accumulated (it ranks best-of-n branches).
    logprobs: list = dataclasses.field(default_factory=list)
    top_logprobs: list = dataclasses.field(default_factory=list)
    cum_logprob: float = 0.0
    # best-of-n (params.n > 1): the submitted request is the PARENT —
    # it never holds a slot; n child branch requests do.  On finish the
    # parent carries the best branch's tokens/logprobs and ``branches``
    # holds every child ranked by (-cum_logprob, branch).  Children
    # point back via ``parent`` and carry their ``branch`` tag (the
    # same integer folded into their sampling key at fork time).
    branches: Optional[list] = dataclasses.field(default=None, repr=False)
    parent: Optional["Request"] = dataclasses.field(default=None,
                                                    repr=False)
    branch: int = 0
    _open: int = 0                        # unfinished children (parent)

    @property
    def finished(self) -> bool:
        return self.t_done is not None


class Engine:
    def __init__(self, cfg, params, ecfg: EngineConfig,
                 logger: Optional[metrics_lib.MetricsLogger] = None,
                 clock: Callable[[], float] = time.perf_counter):
        if cfg.frontend in ("audio_stub", "vision_stub"):
            raise NotImplementedError(
                "serving engine supports token frontends only")
        if ecfg.step_impl is not None:
            # cfg keys the shared jit caches, so fused and unfused engines
            # compile (and benchmark) independently
            cfg = dataclasses.replace(cfg, step_impl=ecfg.step_impl)
        if ecfg.state_dtype is not None:
            # same reasoning: a quantized-state engine and an f32 engine
            # have different cache pytrees and must not share compiles
            cfg = dataclasses.replace(cfg, state_dtype=ecfg.state_dtype)
        if ecfg.kv_cache_dtype is not None:
            cfg = dataclasses.replace(cfg,
                                      kv_cache_dtype=ecfg.kv_cache_dtype)
        prefill_params = params
        if ecfg.weight_dtype is not None:
            from repro.core import weight_quant
            already = weight_quant.is_quantized(cfg.weight_dtype)
            cfg = dataclasses.replace(cfg, weight_dtype=ecfg.weight_dtype)
            if weight_quant.is_quantized(ecfg.weight_dtype) and not already:
                # quantize BEFORE the mesh device_put below so sharded
                # engines place the int8+scale tree (abstract_params
                # reflects the quantized structure for the same cfg).
                # prefill_params keeps aliasing the caller's f32 tree:
                # weight quantization is a decode-bandwidth lever, and
                # the compute-bound prefill stays exact on the master
                # weights (a caller handing in an already-quantized
                # tree has no f32 master, so prefill then dequantizes
                # the codes like the XLA decode reference does)
                params = registry.quantize_params(cfg, params)
        ecfg.default_params.validate()
        # tensor-parallel serving: place the weights once (shape-aware
        # specs — non-divisible dims fall back to replicated) and key
        # every shared jit cache on the (mesh, rules) pair.  Committed
        # sharded params + pool drive jit sharding inference; outputs
        # are constrained back to the pool's placement, so no step ever
        # reshards.  mesh=None leaves params and traces untouched.
        self._shard = None
        if ecfg.mesh is not None:
            # MoE dispatch must stay on the pjit-auto dense path: the
            # expert-parallel shard_map path drops overflow tokens per
            # SHARD-local capacity, so its logits differ from the
            # single-device global-capacity routing — which would break
            # the sharded == single-device token-identity contract
            # (moe.py's EP docstring states the same caveat for tests)
            if getattr(cfg, "moe_impl", None) == "ep":
                raise ValueError(
                    "moe_impl='ep' is unsupported under a serving mesh: "
                    "per-shard capacity drops break token identity")
            if getattr(cfg, "moe_impl", None) == "auto":
                cfg = dataclasses.replace(cfg, moe_impl="dense")
            rules = ecfg.rules or sharding.ShardingRules()
            self._shard = (ecfg.mesh, rules)
            distinct = prefill_params is not params
            params = jax.device_put(
                params, sharding.tree_shardings(
                    registry.abstract_params(cfg), ecfg.mesh, rules))
            if distinct:
                # the f32 prefill master shards under the same rules as
                # an unquantized engine's weights would
                f32_cfg = dataclasses.replace(cfg, weight_dtype="f32")
                prefill_params = jax.device_put(
                    prefill_params, sharding.tree_shardings(
                        registry.abstract_params(f32_cfg), ecfg.mesh,
                        rules))
            else:
                prefill_params = params
        self.cfg = cfg
        self.params = params
        self.prefill_params = prefill_params
        self.ecfg = ecfg
        # one scratch slot per live slot: every live slot can fork a
        # draft in the same speculative pass
        n_scratch = ecfg.n_slots if ecfg.draft is not None else 0
        self.pool = SlotStatePool(cfg, ecfg.n_slots, ecfg.max_seq,
                                  n_scratch=n_scratch, mesh=ecfg.mesh,
                                  rules=ecfg.rules)
        # after device_put: the spec decoder slices its draft param view
        # from the already-sharded tree
        self._spec = (SpecDecoder(cfg, params, ecfg.draft,
                                  shard=self._shard)
                      if ecfg.draft is not None else None)
        # scheduler degradation knob: clamp every slot's speculative
        # window to this depth (None = uncapped).  Pure host-side depth
        # arithmetic — flipping it never retraces, and the clamp flows
        # through _slot_depth so greedy identity survives.
        self.spec_cap: Optional[int] = None
        # infinite-stream sessions are legal only when the decode state
        # is max_seq-independent (mamba/xlstm fixed blocks yes; jamba's
        # per-position KV strips no).  Probe by comparing abstract cache
        # shapes at two horizons — family-agnostic, no allocation.
        a = registry.abstract_cache(cfg, 1, ecfg.max_seq)
        b = registry.abstract_cache(cfg, 1, ecfg.max_seq + 1)
        self._cache_growable = any(
            x.shape != y.shape for x, y in
            zip(jax.tree.leaves(a), jax.tree.leaves(b)))
        self.stats = metrics_lib.ServeStats()
        self.logger = logger
        self._now = clock
        self._prefill = _jit_prefill_admit(cfg, self._shard)
        self._decode = _jit_decode_sample(cfg, self._shard)
        self._prefill_prefix = _jit_prefill_prefix(cfg, self._shard)
        self._prefix = (PrefixCache(ecfg.prefix_cache)
                        if ecfg.prefix_cache is not None else None)
        self._pending: list[Request] = []      # arrival-gated, sorted
        self._ready: list[tuple] = []          # (-priority, seq, Request)
        self._seq = 0                          # FIFO tiebreak in _ready
        self._by_id: dict[int, Request] = {}   # unfinished requests
        self._cancel_dirty = False
        self._slot_req: list[Optional[Request]] = [None] * ecfg.n_slots
        self._next_tok = np.zeros((self.pool.n_total, 1), np.int32)
        self._finished: list[Request] = []
        self._next_id = 0

    # ------------------------------------------------------------------
    # Request intake
    # ------------------------------------------------------------------

    def submit(self, prompt, params: Optional[SamplingParams] = None,
               max_new: Optional[int] = None,
               eos_id: Optional[int] = None,
               arrival: Optional[float] = None,
               priority: int = 0,
               stream_cb: Optional[Callable] = None,
               tenant: Optional[str] = None,
               session: bool = False) -> Request:
        """Enqueue a request.

        params: per-request SamplingParams (None = the engine's
          default_params, greedy unless configured).  ``max_new`` /
          ``eos_id`` are conveniences layered onto it: max_new
          overrides params.max_new, eos_id extends params.stop.
        arrival: seconds from run() start; gates admission for trace
          replay (None = ready immediately).
        priority: higher admits earlier among ready requests (FIFO
          within a priority level).
        stream_cb: ``cb(req, new_tokens)`` called at every scheduler
          sync with the >= 1 tokens appended since the last call; the
          final call has ``req.finished`` True.  The callback may call
          ``Engine.cancel`` (including on its own request).  A raising
          callback is isolated: counted in
          ``ServeStats.n_callback_errors``, dropped, and its request
          auto-cancelled — co-resident requests are unaffected.
        tenant: label for per-tenant ServeStats breakdowns (TTFT/TPOT
          percentiles, shed/degraded/SLO-violation counters).
        session: infinite-stream session — no max_new horizon (the
          stream runs until a stop token/sequence or cancel) and the
          slot holds an eviction-free lease (pinned).  Legal only for
          families whose decode state is max_seq-independent: a fixed
          O(d_inner * d_state) block decodes forever in constant
          bytes, which is exactly what per-position KV strips cannot
          do, so jamba-style hybrids are refused up front.
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        params = params if params is not None else self.ecfg.default_params
        if max_new is not None:
            params = dataclasses.replace(params, max_new=max_new)
        if eos_id is not None:
            params = dataclasses.replace(
                params, stop=tuple(params.stop) + (eos_id,))
        params.validate()
        if session:
            if self._cache_growable:
                raise ValueError(
                    "infinite-stream sessions need a max_seq-independent "
                    "decode state; this family's cache grows with "
                    "max_seq (per-position KV strips)")
            if params.n > 1:
                raise ValueError("sessions are single-stream (n == 1)")
            if prompt.size > self.ecfg.max_seq:
                raise ValueError(
                    f"session prompt ({prompt.size}) exceeds max_seq "
                    f"({self.ecfg.max_seq})")
        elif prompt.size + params.max_new > self.ecfg.max_seq:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new ({params.max_new}) "
                f"exceeds max_seq ({self.ecfg.max_seq})")
        if params.n > self.ecfg.n_slots:
            raise ValueError(
                f"n ({params.n}) exceeds n_slots ({self.ecfg.n_slots}): "
                f"every branch needs a slot")
        if params.n > 1 and stream_cb is not None:
            raise ValueError("stream_cb is unsupported for n > 1 "
                             "(n branches have no single stream)")
        req_id = self._next_id
        self._next_id += 1
        seed = (params.seed if params.seed is not None
                else self._derive_seed(req_id))
        req = Request(req_id=req_id, prompt=prompt, params=params,
                      seed=seed, max_new=params.max_new,
                      stop_ids=frozenset(params.stop), eos_id=eos_id,
                      priority=priority, stream_cb=stream_cb,
                      arrival=arrival or 0.0, t_submit=self._now(),
                      tenant=tenant, session=session)
        self._by_id[req_id] = req
        if arrival is None:
            self._push_ready(req)
        else:
            # bisect keeps the arrival-sorted invariant in O(n) per
            # insert — re-sorting on every submit was O(n^2 log n)
            # across a heavy trace replay
            bisect.insort(self._pending, req, key=lambda r: r.arrival)
        return req

    def _derive_seed(self, req_id: int) -> int:
        """Deterministic per-request seed for unseeded requests: a
        function of (engine seed, request id) only, so streams stay
        reproducible per trace and distinct across requests."""
        return derive_seed(self.ecfg.seed, req_id)

    def submit_snapshot(self, snap, arrival: Optional[float] = None,
                        priority: int = 0,
                        stream_cb: Optional[Callable] = None,
                        tenant: Optional[str] = None,
                        session: bool = False) -> Request:
        """Enqueue a request whose prompt was already prefilled by a
        disaggregated prefill worker (runtime/disagg.py).

        ``snap`` carries the prompt, resolved SamplingParams + seed,
        the post-prompt state block (batch-1 cache pytree: payload,
        absmax scales, stream position — one tree), and the worker's
        first-token surface (token, logprob, top-k rows).  Admission
        restores the state with the pool's one-scatter admit and
        installs the shipped first token — no local prefill — so the
        resulting stream is bitwise the monolithic engine's by
        construction: the worker ran the SAME compiled prefill program
        with the same seed/params, and scatter(gather(x)) is exact
        data movement at any state_dtype.

        The snapshot must come from a compatible engine: same model
        config and state/kv dtypes (checked structurally against the
        pool's cache leaves).
        """
        prompt = np.asarray(snap.prompt, np.int32).reshape(-1)
        params = snap.params
        params.validate()
        if params.n > 1:
            raise ValueError("snapshot admission is single-stream "
                             "(best-of-n forks decode-side state that "
                             "does not exist yet)")
        if session and self._cache_growable:
            raise ValueError(
                "infinite-stream sessions need a max_seq-independent "
                "decode state")
        if not session and prompt.size + params.max_new > self.ecfg.max_seq:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new ({params.max_new}) "
                f"exceeds max_seq ({self.ecfg.max_seq})")
        want = jax.tree.leaves(registry.abstract_cache(
            self.cfg, 1, self.ecfg.max_seq))
        got = jax.tree.leaves(snap.state)
        if len(want) != len(got) or any(
                w.shape != g.shape or w.dtype != g.dtype
                for w, g in zip(want, got)):
            raise ValueError(
                "snapshot state does not match this engine's cache "
                "layout (model config / state_dtype / max_seq mismatch)")
        req_id = self._next_id
        self._next_id += 1
        req = Request(req_id=req_id, prompt=prompt, params=params,
                      seed=snap.seed, max_new=params.max_new,
                      stop_ids=frozenset(params.stop),
                      priority=priority, stream_cb=stream_cb,
                      arrival=arrival or 0.0, t_submit=self._now(),
                      tenant=tenant, session=session, snapshot=snap)
        self._by_id[req_id] = req
        if arrival is None:
            self._push_ready(req)
        else:
            bisect.insort(self._pending, req, key=lambda r: r.arrival)
        return req

    def _push_ready(self, req: Request) -> None:
        heapq.heappush(self._ready, (-req.priority, self._seq, req))
        self._seq += 1

    def cancel(self, req_id: int) -> bool:
        """Cancel a request.  Queued requests are dropped before
        admission; a running request's slot (and, mid-speculation, its
        scratch lease) is reclaimed at the next scheduler sync — any
        tokens already delivered stand, no further tokens are produced.
        Safe to call from a ``stream_cb`` (including the request's
        own).  Returns False for unknown / already-finished ids."""
        req = self._by_id.get(req_id)
        if req is None or req.finished or req.cancelled:
            return False
        req.cancelled = True
        if req.branches is not None:
            # best-of-n cascade: the parent holds no slot, the branches
            # do — flag every live child so the sweep reclaims them all
            for child in req.branches:
                if not child.finished:
                    child.cancelled = True
        self._cancel_dirty = True
        return True

    # ------------------------------------------------------------------
    # Scheduler core
    # ------------------------------------------------------------------

    def _drop_cancelled(self, req: Request) -> None:
        """Retire a request cancelled before admission (no slot held)."""
        req.t_done = self._now()
        self.stats.record_cancelled()
        self._finished.append(req)
        self._by_id.pop(req.req_id, None)
        if self.logger:
            self.logger.log(event="cancel", req=req.req_id, slot=None,
                            n_tokens=len(req.tokens))

    def _sweep_cancelled(self) -> bool:
        """Reclaim every cancelled request at a sync point: evict
        running ones (slot + params row reset), purge queued ones."""
        if not self._cancel_dirty:
            return False
        self._cancel_dirty = False
        did = False
        for slot, req in enumerate(self._slot_req):
            if req is not None and req.cancelled:
                self._finish(slot)
                did = True
        if any(r.cancelled for r in self._pending):
            keep = []
            for r in self._pending:
                (keep.append(r) if not r.cancelled
                 else self._drop_cancelled(r))
            self._pending = keep
            did = True
        if any(e[2].cancelled for e in self._ready):
            for e in self._ready:
                if e[2].cancelled:
                    self._drop_cancelled(e[2])
            # keep the ORIGINAL (priority, seq) tuples: re-pushing with
            # fresh seqs would reassign FIFO order from raw heap-array
            # order and let later submissions jump earlier ones
            self._ready = [e for e in self._ready if not e[2].cancelled]
            heapq.heapify(self._ready)
            did = True
        return did

    def _deliver(self, req: Request, new_toks: list) -> None:
        """Stream delivery at a scheduler sync; the callback may flag a
        cancellation, which the caller reclaims right after.

        A RAISING callback is the client's failure, not the batch's:
        the exception is caught here (it used to propagate out of the
        scheduler loop and abort every co-resident stream), counted in
        ``ServeStats.n_callback_errors``, the callback dropped so it is
        never called again, and the offending request auto-cancelled —
        its slot is reclaimed at this same sync by the caller's
        existing cancelled-check, and every other stream is bitwise
        untouched (delivery never feeds back into token math)."""
        if req.stream_cb is None or not new_toks:
            return
        try:
            req.stream_cb(req, new_toks)
        except Exception:
            self.stats.n_callback_errors += 1
            req.stream_cb = None
            if self.logger:
                self.logger.log(event="stream_cb_error", req=req.req_id,
                                n_tokens=len(req.tokens))
            if not req.finished and not req.cancelled:
                self.cancel(req.req_id)

    def _append_token(self, req: Request, tok: int, lp, tv, ti) -> None:
        """Record one emitted token plus its logprob surface: chosen
        logprob always accumulates into cum_logprob (it ranks best-of-n
        branches); the per-token lists fill only when the request asked
        (params.logprobs / params.top_logprobs)."""
        req.tokens.append(tok)
        req.cum_logprob += float(lp)
        if req.params.logprobs:
            req.logprobs.append(float(lp))
        if req.params.top_logprobs:
            k = req.params.top_logprobs
            req.top_logprobs.append(
                [(int(ti[i]), float(tv[i])) for i in range(k)])

    def _admit_into_slot(self, req: Request, slot: int):
        """Prefill ``req``'s prompt into ``slot`` (params row already
        set), consulting the prefix cache when enabled.  Cache hit:
        restore the deepest cached block-boundary snapshot and chain
        only the suffix through the decode-step micro-scan.  Cold (with
        a usable boundary): prefill the first block once, then chain
        the rest — inserting a snapshot at EVERY boundary the chain
        crosses, so later prompts sharing any block-aligned prefix hit.
        Returns (tok, lp, tv_row, ti_row, last_logits) with the first
        three host-side and ``last_logits`` the device (1, V) logits
        best-of-n samples its remaining branches' first tokens from."""
        t0 = self._now()
        req.t_admit = t0
        prompt = req.prompt
        length = int(prompt.size)
        pc = self._prefix
        sp_row = self.pool.params.row(slot)
        step0 = jnp.zeros((1,), jnp.int32)
        slot_arr = jnp.asarray([slot])
        hit = None
        snap = None
        p_from = 0
        bound = pc.boundary(length) if pc is not None else 0
        if pc is not None and bound > 0:
            hit = pc.lookup(prompt)
            if hit is not None:
                p_from, snap = hit
            else:
                # cold: one fixed-block-length prefill seeds the first
                # snapshot; the suffix scan below computes the rest
                p_from = pc.cfg.block
                snap = self._prefill_prefix(
                    self.prefill_params, self.pool.fresh,
                    jnp.asarray(prompt[None, :p_from]))
                pc.insert(prompt[:p_from], snap)
        if snap is None:
            tok_dev, lp, tv, ti, last, new_pool = self._prefill(
                self.prefill_params, self.pool.fresh,
                jnp.asarray(prompt[None]),
                self.pool.cache, slot_arr, sp_row, step0)
            self.pool.cache = new_pool
        else:
            m = length - p_from
            fn = _jit_suffix_admit(self.cfg, m, self._shard)
            tok_dev, lp, tv, ti, last, new_pool, chain = fn(
                self.prefill_params, snap,
                jnp.asarray(prompt[None, p_from:]),
                self.pool.cache, slot_arr, sp_row, step0)
            self.pool.cache = new_pool
            # chain index j is the state after prompt[:p_from + j + 1]
            for p in range(p_from + pc.cfg.block, bound + 1,
                           pc.cfg.block):
                pc.insert(prompt[:p],
                          jax.tree.map(
                              lambda leaf, j=p - p_from - 1: leaf[j],
                              chain))
        if pc is not None and bound > 0:
            self.stats.record_prefix(hit is not None,
                                     p_from if hit is not None else 0)
        n_computed = length - (p_from if hit is not None else 0)
        tok = int(np.asarray(tok_dev)[0, 0])
        req.t_first = self._now()
        # prefill_tokens stays the honest COMPUTE count: restored-from-
        # cache tokens land in prefix_cached_tokens instead, which is
        # what the bench gate's strict-reduction assertion diffs
        self.stats.record_prefill(n_computed, req.t_first - t0)
        return (tok, float(np.asarray(lp)[0]), np.asarray(tv)[0],
                np.asarray(ti)[0], last)

    def _install(self, req: Request, slot: int, tok: int, lp, tv,
                 ti) -> None:
        """Bind an admitted request to its slot and deliver its first
        token (shared tail of plain and best-of-n admission)."""
        self._slot_req[slot] = req
        self._next_tok[slot, 0] = tok
        self._append_token(req, tok, lp, tv, ti)
        if self.logger:
            self.logger.log(event="admit", req=req.req_id, slot=slot,
                            prompt_len=int(req.prompt.size))
        if self._hit_stop(req):
            self._finish(slot)
        self._deliver(req, [tok])
        if req.cancelled and not req.finished:
            self._finish(slot)

    def _admit_snapshot_into_slot(self, req: Request, slot: int):
        """Disaggregated admission: one scatter of the shipped state
        block into ``slot`` — the same ``SlotStatePool.admit`` a prefix
        restore uses — then install the worker's first token.  No local
        prefill ran, so prefill_tokens is untouched; the transfer is
        accounted in the snapshot_* counters."""
        t0 = self._now()
        req.t_admit = t0
        snap = req.snapshot
        self.pool.admit(slot, snapshot_to_device(snap.state))
        req.t_first = self._now()
        self.stats.record_snapshot_admit(n_tokens=int(req.prompt.size),
                                         nbytes=snap.nbytes)
        return snap.tok, snap.lp, np.asarray(snap.tv), np.asarray(snap.ti)

    def _admit(self, req: Request) -> None:
        slot = self.pool.alloc()
        assert slot is not None
        self.pool.params.set(slot, req.params, req.seed)
        if req.snapshot is not None:
            tok, lp, tv, ti = self._admit_snapshot_into_slot(req, slot)
        else:
            tok, lp, tv, ti, _ = self._admit_into_slot(req, slot)
        if req.session:
            # eviction-free lease: _finish unpins before evicting
            self.pool.pin(slot)
        self._install(req, slot, tok, lp, tv, ti)

    def _branch_request(self, parent: Request, b: int) -> Request:
        """Child request for branch ``b`` of a best-of-n parent.  The
        child's key row is NOT derived from its seed — the fork's
        branch-tag fold is its key derivation — so ``seed`` is carried
        only for bookkeeping."""
        child = Request(
            req_id=self._next_id, prompt=parent.prompt,
            params=dataclasses.replace(parent.params, n=1),
            seed=parent.seed, max_new=parent.max_new,
            stop_ids=parent.stop_ids, eos_id=parent.eos_id,
            priority=parent.priority, t_submit=parent.t_submit,
            branch=b, parent=parent)
        self._next_id += 1
        self._by_id[child.req_id] = child
        return child

    def _admit_group(self, parent: Request) -> None:
        """Best-of-n admission: ONE prefill into the first slot, then
        one fused fork into the remaining n-1 slots with branch tags
        1..n-1 (each branch's key = fold_in(parent key, branch) — the
        fork-seed aliasing fix), then each remaining branch's first
        token sampled from the prefill's last-position logits under its
        own folded key.  Branch 0 keeps the parent's verbatim key, so
        its stream is bitwise the same request served at n=1."""
        n = parent.params.n
        slots = [self.pool.alloc() for _ in range(n)]
        assert all(s is not None for s in slots)
        children = [self._branch_request(parent, b) for b in range(n)]
        parent.branches = list(children)
        parent._open = n
        self.pool.params.set(slots[0], parent.params, parent.seed)
        tok0, lp0, tv0, ti0, last = self._admit_into_slot(children[0],
                                                          slots[0])
        parent.t_admit = children[0].t_admit
        parent.t_first = children[0].t_first
        # fork BEFORE any stop/cancel handling can evict slot 0: every
        # branch needs its post-prompt state (and its params row, which
        # the tagged copy re-keys)
        self.pool.fork([slots[0]] * (n - 1), slots[1:],
                       branch_tags=list(range(1, n)))
        firsts = [(tok0, lp0, tv0, ti0)]
        for b in range(1, n):
            row = self.pool.params.row(slots[b])
            tb = sampling.sample(last, row, jnp.zeros((1,), jnp.int32))
            lb, tvb, tib = sampling.token_logprobs(last, tb)
            firsts.append((int(np.asarray(tb)[0]),
                           float(np.asarray(lb)[0]),
                           np.asarray(tvb)[0], np.asarray(tib)[0]))
        for b in range(n):
            tok, lp, tv, ti = firsts[b]
            self._install(children[b], slots[b], tok, lp, tv, ti)

    def _hit_stop(self, req: Request) -> bool:
        # a session has no token horizon: only stops / cancel end it
        if not req.session and len(req.tokens) >= req.max_new:
            return True
        if req.stop_ids and req.tokens[-1] in req.stop_ids:
            return True
        # multi-token stop sequences: suffix-window match on the emitted
        # stream (the whole sequence is delivered; burst overshoot past
        # the match is trimmed by the caller's break, like single stops)
        for seq in req.params.stop_seqs:
            seq = tuple(seq)
            if (len(req.tokens) >= len(seq)
                    and tuple(req.tokens[-len(seq):]) == seq):
                return True
        return False

    def _finish(self, slot: int) -> None:
        req = self._slot_req[slot]
        req.t_done = self._now()
        if req.parent is not None:
            # branch of a best-of-n group: stats and the finished list
            # see only the parent (one request submitted, one retired)
            pass
        elif req.cancelled:
            self.stats.record_cancelled()
        else:
            self.stats.record_request(ttft=req.t_first - req.t_submit,
                                      latency=req.t_done - req.t_submit,
                                      n_tokens=len(req.tokens),
                                      tenant=req.tenant)
        if req.session:
            self.pool.unpin(slot)
        self.pool.evict(slot)
        self._slot_req[slot] = None
        self._next_tok[slot, 0] = 0
        if req.parent is None:
            self._finished.append(req)
        self._by_id.pop(req.req_id, None)
        if self.logger:
            self.logger.log(
                event="cancel" if req.cancelled else "finish",
                req=req.req_id, slot=slot, n_tokens=len(req.tokens))
        if req.parent is not None:
            self._child_done(req)

    def _child_done(self, child: Request) -> None:
        parent = child.parent
        parent._open -= 1
        if parent._open == 0:
            self._finalize_parent(parent)

    def _finalize_parent(self, parent: Request) -> None:
        """All branches finished: rank them by cumulative logprob
        (ties broken by branch index — deterministic), surface the best
        branch's stream on the parent, retire the parent."""
        kids = sorted(parent.branches,
                      key=lambda c: (-c.cum_logprob, c.branch))
        parent.branches = kids
        best = kids[0]
        parent.tokens = list(best.tokens)
        parent.logprobs = list(best.logprobs)
        parent.top_logprobs = list(best.top_logprobs)
        parent.cum_logprob = best.cum_logprob
        parent.t_done = self._now()
        if parent.cancelled:
            self.stats.record_cancelled()
        else:
            self.stats.record_request(
                ttft=parent.t_first - parent.t_submit,
                latency=parent.t_done - parent.t_submit,
                n_tokens=len(parent.tokens), tenant=parent.tenant)
        self._finished.append(parent)
        self._by_id.pop(parent.req_id, None)
        if self.logger:
            self.logger.log(event="finish_group", req=parent.req_id,
                            n=len(kids), best=best.branch,
                            n_tokens=len(parent.tokens))

    def _base_steps(self, active) -> np.ndarray:
        """Per-slot stream positions at sync start: tokens already
        emitted — the fold_in counter that keys each slot's next
        draws."""
        base = np.zeros((self.pool.n_total,), np.int32)
        for s in active:
            base[s] = len(self._slot_req[s].tokens)
        return base

    @staticmethod
    def _remaining(req: Request) -> int:
        """Token budget left before the CERTAIN eviction — a session
        has none (only stops/cancel end it), so it reports an effectively
        infinite horizon and must never be the burst planner's certain
        event."""
        if req.session:
            return 1 << 30
        return req.max_new - len(req.tokens)

    def _burst_len(self, active) -> int:
        """Decode steps until the next scheduling event.

        The shortest remaining token budget among active slots is the
        next *certain* eviction; nothing can be admitted before then when
        all slots are busy, so in that state the burst runs uncapped to
        the eviction — zero intermediate host syncs, matching a static
        loop's dispatch pipelining with none of its wasted steps.  The
        quantum caps the burst only when an *uncertain* event could act
        sooner: a stop token (single-id or multi-token sequence) may
        evict any step (overshoot is trimmed but wastes the slot until
        the burst ends), a streaming callback must be serviced
        regularly (it may cancel mid-stream), a pending prefix-cache
        snapshot offload is waiting for the next host sync (the
        cache-snapshot deadline), a free slot plus queued/pending
        work means an admission check is worth taking, and an
        infinite-stream session can only ever end on an uncertain
        event (its ``_remaining`` is unbounded — without the quantum
        cap the burst would never return to the host)."""
        remaining = min(self._remaining(self._slot_req[s])
                        for s in active)
        uncertain = any(self._slot_req[s].stop_ids
                        or self._slot_req[s].params.stop_seqs
                        or self._slot_req[s].stream_cb is not None
                        or self._slot_req[s].session
                        for s in active)
        if self._prefix is not None and self._prefix.has_pending():
            uncertain = True
        may_admit = self.pool.n_free > 0 and (self._ready or self._pending)
        if uncertain or may_admit:
            return max(1, min(remaining, self.ecfg.sched_quantum))
        return max(1, remaining)

    def _decode_burst(self) -> None:
        active = self.pool.active_slots()
        n_steps = self._burst_len(active)
        t0 = self._now()
        toks = jnp.asarray(self._next_tok)
        act = jnp.asarray(self.pool.active_mask())
        sp = self.pool.params.device()
        base = jnp.asarray(self._base_steps(active))
        cache = self.pool.cache
        outs, lps, tvs, tis = [], [], [], []
        for t in range(n_steps):
            toks, lp, tv, ti, cache = self._decode(self.params, cache,
                                                   toks, act, sp,
                                                   base + t)
            outs.append(toks)
            lps.append(lp)
            tvs.append(tv)
            tis.append(ti)
        self.pool.cache = cache
        # one host sync per burst; device_get on the lists avoids
        # compiling an XLA concatenate per distinct burst length
        outs_h, lp_h, tv_h, ti_h = jax.device_get((outs, lps, tvs, tis))
        burst = np.concatenate(outs_h, axis=1)
        n_appended = 0
        for slot in active:
            req = self._slot_req[slot]
            new_toks = []
            for t in range(n_steps):
                tok = int(burst[slot, t])
                self._append_token(req, tok, lp_h[t][slot],
                                   tv_h[t][slot], ti_h[t][slot])
                new_toks.append(tok)
                n_appended += 1
                self._next_tok[slot, 0] = tok
                if self._hit_stop(req):
                    self._finish(slot)
                    break                 # trim overshoot past a stop
            self._deliver(req, new_toks)
            if req.cancelled and not req.finished:
                self._finish(slot)
        self.stats.record_decode(n_active=len(active),
                                 n_slots=self.ecfg.n_slots,
                                 dt=self._now() - t0,
                                 n_steps=n_steps, n_tokens=n_appended)

    # ------------------------------------------------------------------
    # Speculative decoding (EngineConfig.draft)
    # ------------------------------------------------------------------

    def _slot_depth(self, req: Request) -> int:
        """Per-slot speculative window (DraftConfig.adaptive): after
        warmup, clamp to the request's realized acceptance + 1 token of
        optimism — pure depth arithmetic, never touches token values,
        so greedy identity survives."""
        dc = self.ecfg.draft
        # the scheduler's degradation cap composes with (never replaces)
        # the adaptive clamp: under load the window shrinks to spec_cap
        # even for a perfectly-accepting slot
        kmax = (self._spec.k if self.spec_cap is None
                else max(1, min(self._spec.k, self.spec_cap)))
        # warmup floors at 1 pass: the clamp needs at least one realized
        # pass or the division below has nothing to divide by
        if not dc.adaptive or req.spec_passes < max(1, dc.adapt_warmup):
            return kmax
        realized = req.spec_accepted / req.spec_passes
        return int(min(kmax, max(1, math.ceil(realized) + 1)))

    def _spec_pass(self) -> None:
        """One fork -> K-draft -> batched-verify -> rollback pass over
        the live slots, emitting 1..K+1 tokens per slot per target
        pass.  Device work chains across fork/draft/verify; the host
        syncs once per pass for accept/stop bookkeeping (vs once per
        token for plain decode — the sync amortization IS part of the
        spec win).  Scratch leases are released even if a jit raises
        mid-pass (the pool-leak tests cover an abandoned burst)."""
        spec = self._spec
        active = self.pool.active_slots()
        # clamp the draft window to the shortest remaining token budget:
        # a slot about to hit max_new would have its whole window
        # trimmed anyway, so drafting past it is pure wasted dispatch
        # (stop tokens stay an uncertain event and are still trimmed
        # host-side); adaptive per-slot depth shrinks it further when
        # every slot's realized acceptance is low
        remaining = min(self._remaining(self._slot_req[s])
                        for s in active)
        depths = {s: self._slot_depth(self._slot_req[s]) for s in active}
        k_eff = min(max(depths.values()), remaining - 1)
        if k_eff < 1:
            # every active slot needs exactly one more token: plain
            # decode burst (its own burst-length logic handles this)
            self._decode_burst()
            return
        t0 = self._now()
        leases: list[int] = []
        try:
            for _ in active:
                sc = self.pool.lease_scratch()
                assert sc is not None        # n_scratch == n_slots
                leases.append(sc)
            # branch_tags deliberately None: the draft scratch slot must
            # continue the request's EXACT key schedule (fork copies the
            # key verbatim) or spec decode loses its faithfulness
            # contract — only best-of-n forks tag
            self.pool.fork(active, leases)   # state + sampling params
            total = self.pool.n_total
            toks = np.zeros((total, 1), np.int32)
            toks[leases, 0] = self._next_tok[active, 0]
            scratch_mask = np.zeros((total,), bool)
            scratch_mask[leases] = True
            base = self._base_steps(active)
            base[leases] = base[active]      # draft keys mirror live
            limit = np.full((total,), k_eff, np.int32)
            for s in active:
                limit[s] = min(depths[s], k_eff)
            sp = self.pool.params.device()
            cache, d_toks, d_logits = spec.propose(
                self.pool.cache, jnp.asarray(toks),
                jnp.asarray(scratch_mask), sp, jnp.asarray(base), k_eff)
            # proposals were drafted at scratch rows; the verify wants
            # them at their live slots' rows
            perm = np.arange(total)
            perm[active] = leases
            perm = jnp.asarray(perm)
            emit, n_acc, _, snap, v_lp, v_tv, v_ti = spec.verify(
                self.params, cache, jnp.asarray(self._next_tok),
                d_toks[:, perm], d_logits[:, perm],
                jnp.asarray(self.pool.active_mask()), sp,
                jnp.asarray(base), jnp.asarray(limit))
            # the rollback: every live slot's row of ``snap`` is the
            # state after exactly its accepted prefix
            self.pool.cache = snap
            emit_h, n_acc_h = np.asarray(emit), np.asarray(n_acc)
            lp_h, tv_h, ti_h = (np.asarray(v_lp), np.asarray(v_tv),
                                np.asarray(v_ti))
        finally:
            for sc in leases:
                self.pool.release_scratch(sc)
        n_appended = 0
        n_accepted = 0
        for slot in active:
            req = self._slot_req[slot]
            n_emit = int(n_acc_h[slot]) + 1
            n_accepted += n_emit - 1
            req.spec_passes += 1
            req.spec_accepted += n_emit - 1
            new_toks = []
            for t in range(n_emit):
                tok = int(emit_h[t, slot])
                self._append_token(req, tok, lp_h[t, slot],
                                   tv_h[t, slot], ti_h[t, slot])
                new_toks.append(tok)
                n_appended += 1
                self._next_tok[slot, 0] = tok
                if self._hit_stop(req):
                    self._finish(slot)
                    break                 # trim overshoot past stop/budget
            self._deliver(req, new_toks)
            if req.cancelled and not req.finished:
                self._finish(slot)
        self.stats.record_decode(n_active=len(active),
                                 n_slots=self.ecfg.n_slots,
                                 dt=self._now() - t0,
                                 n_steps=k_eff + 1, n_tokens=n_appended)
        self.stats.record_spec(n_active=len(active),
                               n_drafted=k_eff * len(active),
                               n_accepted=n_accepted,
                               n_emitted=n_appended)

    def step(self) -> bool:
        """One scheduler iteration: reclaim cancellations, admit into
        free slots (highest priority first), then one decode burst (or
        one speculative pass).  Returns False when there was nothing
        to do.  Admission peeks before popping: a best-of-n request
        needs ``n`` free slots at once, and blocks the line until it
        has them (admitting lower-priority work past it would starve
        it forever under load)."""
        did = self._sweep_cancelled()
        while self._ready and self.pool.n_free:
            req = self._ready[0][2]
            if req.cancelled:
                heapq.heappop(self._ready)
                self._drop_cancelled(req)
                continue
            if req.params.n > self.pool.n_free:
                break
            heapq.heappop(self._ready)
            if req.params.n > 1:
                self._admit_group(req)
            else:
                self._admit(req)
            did = True
        if self.pool.n_active:
            if self._spec is not None:
                self._spec_pass()
            else:
                self._decode_burst()
            did = True
        if self._prefix is not None:
            # the burst just host-synced: drain one deferred host-store
            # snapshot offload (the cache-snapshot deadline) and adopt
            # the cache's storage counters
            if self._prefix.has_pending():
                self._prefix.flush_pending(limit=1)
                did = True
            self.stats.sync_prefix(self._prefix.counters())
        return did

    # ------------------------------------------------------------------
    # Drive loop
    # ------------------------------------------------------------------

    def run(self) -> list[Request]:
        """Run until every submitted request is finished or cancelled;
        replays arrival-gated requests against a wall clock starting
        now.  Returns the requests retired during THIS call, in
        completion order (the engine keeps no reference afterwards)."""
        self.stats.start()
        self._finished = []
        t0 = self._now()
        while self._pending or self._ready or self.pool.n_active:
            now = self._now() - t0
            while self._pending and self._pending[0].arrival <= now:
                req = self._pending.pop(0)
                if req.cancelled:
                    self._drop_cancelled(req)
                    continue
                # TTFT/latency are measured from the (simulated) arrival,
                # not from when the trace was queued before run()
                req.t_submit = self._now()
                self._push_ready(req)
            if not self.step() and self._pending:
                wait = self._pending[0].arrival - (self._now() - t0)
                if wait > 0:
                    time.sleep(min(wait, 0.05))
        if self._prefix is not None:
            # idle: no burst deadline competes with the offloads
            self._prefix.flush_pending(limit=None)
            self.stats.sync_prefix(self._prefix.counters())
        self.stats.stop()
        if self.logger:
            self.logger.log(event="summary", **self.stats.summary())
        return self._finished
