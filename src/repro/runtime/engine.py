"""Continuous-batching serving engine over the slot-based state pool.

Request lifecycle (see also runtime/__init__.py):

  submit() -> [pending until arrival] -> ready queue -> prefill-into-slot
  -> joins the running decode batch -> per-slot EOS / max-token finish
  -> evict (slot reset + freed) -> Request returned with tokens + timings.

Scheduling policy: admit-eagerly FIFO.  Each engine ``step()`` first
admits ready requests into every free slot (one fused exact-length
prefill-scatter-sample dispatch per request), then runs a pooled decode
BURST over all ``n_slots`` slots with inactive slots masked.  Sampling
is fused into the decode jit so tokens chain on-device; the host syncs
once per burst.  A burst runs to the next *certain* scheduling event
(the shortest remaining token budget = the next guaranteed eviction),
capped by ``sched_quantum`` only when an uncertain event could act
sooner (an active EOS, or a free slot with queued work).  Because an
SSM slot is O(d_inner * d_state) regardless of sequence length,
admission/eviction are O(1) scatters and the decode batch shape never
changes — no ragged-batch re-bucketing between steps.

jit discipline: decode compiles once (fixed pool shape) and is shared
across Engine instances per config; the prefill compiles once per
distinct prompt length (callers that care should quantize prompt
lengths; the benchmark draws from a small set).

Speculative decoding (``EngineConfig.draft``): each scheduler iteration
becomes one fork -> K-draft -> batched-verify -> rollback pass
(runtime/spec_decode.py) instead of a token-by-token burst.  The pool
gains one scratch slot per live slot for draft forks; greedy spec
decode is token-identical to plain greedy decode (speculation changes
throughput, never tokens), and each target pass emits 1..K+1 tokens
per slot — accepted-tokens-per-target-pass in ServeStats is the
speedup proxy.

Caveat: MoE families route tokens across the batch through shared expert
capacity, so slot composition can perturb logits at tight
capacity_factor.  Pure Mamba / dense attention families are exactly
slot-independent (the engine's correctness tests assert this).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.runtime import metrics as metrics_lib
from repro.runtime.spec_decode import DraftConfig, SpecDecoder
from repro.runtime.spec_decode import sample_last as _sample_last
from repro.runtime.state_pool import SlotStatePool


# Per-config jit'd step functions, shared across Engine instances (cfg is
# a frozen dataclass, hence hashable).  Without this every Engine would
# carry its own jit cache and re-trace/compile prefill and decode that an
# earlier engine — or the warmup pass — already compiled.
@functools.lru_cache(maxsize=None)
def _jit_prefill_admit(cfg, temperature: float):
    """Fused prefill-into-slot: full-seq prefill of one request, scatter
    of its state into the pool slot, and first-token sampling — one
    dispatch per admission."""
    def _fn(p, fresh, tokens, pool_cache, slot_id, key):
        logits, sub = registry.prefill(cfg, p, fresh, {"tokens": tokens})
        new_pool = registry.scatter_slots(cfg, pool_cache, sub, slot_id)
        return _sample_last(logits, temperature, key), new_pool
    return jax.jit(_fn)


@functools.lru_cache(maxsize=None)
def _jit_decode_sample(cfg, temperature: float):
    """Fused decode + sample: tokens stay on device so consecutive steps
    chain without a host round-trip (the burst loop syncs once per
    scheduling quantum, keeping XLA dispatch pipelined)."""
    def _decode_fn(p, cache, toks, active, key):
        logits, new_cache = registry.decode_step(cfg, p, cache,
                                                 {"tokens": toks})
        new_cache = registry.mask_slots(cfg, cache, new_cache, active)
        return _sample_last(logits, temperature, key), new_cache
    return jax.jit(_decode_fn)


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 4
    max_seq: int = 256
    temperature: float = 0.0
    seed: int = 0
    # scheduling quantum: max decode steps per burst between host syncs /
    # admission checks.  Larger = fewer syncs (throughput), smaller =
    # faster admission + tighter EOS eviction (latency).
    sched_quantum: int = 8
    # override for the model's per-token step routing (cfg.step_impl):
    # "fused" = one kernel launch per layer per token for the whole SSM
    # state-update/contraction/gate chain, "xla" = unfused reference ops,
    # None = keep the model config's setting ("auto" resolves per backend).
    step_impl: Optional[str] = None
    # override for the pooled recurrent-state storage dtype
    # (cfg.state_dtype): "f32" | "bf16" | "int8" | "fp8".  int8/fp8
    # multiply slot capacity ~4x (per-slot absmax scales ride along in
    # the cache pytree); None = keep the model config's setting.
    state_dtype: Optional[str] = None
    # override for the attention KV-cache storage dtype
    # (cfg.kv_cache_dtype): "model" | "int8".  Composes with
    # state_dtype: on jamba, state_dtype covers the recurrent blocks
    # and kv_cache_dtype the per-position KV strips (which dominate
    # slot bytes at long max_seq).  None = keep the model config's.
    kv_cache_dtype: Optional[str] = None
    # speculative decoding: None = plain decode bursts; a DraftConfig
    # turns every decode step into a fork -> K-draft -> batched-verify
    # -> rollback pass emitting 1..K+1 tokens per slot per target pass.
    # Greedy (temperature=0) spec decode is token-identical to plain
    # greedy decode; sampled mode preserves the target distribution via
    # rejection sampling.  The pool grows n_slots scratch slots.
    draft: Optional[DraftConfig] = None


@dataclasses.dataclass
class Request:
    """One generation request; engine fills tokens + timing fields."""
    req_id: int
    prompt: np.ndarray                    # (Lp,) int32
    max_new: int = 32
    eos_id: Optional[int] = None
    arrival: float = 0.0                  # offset (s) from run() start
    tokens: list = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_admit: Optional[float] = None       # prefill start
    t_first: Optional[float] = None       # first token out (TTFT anchor)
    t_done: Optional[float] = None
    # per-slot speculative-depth bookkeeping (spec decode only): how
    # many target passes this request's slot took and how many drafted
    # tokens were accepted — accepted/passes is the request's realized
    # speculative depth.
    spec_passes: int = 0
    spec_accepted: int = 0

    @property
    def finished(self) -> bool:
        return self.t_done is not None


class Engine:
    def __init__(self, cfg, params, ecfg: EngineConfig,
                 logger: Optional[metrics_lib.MetricsLogger] = None,
                 clock: Callable[[], float] = time.perf_counter):
        if cfg.frontend in ("audio_stub", "vision_stub"):
            raise NotImplementedError(
                "serving engine supports token frontends only")
        if ecfg.step_impl is not None:
            # cfg keys the shared jit caches, so fused and unfused engines
            # compile (and benchmark) independently
            cfg = dataclasses.replace(cfg, step_impl=ecfg.step_impl)
        if ecfg.state_dtype is not None:
            # same reasoning: a quantized-state engine and an f32 engine
            # have different cache pytrees and must not share compiles
            cfg = dataclasses.replace(cfg, state_dtype=ecfg.state_dtype)
        if ecfg.kv_cache_dtype is not None:
            cfg = dataclasses.replace(cfg,
                                      kv_cache_dtype=ecfg.kv_cache_dtype)
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        # one scratch slot per live slot: every live slot can fork a
        # draft in the same speculative pass
        n_scratch = ecfg.n_slots if ecfg.draft is not None else 0
        self.pool = SlotStatePool(cfg, ecfg.n_slots, ecfg.max_seq,
                                  n_scratch=n_scratch)
        self._spec = (SpecDecoder(cfg, params, ecfg.draft,
                                  float(ecfg.temperature))
                      if ecfg.draft is not None else None)
        self.stats = metrics_lib.ServeStats()
        self.logger = logger
        self._now = clock
        self._prefill = _jit_prefill_admit(cfg, float(ecfg.temperature))
        self._decode = _jit_decode_sample(cfg, float(ecfg.temperature))
        self._key = jax.random.key(ecfg.seed)
        self._pending: list[Request] = []      # arrival-gated, sorted
        self._ready: collections.deque[Request] = collections.deque()
        self._slot_req: list[Optional[Request]] = [None] * ecfg.n_slots
        self._next_tok = np.zeros((self.pool.n_total, 1), np.int32)
        self._finished: list[Request] = []
        self._next_id = 0

    # ------------------------------------------------------------------
    # Request intake
    # ------------------------------------------------------------------

    def submit(self, prompt, max_new: int = 32,
               eos_id: Optional[int] = None,
               arrival: Optional[float] = None) -> Request:
        """Enqueue a request.  ``arrival`` (seconds from run() start)
        gates admission for trace replay; None means ready immediately."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if prompt.size + max_new > self.ecfg.max_seq:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new ({max_new}) exceeds "
                f"max_seq ({self.ecfg.max_seq})")
        req = Request(req_id=self._next_id, prompt=prompt, max_new=max_new,
                      eos_id=eos_id, arrival=arrival or 0.0,
                      t_submit=self._now())
        self._next_id += 1
        if arrival is None:
            self._ready.append(req)
        else:
            self._pending.append(req)
            self._pending.sort(key=lambda r: r.arrival)
        return req

    # ------------------------------------------------------------------
    # Scheduler core
    # ------------------------------------------------------------------

    def _admit(self, req: Request) -> None:
        slot = self.pool.alloc()
        assert slot is not None
        t0 = self._now()
        req.t_admit = t0
        self._key, k = jax.random.split(self._key)
        tok_dev, new_pool = self._prefill(
            self.params, self.pool.fresh, jnp.asarray(req.prompt[None]),
            self.pool.cache, jnp.asarray([slot]), k)
        tok = int(np.asarray(tok_dev)[0, 0])
        self.pool.cache = new_pool
        req.t_first = self._now()
        self.stats.record_prefill(req.prompt.size, req.t_first - t0)
        self._slot_req[slot] = req
        self._next_tok[slot, 0] = tok
        req.tokens.append(tok)
        if self.logger:
            self.logger.log(event="admit", req=req.req_id, slot=slot,
                            prompt_len=int(req.prompt.size))
        if self._hit_stop(req):
            self._finish(slot)

    def _hit_stop(self, req: Request) -> bool:
        return (len(req.tokens) >= req.max_new
                or (req.eos_id is not None
                    and req.tokens[-1] == req.eos_id))

    def _finish(self, slot: int) -> None:
        req = self._slot_req[slot]
        req.t_done = self._now()
        self.stats.record_request(ttft=req.t_first - req.t_submit,
                                  latency=req.t_done - req.t_submit)
        self.pool.evict(slot)
        self._slot_req[slot] = None
        self._next_tok[slot, 0] = 0
        self._finished.append(req)
        if self.logger:
            self.logger.log(event="finish", req=req.req_id, slot=slot,
                            n_tokens=len(req.tokens))

    def _burst_len(self, active) -> int:
        """Decode steps until the next scheduling event.

        The shortest remaining token budget among active slots is the
        next *certain* eviction; nothing can be admitted before then when
        all slots are busy, so in that state the burst runs uncapped to
        the eviction — zero intermediate host syncs, matching a static
        loop's dispatch pipelining with none of its wasted steps.  The
        quantum caps the burst only when an *uncertain* event could act
        sooner: an EOS may evict any step (overshoot is trimmed but
        wastes the slot until the burst ends), and a free slot plus
        queued/pending work means an admission check is worth taking."""
        remaining = min(self._slot_req[s].max_new - len(self._slot_req[s].tokens)
                        for s in active)
        has_eos = any(self._slot_req[s].eos_id is not None for s in active)
        may_admit = self.pool.n_free > 0 and (self._ready or self._pending)
        if has_eos or may_admit:
            return max(1, min(remaining, self.ecfg.sched_quantum))
        return max(1, remaining)

    def _decode_burst(self) -> None:
        active = self.pool.active_slots()
        n_steps = self._burst_len(active)
        t0 = self._now()
        toks = jnp.asarray(self._next_tok)
        act = jnp.asarray(self.pool.active_mask())
        cache = self.pool.cache
        outs = []
        for _ in range(n_steps):
            self._key, k = jax.random.split(self._key)
            toks, cache = self._decode(self.params, cache, toks, act, k)
            outs.append(toks)
        self.pool.cache = cache
        # one host sync per burst; device_get on the list avoids compiling
        # an XLA concatenate per distinct burst length
        burst = np.concatenate(jax.device_get(outs), axis=1)
        n_appended = 0
        for slot in active:
            req = self._slot_req[slot]
            for t in range(n_steps):
                tok = int(burst[slot, t])
                req.tokens.append(tok)
                n_appended += 1
                self._next_tok[slot, 0] = tok
                if self._hit_stop(req):
                    self._finish(slot)
                    break                 # trim overshoot past EOS
        self.stats.record_decode(n_active=len(active),
                                 n_slots=self.ecfg.n_slots,
                                 dt=self._now() - t0,
                                 n_steps=n_steps, n_tokens=n_appended)

    # ------------------------------------------------------------------
    # Speculative decoding (EngineConfig.draft)
    # ------------------------------------------------------------------

    def _spec_pass(self) -> None:
        """One fork -> K-draft -> batched-verify -> rollback pass over
        the live slots, emitting 1..K+1 tokens per slot per target
        pass.  Device work chains across fork/draft/verify; the host
        syncs once per pass for accept/stop bookkeeping (vs once per
        token for plain decode — the sync amortization IS part of the
        spec win).  Scratch leases are released even if a jit raises
        mid-pass (the pool-leak tests cover an abandoned burst)."""
        spec = self._spec
        active = self.pool.active_slots()
        # clamp the draft window to the shortest remaining token budget:
        # a slot about to hit max_new would have its whole window
        # trimmed anyway, so drafting past it is pure wasted dispatch
        # (EOS stays an uncertain event and is still trimmed host-side)
        remaining = min(self._slot_req[s].max_new
                        - len(self._slot_req[s].tokens) for s in active)
        k_eff = min(spec.k, remaining - 1)
        if k_eff < 1:
            # every active slot needs exactly one more token: plain
            # decode burst (its own burst-length logic handles this)
            self._decode_burst()
            return
        t0 = self._now()
        leases: list[int] = []
        try:
            for _ in active:
                sc = self.pool.lease_scratch()
                assert sc is not None        # n_scratch == n_slots
                leases.append(sc)
            self.pool.fork(active, leases)
            total = self.pool.n_total
            toks = np.zeros((total, 1), np.int32)
            toks[leases, 0] = self._next_tok[active, 0]
            scratch_mask = np.zeros((total,), bool)
            scratch_mask[leases] = True
            keys = []
            for _ in range(k_eff):
                self._key, k = jax.random.split(self._key)
                keys.append(k)
            cache, d_toks, d_logits = spec.propose(
                self.pool.cache, jnp.asarray(toks),
                jnp.asarray(scratch_mask), keys)
            # proposals were drafted at scratch rows; the verify wants
            # them at their live slots' rows
            perm = np.arange(total)
            perm[active] = leases
            perm = jnp.asarray(perm)
            self._key, vk = jax.random.split(self._key)
            emit, n_acc, _, snap = spec.verify(
                self.params, cache, jnp.asarray(self._next_tok),
                d_toks[:, perm], d_logits[:, perm],
                jnp.asarray(self.pool.active_mask()), vk)
            # the rollback: every live slot's row of ``snap`` is the
            # state after exactly its accepted prefix
            self.pool.cache = snap
            emit_h, n_acc_h = np.asarray(emit), np.asarray(n_acc)
        finally:
            for sc in leases:
                self.pool.release_scratch(sc)
        n_appended = 0
        n_accepted = 0
        for slot in active:
            req = self._slot_req[slot]
            n_emit = int(n_acc_h[slot]) + 1
            n_accepted += n_emit - 1
            req.spec_passes += 1
            req.spec_accepted += n_emit - 1
            for t in range(n_emit):
                tok = int(emit_h[t, slot])
                req.tokens.append(tok)
                n_appended += 1
                self._next_tok[slot, 0] = tok
                if self._hit_stop(req):
                    self._finish(slot)
                    break                 # trim overshoot past EOS/budget
        self.stats.record_decode(n_active=len(active),
                                 n_slots=self.ecfg.n_slots,
                                 dt=self._now() - t0,
                                 n_steps=k_eff + 1, n_tokens=n_appended)
        self.stats.record_spec(n_active=len(active),
                               n_drafted=k_eff * len(active),
                               n_accepted=n_accepted,
                               n_emitted=n_appended)

    def step(self) -> bool:
        """One scheduler iteration: admit into free slots, then one decode
        burst (or one speculative pass).  Returns False when there was
        nothing to do."""
        did = False
        while self._ready and self.pool.n_free:
            self._admit(self._ready.popleft())
            did = True
        if self.pool.n_active:
            if self._spec is not None:
                self._spec_pass()
            else:
                self._decode_burst()
            did = True
        return did

    # ------------------------------------------------------------------
    # Drive loop
    # ------------------------------------------------------------------

    def run(self) -> list[Request]:
        """Run until every submitted request is finished; replays
        arrival-gated requests against a wall clock starting now.
        Returns the requests finished during THIS call, in completion
        order (the engine keeps no reference afterwards)."""
        self.stats.start()
        self._finished = []
        t0 = self._now()
        while self._pending or self._ready or self.pool.n_active:
            now = self._now() - t0
            while self._pending and self._pending[0].arrival <= now:
                req = self._pending.pop(0)
                # TTFT/latency are measured from the (simulated) arrival,
                # not from when the trace was queued before run()
                req.t_submit = self._now()
                self._ready.append(req)
            if not self.step() and self._pending:
                wait = self._pending[0].arrival - (self._now() - t0)
                if wait > 0:
                    time.sleep(min(wait, 0.05))
        self.stats.stop()
        if self.logger:
            self.logger.log(event="summary", **self.stats.summary())
        return self._finished
