"""Continuous-batching serving engine over the slot-based state pool.

Request lifecycle (see also runtime/__init__.py):

  submit(prompt, SamplingParams) -> [pending until arrival] -> ready
  queue (priority-ordered) -> prefill-into-slot -> joins the running
  decode batch -> per-slot stop-token / max-token finish (or cancel())
  -> evict (slot reset + freed) -> Request returned with tokens +
  timings.  A ``stream_cb`` receives each request's new tokens at every
  scheduler sync.

Scheduling policy: admit-eagerly, highest priority first (FIFO within a
priority).  Each engine ``step()`` first admits ready requests into
every free slot (one fused exact-length prefill-scatter-sample dispatch
per request), then runs a pooled decode BURST over all ``n_slots``
slots with inactive slots masked.  Sampling is fused into the decode
jit so tokens chain on-device; the host syncs once per burst.  A burst
runs to the next *certain* scheduling event (the shortest remaining
token budget = the next guaranteed eviction), capped by
``sched_quantum`` only when an uncertain event could act sooner (an
active stop token, a streaming callback that must be serviced — it may
cancel — or a free slot with queued work).  Because an SSM slot is
O(d_inner * d_state) regardless of sequence length, admission/eviction
are O(1) scatters and the decode batch shape never changes — no
ragged-batch re-bucketing between steps.

Sampling discipline (runtime/sampling.py): every per-request knob —
temperature, top-k, top-p, seed, stop ids, budget — is DATA.  The pool
carries per-slot parameter arrays that enter the jit'd steps as traced
arguments, so ONE compiled prefill/decode/verify signature serves a
batch mixing greedy and sampled requests and changing any
SamplingParams field never retraces (``sampling.TRACE_COUNTS`` is the
proof hook).  Randomness is per-slot counter-based: token i of request
r is drawn with fold_in(key(seed_r), i), so a sampled stream is
bitwise reproducible regardless of slot placement, batch composition,
or co-resident cancellations.

jit discipline: decode compiles once (fixed pool shape) and is shared
across Engine instances per config; the prefill compiles once per
distinct prompt length (callers that care should quantize prompt
lengths; the benchmark draws from a small set).

Speculative decoding (``EngineConfig.draft``): each scheduler iteration
becomes one fork -> K-draft -> batched-verify -> rollback pass
(runtime/spec_decode.py) instead of a token-by-token burst.  The pool
gains one scratch slot per live slot for draft forks; a greedy slot's
spec decode is token-identical to plain greedy decode — even in a
mixed greedy+sampled batch — and each target pass emits 1..K+1 tokens
per slot.  ``DraftConfig.adaptive`` clamps each slot's window to its
realized acceptance (Request.spec_accepted / spec_passes).

Caveat: MoE families route tokens across the batch through shared expert
capacity, so slot composition can perturb logits at tight
capacity_factor.  Pure Mamba / dense attention families are exactly
slot-independent (the engine's correctness tests assert this).
"""
from __future__ import annotations

import bisect
import dataclasses
import functools
import heapq
import math
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.runtime import metrics as metrics_lib
from repro.runtime import sampling
from repro.runtime.sampling import SamplingParams
from repro.runtime.spec_decode import DraftConfig, SpecDecoder
from repro.runtime.state_pool import SlotStatePool


# Per-config jit'd step functions, shared across Engine instances (cfg is
# a frozen dataclass, hence hashable).  Without this every Engine would
# carry its own jit cache and re-trace/compile prefill and decode that an
# earlier engine — or the warmup pass — already compiled.  Sampling
# parameters are traced ARRAY arguments, never part of the cache key:
# heterogeneous per-request settings share one compile.
@functools.lru_cache(maxsize=None)
def _jit_prefill_admit(cfg):
    """Fused prefill-into-slot: full-seq prefill of one request, scatter
    of its state into the pool slot, and first-token sampling with the
    request's own params — one dispatch per admission."""
    def _fn(p, fresh, tokens, pool_cache, slot_id, sp, step):
        sampling.TRACE_COUNTS["prefill_admit"] += 1
        logits, sub = registry.prefill(cfg, p, fresh, {"tokens": tokens})
        new_pool = registry.scatter_slots(cfg, pool_cache, sub, slot_id)
        tok = sampling.sample(logits[:, -1, :], sp, step)
        return tok[:, None], new_pool
    return jax.jit(_fn)


@functools.lru_cache(maxsize=None)
def _jit_decode_sample(cfg):
    """Fused decode + per-slot sample: tokens stay on device so
    consecutive steps chain without a host round-trip (the burst loop
    syncs once per scheduling quantum, keeping XLA dispatch
    pipelined)."""
    def _decode_fn(p, cache, toks, active, sp, step):
        sampling.TRACE_COUNTS["decode_step"] += 1
        logits, new_cache = registry.decode_step(cfg, p, cache,
                                                 {"tokens": toks})
        new_cache = registry.mask_slots(cfg, cache, new_cache, active)
        tok = sampling.sample(logits[:, -1, :], sp, step)
        return tok[:, None], new_cache
    return jax.jit(_decode_fn)


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 4
    max_seq: int = 256
    # engine seed: derives per-request seeds for requests whose
    # SamplingParams.seed is None (deterministically from the request
    # id, so unseeded streams are still reproducible per trace)
    seed: int = 0
    # default per-request params when submit() gets none (greedy)
    default_params: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    # scheduling quantum: max decode steps per burst between host syncs /
    # admission checks.  Larger = fewer syncs (throughput), smaller =
    # faster admission + tighter stop-token eviction + lower streaming /
    # cancellation latency.
    sched_quantum: int = 8
    # override for the model's per-token step routing (cfg.step_impl):
    # "fused" = one kernel launch per layer per token for the whole SSM
    # state-update/contraction/gate chain, "xla" = unfused reference ops,
    # None = keep the model config's setting ("auto" resolves per backend).
    step_impl: Optional[str] = None
    # override for the pooled recurrent-state storage dtype
    # (cfg.state_dtype): "f32" | "bf16" | "int8" | "fp8".  int8/fp8
    # multiply slot capacity ~4x (per-slot absmax scales ride along in
    # the cache pytree); None = keep the model config's setting.
    state_dtype: Optional[str] = None
    # override for the attention KV-cache storage dtype
    # (cfg.kv_cache_dtype): "model" | "int8".  Composes with
    # state_dtype: on jamba, state_dtype covers the recurrent blocks
    # and kv_cache_dtype the per-position KV strips (which dominate
    # slot bytes at long max_seq).  None = keep the model config's.
    kv_cache_dtype: Optional[str] = None
    # speculative decoding: None = plain decode bursts; a DraftConfig
    # turns every decode step into a fork -> K-draft -> batched-verify
    # -> rollback pass emitting 1..K+1 tokens per slot per target pass.
    # Greedy slots are token-identical to plain greedy decode; sampled
    # slots preserve their target distribution via per-slot rejection
    # sampling.  The pool grows n_slots scratch slots.
    draft: Optional[DraftConfig] = None


@dataclasses.dataclass
class Request:
    """One generation request; engine fills tokens + timing fields."""
    req_id: int
    prompt: np.ndarray                    # (Lp,) int32
    params: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    seed: int = 0                         # resolved per-request PRNG seed
    max_new: int = 32                     # mirrors params.max_new
    stop_ids: frozenset = frozenset()     # params.stop (+ eos_id)
    eos_id: Optional[int] = None          # convenience mirror
    priority: int = 0                     # higher admits earlier
    stream_cb: Optional[Callable] = None  # (req, new_tokens) per sync
    cancelled: bool = False
    arrival: float = 0.0                  # offset (s) from run() start
    tokens: list = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_admit: Optional[float] = None       # prefill start
    t_first: Optional[float] = None       # first token out (TTFT anchor)
    t_done: Optional[float] = None
    # per-slot speculative-depth bookkeeping (spec decode only): how
    # many target passes this request's slot took and how many drafted
    # tokens were accepted — accepted/passes is the request's realized
    # speculative depth (and drives DraftConfig.adaptive).
    spec_passes: int = 0
    spec_accepted: int = 0

    @property
    def finished(self) -> bool:
        return self.t_done is not None


class Engine:
    def __init__(self, cfg, params, ecfg: EngineConfig,
                 logger: Optional[metrics_lib.MetricsLogger] = None,
                 clock: Callable[[], float] = time.perf_counter):
        if cfg.frontend in ("audio_stub", "vision_stub"):
            raise NotImplementedError(
                "serving engine supports token frontends only")
        if ecfg.step_impl is not None:
            # cfg keys the shared jit caches, so fused and unfused engines
            # compile (and benchmark) independently
            cfg = dataclasses.replace(cfg, step_impl=ecfg.step_impl)
        if ecfg.state_dtype is not None:
            # same reasoning: a quantized-state engine and an f32 engine
            # have different cache pytrees and must not share compiles
            cfg = dataclasses.replace(cfg, state_dtype=ecfg.state_dtype)
        if ecfg.kv_cache_dtype is not None:
            cfg = dataclasses.replace(cfg,
                                      kv_cache_dtype=ecfg.kv_cache_dtype)
        ecfg.default_params.validate()
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        # one scratch slot per live slot: every live slot can fork a
        # draft in the same speculative pass
        n_scratch = ecfg.n_slots if ecfg.draft is not None else 0
        self.pool = SlotStatePool(cfg, ecfg.n_slots, ecfg.max_seq,
                                  n_scratch=n_scratch)
        self._spec = (SpecDecoder(cfg, params, ecfg.draft)
                      if ecfg.draft is not None else None)
        self.stats = metrics_lib.ServeStats()
        self.logger = logger
        self._now = clock
        self._prefill = _jit_prefill_admit(cfg)
        self._decode = _jit_decode_sample(cfg)
        self._pending: list[Request] = []      # arrival-gated, sorted
        self._ready: list[tuple] = []          # (-priority, seq, Request)
        self._seq = 0                          # FIFO tiebreak in _ready
        self._by_id: dict[int, Request] = {}   # unfinished requests
        self._cancel_dirty = False
        self._slot_req: list[Optional[Request]] = [None] * ecfg.n_slots
        self._next_tok = np.zeros((self.pool.n_total, 1), np.int32)
        self._finished: list[Request] = []
        self._next_id = 0

    # ------------------------------------------------------------------
    # Request intake
    # ------------------------------------------------------------------

    def submit(self, prompt, params: Optional[SamplingParams] = None,
               max_new: Optional[int] = None,
               eos_id: Optional[int] = None,
               arrival: Optional[float] = None,
               priority: int = 0,
               stream_cb: Optional[Callable] = None) -> Request:
        """Enqueue a request.

        params: per-request SamplingParams (None = the engine's
          default_params, greedy unless configured).  ``max_new`` /
          ``eos_id`` are conveniences layered onto it: max_new
          overrides params.max_new, eos_id extends params.stop.
        arrival: seconds from run() start; gates admission for trace
          replay (None = ready immediately).
        priority: higher admits earlier among ready requests (FIFO
          within a priority level).
        stream_cb: ``cb(req, new_tokens)`` called at every scheduler
          sync with the >= 1 tokens appended since the last call; the
          final call has ``req.finished`` True.  The callback may call
          ``Engine.cancel`` (including on its own request); it must not
          raise (an exception aborts ``run()``).
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        params = params if params is not None else self.ecfg.default_params
        if max_new is not None:
            params = dataclasses.replace(params, max_new=max_new)
        if eos_id is not None:
            params = dataclasses.replace(
                params, stop=tuple(params.stop) + (eos_id,))
        params.validate()
        if prompt.size + params.max_new > self.ecfg.max_seq:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new ({params.max_new}) "
                f"exceeds max_seq ({self.ecfg.max_seq})")
        req_id = self._next_id
        self._next_id += 1
        seed = (params.seed if params.seed is not None
                else self._derive_seed(req_id))
        req = Request(req_id=req_id, prompt=prompt, params=params,
                      seed=seed, max_new=params.max_new,
                      stop_ids=frozenset(params.stop), eos_id=eos_id,
                      priority=priority, stream_cb=stream_cb,
                      arrival=arrival or 0.0, t_submit=self._now())
        self._by_id[req_id] = req
        if arrival is None:
            self._push_ready(req)
        else:
            # bisect keeps the arrival-sorted invariant in O(n) per
            # insert — re-sorting on every submit was O(n^2 log n)
            # across a heavy trace replay
            bisect.insort(self._pending, req, key=lambda r: r.arrival)
        return req

    def _derive_seed(self, req_id: int) -> int:
        """Deterministic per-request seed for unseeded requests: a
        function of (engine seed, request id) only, so streams stay
        reproducible per trace and distinct across requests."""
        return (self.ecfg.seed * 1_000_003 + req_id) & 0x7FFFFFFF

    def _push_ready(self, req: Request) -> None:
        heapq.heappush(self._ready, (-req.priority, self._seq, req))
        self._seq += 1

    def cancel(self, req_id: int) -> bool:
        """Cancel a request.  Queued requests are dropped before
        admission; a running request's slot (and, mid-speculation, its
        scratch lease) is reclaimed at the next scheduler sync — any
        tokens already delivered stand, no further tokens are produced.
        Safe to call from a ``stream_cb`` (including the request's
        own).  Returns False for unknown / already-finished ids."""
        req = self._by_id.get(req_id)
        if req is None or req.finished or req.cancelled:
            return False
        req.cancelled = True
        self._cancel_dirty = True
        return True

    # ------------------------------------------------------------------
    # Scheduler core
    # ------------------------------------------------------------------

    def _drop_cancelled(self, req: Request) -> None:
        """Retire a request cancelled before admission (no slot held)."""
        req.t_done = self._now()
        self.stats.record_cancelled()
        self._finished.append(req)
        self._by_id.pop(req.req_id, None)
        if self.logger:
            self.logger.log(event="cancel", req=req.req_id, slot=None,
                            n_tokens=len(req.tokens))

    def _sweep_cancelled(self) -> bool:
        """Reclaim every cancelled request at a sync point: evict
        running ones (slot + params row reset), purge queued ones."""
        if not self._cancel_dirty:
            return False
        self._cancel_dirty = False
        did = False
        for slot, req in enumerate(self._slot_req):
            if req is not None and req.cancelled:
                self._finish(slot)
                did = True
        if any(r.cancelled for r in self._pending):
            keep = []
            for r in self._pending:
                (keep.append(r) if not r.cancelled
                 else self._drop_cancelled(r))
            self._pending = keep
            did = True
        if any(e[2].cancelled for e in self._ready):
            for e in self._ready:
                if e[2].cancelled:
                    self._drop_cancelled(e[2])
            # keep the ORIGINAL (priority, seq) tuples: re-pushing with
            # fresh seqs would reassign FIFO order from raw heap-array
            # order and let later submissions jump earlier ones
            self._ready = [e for e in self._ready if not e[2].cancelled]
            heapq.heapify(self._ready)
            did = True
        return did

    def _deliver(self, req: Request, new_toks: list) -> None:
        """Stream delivery at a scheduler sync; the callback may flag a
        cancellation, which the caller reclaims right after."""
        if req.stream_cb is not None and new_toks:
            req.stream_cb(req, new_toks)

    def _admit(self, req: Request) -> None:
        slot = self.pool.alloc()
        assert slot is not None
        t0 = self._now()
        req.t_admit = t0
        self.pool.params.set(slot, req.params, req.seed)
        tok_dev, new_pool = self._prefill(
            self.params, self.pool.fresh, jnp.asarray(req.prompt[None]),
            self.pool.cache, jnp.asarray([slot]),
            self.pool.params.row(slot), jnp.zeros((1,), jnp.int32))
        tok = int(np.asarray(tok_dev)[0, 0])
        self.pool.cache = new_pool
        req.t_first = self._now()
        self.stats.record_prefill(req.prompt.size, req.t_first - t0)
        self._slot_req[slot] = req
        self._next_tok[slot, 0] = tok
        req.tokens.append(tok)
        if self.logger:
            self.logger.log(event="admit", req=req.req_id, slot=slot,
                            prompt_len=int(req.prompt.size))
        if self._hit_stop(req):
            self._finish(slot)
        self._deliver(req, [tok])
        if req.cancelled and not req.finished:
            self._finish(slot)

    def _hit_stop(self, req: Request) -> bool:
        return (len(req.tokens) >= req.max_new
                or (bool(req.stop_ids) and req.tokens[-1] in req.stop_ids))

    def _finish(self, slot: int) -> None:
        req = self._slot_req[slot]
        req.t_done = self._now()
        if req.cancelled:
            self.stats.record_cancelled()
        else:
            self.stats.record_request(ttft=req.t_first - req.t_submit,
                                      latency=req.t_done - req.t_submit)
        self.pool.evict(slot)
        self._slot_req[slot] = None
        self._next_tok[slot, 0] = 0
        self._finished.append(req)
        self._by_id.pop(req.req_id, None)
        if self.logger:
            self.logger.log(
                event="cancel" if req.cancelled else "finish",
                req=req.req_id, slot=slot, n_tokens=len(req.tokens))

    def _base_steps(self, active) -> np.ndarray:
        """Per-slot stream positions at sync start: tokens already
        emitted — the fold_in counter that keys each slot's next
        draws."""
        base = np.zeros((self.pool.n_total,), np.int32)
        for s in active:
            base[s] = len(self._slot_req[s].tokens)
        return base

    def _burst_len(self, active) -> int:
        """Decode steps until the next scheduling event.

        The shortest remaining token budget among active slots is the
        next *certain* eviction; nothing can be admitted before then when
        all slots are busy, so in that state the burst runs uncapped to
        the eviction — zero intermediate host syncs, matching a static
        loop's dispatch pipelining with none of its wasted steps.  The
        quantum caps the burst only when an *uncertain* event could act
        sooner: a stop token may evict any step (overshoot is trimmed
        but wastes the slot until the burst ends), a streaming callback
        must be serviced regularly (it may cancel mid-stream), and a
        free slot plus queued/pending work means an admission check is
        worth taking."""
        remaining = min(self._slot_req[s].max_new - len(self._slot_req[s].tokens)
                        for s in active)
        uncertain = any(self._slot_req[s].stop_ids
                        or self._slot_req[s].stream_cb is not None
                        for s in active)
        may_admit = self.pool.n_free > 0 and (self._ready or self._pending)
        if uncertain or may_admit:
            return max(1, min(remaining, self.ecfg.sched_quantum))
        return max(1, remaining)

    def _decode_burst(self) -> None:
        active = self.pool.active_slots()
        n_steps = self._burst_len(active)
        t0 = self._now()
        toks = jnp.asarray(self._next_tok)
        act = jnp.asarray(self.pool.active_mask())
        sp = self.pool.params.device()
        base = jnp.asarray(self._base_steps(active))
        cache = self.pool.cache
        outs = []
        for t in range(n_steps):
            toks, cache = self._decode(self.params, cache, toks, act,
                                       sp, base + t)
            outs.append(toks)
        self.pool.cache = cache
        # one host sync per burst; device_get on the list avoids compiling
        # an XLA concatenate per distinct burst length
        burst = np.concatenate(jax.device_get(outs), axis=1)
        n_appended = 0
        for slot in active:
            req = self._slot_req[slot]
            new_toks = []
            for t in range(n_steps):
                tok = int(burst[slot, t])
                req.tokens.append(tok)
                new_toks.append(tok)
                n_appended += 1
                self._next_tok[slot, 0] = tok
                if self._hit_stop(req):
                    self._finish(slot)
                    break                 # trim overshoot past a stop
            self._deliver(req, new_toks)
            if req.cancelled and not req.finished:
                self._finish(slot)
        self.stats.record_decode(n_active=len(active),
                                 n_slots=self.ecfg.n_slots,
                                 dt=self._now() - t0,
                                 n_steps=n_steps, n_tokens=n_appended)

    # ------------------------------------------------------------------
    # Speculative decoding (EngineConfig.draft)
    # ------------------------------------------------------------------

    def _slot_depth(self, req: Request) -> int:
        """Per-slot speculative window (DraftConfig.adaptive): after
        warmup, clamp to the request's realized acceptance + 1 token of
        optimism — pure depth arithmetic, never touches token values,
        so greedy identity survives."""
        dc = self.ecfg.draft
        # warmup floors at 1 pass: the clamp needs at least one realized
        # pass or the division below has nothing to divide by
        if not dc.adaptive or req.spec_passes < max(1, dc.adapt_warmup):
            return self._spec.k
        realized = req.spec_accepted / req.spec_passes
        return int(min(self._spec.k, max(1, math.ceil(realized) + 1)))

    def _spec_pass(self) -> None:
        """One fork -> K-draft -> batched-verify -> rollback pass over
        the live slots, emitting 1..K+1 tokens per slot per target
        pass.  Device work chains across fork/draft/verify; the host
        syncs once per pass for accept/stop bookkeeping (vs once per
        token for plain decode — the sync amortization IS part of the
        spec win).  Scratch leases are released even if a jit raises
        mid-pass (the pool-leak tests cover an abandoned burst)."""
        spec = self._spec
        active = self.pool.active_slots()
        # clamp the draft window to the shortest remaining token budget:
        # a slot about to hit max_new would have its whole window
        # trimmed anyway, so drafting past it is pure wasted dispatch
        # (stop tokens stay an uncertain event and are still trimmed
        # host-side); adaptive per-slot depth shrinks it further when
        # every slot's realized acceptance is low
        remaining = min(self._slot_req[s].max_new
                        - len(self._slot_req[s].tokens) for s in active)
        depths = {s: self._slot_depth(self._slot_req[s]) for s in active}
        k_eff = min(max(depths.values()), remaining - 1)
        if k_eff < 1:
            # every active slot needs exactly one more token: plain
            # decode burst (its own burst-length logic handles this)
            self._decode_burst()
            return
        t0 = self._now()
        leases: list[int] = []
        try:
            for _ in active:
                sc = self.pool.lease_scratch()
                assert sc is not None        # n_scratch == n_slots
                leases.append(sc)
            self.pool.fork(active, leases)   # state + sampling params
            total = self.pool.n_total
            toks = np.zeros((total, 1), np.int32)
            toks[leases, 0] = self._next_tok[active, 0]
            scratch_mask = np.zeros((total,), bool)
            scratch_mask[leases] = True
            base = self._base_steps(active)
            base[leases] = base[active]      # draft keys mirror live
            limit = np.full((total,), k_eff, np.int32)
            for s in active:
                limit[s] = min(depths[s], k_eff)
            sp = self.pool.params.device()
            cache, d_toks, d_logits = spec.propose(
                self.pool.cache, jnp.asarray(toks),
                jnp.asarray(scratch_mask), sp, jnp.asarray(base), k_eff)
            # proposals were drafted at scratch rows; the verify wants
            # them at their live slots' rows
            perm = np.arange(total)
            perm[active] = leases
            perm = jnp.asarray(perm)
            emit, n_acc, _, snap = spec.verify(
                self.params, cache, jnp.asarray(self._next_tok),
                d_toks[:, perm], d_logits[:, perm],
                jnp.asarray(self.pool.active_mask()), sp,
                jnp.asarray(base), jnp.asarray(limit))
            # the rollback: every live slot's row of ``snap`` is the
            # state after exactly its accepted prefix
            self.pool.cache = snap
            emit_h, n_acc_h = np.asarray(emit), np.asarray(n_acc)
        finally:
            for sc in leases:
                self.pool.release_scratch(sc)
        n_appended = 0
        n_accepted = 0
        for slot in active:
            req = self._slot_req[slot]
            n_emit = int(n_acc_h[slot]) + 1
            n_accepted += n_emit - 1
            req.spec_passes += 1
            req.spec_accepted += n_emit - 1
            new_toks = []
            for t in range(n_emit):
                tok = int(emit_h[t, slot])
                req.tokens.append(tok)
                new_toks.append(tok)
                n_appended += 1
                self._next_tok[slot, 0] = tok
                if self._hit_stop(req):
                    self._finish(slot)
                    break                 # trim overshoot past stop/budget
            self._deliver(req, new_toks)
            if req.cancelled and not req.finished:
                self._finish(slot)
        self.stats.record_decode(n_active=len(active),
                                 n_slots=self.ecfg.n_slots,
                                 dt=self._now() - t0,
                                 n_steps=k_eff + 1, n_tokens=n_appended)
        self.stats.record_spec(n_active=len(active),
                               n_drafted=k_eff * len(active),
                               n_accepted=n_accepted,
                               n_emitted=n_appended)

    def step(self) -> bool:
        """One scheduler iteration: reclaim cancellations, admit into
        free slots (highest priority first), then one decode burst (or
        one speculative pass).  Returns False when there was nothing
        to do."""
        did = self._sweep_cancelled()
        while self._ready and self.pool.n_free:
            req = heapq.heappop(self._ready)[2]
            if req.cancelled:
                self._drop_cancelled(req)
                continue
            self._admit(req)
            did = True
        if self.pool.n_active:
            if self._spec is not None:
                self._spec_pass()
            else:
                self._decode_burst()
            did = True
        return did

    # ------------------------------------------------------------------
    # Drive loop
    # ------------------------------------------------------------------

    def run(self) -> list[Request]:
        """Run until every submitted request is finished or cancelled;
        replays arrival-gated requests against a wall clock starting
        now.  Returns the requests retired during THIS call, in
        completion order (the engine keeps no reference afterwards)."""
        self.stats.start()
        self._finished = []
        t0 = self._now()
        while self._pending or self._ready or self.pool.n_active:
            now = self._now() - t0
            while self._pending and self._pending[0].arrival <= now:
                req = self._pending.pop(0)
                if req.cancelled:
                    self._drop_cancelled(req)
                    continue
                # TTFT/latency are measured from the (simulated) arrival,
                # not from when the trace was queued before run()
                req.t_submit = self._now()
                self._push_ready(req)
            if not self.step() and self._pending:
                wait = self._pending[0].arrival - (self._now() - t0)
                if wait > 0:
                    time.sleep(min(wait, 0.05))
        self.stats.stop()
        if self.logger:
            self.logger.log(event="summary", **self.stats.summary())
        return self._finished
