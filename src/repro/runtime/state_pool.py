"""Slot-based decode-state pool — MARCA's inter-operation buffer insight
applied at serving scale.

A Mamba sequence's entire decode state is a fixed O(d_inner * d_state)
block per layer (plus the (k-1)-tap conv tail), so unlike a ragged KV
cache it can live in a fixed-shape pool with one slot per in-flight
sequence: admission is a scatter of freshly prefilled state into a free
slot, eviction is a scatter of the init state, and the running decode
batch never changes shape.  The same layout generalizes to the other
registry families (KV caches are per-slot [max_seq] strips; xLSTM
matrix-memory states are per-slot blocks), which is why the pool is
family-agnostic: all slot knowledge lives in registry.cache_slot_axes.

All device ops are jit'd once with fixed shapes (slot ids are traced
(1,) arrays), so admit/evict/read never recompile.  The free list and
slot accounting are host-side.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.parallel import sharding
from repro.runtime import sampling


# Shared per-(config, shard) jit caches (cfg is frozen/hashable; shard is
# None or a hashable (Mesh, ShardingRules) pair): every pool for a given
# model reuses the same compiled gather/scatter/mask executables, and a
# sharded pool gets its OWN trace — the mesh context and the output
# constraints are baked at trace time, so a single-device pool can never
# alias a sharded compile (or vice versa).  Outputs are constrained to
# the cache's logical axes: a slot op's output sharding equals its input
# sharding, so admission/eviction/fork chains introduce zero resharding.
@functools.lru_cache(maxsize=None)
def _jit_gather(cfg, shard=None):
    cax = registry.cache_axes(cfg) if shard is not None else None

    def _fn(c, i):
        with sharding.shard_ctx(shard):
            out = registry.gather_slots(cfg, c, i)
            if shard is not None:
                out = sharding.constrain_tree(out, cax)
        return out
    return jax.jit(_fn)


@functools.lru_cache(maxsize=None)
def _jit_scatter(cfg, shard=None):
    cax = registry.cache_axes(cfg) if shard is not None else None

    def _fn(c, s, i):
        with sharding.shard_ctx(shard):
            out = registry.scatter_slots(cfg, c, s, i)
            if shard is not None:
                out = sharding.constrain_tree(out, cax)
        return out
    return jax.jit(_fn)


@functools.lru_cache(maxsize=None)
def _jit_mask(cfg, shard=None):
    cax = registry.cache_axes(cfg) if shard is not None else None

    def _fn(o, n, m):
        with sharding.shard_ctx(shard):
            out = registry.mask_slots(cfg, o, n, m)
            if shard is not None:
                out = sharding.constrain_tree(out, cax)
        return out
    return jax.jit(_fn)


@functools.lru_cache(maxsize=None)
def _jit_fork(cfg, shard=None):
    """Fork = gather(src) + scatter(dst) fused into one dispatch.  Every
    cache leaf — quantized payloads AND their absmax scales — moves in
    the same op, so a fork can never tear payload from scale."""
    cax = registry.cache_axes(cfg) if shard is not None else None

    def _fn(c, src, dst):
        with sharding.shard_ctx(shard):
            out = registry.scatter_slots(
                cfg, c, registry.gather_slots(cfg, c, src), dst)
            if shard is not None:
                out = sharding.constrain_tree(out, cax)
        return out
    return jax.jit(_fn)


class SlotStatePool:
    """Fixed-capacity pool of per-slot decode state for one model config.

    ``cache`` is a plain-value pytree (Param wrappers stripped) whose every
    leaf has ``n_total = n_slots + n_scratch`` entries along its slot
    axis: ``n_slots`` live slots (request state) plus ``n_scratch``
    scratch slots leased transiently for speculative-decode draft forks.
    Mutation is functional: admit/evict/commit/fork rebind ``self.cache``.
    """

    def __init__(self, cfg, n_slots: int, max_seq: int, dtype=None,
                 n_scratch: int = 0, mesh=None, rules=None):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if n_scratch < 0:
            raise ValueError("n_scratch must be >= 0")
        self.cfg = cfg
        self.n_slots = n_slots
        self.n_scratch = n_scratch
        self.n_total = n_slots + n_scratch
        self.max_seq = max_seq
        cache_p = registry.init_cache(cfg, self.n_total, max_seq, dtype)
        fresh_p = registry.init_cache(cfg, 1, max_seq, dtype)
        self.cache = sharding.tree_values(cache_p)
        # the init state of a single slot — eviction scatters this (NOT
        # zeros: e.g. xLSTM stabilizer state m inits to -1e30)
        self._fresh = sharding.tree_values(fresh_p)
        # tensor-parallel pool: place every cache leaf (payloads, absmax
        # scales, KV strips, positions) on the mesh by its logical axes
        # — TP-interior axes (act_ffn/act_heads) shard, slot axes stay
        # replicated — so all the jit'd slot ops below run on sharded
        # arrays in place.  mesh=None is the bitwise-unchanged
        # single-device path.
        self.mesh = mesh
        self.rules = ((rules if rules is not None else
                       sharding.ShardingRules())
                      if mesh is not None else None)
        self._shard = (mesh, self.rules) if mesh is not None else None
        if mesh is not None:
            self.cache = jax.device_put(
                self.cache,
                sharding.tree_shardings(cache_p, mesh, self.rules))
            self._fresh = jax.device_put(
                self._fresh,
                sharding.tree_shardings(fresh_p, mesh, self.rules))
        self._gather_fn = _jit_gather(cfg, self._shard)
        self._scatter_fn = _jit_scatter(cfg, self._shard)
        self._mask_fn = _jit_mask(cfg, self._shard)
        self._fork_fn = _jit_fork(cfg, self._shard)
        # per-slot sampling parameters (temperature/top-k/top-p/key) ride
        # with the slot: set on admission, copied on fork, reset on
        # eviction — the engine passes params.device() into the jit'd
        # steps as traced arrays, so heterogeneous values never retrace
        self.params = sampling.SlotParams(self.n_total)
        self._free: list[int] = list(range(n_slots))
        # scratch ids live in [n_slots, n_total): the ranges are disjoint
        # by construction, so a scratch lease can never collide with a
        # live slot no matter how admission/eviction interleave.
        self._scratch_free: list[int] = list(range(n_slots, self.n_total))
        self._active: list[bool] = [False] * self.n_total
        # eviction-free leases (infinite-stream sessions): a pinned slot
        # is active state under an open-ended lease — evicting it is a
        # bug, not a policy choice, so evict() refuses until unpin.
        self._pinned: list[bool] = [False] * self.n_total

    @property
    def fresh(self):
        """The (batch-1) init-state cache — reusable prefill scratch."""
        return self._fresh

    # -- host-side slot accounting ------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    def active_slots(self) -> list[int]:
        return [i for i, a in enumerate(self._active) if a]

    def active_mask(self) -> np.ndarray:
        return np.asarray(self._active, bool)

    def alloc(self) -> Optional[int]:
        """Reserve a free slot id (lowest first), or None when full."""
        if not self._free:
            return None
        slot = min(self._free)
        self._free.remove(slot)
        self._active[slot] = True
        return slot

    # -- eviction-free leases (infinite-stream sessions) --------------------

    @property
    def n_pinned(self) -> int:
        return sum(self._pinned)

    def pin(self, slot: int) -> None:
        """Mark an active slot as holding an open-ended lease: evict()
        refuses it until unpin().  The scheduler subtracts pinned slots
        from its effective capacity, so admission-control projections
        never assume a session slot will free up."""
        assert self._active[slot], f"slot {slot} not active"
        self._pinned[slot] = True

    def unpin(self, slot: int) -> None:
        self._pinned[slot] = False

    def is_pinned(self, slot: int) -> bool:
        return self._pinned[slot]

    # -- scratch slots (speculative-decode draft forks) ---------------------
    #
    # Scratch slots are extra pool rows reserved for transient state
    # forks: the spec-decode draft leases one, receives a fork of a live
    # slot's state, runs draft steps on it, and releases it after the
    # verify pass.  They are invisible to the live accounting above
    # (alloc/evict/n_free/active_*), and their id range is disjoint from
    # live ids, so lease/release can interleave arbitrarily with
    # admission/eviction without collisions.

    @property
    def n_scratch_free(self) -> int:
        return len(self._scratch_free)

    def lease_scratch(self) -> Optional[int]:
        """Reserve a scratch slot id (lowest first), or None when none
        are free.  The leased slot's state is whatever the previous
        lease left — callers must fork real state in before reading."""
        if not self._scratch_free:
            return None
        slot = min(self._scratch_free)
        self._scratch_free.remove(slot)
        return slot

    def release_scratch(self, slot: int) -> None:
        """Return a leased scratch slot.  No state reset: unlike evict,
        a scratch slot is only ever read after a fork overwrote every
        leaf (payload and scales move together in fork), so stale state
        cannot leak into the next lease."""
        if not (self.n_slots <= slot < self.n_total):
            raise ValueError(f"{slot} is not a scratch slot id")
        if slot in self._scratch_free:
            raise ValueError(f"scratch slot {slot} is not leased")
        self._scratch_free.append(slot)

    def fork(self, src: Sequence[int], dst: Sequence[int],
             branch_tags: Optional[Sequence[Optional[int]]] = None) -> None:
        """Copy per-slot state src[i] -> dst[i] in one fused
        gather+scatter dispatch.  Quantized payloads and their absmax
        scales are both cache leaves, so they fork together — a forked
        draft can never observe a live slot's payload under a stale
        scale (or vice versa).

        ``branch_tags`` (same length as dst) controls the destination
        key stream.  None / a 0 entry copies the source key verbatim:
        the spec-decode draft contract — the scratch slot continues the
        request's exact key schedule, so the draft's proposals are
        bitwise the tokens the request itself would sample.  A truthy
        tag t folds it into the source key (best-of-n branch b uses
        tag b), so forked "alternatives" draw from genuinely distinct
        streams instead of aliasing the parent's — the fork-seed
        aliasing fix.
        """
        if len(src) != len(dst):
            raise ValueError("fork src/dst length mismatch")
        if branch_tags is not None and len(branch_tags) != len(dst):
            raise ValueError("fork branch_tags/dst length mismatch")
        if not src:
            return
        self.cache = self._fork_fn(self.cache, jnp.asarray(list(src)),
                                   jnp.asarray(list(dst)))
        # the fork's sampling params move with the state: the draft must
        # propose with the request's own temperature/top-k/top-p and key
        self.params.copy(src, dst, tags=branch_tags)

    # -- device-state operations --------------------------------------------

    def admit(self, slot: int, sub_cache) -> None:
        """Scatter a batch-1 prefilled cache into ``slot`` (from alloc)."""
        assert self._active[slot], f"slot {slot} not allocated"
        self.cache = self._scatter_fn(self.cache, sub_cache,
                                      jnp.asarray([slot]))

    # -- capacity accounting ------------------------------------------------

    def state_bytes_per_slot(self) -> int:
        """Device bytes one slot occupies across every cache leaf —
        quantized payloads count at their storage width, and the f32
        absmax scales (cache leaves themselves) are included, so the
        number is the honest marginal cost of one more slot."""
        return sum(leaf.nbytes for leaf in jax.tree.leaves(self.cache)
                   ) // self.n_total

    def slots_per_gb(self) -> float:
        """Slot capacity per GB of decode-state memory (the serving
        capacity axis cfg.state_dtype multiplies)."""
        return (1 << 30) / max(1, self.state_bytes_per_slot())

    def device_state_bytes_per_slot(self) -> int:
        """Per-DEVICE bytes one slot occupies.  Under a TP mesh the
        sharded cache leaves split across devices (each holds one shard
        shape's worth), while replicated leaves count in full on every
        device — so this is the honest per-chip marginal cost of a slot
        and the number the sharded slots-per-GB capacity claim gates.
        Without a mesh it equals ``state_bytes_per_slot``."""
        def per_dev(leaf):
            sh = getattr(leaf, "sharding", None)
            if sh is None:
                return leaf.nbytes
            shape = sh.shard_shape(leaf.shape)
            return int(np.prod(shape, dtype=np.int64)) * leaf.dtype.itemsize
        return sum(per_dev(leaf) for leaf in jax.tree.leaves(self.cache)
                   ) // self.n_total

    def device_slots_per_gb(self) -> float:
        """Slot capacity per GB of PER-DEVICE decode-state memory —
        under TP this exceeds ``slots_per_gb`` because sharded leaves
        split across the mesh."""
        return (1 << 30) / max(1, self.device_state_bytes_per_slot())

    def evict(self, slot: int) -> None:
        """Reset ``slot`` to the init state and return it to the free list.

        The scatter-of-fresh-state is what guarantees no stale-state leak:
        a later admit overwrites the slot again, so even a torn admit can
        never observe a previous request's recurrent state.  With a
        quantized state_dtype the per-slot absmax scales are cache
        leaves, so the same scatter resets them too — a freed slot
        cannot leak a stale scale into the next admitted sequence.
        """
        assert self._active[slot], f"slot {slot} not active"
        if self._pinned[slot]:
            raise RuntimeError(
                f"slot {slot} holds an eviction-free lease (pinned "
                "session) — unpin before evicting")
        self.cache = self._scatter_fn(self.cache, self._fresh,
                                      jnp.asarray([slot]))
        self.params.clear(slot)
        self._active[slot] = False
        self._free.append(slot)

    def read(self, slots: Sequence[int]):
        """Gather a sub-cache for ``slots`` (testing/debug/migration)."""
        return self._gather_fn(self.cache, jnp.asarray(list(slots)))

    def commit(self, new_cache, active: Optional[np.ndarray] = None) -> None:
        """Accept a post-decode cache, keeping inactive slots frozen."""
        if active is None:
            active = self.active_mask()
        self.cache = self._mask_fn(self.cache, new_cache,
                                   jnp.asarray(active))
