"""Async serving front-end: per-request token streams over the engine.

The engine is a synchronous host loop (submit / step / run) built
around one thread touching the pool.  This module puts an asyncio face
on it without changing that contract: ONE pump task drives the engine
inside ``loop.run_in_executor`` (so jit dispatch never blocks the event
loop), and every client-visible edge crosses back with
``call_soon_threadsafe``:

  submit()  -> StreamHandle whose ``tokens()`` async-iterates the
               request's tokens as the engine emits them (SSE-style:
               each scheduler sync delivers the >= 1 new tokens) and
               whose ``result()`` awaits the finished Request;
  cancel()  -> enqueued to the pump, takes effect at the next sync;
  tenant()  -> a per-tenant context binding tenant/SLO labels so
               callers don't thread them through every submit.

With an ``SLOScheduler`` attached, submissions go through its
admission-control ladder: a shed request's handle resolves immediately
with ``handle.shed`` True and an empty stream — the rejection IS the
response, matching how an overloaded front door should answer.

Delivery plumbing: the engine's ``stream_cb`` fires in the pump
(executor) thread and forwards token batches onto the handle's
``asyncio.Queue`` via ``call_soon_threadsafe`` — the only thread-safe
way onto a loop — and the pump marks handles done centrally after each
step (covers cancel-before-admission, which never fires the callback).
Backpressure note: queues are unbounded on purpose; tokens are a few
ints per sync and the alternative (blocking the engine thread on a slow
client) would stall every co-resident stream.

All waiting is event-driven for clients (``await`` on queues/events);
the pump itself yields to the loop between engine steps so concurrent
submits/cancels interleave with decode bursts.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
from typing import Optional

from repro.runtime.engine import Engine
from repro.runtime.sampling import SamplingParams
from repro.runtime.scheduler import SLOScheduler

_DONE = object()


class StreamHandle:
    """One submitted request as seen by an async client."""

    def __init__(self, loop: asyncio.AbstractEventLoop):
        self._loop = loop
        self._queue: asyncio.Queue = asyncio.Queue()
        self._done = asyncio.Event()
        self.req = None               # engine Request once admitted
        self.ticket = None            # scheduler Ticket when scheduled
        self.shed = False
        self.cancelled = False

    # -- engine-thread side (pump) ------------------------------------------

    def _push_threadsafe(self, toks: list) -> None:
        self._loop.call_soon_threadsafe(self._queue.put_nowait,
                                        list(toks))

    def _finish_threadsafe(self) -> None:
        def _fin():
            self._queue.put_nowait(_DONE)
            self._done.set()
        self._loop.call_soon_threadsafe(_fin)

    # -- client side --------------------------------------------------------

    async def tokens(self):
        """Async-iterate the stream's tokens until it finishes (or is
        cancelled/shed — the stream just ends; inspect ``req`` /
        ``shed`` afterwards)."""
        while True:
            item = await self._queue.get()
            if item is _DONE:
                return
            for tok in item:
                yield tok

    async def result(self):
        """Await completion; returns the finished Request (None when
        the request was shed at admission control)."""
        await self._done.wait()
        return self.req

    @property
    def finished(self) -> bool:
        return self._done.is_set()


@dataclasses.dataclass
class _Submit:
    handle: StreamHandle
    prompt: object
    params: Optional[SamplingParams]
    kw: dict


@dataclasses.dataclass
class _Cancel:
    handle: StreamHandle


class TenantContext:
    """Binds tenant + SLO class labels onto submissions."""

    def __init__(self, frontend: "AsyncFrontend", tenant: str,
                 slo: Optional[str] = None):
        self._fe = frontend
        self.tenant = tenant
        self.slo = slo

    async def submit(self, prompt, params=None, **kw):
        kw.setdefault("tenant", self.tenant)
        if self.slo is not None:
            kw.setdefault("slo", self.slo)
        return await self._fe.submit(prompt, params, **kw)


class AsyncFrontend:
    """Asyncio front door over an Engine (optionally behind an
    SLOScheduler).  Use as an async context manager::

        async with AsyncFrontend(engine, scheduler) as fe:
            h = await fe.submit(prompt, params, tenant="acme")
            async for tok in h.tokens(): ...
            req = await h.result()
    """

    def __init__(self, engine: Engine,
                 scheduler: Optional[SLOScheduler] = None):
        if scheduler is not None and scheduler.engine is not engine:
            raise ValueError("scheduler drives a different engine")
        self.engine = engine
        self.scheduler = scheduler
        self._inbox: collections.deque = collections.deque()
        self._handles: list[StreamHandle] = []
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._task: Optional[asyncio.Task] = None
        self._running = False

    # -- lifecycle ----------------------------------------------------------

    async def __aenter__(self) -> "AsyncFrontend":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    def start(self) -> None:
        if self._task is not None:
            raise RuntimeError("frontend already started")
        self._loop = asyncio.get_running_loop()
        self._running = True
        self._task = self._loop.create_task(self._pump())

    async def stop(self, drain: bool = True) -> None:
        """Stop pumping.  ``drain=True`` (default) first cancels every
        live request — including infinite-stream sessions, which never
        end on their own — and lets the engine retire them, so no slot
        is left pinned and every handle resolves."""
        if self._task is None:
            return
        if drain:
            for h in self._handles:
                if not h.finished:
                    self._inbox.append(_Cancel(h))
            while any(not h.finished for h in self._handles):
                await asyncio.sleep(0)
        self._running = False
        await self._task
        self._task = None

    def tenant(self, name: str, slo: Optional[str] = None) -> TenantContext:
        return TenantContext(self, name, slo)

    # -- client API ---------------------------------------------------------

    async def submit(self, prompt,
                     params: Optional[SamplingParams] = None,
                     **kw) -> StreamHandle:
        """Submit a request; resolves once admission control has run
        (so ``handle.shed`` is meaningful on return).  ``kw`` passes
        through to ``SLOScheduler.submit`` (tenant, slo, session,
        max_new, ...) or — without a scheduler — to ``Engine.submit``.
        """
        if self._task is None:
            raise RuntimeError("frontend not started")
        handle = StreamHandle(self._loop)
        self._handles.append(handle)
        submitted = asyncio.Event()
        self._inbox.append((_Submit(handle, prompt, params, kw),
                            submitted))
        await submitted.wait()
        return handle

    async def cancel(self, handle: StreamHandle) -> None:
        """Request cancellation; the stream ends at the engine's next
        scheduler sync (tokens already delivered stand)."""
        handle.cancelled = True
        self._inbox.append(_Cancel(handle))

    # -- pump ---------------------------------------------------------------

    def _stream_cb(self, handle: StreamHandle):
        def cb(req, new_toks):
            handle._push_threadsafe(new_toks)
        return cb

    def _do_submit(self, msg: _Submit) -> None:
        h = msg.handle
        cb = self._stream_cb(h)
        if self.scheduler is not None:
            t = self.scheduler.submit(msg.prompt, msg.params,
                                      stream_cb=cb, **msg.kw)
            h.ticket = t
            if t.shed:
                h.shed = True
                h._finish_threadsafe()
        else:
            kw = dict(msg.kw)
            kw.pop("slo", None)
            h.req = self.engine.submit(msg.prompt, msg.params,
                                       stream_cb=cb, **kw)

    def _pump_once(self) -> bool:
        """One synchronous pump iteration (runs in the executor
        thread): drain the inbox, release + step the engine, resolve
        finished handles."""
        did = False
        while self._inbox:
            msg = self._inbox.popleft()
            if isinstance(msg, _Cancel):
                h = msg.handle
                if h.req is not None:
                    self.engine.cancel(h.req.req_id)
                elif h.ticket is not None and h.ticket.req is not None:
                    self.engine.cancel(h.ticket.req.req_id)
                elif h.ticket is not None and not h.ticket.shed:
                    # still queued in the scheduler: drop it there
                    q = self.scheduler._queues.get(h.ticket.tenant)
                    if q is not None and h.ticket in q:
                        q.remove(h.ticket)
                        self.scheduler._n_queued -= 1
                        self.scheduler._queued_cost -= h.ticket.cost
                        h._finish_threadsafe()
                did = True
            else:
                submit_msg, submitted = msg
                self._do_submit(submit_msg)
                self._loop.call_soon_threadsafe(submitted.set)
                did = True
        if self.scheduler is not None:
            did = self.scheduler.step() or did
        else:
            did = self.engine.step() or did
        for h in self._handles:
            if h.finished:
                continue
            req = h.req or (h.ticket.req if h.ticket is not None
                            else None)
            if req is not None:
                h.req = req
                if req.finished:
                    h._finish_threadsafe()
                    did = True
        return did

    async def _pump(self) -> None:
        loop = self._loop
        while True:
            did = await loop.run_in_executor(None, self._pump_once)
            if not self._running and not self._inbox and not did:
                break
            if not did:
                # idle: yield without burning the executor
                await asyncio.sleep(0.001)
            else:
                await asyncio.sleep(0)
