"""Per-request sampling parameters as DATA, not compile-time constants.

MARCA's core idea is one reconfigurable datapath that serves
heterogeneous operations without rewiring.  The serving analogue: ONE
jit'd prefill/decode/verify signature must serve a batch whose slots
mix greedy, temperature, top-k and top-p requests — so every sampling
knob lives in per-slot device arrays (``SlotParams``) that are traced
jit *arguments*, never Python constants baked into the jit cache key.
Changing any request's ``SamplingParams`` therefore changes array
VALUES, not traced shapes/consts: zero retracing for heterogeneous
traffic (``TRACE_COUNTS`` below is the proof hook the tests and the
bench gate assert on).

Randomness is per-slot counter-based: each request carries its own PRNG
key (from ``SamplingParams.seed``), and the token at stream position
``i`` is drawn with ``fold_in(key, i)``.  A request's sampled stream
is therefore a pure function of (params, prompt, weights) — bitwise
reproducible no matter which slot it lands in, what else shares the
batch, or when co-resident requests are admitted/evicted/cancelled.

Greedy contract: a slot with ``temperature <= 0`` emits
``argmax(float32 logits)`` — bitwise the pre-redesign engine's greedy
path, and bitwise identical whether the surrounding batch is greedy or
sampled (slot independence is the engine's existing exactness
contract).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

#: jit re-trace counters.  The step functions in engine.py/spec_decode.py
#: bump these with a Python side effect, which runs only when jax traces
#: (never on a cache hit) — so a test can snapshot, serve heterogeneous
#: traffic, and assert the delta is zero: one compile serves all
#: SamplingParams.  Keyed by step name ("decode_step", "prefill_admit",
#: "draft_step", "verify").
TRACE_COUNTS: collections.Counter = collections.Counter()

#: alternatives the step functions always compute per emitted token
#: (jax.lax.top_k over the log-softmax).  A fixed width keeps the jit
#: signatures free of per-request shape dependence; requests asking for
#: fewer (SamplingParams.top_logprobs) take a host-side prefix, requests
#: asking for none pay only the top_k, which is noise next to the argmax
#: the sampler already runs over the same vocab axis.
TOP_LOGPROBS = 5


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request generation knobs (``Engine.submit(prompt, params)``).

    temperature: 0 = greedy argmax (exact, reproducible); > 0 samples
        from the temperature-scaled, top-k/top-p-filtered softmax.
    top_k: keep only the k highest logits (0 = disabled).  Ties at the
        k-th value are all kept (deterministic, version-stable).
    top_p: keep the smallest prefix of the sorted distribution whose
        cumulative probability reaches ``top_p`` (1.0 = disabled); the
        crossing token is included, and at least one token always
        survives.
    seed: per-request PRNG seed; the sampled stream is a pure function
        of (seed, params, prompt, weights), independent of batch
        composition.  None derives a deterministic seed from the
        engine seed and the request id.
    stop: token ids, ANY of which ends the stream (the stop token is
        delivered, then the slot is evicted).  ``Engine.submit``'s
        ``eos_id`` convenience appends to this.
    stop_seqs: multi-token stop sequences; the stream ends as soon as
        its emitted tokens END WITH any of them (suffix-window match —
        the whole sequence is delivered, overshoot past it inside a
        decode burst is trimmed).  Orthogonal to ``stop``.
    max_new: token budget including the prefill-sampled first token.
    n: best-of-n — fork-served branches per request.  One prefill, n
        forked slots; branch b >= 1 samples from a per-branch key
        (``fold_in(key(seed), b)`` applied at fork time), branch 0 keeps
        the request's own stream.  The parent request returns the
        highest-cumulative-logprob branch's tokens, with all branches
        ranked in ``Request.branches``.
    logprobs: return the chosen token's log-probability per emitted
        token (``Request.logprobs``), under log-softmax of the raw f32
        logits — the model's own distribution, before temperature/
        filtering, so values are comparable across branches with
        different sampling knobs.
    top_logprobs: also return the top-``top_logprobs`` (token, logprob)
        alternatives per emitted token (``Request.top_logprobs``);
        bounded by ``TOP_LOGPROBS``.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None
    stop: tuple = ()
    stop_seqs: tuple = ()
    max_new: int = 32
    n: int = 1
    logprobs: bool = False
    top_logprobs: int = 0

    def validate(self) -> None:
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0; "
                             f"got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 disables); "
                             f"got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1]; got {self.top_p}")
        if self.max_new < 1:
            raise ValueError(f"max_new must be >= 1; got {self.max_new}")
        if self.n < 1:
            raise ValueError(f"n must be >= 1; got {self.n}")
        for s in self.stop_seqs:
            if len(tuple(s)) < 1:
                raise ValueError("stop_seqs entries must be non-empty")
        if not 0 <= self.top_logprobs <= TOP_LOGPROBS:
            raise ValueError(f"top_logprobs must be in [0, {TOP_LOGPROBS}]"
                             f"; got {self.top_logprobs}")


#: the engine-wide default: greedy argmax, 32-token budget
GREEDY = SamplingParams()


def seed_key_data(seed: int) -> np.ndarray:
    """Raw uint32 key data for ``jax.random.key(seed)`` — the host-side
    representation SlotParams stores per slot (wrapped back into a
    typed key inside the jit, so key material is ordinary array data
    that never keys a jit cache)."""
    return np.asarray(jax.random.key_data(jax.random.key(seed)))


class SlotParams:
    """Per-slot sampling-parameter arrays over a pool's rows.

    Host-side numpy mirrors (mutated O(1) on admit/evict/fork — the
    slot lifecycle never touches the device) with ``device()``
    producing the dict of jnp arrays the jit'd step functions take as
    traced arguments.  Rows are the pool's rows (live + scratch); a
    speculative fork copies the live row onto the scratch row so the
    draft samples with the request's own knobs and key stream.
    """

    FIELDS = ("temperature", "top_k", "top_p", "key_data")

    def __init__(self, n: int):
        kd = seed_key_data(0)
        self.n = n
        self.temperature = np.zeros((n,), np.float32)
        self.top_k = np.zeros((n,), np.int32)
        self.top_p = np.ones((n,), np.float32)
        self.key_data = np.zeros((n,) + kd.shape, kd.dtype)

    def set(self, slot: int, sp: SamplingParams, seed: int) -> None:
        self.temperature[slot] = sp.temperature
        self.top_k[slot] = sp.top_k
        self.top_p[slot] = sp.top_p
        self.key_data[slot] = seed_key_data(seed)

    def clear(self, slot: int) -> None:
        """Reset a row to the greedy default (eviction hygiene: a freed
        slot can never leak its request's temperature or key into the
        next admission)."""
        self.temperature[slot] = 0.0
        self.top_k[slot] = 0
        self.top_p[slot] = 1.0
        self.key_data[slot] = 0

    def copy(self, src: Sequence[int], dst: Sequence[int],
             tags: Optional[Sequence[Optional[int]]] = None) -> None:
        """Mirror a state fork: dst rows take src rows' params.

        ``tags`` (same length as dst) re-derives destination keys:
        a truthy tag t folds it into the SOURCE row's key —
        ``key_data(fold_in(key, t))`` — giving each best-of-n branch
        its own stream while sharing every other knob.  A tag of
        0/None copies the key verbatim (byte-for-byte the pre-tag
        behavior): the spec-decode draft-fork contract, where the
        scratch slot MUST continue the request's exact key schedule,
        and the branch-0 convention, where the first branch coincides
        bitwise with the same request served at n=1.
        """
        src, dst = list(src), list(dst)
        for f in self.FIELDS:
            a = getattr(self, f)
            a[dst] = a[src]
        if tags is None:
            return
        for s, d, t in zip(src, dst, tags):
            if t:
                key = jax.random.wrap_key_data(jnp.asarray(self.key_data[s]))
                self.key_data[d] = np.asarray(
                    jax.random.key_data(jax.random.fold_in(key, int(t))))

    def row(self, slot: int) -> dict:
        """Single-row device view (batch-1 prefill sampling)."""
        return {f: jnp.asarray(getattr(self, f)[slot:slot + 1])
                for f in self.FIELDS}

    def device(self) -> dict:
        """All rows as jnp arrays — the traced jit argument."""
        return {f: jnp.asarray(getattr(self, f)) for f in self.FIELDS}


# ---------------------------------------------------------------------------
# Device-side sampling (runs inside the jit'd step functions)
# ---------------------------------------------------------------------------

def slot_keys(key_data, idx):
    """Per-slot derived keys: wrap row r's key data and fold in
    ``idx[r]`` (the slot's stream position / pass counter) — the
    counter-based key schedule that makes streams batch-independent."""
    keys = jax.random.wrap_key_data(key_data)
    return jax.vmap(jax.random.fold_in)(keys, idx)


def fold_tag(keys, tag: int):
    """Derive a sub-stream (accept / residual / bonus draws in the
    speculative pass) from already-folded per-slot keys."""
    return jax.vmap(lambda k: jax.random.fold_in(k, tag))(keys)


def token_logprobs(logits, tok):
    """Per-token logprob surface: (b, V) raw logits + (b,) chosen ids
    -> (chosen_lp (b,), top_vals (b, K), top_ids (b, K)) with
    K = min(TOP_LOGPROBS, V).

    Log-softmax of the RAW float32 logits — the model's distribution
    before temperature scaling or top-k/top-p filtering — so logprobs
    are comparable across requests/branches with different sampling
    knobs (and a sampled token filtered into a renormalized dist still
    reports its true model probability).  Computed unconditionally
    inside the step jits: the chosen-token math is untouched, so token
    streams stay bitwise identical to the logprob-free engine.
    """
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    chosen = jnp.take_along_axis(lp, tok[:, None], axis=-1)[:, 0]
    k = min(TOP_LOGPROBS, lp.shape[-1])
    tv, ti = jax.lax.top_k(lp, k)
    return chosen, tv, ti.astype(jnp.int32)


def filter_logits(scaled, top_k, top_p):
    """Vectorized per-row top-k + top-p masking.

    scaled (b, V) f32 logits (already temperature-scaled);
    top_k (b,) int32 (0 disables); top_p (b,) f32 (1.0 ~disables).
    Returns logits with masked-out entries at -inf.  Ties at either
    threshold are kept (a deterministic superset — stable across
    platforms, and harmless: tied logits are interchangeable).
    """
    v = scaled.shape[-1]
    srt = jnp.sort(scaled, axis=-1)[..., ::-1]            # descending
    k = jnp.where(top_k > 0, jnp.clip(top_k, 1, v), v)
    kth = jnp.take_along_axis(srt, k[:, None] - 1, axis=-1)
    probs = jax.nn.softmax(srt, axis=-1)
    csum = jnp.cumsum(probs, axis=-1)
    # keep sorted position j iff the mass strictly before it is < top_p
    # (includes the crossing token); clamp so >= 1 token survives
    n_keep = jnp.maximum(((csum - probs) < top_p[:, None]).sum(-1), 1)
    pth = jnp.take_along_axis(srt, n_keep[:, None] - 1, axis=-1)
    return jnp.where((scaled >= kth) & (scaled >= pth), scaled, -jnp.inf)


def sample_dist(logits, sp):
    """(b, V) raw logits -> the per-slot SAMPLING distribution's logits:
    temperature-scaled then top-k/top-p filtered.  Shared between the
    burst sampler and speculative acceptance so the draft's proposal
    distribution and the acceptance ratio use identical math (greedy
    rows get a neutral scale of 1; callers select argmax for them)."""
    lg = logits.astype(jnp.float32)
    t = jnp.where(sp["temperature"] > 0, sp["temperature"], 1.0)
    return filter_logits(lg / t[:, None], sp["top_k"], sp["top_p"])


def sample(logits, sp, step):
    """Vectorized per-slot sampling: (b, V) logits -> (b,) int32 tokens.

    ``sp`` is a SlotParams.device()/row() dict with b rows; ``step``
    (b,) int32 is each slot's stream position (tokens already emitted),
    folded into the slot key so position i's draw is reproducible
    independent of batch composition.  Rows with temperature <= 0 take
    the greedy argmax (bitwise the pre-redesign path); a mixed batch
    costs one dispatch and heterogeneous params never retrace.

    The sampled battery (sort/softmax/cumsum/categorical) sits behind a
    ``lax.cond`` on ``any(temperature > 0)``: an all-greedy batch pays
    one argmax plus the predicate at runtime — the pre-redesign greedy
    cost — while keeping a single compiled program (a static host flag
    would fork the jit cache and retrace when traffic turns mixed).
    """
    lg = logits.astype(jnp.float32)
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)

    def _mixed(_):
        dist = sample_dist(logits, sp)
        keys = slot_keys(sp["key_data"], step)
        drawn = jax.vmap(jax.random.categorical)(keys,
                                                 dist).astype(jnp.int32)
        return jnp.where(sp["temperature"] > 0, drawn, greedy)

    return jax.lax.cond(jnp.any(sp["temperature"] > 0),
                        _mixed, lambda _: greedy, None)
