"""SLO-aware multi-tenant admission scheduler for the serving engine.

The engine's own policy (engine.py ``step``) is admit-eagerly by
priority — correct for one cooperative client, wrong for a shared
front door: one tenant bursting 50 requests starves everyone behind
it, and admission control that only looks at free slots happily queues
an hour of work against a 200 ms TTFT budget.  This module holds the
requests OUTSIDE the engine and releases them by policy:

Weighted fair queuing (start-time virtual clock).  Each tenant has a
weight; each request a cost in *service units* (prompt tokens +
max_new * n — the token work the engine will spend on it).  On submit
the request is stamped ``start = max(V, tenant_finish)`` and the
tenant's virtual finish advances by ``cost / weight``; release always
picks the smallest start tag across tenant-queue heads (FIFO within a
tenant).  This is textbook SFQ: a tenant's share of admissions
converges to its weight share, and no backlogged tenant waits more
than one maximal request per competing tenant between its own
admissions — the no-starvation bound the tests and the bench gate
assert deterministically via ``starvation_bound``.

SLO classes + load shedding.  Every request carries an SLOClass with a
TTFT budget in deterministic service STEPS (never wall-clock — CPU CI
would flap): the projected queue wait for a new request is
``(resident remaining tokens + queued cost) / effective slots``,
where effective slots excludes pinned session leases.  The degradation
ladder runs at submit, cheapest remedy first, so resident requests
keep their slots and their pace *before* anything is refused:

  1. projected > spec_degrade_frac * budget: cap speculative depth
     engine-wide (``Engine.spec_cap = 1``) — sheds draft/verify work,
     token streams unchanged (depth is data, not distribution);
  2. projected > degrade_n_frac * budget: admit best-of-n requests at
     n=1 (cost shrinks n-fold; counted in ``n_degraded``);
  3. projected > budget: reject (shed) if the class allows it —
     counted, never submitted, ``Ticket.shed`` True.  Non-sheddable
     classes are always admitted and may violate (the wall-clock SLO
     accounting in ``finalize`` counts that, decisions never read it).

Determinism: every decision above is a function of (submission order,
token counts, config) only.  Wall-clock appears exactly once — in
``finalize``'s per-tenant violation accounting, which feeds dashboards
and uses the engine's injectable clock, so tests pin it.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import numpy as np

from repro.runtime.engine import Engine
from repro.runtime.sampling import SamplingParams


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One request class's service-level objective.

    ttft_budget: admission-control budget in deterministic service
      steps (projected decode-step-equivalents of queue wait) — the
      shed/degrade ladder compares against this, never wall-clock.
    ttft_slo_s / tpot_slo_s: optional wall-clock budgets for
      *accounting* (violation counters in ServeStats); decisions never
      read them.
    sheddable: False = never rejected (degrade only; may violate)."""
    name: str = "standard"
    ttft_budget: int = 256
    ttft_slo_s: Optional[float] = None
    tpot_slo_s: Optional[float] = None
    sheddable: bool = True


@dataclasses.dataclass
class SchedConfig:
    """weights: tenant -> WFQ weight (unknown tenants get 1.0).
    classes: available SLOClasses; default_class names the fallback.
    spec_degrade_frac / degrade_n_frac: ladder thresholds as fractions
    of the request's class ttft_budget.  session_cost: WFQ cost charge
    for an infinite-stream session (its true cost is unbounded; this
    is the admission-fairness charge for taking a slot out of the
    pool)."""
    weights: dict = dataclasses.field(
        default_factory=lambda: {"default": 1.0})
    classes: tuple = (SLOClass(),)
    default_class: str = "standard"
    spec_degrade_frac: float = 0.5
    degrade_n_frac: float = 0.75
    session_cost: int = 256

    def validate(self) -> None:
        names = [c.name for c in self.classes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO class names: {names}")
        if self.default_class not in names:
            raise ValueError(f"default_class {self.default_class!r} "
                             f"not in classes {names}")
        if not (0.0 < self.spec_degrade_frac
                <= self.degrade_n_frac <= 1.0):
            raise ValueError(
                "need 0 < spec_degrade_frac <= degrade_n_frac <= 1 "
                "(the ladder runs cheapest remedy first)")
        for w in self.weights.values():
            if w <= 0:
                raise ValueError("tenant weights must be > 0")


@dataclasses.dataclass
class Ticket:
    """The scheduler's handle for one submission.  ``req`` is None
    until release (and stays None forever when shed)."""
    tenant: str
    slo: SLOClass
    cost: int
    start: float                     # WFQ start tag
    seq: int                         # global FIFO tiebreak
    shed: bool = False
    degraded: bool = False           # best-of-n shrunk to 1
    req: Optional[object] = None
    _kw: dict = dataclasses.field(default_factory=dict, repr=False)


class SLOScheduler:
    """Admission front door over an Engine.  Hold -> decide -> release.

    Usage::

        sched = SLOScheduler(engine, SchedConfig(...))
        t = sched.submit(prompt, params, tenant="acme", slo="premium")
        if t.shed: ...           # rejected at the door
        done = sched.run()       # drives engine to completion
    """

    def __init__(self, engine: Engine, scfg: Optional[SchedConfig] = None):
        self.engine = engine
        self.cfg = scfg or SchedConfig()
        self.cfg.validate()
        self._classes = {c.name: c for c in self.cfg.classes}
        self._queues: dict[str, collections.deque] = {}
        self._vtime = 0.0
        self._finish: dict[str, float] = {}   # per-tenant virtual finish
        self._seq = 0
        self._n_queued = 0
        self._queued_cost = 0
        # deterministic fairness audit trail: tenant name per admission,
        # and the worst pass-over count any backlogged tenant suffered
        self.admitted_order: list[str] = []
        self.starvation_bound = 0
        self._waited: dict[str, int] = {}
        self.tickets: list[Ticket] = []

    # -- projections (all deterministic service-step arithmetic) ------------

    def _weight(self, tenant: str) -> float:
        return float(self.cfg.weights.get(tenant, 1.0))

    def _effective_slots(self) -> int:
        """Slots admission can ever reuse: pinned session leases are
        never evicted, so they are capacity the projection must not
        count on."""
        return self.engine.ecfg.n_slots - self.engine.pool.n_pinned

    def _resident_cost(self) -> int:
        """Remaining token work held by live non-session slots."""
        total = 0
        for req in self.engine._slot_req:
            if req is not None and not req.session:
                total += max(0, req.max_new - len(req.tokens))
        return total

    def projected_wait(self) -> float:
        """Service steps a request submitted NOW waits before a slot
        frees for it: all resident + queued work divided across the
        effective slots.  inf when sessions pinned every slot."""
        eff = self._effective_slots()
        backlog = self._resident_cost() + self._queued_cost + sum(
            e[2].params.max_new * e[2].params.n for e in
            self.engine._ready)
        if eff <= 0:
            return float("inf")
        return backlog / eff

    @staticmethod
    def _cost_of(prompt_len: int, params: SamplingParams,
                 session: bool, session_cost: int) -> int:
        if session:
            return prompt_len + session_cost
        return prompt_len + params.max_new * params.n

    # -- intake -------------------------------------------------------------

    def submit(self, prompt, params: Optional[SamplingParams] = None,
               tenant: str = "default", slo: Optional[str] = None,
               session: bool = False, **engine_kw) -> Ticket:
        """Admission-control a request and queue it for WFQ release.

        Runs the degradation ladder against the current projected wait
        (see module docstring); a shed ticket never reaches the engine.
        ``engine_kw`` passes through to ``Engine.submit`` (max_new,
        eos_id, stream_cb, ...)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        params = (params if params is not None
                  else self.engine.ecfg.default_params)
        if "max_new" in engine_kw and engine_kw["max_new"] is not None:
            params = dataclasses.replace(params,
                                         max_new=engine_kw.pop("max_new"))
        cls = self._classes[slo or self.cfg.default_class]
        projected = self.projected_wait()
        self._update_pressure(projected)
        degraded = False
        if (projected > self.cfg.degrade_n_frac * cls.ttft_budget
                and params.n > 1):
            # rung 2: a best-of-n under pressure costs n slots and n
            # streams — collapse to the single branch 0 stream (which
            # is bitwise the n=1 serve of the same request) instead of
            # shedding it outright
            params = dataclasses.replace(params, n=1)
            degraded = True
        if projected > cls.ttft_budget and cls.sheddable:
            t = Ticket(tenant=tenant, slo=cls, cost=0, start=self._vtime,
                       seq=self._seq, shed=True)
            self._seq += 1
            self.tickets.append(t)
            self.engine.stats.record_shed(tenant)
            return t
        if degraded:
            self.engine.stats.record_degraded(tenant)
        cost = self._cost_of(int(prompt.size), params, session,
                             self.cfg.session_cost)
        start = max(self._vtime, self._finish.get(tenant, 0.0))
        self._finish[tenant] = start + cost / self._weight(tenant)
        t = Ticket(tenant=tenant, slo=cls, cost=cost, start=start,
                   seq=self._seq, degraded=degraded,
                   _kw=dict(engine_kw, params=params, session=session))
        t._kw["prompt"] = prompt
        self._seq += 1
        self._queues.setdefault(tenant, collections.deque()).append(t)
        self._n_queued += 1
        self._queued_cost += cost
        self.tickets.append(t)
        return t

    def _update_pressure(self, projected: float) -> None:
        """Rung 1 of the ladder: under pressure, cap speculative depth
        engine-wide.  Depth is pure host-side arithmetic (engine
        ``_slot_depth``), so flipping the cap never retraces and never
        changes a token — it sheds draft/verify dispatches only.
        Threshold uses the default class's budget (engine-wide knob,
        engine-wide reference point); restored as soon as the backlog
        clears it."""
        if self.engine._spec is None:
            return
        budget = self._classes[self.cfg.default_class].ttft_budget
        over = projected > self.cfg.spec_degrade_frac * budget
        self.engine.spec_cap = 1 if over else None

    # -- release ------------------------------------------------------------

    def _committed(self) -> int:
        """Slots the engine's ready queue will consume once admitted."""
        return sum(e[2].params.n for e in self.engine._ready)

    def release(self) -> int:
        """Move queued tickets into the engine while capacity allows,
        smallest WFQ start tag first (seq breaks ties FIFO).  Returns
        the number released.  Also the fairness audit point: every
        release that passes over a backlogged tenant bumps its waited
        counter, and ``starvation_bound`` records the worst wait any
        tenant's head-of-queue ever saw."""
        released = 0
        while self._n_queued:
            free = self.engine.pool.n_free - self._committed()
            head = None
            for tenant, q in self._queues.items():
                if not q:
                    continue
                cand = q[0]
                if head is None or (cand.start, cand.seq) < (head.start,
                                                             head.seq):
                    head = cand
            if head is None:
                break
            if head._kw["params"].n > free:
                break
            self._queues[head.tenant].popleft()
            self._n_queued -= 1
            self._queued_cost -= head.cost
            self._vtime = max(self._vtime, head.start)
            # fairness audit: everyone else still backlogged was passed
            # over by this admission
            self.starvation_bound = max(self.starvation_bound,
                                        self._waited.get(head.tenant, 0))
            self._waited[head.tenant] = 0
            for tenant, q in self._queues.items():
                if q and tenant != head.tenant:
                    self._waited[tenant] = self._waited.get(tenant, 0) + 1
            kw = dict(head._kw)
            head.req = self.engine.submit(
                kw.pop("prompt"), kw.pop("params"), tenant=head.tenant,
                **kw)
            self.admitted_order.append(head.tenant)
            released += 1
        return released

    # -- drive --------------------------------------------------------------

    def step(self) -> bool:
        did = self.release() > 0
        return self.engine.step() or did

    def run(self) -> list:
        """Release + step until every queued and resident request
        retires.  Infinite-stream sessions never retire on their own —
        cancel them (or run the loop yourself) before calling this
        with sessions resident.  Returns the engine's finished list and
        runs the wall-clock SLO accounting over it."""
        eng = self.engine
        eng.stats.start()
        eng._finished = []
        while True:
            did = self.step()
            if (not did and not self._n_queued and not eng._ready
                    and not eng.pool.n_active):
                break
        eng.stats.stop()
        self.finalize(eng._finished)
        return eng._finished

    def finalize(self, finished: list) -> None:
        """Wall-clock SLO violation accounting (the only place the
        scheduler touches time, via the engine's injectable clock).
        Cancelled requests are excluded — a client that hung up cannot
        violate an SLO it stopped caring about."""
        by_req = {id(t.req): t for t in self.tickets if t.req is not None}
        for req in finished:
            t = by_req.get(id(req))
            if t is None or req.cancelled or req.t_first is None:
                continue
            cls = t.slo
            ttft = req.t_first - req.t_submit
            if cls.ttft_slo_s is not None and ttft > cls.ttft_slo_s:
                self.engine.stats.record_slo_violation("ttft", t.tenant)
            if (cls.tpot_slo_s is not None and len(req.tokens) > 1
                    and req.t_done is not None):
                tpot = (req.t_done - req.t_first) / (len(req.tokens) - 1)
                if tpot > cls.tpot_slo_s:
                    self.engine.stats.record_slo_violation("tpot",
                                                           t.tenant)

    # -- audit --------------------------------------------------------------

    def counters(self) -> dict:
        return {
            "admitted": len(self.admitted_order),
            "shed": sum(1 for t in self.tickets if t.shed),
            "degraded": sum(1 for t in self.tickets if t.degraded),
            "starvation_bound": self.starvation_bound,
            "admitted_per_tenant": dict(collections.Counter(
                self.admitted_order)),
        }
