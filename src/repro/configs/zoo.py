"""All architecture configs.

10 assigned archs (exact hyperparameters from the assignment table,
[source; verified-tier] in each docstring line) + the paper's own Mamba
family (Table 1).  One ``register(ModelConfig(...))`` per arch; resolve with
``--arch <name>``.
"""
from repro.configs.base import ModelConfig, register

# --- dense transformers ----------------------------------------------------

#: granite-20b [dense] 52L d6144 48H (kv=1 MQA) ff24576 V49152 — llama-arch,
#: code [arXiv:2405.04324; hf]
GRANITE_20B = register(ModelConfig(
    name="granite-20b", family="transformer", n_layers=52, d_model=6144,
    n_heads=48, n_kv_heads=1, d_ff=24576, vocab=49152, norm="rmsnorm",
    mlp="swiglu"))

#: olmo-1b [dense] 16L d2048 16H (MHA) ff8192 V50304 — non-parametric LN
#: [arXiv:2402.00838; hf]
OLMO_1B = register(ModelConfig(
    name="olmo-1b", family="transformer", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=8192, vocab=50304, norm="ln_nonparam",
    mlp="swiglu", tie_embeddings=True))

#: qwen2-7b [dense] 28L d3584 28H (kv=4) ff18944 V152064 — GQA, QKV bias
#: [arXiv:2407.10671; hf]
QWEN2_7B = register(ModelConfig(
    name="qwen2-7b", family="transformer", n_layers=28, d_model=3584,
    n_heads=28, n_kv_heads=4, d_ff=18944, vocab=152064, qkv_bias=True,
    rope_theta=1e6, norm="rmsnorm", mlp="swiglu"))

#: qwen2.5-14b [dense] 48L d5120 40H (kv=8) ff13824 V152064 — GQA, QKV bias
#: [hf:Qwen/Qwen2.5-0.5B; hf]
QWEN2_5_14B = register(ModelConfig(
    name="qwen2.5-14b", family="transformer", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=13824, vocab=152064, qkv_bias=True,
    rope_theta=1e6, norm="rmsnorm", mlp="swiglu"))

#: musicgen-large [audio] 48L d2048 32H (MHA) ff8192 V2048 — decoder-only
#: over EnCodec tokens, 4 codebooks, stub frontend [arXiv:2306.05284; hf]
MUSICGEN_LARGE = register(ModelConfig(
    name="musicgen-large", family="transformer", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=2048, norm="ln", mlp="gelu",
    frontend="audio_stub", n_codebooks=4))

#: phi-3-vision-4.2b [vlm] 32L d3072 32H (MHA) ff8192 V32064 — phi3-mini +
#: CLIP stub (576 patch embeds) [hf:microsoft/Phi-3-vision-128k-instruct; hf]
PHI3_VISION = register(ModelConfig(
    name="phi-3-vision-4.2b", family="transformer", n_layers=32,
    d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32064,
    norm="rmsnorm", mlp="swiglu", frontend="vision_stub", img_tokens=576))

# --- MoE transformers --------------------------------------------------------

#: qwen2-moe-a2.7b [moe] 24L d2048 16H (MHA) ff1408/expert V151936 —
#: 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
QWEN2_MOE = register(ModelConfig(
    name="qwen2-moe-a2.7b", family="transformer", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=151936, qkv_bias=True,
    norm="rmsnorm", mlp="swiglu", n_experts=60, top_k=4,
    n_shared_experts=4, expert_pad_to=64))

#: arctic-480b [moe] 35L d7168 56H (kv=8) ff4864 V32000 — 128 experts top-2
#: + dense residual [hf:Snowflake/snowflake-arctic-base; hf]
ARCTIC_480B = register(ModelConfig(
    name="arctic-480b", family="transformer", n_layers=35, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=4864, vocab=32000, norm="rmsnorm",
    mlp="swiglu", n_experts=128, top_k=2, dense_residual=True))

# --- hybrid / SSM -----------------------------------------------------------

#: jamba-v0.1-52b [hybrid] 32L d4096 32H (kv=8) ff14336 V65536, MoE 16e
#: top-2 — Mamba+attn 1:7, MoE every other layer [arXiv:2403.19887; hf]
JAMBA_52B = register(ModelConfig(
    name="jamba-v0.1-52b", family="jamba", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=65536, norm="rmsnorm",
    mlp="swiglu", n_experts=16, top_k=2, moe_every=2, moe_offset=1,
    attn_every=8, attn_offset=4, d_state=16, d_conv=4, expand=2))

#: xlstm-350m [ssm] 24L d1024 4H ff0 V50304 — sLSTM + mLSTM 1:7
#: [arXiv:2405.04517; unverified]
XLSTM_350M = register(ModelConfig(
    name="xlstm-350m", family="xlstm", n_layers=24, d_model=1024, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab=50304, norm="ln", slstm_every=8,
    slstm_offset=7, tie_embeddings=True))

# --- the paper's own models (Table 1) ----------------------------------------

_MAMBA_TABLE1 = {
    "mamba-130m": (24, 768),
    "mamba-370m": (48, 1024),
    "mamba-790m": (48, 1536),
    "mamba-1.4b": (48, 2048),
    "mamba-2.8b": (64, 2560),
}

# vocab: Mamba's GPT-NeoX tokenizer is 50277, padded to 50280 in the
# release; we pad further to 50304 (multiple of 256) so the embedding
# shards evenly over the 16-way mesh axes — standard practice.
for _name, (_L, _d) in _MAMBA_TABLE1.items():
    register(ModelConfig(
        name=_name, family="mamba", n_layers=_L, d_model=_d,
        n_heads=1, n_kv_heads=1, d_ff=0, vocab=50304, norm="rmsnorm",
        tie_embeddings=True, d_state=16, d_conv=4, expand=2))

#: The ten assigned architectures (dry-run / roofline set).
ASSIGNED = [
    "granite-20b", "olmo-1b", "qwen2-7b", "qwen2.5-14b", "musicgen-large",
    "jamba-v0.1-52b", "xlstm-350m", "qwen2-moe-a2.7b", "arctic-480b",
    "phi-3-vision-4.2b",
]

MAMBA_FAMILY = list(_MAMBA_TABLE1)
