"""Assigned input-shape sets (LM-family: seq_len x global_batch).

  train_4k     seq 4,096   batch 256   -> lowers train_step
  prefill_32k  seq 32,768  batch 32    -> lowers prefill forward
  decode_32k   cache 32,768 batch 128  -> lowers serve_step (1 new token)
  long_500k    cache 524,288 batch 1   -> serve_step; SSM/hybrid only
                                          (sub-quadratic rule, DESIGN.md §5)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

#: Families with O(1)-state token mixing (sub-quadratic): run long_500k.
SUBQUADRATIC_FAMILIES = ("mamba", "xlstm", "jamba")


def applicable_shapes(cfg) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in SUBQUADRATIC_FAMILIES:
        out.append("long_500k")
    return out


def skip_reason(cfg, shape_name: str):
    if shape_name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return ("full-attention arch: O(L^2) at 524k; skipped per "
                "assignment rule (DESIGN.md §5)")
    return None
