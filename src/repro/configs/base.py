"""Model configuration dataclass + registry (``--arch`` resolution)."""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config per architecture; frozen/hashable so it can be a static
    argument to jit'd step functions."""
    name: str
    family: str                  # transformer | mamba | jamba | xlstm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # attention
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"        # rmsnorm | ln | ln_nonparam
    tie_embeddings: bool = False

    # mlp
    mlp: str = "swiglu"          # swiglu | gelu

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    dense_residual: bool = False     # arctic: dense MLP in parallel with MoE
    moe_every: int = 1               # MoE at layers i % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    #: pad the expert dim to this multiple-of-mesh size with inert experts
    #: (router logits forced to -inf) so EP shards evenly; 0 = no padding.
    expert_pad_to: int = 0
    #: MoE dispatch: "dense" (pjit-auto) | "ep" (shard_map all-to-all) |
    #: "auto" (ep when a mesh with a model axis is active)
    moe_impl: str = "auto"
    norm_topk: bool = True
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3

    # Mamba / SSM
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                 # 0 -> ceil(d_model / 16)
    attn_every: int = 0              # jamba: attention at i%attn_every==attn_offset
    attn_offset: int = 0

    # xLSTM
    slstm_every: int = 0             # sLSTM at i%slstm_every==slstm_offset
    slstm_offset: int = 7

    # modality frontends (STUBS per assignment: precomputed embeddings)
    frontend: str = "tokens"         # tokens | audio_stub | vision_stub
    n_codebooks: int = 1             # musicgen output heads
    img_tokens: int = 0              # phi3v: image patch embeds prepended

    # numerics / implementation selection (the MARCA knobs)
    dtype: str = "bfloat16"
    #: production default: chunked_seq (fused per-step chain, chunk-level
    #: remat — §Perf iterations M1-M2); "chunked" (associative) is the
    #: paper-baseline XLA implementation, "pallas" the TPU kernel.
    scan_impl: str = "chunked_seq"   # seq | assoc | chunked | chunked_seq | pallas
    scan_chunk: int = 64
    #: per-token decode step: "megakernel" = ONE Pallas launch per token
    #: for the whole layer stack (layer axis in the kernel grid; jamba
    #: attention sublayers excepted), "fused" = single launch per layer
    #: for the state-update/contraction/gate chain, "xla" = the ref.py
    #: oracle, "auto" = megakernel on TPU, else fused where it compiles
    #: natively (everywhere for pure-XLA fused steps); the
    #: REPRO_STEP_IMPL env var overrides "auto" only
    step_impl: str = "auto"          # auto | megakernel | fused | xla
    attn_impl: str = "chunked"       # chunked | ref | pallas
    attn_chunk: int = 512
    exp_impl: str = "exact"          # exact | ours | fast   (MARCA §5)
    silu_impl: str = "exact"         # exact | ours | paper  (MARCA §5)
    conv_impl: str = "xla"           # xla | pallas
    remat: bool = True
    scan_layers: bool = True         # lax.scan over stacked layer params

    #: logits dtype out of the unembed matmul ("float32" | "bfloat16");
    #: bf16 halves the (tokens x vocab) stream, lse still accumulates f32
    logits_dtype: str = "float32"

    #: KV-cache storage dtype for decode: "model" (= cfg.dtype) | "int8"
    #: (per-position absmax scales; halves/quarters decode cache memory,
    #: fixes the MHA decode_32k cells that exceed 16 GB/chip)
    kv_cache_dtype: str = "model"

    #: Weight storage dtype: "f32" (params as handed in) | "int8"
    #: (per-output-channel absmax codes with f32 scale leaves riding the
    #: same pytree — see core/weight_quant.py).  Dense projections and
    #: mamba's A dequantize where they are consumed — inside the decode
    #: kernels for fused/megakernel steps — so decode streams ~4x fewer
    #: weight bytes per token; embed/unembed/MoE stay f32.
    weight_dtype: str = "f32"

    #: Recurrent-state storage dtype for the pooled decode state
    #: ("f32" | "bf16" | "int8" | "fp8").  int8/fp8 store the SSM h (and
    #: xLSTM matrix memory C) with per-slot-per-layer-per-channel-group
    #: f32 absmax scales kept alongside the cache pytree; the decode
    #: step dequantizes on read and requantizes on write (decayed
    #: running absmax), so slot capacity scales ~4x while step math
    #: stays f32.  Pairs with kv_cache_dtype, which covers the
    #: attention KV strips; state_dtype covers the recurrent blocks.
    state_dtype: str = "f32"

    # training defaults
    max_seq: int = 4096

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))
        if self.dt_rank == 0:
            object.__setattr__(self, "dt_rank",
                               math.ceil(self.d_model / 16))

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def n_params(self) -> int:
        """Analytical parameter count (drives 6ND roofline + memory calc)."""
        from repro.models import registry
        return registry.count_params(self)

    def n_active_params(self) -> int:
        from repro.models import registry
        return registry.count_params(self, active_only=True)


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: small layers/width,
    few experts, tiny vocab — structure preserved."""
    kw: dict = dict(
        name=cfg.name + "-smoke",
        n_layers=max(2, min(4, cfg.n_layers)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        head_dim=16,
        dt_rank=8,
        max_seq=64,
        scan_chunk=16,
        attn_chunk=32,
        dtype="float32",
    )
    if cfg.is_moe:
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2),
                  n_shared_experts=min(cfg.n_shared_experts, 1))
    if cfg.attn_every:
        kw.update(n_layers=max(cfg.attn_every, 2))
    if cfg.slstm_every:
        kw.update(n_layers=max(cfg.slstm_every, 2))
    if cfg.img_tokens:
        kw.update(img_tokens=8)
    return dataclasses.replace(cfg, **kw)
