"""Architecture configs: the 10 assigned archs + the paper's Mamba family.

``get_config(name)`` resolves ``--arch`` ids (dashes) to config objects;
``list_archs()`` enumerates them.  Input-shape sets live in ``shapes.py``.
"""
from repro.configs.base import (ModelConfig, get_config, list_archs,
                                register, smoke_variant)
from repro.configs import shapes  # noqa: F401
from repro.configs import zoo  # noqa: F401  (registers everything)

__all__ = ["ModelConfig", "get_config", "list_archs", "register",
           "smoke_variant", "shapes"]
