"""Model registry: family dispatch + step functions + abstract inits.

API surface used by the launcher / trainer / dry-run:

  init_params(cfg, key)        -> Param tree (real arrays)
  abstract_params(cfg)         -> Param tree (ShapeDtypeStructs)  [no alloc]
  forward(cfg, params, batch)  -> (logits, aux)     params/batch plain values
  loss_fn(cfg, params, batch)  -> (loss, metrics)
  train_step / make_train_step -> jit-able step with optimizer
  init_cache / abstract_cache  -> decode cache (Param tree)
  decode_step(cfg, p, cache, batch) -> (logits, new_cache)
  input_specs(cfg, shape)      -> ShapeDtypeStruct batch stand-ins
  count_params(cfg)            -> analytical N (for 6ND roofline)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import jamba, mamba_lm, transformer, xlstm


_FAMILIES = {
    "transformer": transformer,
    "mamba": mamba_lm,
    "jamba": jamba,
    "xlstm": xlstm,
}


def family(cfg):
    return _FAMILIES[cfg.family]


# ---------------------------------------------------------------------------
# Params / caches
# ---------------------------------------------------------------------------

def init_params(cfg, key):
    from repro.core import weight_quant
    p = family(cfg).init(cfg, key)
    if weight_quant.is_quantized(cfg.weight_dtype):
        p = weight_quant.quantize_tree(p)
    return p


def abstract_params(cfg):
    """Param tree of ShapeDtypeStructs — Param.axes survive eval_shape.
    Routed through the quantizing ``init_params`` so the abstract tree
    (and the shardings derived from it) matches the real one leaf for
    leaf under any cfg.weight_dtype."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.key(0))


def quantize_params(cfg, values):
    """Quantize a PLAIN-VALUE param tree per cfg.weight_dtype (no-op for
    "f32").  Serving entry: the Engine hands f32 weights in and this
    produces the int8+scale tree its jitted steps expect."""
    from repro.core import weight_quant
    if not weight_quant.is_quantized(cfg.weight_dtype):
        return values
    return weight_quant.quantize_tree(values)


def init_cache(cfg, batch, max_seq, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    return family(cfg).init_cache(cfg, batch, max_seq, dtype)


def abstract_cache(cfg, batch, max_seq):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, max_seq))


@functools.lru_cache(maxsize=None)
def cache_axes(cfg):
    """Pytree (matching init_cache structure) of per-leaf logical-axis
    tuples — the sharding counterpart of ``cache_slot_axes``.  The
    serving stack constrains its jit outputs with these so a pooled
    cache sharded over a mesh stays sharded across decode/fork/evict
    dispatches.  Structure depends only on cfg (state/kv dtypes add or
    drop scale leaves), never on batch or max_seq."""
    from repro.parallel import sharding
    return sharding.tree_axes(abstract_cache(cfg, 1, 8))


# ---------------------------------------------------------------------------
# Slot-indexable caches (continuous-batching serving engine)
#
# Every family exposes cache_slot_axes(cfg): a pytree congruent with
# init_cache whose leaves are the index of the batch ("slot") axis of the
# corresponding cache leaf.  The three operations below are the whole
# contract the serving engine needs: fixed-shape gather/scatter of
# per-sequence state by slot id, plus masking so inactive slots never
# mutate.  All are jit-safe with traced slot ids.
# ---------------------------------------------------------------------------

def cache_slot_axes(cfg):
    """Pytree (matching init_cache structure) of per-leaf slot-axis ints."""
    return family(cfg).cache_slot_axes(cfg)


def gather_slots(cfg, cache, slot_ids):
    """Extract a sub-cache for ``slot_ids`` (int array (m,)) from a pooled
    cache: each leaf is narrowed to m entries along its slot axis."""
    return jax.tree.map(
        lambda ax, leaf: jnp.take(leaf, slot_ids, axis=ax),
        cache_slot_axes(cfg), cache)


def scatter_slots(cfg, pool_cache, sub_cache, slot_ids):
    """Write a sub-cache (m slot entries) into ``pool_cache`` at
    ``slot_ids``; the pooled shapes are unchanged (pure functional .at)."""
    def put(ax, dst, src):
        idx = (slice(None),) * ax + (slot_ids,)
        return dst.at[idx].set(src.astype(dst.dtype))
    return jax.tree.map(put, cache_slot_axes(cfg), pool_cache, sub_cache)


def mask_slots(cfg, old_cache, new_cache, active):
    """Per-slot select: keep ``new_cache`` where ``active`` (bool (slots,))
    else ``old_cache`` — freezes state (incl. pos) of inactive slots so a
    pooled decode step cannot disturb free or finished slots."""
    def mix(ax, old, new):
        shape = [1] * old.ndim
        shape[ax] = -1
        return jnp.where(active.reshape(shape), new.astype(old.dtype), old)
    return jax.tree.map(mix, cache_slot_axes(cfg), old_cache, new_cache)


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------

def forward(cfg, params, batch):
    return family(cfg).forward(cfg, params, batch)


def decode_step(cfg, params, cache, batch):
    return family(cfg).decode_step(cfg, params, cache, batch)


def stacked_step(cfg, params, cache, batch):
    """Cross-layer megakernel decode: the whole layer stack in one (or,
    for heterogeneous stacks, per homogeneous run) Pallas launch, with
    per-layer weights/state carried on a stacked leading axis.  This is
    what ``decode_step`` dispatches to when cfg.step_impl resolves to
    "megakernel"; exposed for direct use by launch-count tests and
    benchmarks."""
    fam = family(cfg)
    if not hasattr(fam, "stacked_step"):
        raise NotImplementedError(
            f"family {cfg.family!r} has no megakernel decode path")
    return fam.stacked_step(cfg, params, cache, batch)


# ---------------------------------------------------------------------------
# Speculative decode support: K-step verify micro-scan, per-slot step
# selection (rollback), and self-speculative draft views.
# ---------------------------------------------------------------------------

def _freeze_steps(cfg, cache0, stacked, active):
    """Per-slot freeze over a verify cache stack (leading per-step axis):
    inactive slots read ``cache0`` at EVERY step — exactly what the
    chained scan's per-step mask_slots accumulates to, since a frozen
    slot never advances past its initial state."""
    def mix(ax, old, new):
        shape = [1] * new.ndim
        shape[ax + 1] = -1
        return jnp.where(active.reshape(shape), new.astype(old.dtype),
                         old[None])
    return jax.tree.map(mix, cache_slot_axes(cfg), cache0, stacked)


def verify_scan(cfg, params, cache, tokens, active=None):
    """Run K candidate tokens through the model — the spec-decode verify
    pass.  Families with a batched ``verify_window`` (mamba / jamba /
    xlstm) run the whole window through their block_verify front-ends:
    projections and convs batched over K tokens, only the recurrences
    sequential.  Token identity with the chained path holds because a
    (b, K, d) matmul computes each row exactly as the (b, 1, d) one
    (and the recurrence micro-scans chain the same per-token cells at
    the same shapes).  Families without one (transformer) chain
    ``decode_step`` per token.

    tokens (b, K) int32; ``active`` (b,) bool freezes inactive slots
    (as the engine's burst does).  Returns (logits (b, K, V), caches)
    where ``caches`` is the cache pytree with a leading per-step axis:
    caches[t] = cache after consuming tokens[:, t]."""
    window = getattr(family(cfg), "verify_window", None)
    if window is not None:
        logits, caches = window(cfg, params, cache, tokens)
        if active is not None:
            caches = _freeze_steps(cfg, cache, caches, active)
        return logits, caches

    def step(c, tok_t):
        logits, c2 = decode_step(cfg, params, c, {"tokens": tok_t})
        if active is not None:
            c2 = mask_slots(cfg, c, c2, active)
        return c2, (logits[:, -1, :], c2)

    xs = jnp.moveaxis(tokens[..., None], 1, 0)         # (K, b, 1)
    _, (logits, caches) = jax.lax.scan(step, cache, xs)
    return jnp.moveaxis(logits, 0, 1), caches


def select_step(cfg, stacked_cache, step_idx):
    """Per-slot rollback gather: from a ``verify_scan`` cache stack
    (leading per-step axis, length K) pick step ``step_idx[s]`` for
    slot ``s``.  Returns a normal cache pytree — the state each slot
    would have had had it decoded exactly its accepted prefix."""
    def pick(ax, leaf):
        m = jnp.moveaxis(leaf, ax + 1, 0)              # (slots, K, ...)
        sel = jax.vmap(lambda row, i: row[i])(m, step_idx)
        return jnp.moveaxis(sel, 0, ax)
    return jax.tree.map(pick, cache_slot_axes(cfg), stacked_cache)


def supports_draft(cfg) -> bool:
    return hasattr(family(cfg), "draft_params")


def draft_config(cfg, n_layers: int):
    """Model config of the first-``n_layers`` self-speculative draft
    (embed/norm/unembed shared with the target).  Families validate
    their own granularity (jamba: whole groups)."""
    import dataclasses
    if not supports_draft(cfg):
        raise NotImplementedError(
            f"family {cfg.family!r} has no self-speculative draft view")
    if cfg.family == "jamba":
        jamba._n_draft_groups(cfg, n_layers)           # validates
    elif not (0 < n_layers <= cfg.n_layers):
        raise ValueError(
            f"draft layers must be in (0, {cfg.n_layers}]; got {n_layers}")
    return dataclasses.replace(cfg, n_layers=n_layers)


def draft_params(cfg, params, n_layers: int):
    """First-``n_layers`` view of a plain-value param tree."""
    return family(cfg).draft_params(cfg, params, n_layers)


def draft_cache(cfg, cache, n_layers: int):
    return family(cfg).draft_cache(cfg, cache, n_layers)


def draft_cache_merge(cfg, full_cache, sub_cache, n_layers: int):
    return family(cfg).draft_cache_merge(cfg, full_cache, sub_cache,
                                         n_layers)


def prefill(cfg, params, cache, batch):
    """Full-seq forward that fills the decode cache (serving entry)."""
    return family(cfg).prefill(cfg, params, cache, batch)


def loss_fn(cfg, params, batch):
    """Causal LM loss (multi-codebook aware), fp32 softmax, z-reg metrics."""
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    if cfg.n_codebooks > 1:                    # (b, l, ncb, V) vs (b, l, ncb)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None],
                                 axis=-1)[..., 0]
        nll = (lse - ll).mean()
    else:
        if cfg.frontend == "vision_stub":      # image prefix carries no loss
            logits = logits[:, -labels.shape[1]:]
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None],
                                 axis=-1)[..., 0]
        nll = (lse - ll).mean()
    loss = nll
    metrics = {"nll": nll}
    for k, v in aux.items():
        loss = loss + v
        metrics[k] = v
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, shardable, no allocation)
# ---------------------------------------------------------------------------

def batch_struct(cfg, batch_size, seq_len, with_labels=True):
    """Concrete-shape dict for one step (tokens/embeds per frontend)."""
    tok = jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32)
    out = {}
    if cfg.frontend == "audio_stub":
        out["embeds"] = jax.ShapeDtypeStruct(
            (batch_size, seq_len, cfg.d_model), jnp.dtype(cfg.dtype))
        if with_labels:
            out["labels"] = jax.ShapeDtypeStruct(
                (batch_size, seq_len, cfg.n_codebooks), jnp.int32)
    elif cfg.frontend == "vision_stub":
        out["tokens"] = tok
        out["img_embeds"] = jax.ShapeDtypeStruct(
            (batch_size, cfg.img_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
        if with_labels:
            out["labels"] = jax.ShapeDtypeStruct(
                (batch_size, seq_len), jnp.int32)
    else:
        out["tokens"] = tok
        if with_labels:
            out["labels"] = jax.ShapeDtypeStruct(
                (batch_size, seq_len), jnp.int32)
    return out


def batch_axes(cfg, struct):
    """Logical axes tree matching batch_struct (for in_shardings)."""
    ax = {}
    for k, v in struct.items():
        if v.ndim == 2:
            ax[k] = ("act_batch", "act_seq")
        elif k in ("embeds", "img_embeds"):
            ax[k] = ("act_batch", "act_seq", "act_embed")
        else:
            ax[k] = ("act_batch", "act_seq", None)
    return ax


def decode_batch_struct(cfg, batch_size):
    out = {}
    if cfg.frontend == "audio_stub":
        out["embeds"] = jax.ShapeDtypeStruct(
            (batch_size, 1, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        out["tokens"] = jax.ShapeDtypeStruct((batch_size, 1), jnp.int32)
    return out


def make_batch(cfg, batch_size, seq_len, key=None, with_labels=True):
    """Concrete random batch with the struct above (smoke tests/examples)."""
    key = key if key is not None else jax.random.key(0)
    struct = batch_struct(cfg, batch_size, seq_len, with_labels)
    ks = jax.random.split(key, len(struct))
    out = {}
    for (name, s), k in zip(sorted(struct.items()), ks):
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab,
                                           dtype=s.dtype)
        else:
            out[name] = jax.random.normal(k, s.shape, s.dtype)
    return out


# ---------------------------------------------------------------------------
# Analytical parameter counts (roofline MODEL_FLOPS = 6 N D)
# ---------------------------------------------------------------------------

def count_params(cfg, active_only: bool = False) -> int:
    d, f, V, L = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    n = 0

    def attn():
        return d * hq * dh + 2 * d * hkv * dh + hq * dh * d

    def dense_mlp(ff):
        return 3 * d * ff if cfg.mlp == "swiglu" else 2 * d * ff

    def moe_mlp():
        E = cfg.top_k if active_only else cfg.n_experts
        m = E * 3 * d * f + d * cfg.n_experts  # router always full
        if cfg.n_shared_experts:
            m += 3 * d * (cfg.n_shared_experts * f)
        if cfg.dense_residual:
            m += dense_mlp(f)
        return m

    def mamba_blk():
        di, ns, r, k = cfg.d_inner, cfg.d_state, cfg.dt_rank, cfg.d_conv
        return (2 * d * di + k * di + di * (r + 2 * ns) + r * di
                + di * ns + di + di * d)

    def mlstm_blk():
        di = 2 * d
        dh2 = di // hq
        return 2 * d * di + cfg.d_conv * di + 2 * hq * dh2 * dh2 + di + d * di

    def slstm_blk():
        dh2 = d // hq
        return 4 * d * d + 4 * hq * dh2 * dh2 + d * d

    if cfg.family == "mamba":
        n += L * mamba_blk()
    elif cfg.family == "xlstm":
        for i in range(L):
            n += slstm_blk() if xlstm._is_slstm(cfg, i) else mlstm_blk()
    elif cfg.family == "jamba":
        for i in range(L):
            is_attn, is_moe = jamba._pos_kind(cfg, i)
            n += attn() if is_attn else mamba_blk()
            n += moe_mlp() if is_moe else dense_mlp(f)
    else:
        per = attn() + (moe_mlp() if cfg.is_moe else dense_mlp(f))
        n += L * per
    n += V * d                      # embed
    if not cfg.tie_embeddings:
        n += d * V * cfg.n_codebooks
    return int(n)
