"""Mamba block (Gu & Dao 2023) — the architecture MARCA accelerates.

Computational flow per block (paper Fig. 3): LN -> in_proj -> [x | z] ->
causal depthwise conv -> SiLU -> x_proj -> (dt, B, C) -> softplus(dt_proj) ->
selective scan (the element-wise chain MARCA fuses) -> gate by SiLU(z) ->
out_proj -> residual.

The MARCA knobs: cfg.scan_impl selects seq/assoc/chunked/pallas,
cfg.exp_impl/silu_impl select exact vs the paper's approximations, and
cfg.conv_impl selects the Pallas conv kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import approx, state_quant, weight_quant
from repro.kernels import ops
from repro.models import blocks
from repro.parallel.sharding import Param, constrain


def _a_and_scale(p):
    """The SSM A matrix as the step math consumes it: (A, a_scale).

    f32 weights (no "A_q" leaf) recompute A = -exp(A_log) and carry no
    scale; int8 weights (cfg.weight_dtype="int8") hand back the stored
    codes plus their per-d_inner-channel scales, leaving the dequant to
    the point of consumption — in-kernel for fused/megakernel steps."""
    if "A_q" in p:
        return p["A_q"], p["A_scale"]
    return -jnp.exp(p["A_log"]), None


def read_state_h(cfg, state):
    """Decode the stored recurrent state to the f32 the scan/step math
    uses.  f32/bf16 is a cast; int8/fp8 dequantizes with the state's
    group scales (state["h_scale"])."""
    if state_quant.is_quantized(cfg.state_dtype):
        return state_quant.dequantize_h(state["h"], state["h_scale"])
    return state["h"].astype(jnp.float32)


def write_state_h(cfg, h, prev_state=None):
    """Encode a f32 state for storage: the {"h": ...} (+"h_scale") leaves
    of the new state dict.  ``prev_state`` supplies the previous scales
    for the decayed-running-absmax update; None = cold start (prefill)."""
    if state_quant.is_quantized(cfg.state_dtype):
        prev = None if prev_state is None else prev_state["h_scale"]
        q, scale = state_quant.quantize_h(h, cfg.state_dtype,
                                          prev_scale=prev)
        return {"h": q, "h_scale": scale}
    return {"h": h.astype(state_quant.storage_dtype(cfg.state_dtype))}


def mamba_block_init(cfg, key):
    d, di, n, k, r = (cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv,
                      cfg.dt_rank)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A; dt bias init for softplus range
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :],
                      (di, 1))
    dt_init = jnp.exp(
        jax.random.uniform(ks[0], (di,)) * (jnp.log(0.1) - jnp.log(0.001))
        + jnp.log(0.001))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus
    return {
        "in_proj": blocks.dense_init(ks[1], d, 2 * di, ("embed", "ffn")),
        "conv_w": Param(
            jax.random.normal(ks[2], (k, di), jnp.float32) * (1.0 / k),
            ("conv", "ffn")),
        "conv_b": Param(jnp.zeros((di,), jnp.float32), ("ffn",)),
        "x_proj": blocks.dense_init(ks[3], di, r + 2 * n, ("ffn", None)),
        "dt_proj": blocks.dense_init(ks[4], r, di, (None, "ffn"),
                                     scale=r ** -0.5),
        "dt_bias": Param(dt_bias, ("ffn",)),
        "A_log": Param(jnp.log(a_init), ("ffn", "state")),
        "D": Param(jnp.ones((di,), jnp.float32), ("ffn",)),
        "out_proj": blocks.dense_init(ks[5], di, d, ("ffn", "embed")),
    }


def _project(cfg, p, x):
    """Shared pre-scan computation: returns x_conv_in, z."""
    cdt = x.dtype
    xz = blocks.dense(p["in_proj"], x, cdt)
    xz = constrain(xz, "act_batch", "act_seq", "act_ffn")
    return jnp.split(xz, 2, axis=-1)


def _ssm_inputs(cfg, p, x_a):
    """x_a (b, l, di) -> dt (b,l,di), B (b,l,n), C (b,l,n)."""
    n, r = cfg.d_state, cfg.dt_rank
    cdt = x_a.dtype
    dbc = blocks.dense(p["x_proj"], x_a, cdt)
    dt_low, B, C = jnp.split(dbc, [r, r + n], axis=-1)
    dt = blocks.dense(p["dt_proj"], dt_low, cdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"]).astype(cdt)
    return dt, B, C


def mamba_block_apply(cfg, p, x, state=None):
    """Full-sequence path.  state (decode continuation) is a dict with
    'h' (b, di, n) f32 and 'conv' (b, k-1, di); returns (y, new_state)."""
    silu = approx.get_silu(cfg.silu_impl)
    x_in, z = _project(cfg, p, x)
    conv_state = None if state is None else state["conv"]
    x_c, new_conv = ops.causal_conv1d(
        x_in, p["conv_w"], p["conv_b"], x_prev=conv_state,
        impl=cfg.conv_impl)
    x_a = silu(x_c)
    dt, B, C = _ssm_inputs(cfg, p, x_a)
    A, a_scale = _a_and_scale(p)
    if a_scale is not None:
        # prefill is compute-bound; dequant up front with the same
        # multiply the decode kernels run in their dequant phase
        A = weight_quant.dequantize_rows(A, a_scale)
    h0 = None if state is None else read_state_h(cfg, state)
    y, h_last = ops.selective_scan(
        x_a, dt, A, B, C, D=p["D"], z=z, h0=h0,
        impl=cfg.scan_impl, chunk=cfg.scan_chunk,
        exp_impl=cfg.exp_impl, silu_impl=cfg.silu_impl)
    y = constrain(y, "act_batch", "act_seq", "act_ffn")
    out = blocks.dense(p["out_proj"], y, x.dtype)
    new_state = write_state_h(cfg, h_last, prev_state=state)
    new_state["conv"] = new_conv
    return out, new_state


def mamba_block_step(cfg, p, x_t, state):
    """Single-token decode.  x_t (b, 1, d); state dict as above.

    The conv-state update (shift window, depthwise filter at the last tap)
    is the L=1 case of the streaming causal conv, so it shares the
    ops.causal_conv1d dispatch with prefill — decode uses the same
    cfg.conv_impl kernel.

    The SSM step itself routes through ops.selective_state_step: with
    cfg.step_impl resolving to "fused" the state update, output
    contraction, D-skip, and SiLU gate are one Pallas launch over the
    pooled batch instead of the per-op XLA chain."""
    from repro.core.selective_scan import resolve_cell_impl
    silu = approx.get_silu(cfg.silu_impl)
    x_in, z = _project(cfg, p, x_t)             # (b,1,di)
    x_c, new_conv = ops.causal_conv1d(
        x_in, p["conv_w"], p["conv_b"], x_prev=state["conv"],
        impl=cfg.conv_impl)
    x_a = silu(x_c)
    dt, B, C = _ssm_inputs(cfg, p, x_a)
    A, a_scale = _a_and_scale(p)
    impl = resolve_cell_impl(cfg.step_impl)
    if state_quant.is_quantized(cfg.state_dtype):
        # storage-dtype round-trip stays inside the step: dequant on
        # read, requant on write (in-kernel for the fused impl) — the
        # pooled h never crosses HBM at f32
        y, hq, scale = ops.selective_state_step_q(
            state["h"], state["h_scale"], x_a[:, 0], dt[:, 0], A,
            B[:, 0], C[:, 0], D=p["D"], z_t=z[:, 0],
            state_dtype=cfg.state_dtype, impl=impl,
            exp_impl=cfg.exp_impl, silu_impl=cfg.silu_impl,
            a_scale=a_scale)
        out = blocks.dense(p["out_proj"], y[:, None, :], x_t.dtype)
        return out, {"h": hq, "h_scale": scale, "conv": new_conv}
    y, h = ops.selective_state_step(
        read_state_h(cfg, state), x_a[:, 0], dt[:, 0], A, B[:, 0],
        C[:, 0], D=p["D"], z_t=z[:, 0], impl=impl,
        exp_impl=cfg.exp_impl, silu_impl=cfg.silu_impl, a_scale=a_scale)
    out = blocks.dense(p["out_proj"], y[:, None, :], x_t.dtype)
    return out, {**write_state_h(cfg, h), "conv": new_conv}


def mamba_block_megastep(cfg, p, x_t, state):
    """``mamba_block_step`` restated for INSIDE a megakernel body.

    Same signature and bitwise-identical values, but no nested
    pallas_call: the SSM step is the s6 cell skeleton applied inline
    (the per-layer kernel's ``_chain`` is the same cell at (N, BD)
    block shapes; element-wise phases + the exactly-associative N-sum
    make blocking/batching irrelevant to the produced bits), and the
    conv tail always uses the reference math (a Pallas kernel cannot
    nest another launch).  With cfg.conv_impl="xla" — the default —
    that is the identical computation; under conv_impl="pallas" the
    megakernel silently uses the ref conv instead (documented caveat).
    """
    from repro.kernels import decode_step as dsk
    from repro.kernels import ref as kref
    silu = approx.get_silu(cfg.silu_impl)
    x_in, z = _project(cfg, p, x_t)             # (b,1,di)
    x_c, new_conv = kref.causal_conv1d(
        x_in, p["conv_w"], p["conv_b"], x_prev=state["conv"])
    x_a = silu(x_c)
    dt, B, C = _ssm_inputs(cfg, p, x_a)
    A, a_scale = _a_and_scale(p)
    wq = a_scale is not None
    cell = dsk.s6_cell(cfg.exp_impl, cfg.silu_impl, True, True, wq)
    at = A.astype(jnp.float32).T                         # (n, di)
    ins = {
        "x": x_a[:, 0].astype(jnp.float32),
        "dt": dt[:, 0].astype(jnp.float32),
        "at": at,
        "b": B[:, 0].astype(jnp.float32),
        "c": C[:, 0].astype(jnp.float32),
        "d": p["D"].astype(jnp.float32),
        "z": z[:, 0].astype(jnp.float32),
    }
    if wq:
        # at holds int8 codes (transposed, cast f32); the cell's dequant
        # phase multiplies the per-channel scales back in — inside the
        # megakernel launch, on this layer's grid-local weight slice
        ins["at_scale"] = a_scale.astype(jnp.float32)
    h = read_state_h(cfg, state).swapaxes(1, 2)          # (b, n, di)
    y, h_new = cell(h, ins)
    y = y.astype(x_a.dtype)
    h_new = h_new.swapaxes(1, 2)                         # (b, di, n)
    out = blocks.dense(p["out_proj"], y[:, None, :], x_t.dtype)
    new_state = write_state_h(cfg, h_new, prev_state=state)
    new_state["conv"] = new_conv
    return out, new_state


def _conv_tail_states(conv_state, x_in):
    """Per-step conv tails over a K-token window.

    conv_state (b, k-1, di) entering tail; x_in (b, K, di) the window's
    raw conv inputs.  Returns (b, K, k-1, di): entry t is exactly the
    ``new_state`` ops.causal_conv1d would return after consuming tokens
    0..t — so rolling back to step t restores the same conv tail a
    per-token decode would have."""
    k1 = conv_state.shape[1]
    K = x_in.shape[1]
    full = jnp.concatenate([conv_state, x_in.astype(conv_state.dtype)],
                           axis=1)
    idx = jnp.arange(K)[:, None] + jnp.arange(k1)[None, :] + 1
    return full[:, idx]


def mamba_block_verify(cfg, p, x, state):
    """K-token verify pass (speculative decode): semantically K chained
    ``mamba_block_step`` calls, but the block front-end (projections,
    conv, dt/B/C) runs over the whole K-token window at once and only
    the SSM recurrence is sequential — a K-step micro-scan
    (core.selective_scan.decode_scan) that reuses the fused decode-step
    kernel per step and returns every intermediate state.

    x (b, K, d_model); state as in mamba_block_step.  Returns
    (out (b, K, d_model), states) where ``states`` leaves are stacked
    per step on axis 1: states[t] is the block state after consuming
    token t (spec-decode rollback selects one index).
    """
    from repro.core.selective_scan import (decode_scan, decode_scan_q,
                                           resolve_cell_impl)
    silu = approx.get_silu(cfg.silu_impl)
    x_in, z = _project(cfg, p, x)                # (b,K,di)
    x_c, _ = ops.causal_conv1d(
        x_in, p["conv_w"], p["conv_b"], x_prev=state["conv"],
        impl=cfg.conv_impl)
    conv_all = _conv_tail_states(state["conv"], x_in)
    x_a = silu(x_c)
    dt, B, C = _ssm_inputs(cfg, p, x_a)
    A, a_scale = _a_and_scale(p)
    impl = resolve_cell_impl(cfg.step_impl)
    if state_quant.is_quantized(cfg.state_dtype):
        y, hq_all, scale_all = decode_scan_q(
            state["h"], state["h_scale"], x_a, dt, A, B, C,
            D=p["D"], z_seq=z, state_dtype=cfg.state_dtype, impl=impl,
            exp_impl=cfg.exp_impl, silu_impl=cfg.silu_impl,
            a_scale=a_scale)
        out = blocks.dense(p["out_proj"], y, x.dtype)
        return out, {"h": hq_all, "h_scale": scale_all, "conv": conv_all}
    y, h_all = decode_scan(
        read_state_h(cfg, state), x_a, dt, A, B, C, D=p["D"], z_seq=z,
        impl=impl, exp_impl=cfg.exp_impl, silu_impl=cfg.silu_impl,
        a_scale=a_scale)
    out = blocks.dense(p["out_proj"], y, x.dtype)
    storage = state_quant.storage_dtype(cfg.state_dtype)
    return out, {"h": h_all.astype(storage), "conv": conv_all}


def mamba_state_init(cfg, batch, dtype):
    di, n, k = cfg.d_inner, cfg.d_state, cfg.d_conv
    out = {
        "h": Param(jnp.zeros((batch, di, n),
                             state_quant.storage_dtype(cfg.state_dtype)),
                   ("act_batch", "act_ffn", None)),
        "conv": Param(jnp.zeros((batch, k - 1, di), dtype),
                      ("act_batch", None, "act_ffn")),
    }
    if state_quant.is_quantized(cfg.state_dtype):
        # zero scales decode the zero init state exactly; the first
        # write (prefill quantize or step requant) sets real scales
        out["h_scale"] = Param(
            jnp.zeros((batch, state_quant.n_groups(di)), jnp.float32),
            ("act_batch", None))
    return out
