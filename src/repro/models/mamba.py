"""Mamba block (Gu & Dao 2023) — the architecture MARCA accelerates.

Computational flow per block (paper Fig. 3): LN -> in_proj -> [x | z] ->
causal depthwise conv -> SiLU -> x_proj -> (dt, B, C) -> softplus(dt_proj) ->
selective scan (the element-wise chain MARCA fuses) -> gate by SiLU(z) ->
out_proj -> residual.

The MARCA knobs: cfg.scan_impl selects seq/assoc/chunked/pallas,
cfg.exp_impl/silu_impl select exact vs the paper's approximations, and
cfg.conv_impl selects the Pallas conv kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import approx
from repro.kernels import ops
from repro.models import blocks
from repro.parallel.sharding import Param, constrain


def mamba_block_init(cfg, key):
    d, di, n, k, r = (cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv,
                      cfg.dt_rank)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A; dt bias init for softplus range
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :],
                      (di, 1))
    dt_init = jnp.exp(
        jax.random.uniform(ks[0], (di,)) * (jnp.log(0.1) - jnp.log(0.001))
        + jnp.log(0.001))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus
    return {
        "in_proj": blocks.dense_init(ks[1], d, 2 * di, ("embed", "ffn")),
        "conv_w": Param(
            jax.random.normal(ks[2], (k, di), jnp.float32) * (1.0 / k),
            ("conv", "ffn")),
        "conv_b": Param(jnp.zeros((di,), jnp.float32), ("ffn",)),
        "x_proj": blocks.dense_init(ks[3], di, r + 2 * n, ("ffn", None)),
        "dt_proj": blocks.dense_init(ks[4], r, di, (None, "ffn"),
                                     scale=r ** -0.5),
        "dt_bias": Param(dt_bias, ("ffn",)),
        "A_log": Param(jnp.log(a_init), ("ffn", "state")),
        "D": Param(jnp.ones((di,), jnp.float32), ("ffn",)),
        "out_proj": blocks.dense_init(ks[5], di, d, ("ffn", "embed")),
    }


def _project(cfg, p, x):
    """Shared pre-scan computation: returns x_conv_in, z."""
    cdt = x.dtype
    xz = blocks.dense(p["in_proj"], x, cdt)
    xz = constrain(xz, "act_batch", "act_seq", "act_ffn")
    return jnp.split(xz, 2, axis=-1)


def _ssm_inputs(cfg, p, x_a):
    """x_a (b, l, di) -> dt (b,l,di), B (b,l,n), C (b,l,n)."""
    n, r = cfg.d_state, cfg.dt_rank
    cdt = x_a.dtype
    dbc = blocks.dense(p["x_proj"], x_a, cdt)
    dt_low, B, C = jnp.split(dbc, [r, r + n], axis=-1)
    dt = blocks.dense(p["dt_proj"], dt_low, cdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"]).astype(cdt)
    return dt, B, C


def mamba_block_apply(cfg, p, x, state=None):
    """Full-sequence path.  state (decode continuation) is a dict with
    'h' (b, di, n) f32 and 'conv' (b, k-1, di); returns (y, new_state)."""
    silu = approx.get_silu(cfg.silu_impl)
    x_in, z = _project(cfg, p, x)
    conv_state = None if state is None else state["conv"]
    x_c, new_conv = ops.causal_conv1d(
        x_in, p["conv_w"], p["conv_b"], x_prev=conv_state,
        impl=cfg.conv_impl)
    x_a = silu(x_c)
    dt, B, C = _ssm_inputs(cfg, p, x_a)
    A = -jnp.exp(p["A_log"])
    h0 = None if state is None else state["h"]
    y, h_last = ops.selective_scan(
        x_a, dt, A, B, C, D=p["D"], z=z, h0=h0,
        impl=cfg.scan_impl, chunk=cfg.scan_chunk,
        exp_impl=cfg.exp_impl, silu_impl=cfg.silu_impl)
    y = constrain(y, "act_batch", "act_seq", "act_ffn")
    out = blocks.dense(p["out_proj"], y, x.dtype)
    return out, {"h": h_last, "conv": new_conv}


def mamba_block_step(cfg, p, x_t, state):
    """Single-token decode.  x_t (b, 1, d); state dict as above.

    The conv-state update (shift window, depthwise filter at the last tap)
    is the L=1 case of the streaming causal conv, so it shares the
    ops.causal_conv1d dispatch with prefill — decode uses the same
    cfg.conv_impl kernel.

    The SSM step itself routes through ops.selective_state_step: with
    cfg.step_impl resolving to "fused" the state update, output
    contraction, D-skip, and SiLU gate are one Pallas launch over the
    pooled batch instead of the per-op XLA chain."""
    from repro.core.selective_scan import resolve_step_impl
    silu = approx.get_silu(cfg.silu_impl)
    x_in, z = _project(cfg, p, x_t)             # (b,1,di)
    x_c, new_conv = ops.causal_conv1d(
        x_in, p["conv_w"], p["conv_b"], x_prev=state["conv"],
        impl=cfg.conv_impl)
    x_a = silu(x_c)
    dt, B, C = _ssm_inputs(cfg, p, x_a)
    A = -jnp.exp(p["A_log"])
    y, h = ops.selective_state_step(
        state["h"], x_a[:, 0], dt[:, 0], A, B[:, 0], C[:, 0],
        D=p["D"], z_t=z[:, 0], impl=resolve_step_impl(cfg.step_impl),
        exp_impl=cfg.exp_impl, silu_impl=cfg.silu_impl)
    out = blocks.dense(p["out_proj"], y[:, None, :], x_t.dtype)
    return out, {"h": h, "conv": new_conv}


def mamba_state_init(cfg, batch, dtype):
    di, n, k = cfg.d_inner, cfg.d_state, cfg.d_conv
    return {
        "h": Param(jnp.zeros((batch, di, n), jnp.float32),
                   ("act_batch", "act_ffn", None)),
        "conv": Param(jnp.zeros((batch, k - 1, di), dtype),
                      ("act_batch", None, "act_ffn")),
    }
