"""Decoder-only transformer LM covering the dense and MoE assigned archs:

  granite-20b (MQA), olmo-1b (non-parametric LN), qwen2-7b / qwen2.5-14b
  (GQA + QKV bias), musicgen-large (audio_stub frontend, 4 codebook heads),
  phi-3-vision (vision_stub prefix embeddings), qwen2-moe-a2.7b (shared +
  routed experts), arctic-480b (MoE + dense residual).

Layer stack is lax.scan over stacked params (small HLO, FSDP-friendly
per-layer weight gathers) with optional remat.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import blocks, moe
from repro.parallel.sharding import Param, constrain


def _layer_init(cfg, key):
    ks = jax.random.split(key, 4)
    p = {
        "norm1": blocks.norm_init(cfg, ks[0]),
        "attn": blocks.attention_init(cfg, ks[1]),
        "norm2": blocks.norm_init(cfg, ks[2]),
    }
    if cfg.is_moe:
        p["moe"] = moe.moe_init(cfg, ks[3])
        if cfg.dense_residual:
            p["mlp"] = blocks.mlp_init(cfg, jax.random.fold_in(ks[3], 1))
    else:
        p["mlp"] = blocks.mlp_init(cfg, ks[3])
    return p


def _layer_apply(cfg, p, x, positions, cache=None, pos=None,
                 return_kv=False):
    h, new_cache = blocks.attention_apply(
        cfg, p["attn"], blocks.apply_norm(cfg, p["norm1"], x),
        positions, cache=cache, pos=pos, return_kv=return_kv)
    x = x + h
    hn = blocks.apply_norm(cfg, p["norm2"], x)
    aux = {"moe_lb": jnp.float32(0), "moe_z": jnp.float32(0)}
    if cfg.is_moe:
        hm, aux = moe.moe_apply(cfg, p["moe"], hn)
        if cfg.dense_residual:
            hm = hm + blocks.mlp_apply(cfg, p["mlp"], hn)
    else:
        hm = blocks.mlp_apply(cfg, p["mlp"], hn)
    x = x + hm
    x = constrain(x, "act_batch", "act_seq", "act_embed")
    return x, new_cache, aux


def init(cfg, key):
    ks = jax.random.split(key, 4 + cfg.n_layers)
    p = {"embed": blocks.embed_init(cfg, ks[0]),
         "norm_f": blocks.norm_init(cfg, ks[1])}
    if cfg.n_codebooks > 1:
        p["heads"] = {
            f"cb{i}": blocks.dense_init(
                jax.random.fold_in(ks[2], i), cfg.d_model, cfg.vocab,
                ("embed", "vocab"))
            for i in range(cfg.n_codebooks)}
    else:
        p["unembed"] = blocks.unembed_init(cfg, ks[2])
    if cfg.scan_layers:
        layer_keys = jax.random.split(ks[3], cfg.n_layers)
        p["layers"] = jax.vmap(lambda k: _layer_init(cfg, k))(layer_keys)
        p["layers"] = jax.tree.map(
            lambda q: Param(q.value, ("layers",) + q.axes), p["layers"],
            is_leaf=lambda q: isinstance(q, Param))
    else:
        p["layers"] = [_layer_init(cfg, ks[4 + i])
                       for i in range(cfg.n_layers)]
    return p


def _inputs_to_h(cfg, p, batch, dtype):
    """Resolve the (stub) frontend to the first hidden state + positions."""
    if cfg.frontend == "audio_stub":
        # precomputed EnCodec frame embeddings from input_specs()
        h = batch["embeds"].astype(dtype)
        b, l = h.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(l)[None], (b, l))
    elif cfg.frontend == "vision_stub":
        # CLIP patch embeddings prepended to token embeddings
        img = batch["img_embeds"].astype(dtype)        # (b, n_img, d)
        tok = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
        h = jnp.concatenate([img, tok], axis=1)
        b, l = h.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(l)[None], (b, l))
    else:
        h = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
        b, l = h.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(l)[None], (b, l))
    return constrain(h, "act_batch", "act_seq", "act_embed"), positions


def forward(cfg, p, batch):
    """Full-sequence forward -> (logits, aux).  batch per frontend."""
    dtype = jnp.dtype(cfg.dtype)
    h, positions = _inputs_to_h(cfg, p, batch, dtype)

    if cfg.scan_layers:
        stacked = p["layers"]

        def body(x, lp):
            y, _, aux = _layer_apply(cfg, lp, x, positions)
            return y, aux

        if cfg.remat:
            body = jax.checkpoint(body)
        h, auxs = jax.lax.scan(body, h, stacked)
        aux = jax.tree.map(jnp.sum, auxs)
    else:
        aux = {"moe_lb": jnp.float32(0), "moe_z": jnp.float32(0)}
        for lp in p["layers"]:
            h, _, a = _layer_apply(cfg, lp, h, positions)
            aux = jax.tree.map(jnp.add, aux, a)

    h = blocks.apply_norm(cfg, p["norm_f"], h)
    if cfg.n_codebooks > 1:
        logits = jnp.stack(
            [blocks.dense(p["heads"][f"cb{i}"], h.astype(jnp.float32))
             for i in range(cfg.n_codebooks)], axis=2)  # (b, l, ncb, V)
    else:
        logits = blocks.unembed_apply(cfg, p.get("unembed", {}),
                                      p["embed"], h)
    return logits, aux


def init_cache(cfg, batch, max_seq, dtype):
    """Per-layer KV caches, stacked on a leading 'layers' dim (flat kv).
    kv_cache_dtype == "int8": int8 payload + per-(layer,b,pos) f32 absmax
    scales (~2x less decode-cache HBM vs bf16; see EXPERIMENTS.md)."""
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    shape = (cfg.n_layers, batch, max_seq, hkv * dh)
    axes = ("layers", "act_batch", "act_seq", "act_ffn")
    out = {"pos": Param(jnp.zeros((batch,), jnp.int32), ("act_batch",))}
    if cfg.kv_cache_dtype == "int8":
        sshape = (cfg.n_layers, batch, max_seq, 1)
        saxes = ("layers", "act_batch", "act_seq", None)
        out.update({
            "k": Param(jnp.zeros(shape, jnp.int8), axes),
            "v": Param(jnp.zeros(shape, jnp.int8), axes),
            "k_scale": Param(jnp.zeros(sshape, jnp.float32), saxes),
            "v_scale": Param(jnp.zeros(sshape, jnp.float32), saxes)})
    else:
        out.update({"k": Param(jnp.zeros(shape, dtype), axes),
                    "v": Param(jnp.zeros(shape, dtype), axes)})
    return out


def cache_slot_axes(cfg):
    """Batch/slot axis index per cache leaf (layout matches init_cache)."""
    ax = {"k": 1, "v": 1, "pos": 0}
    if cfg.kv_cache_dtype == "int8":
        ax.update({"k_scale": 1, "v_scale": 1})
    return ax


def decode_step(cfg, p, cache, batch):
    """One-token decode.  batch['tokens'] (b, 1) (or embeds for stubs);
    cache from init_cache.  Returns (logits (b,1,V...), new_cache)."""
    dtype = jnp.dtype(cfg.dtype)
    pos = cache["pos"]                                   # (b,)
    if cfg.frontend == "audio_stub":
        h = batch["embeds"].astype(dtype)
    else:
        h = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
    positions = pos[:, None]
    h = constrain(h, "act_batch", None, "act_embed")

    kv_keys = [k2 for k2 in ("k", "v", "k_scale", "v_scale")
               if k2 in cache]
    if cfg.scan_layers:
        stacked = p["layers"]

        def body(x, lp_kv):
            lp = lp_kv[0]
            layer_cache = dict(zip(kv_keys, lp_kv[1:]))
            y, nc, _ = _layer_apply(cfg, lp, x, positions,
                                    cache=layer_cache, pos=pos)
            return y, tuple(nc[k2] for k2 in kv_keys)

        h, outs = jax.lax.scan(
            body, h, (stacked,) + tuple(cache[k2] for k2 in kv_keys))
        new_cache = dict(zip(kv_keys, outs))
        new_cache["pos"] = pos + 1
    else:
        accum = {k2: [] for k2 in kv_keys}
        for i, lp in enumerate(p["layers"]):
            h, nc, _ = _layer_apply(
                cfg, lp, h, positions,
                cache={k2: cache[k2][i] for k2 in kv_keys}, pos=pos)
            for k2 in kv_keys:
                accum[k2].append(nc[k2])
        new_cache = {k2: jnp.stack(v) for k2, v in accum.items()}
        new_cache["pos"] = pos + 1

    h = blocks.apply_norm(cfg, p["norm_f"], h)
    if cfg.n_codebooks > 1:
        logits = jnp.stack(
            [blocks.dense(p["heads"][f"cb{i}"], h.astype(jnp.float32))
             for i in range(cfg.n_codebooks)], axis=2)
    else:
        logits = blocks.unembed_apply(cfg, p.get("unembed", {}),
                                      p["embed"], h)
    return logits, new_cache


def prefill(cfg, p, cache, batch):
    """Full-sequence forward that fills the decode cache (pos = seq_len).
    cache: zero-initialized init_cache values with max_seq capacity."""
    dtype = jnp.dtype(cfg.dtype)
    h, positions = _inputs_to_h(cfg, p, batch, dtype)
    b, l = h.shape[:2]
    S = cache["k"].shape[2]

    def body(x, lp):
        y, kv, _ = _layer_apply(cfg, lp, x, positions, return_kv=True)
        return y, (kv["k"], kv["v"])

    if cfg.scan_layers:
        h, (ks_, vs_) = jax.lax.scan(body, h, p["layers"])
    else:
        kl, vl = [], []
        for lp in p["layers"]:
            h, kv, _ = _layer_apply(cfg, lp, h, positions, return_kv=True)
            kl.append(kv["k"]); vl.append(kv["v"])
        ks_, vs_ = jnp.stack(kl), jnp.stack(vl)

    pad = S - l
    extra = {}
    if cfg.kv_cache_dtype == "int8":
        kq, ksc = blocks._kv_quant(ks_)
        vq, vsc = blocks._kv_quant(vs_)
        ks_ = jnp.pad(kq, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vs_ = jnp.pad(vq, ((0, 0), (0, 0), (0, pad), (0, 0)))
        extra = {"k_scale": jnp.pad(ksc, ((0, 0), (0, 0), (0, pad),
                                          (0, 0))),
                 "v_scale": jnp.pad(vsc, ((0, 0), (0, 0), (0, pad),
                                          (0, 0)))}
    else:
        ks_ = jnp.pad(ks_, ((0, 0), (0, 0), (0, pad), (0, 0))).astype(
            cache["k"].dtype)
        vs_ = jnp.pad(vs_, ((0, 0), (0, 0), (0, pad), (0, 0))).astype(
            cache["v"].dtype)
    h = blocks.apply_norm(cfg, p["norm_f"], h)
    if cfg.n_codebooks > 1:
        logits = jnp.stack(
            [blocks.dense(p["heads"][f"cb{i}"], h.astype(jnp.float32))
             for i in range(cfg.n_codebooks)], axis=2)
    else:
        logits = blocks.unembed_apply(cfg, p.get("unembed", {}),
                                      p["embed"], h)
    new_cache = {"k": ks_, "v": vs_,
                 "pos": jnp.full((b,), l, jnp.int32), **extra}
    return logits, new_cache
